"""Mamba2 mixer via SSD (state-space duality, arXiv:2405.21060).

Chunked algorithm for train/prefill (intra-chunk quadratic + inter-chunk state
recurrence), O(1)-state decode step. Heads are sharded over the `model` axis;
B/C groups (n_groups=1) are replicated (small: 2·n_groups·state per token).

Layout: x (B, S, H, P) with H = expand·d_model / head_dim, P = head_dim.
Separate projections (wz/wx/wbc/wdt) instead of one fused in_proj so each gets
the TP-correct sharding (see DESIGN.md §Dist).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig


def dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    ssm = cfg.ssm
    d_inner = ssm.expand * cfg.d_model
    n_heads = d_inner // ssm.head_dim
    return d_inner, n_heads, ssm.head_dim, ssm.n_groups


def conv1d_causal(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv: out[b,s,c] = b_c + Σ_i x[b, s-w+1+i, c]·w[c,i].
    x (B, S, C), w (C, width), b (C,)."""
    width = w.shape[1]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(width):
        out = out + xp[:, i:i + x.shape[1], :].astype(jnp.float32) * w[:, i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _segsum(dta: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j < m <= i} dta[..., m],
    -inf for j > i. dta (..., Q)."""
    Q = dta.shape[-1]
    cs = jnp.cumsum(dta, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]       # sum_{j<m<=i}
    i = jnp.arange(Q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, D, *, chunk: int):
    """SSD forward.
    x (b, S, H, P); dt (b, S, H) [post-softplus]; A (H,) negative;
    B, C (b, S, G, N); D (H,). Returns y (b, S, H, P) and final state
    (b, H, P, N)."""
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    rep = H // G

    xc = x.reshape(b, nc, chunk, H, P).astype(jnp.float32)
    dtc = dt.reshape(b, nc, chunk, H).astype(jnp.float32)
    Bc = B.reshape(b, nc, chunk, G, N).astype(jnp.float32)
    Cc = C.reshape(b, nc, chunk, G, N).astype(jnp.float32)

    dta = dtc * A                                     # (b,nc,Q,H)
    dtx = xc * dtc[..., None]                         # dt-weighted inputs

    # --- intra-chunk (quadratic within chunk) ---
    Lmat = jnp.exp(_segsum(jnp.moveaxis(dta, 3, 2)))  # (b,nc,H,Q,Q)
    scores = jnp.einsum("bcqgn,bckgn->bcgqk", Cc, Bc)
    if G != H:  # head h uses group h // rep
        scores = jnp.repeat(scores, rep, axis=2)
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", scores * Lmat, dtx)

    # --- chunk states ---
    cum = jnp.cumsum(dta, axis=2)                     # (b,nc,Q,H)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)   # (b,nc,Q,H)
    Bh = jnp.repeat(Bc, rep, axis=3) if G != H else Bc  # (b,nc,Q,H,N)
    states = jnp.einsum("bcqhn,bcqhp->bchpn", Bh * decay_to_end[..., None], dtx)

    # --- inter-chunk recurrence ---
    chunk_decay = jnp.exp(jnp.sum(dta, axis=2))       # (b,nc,H)

    def step(carry, inp):
        s_prev = carry
        s_new, dec = inp
        s = s_prev * dec[:, :, None, None] + s_new
        return s, s_prev

    init = jnp.zeros((b, H, P, N), jnp.float32)
    final, prev_states = jax.lax.scan(
        step, init, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)     # (b,nc,H,P,N)

    # --- inter-chunk output ---
    in_decay = jnp.exp(cum)                           # (b,nc,Q,H)
    Ch = jnp.repeat(Cc, rep, axis=3) if G != H else Cc
    y_off = jnp.einsum("bcqhn,bchpn->bcqhp", Ch * in_decay[..., None], prev_states)

    y = (y_diag + y_off).reshape(b, S, H, P)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), final


def ssd_recurrent_oracle(x, dt, A, B, C, D):
    """Naive per-token recurrence (test oracle). Same signature/semantics."""
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    xf = x.astype(jnp.float32)

    def step(state, t):
        xt, dtt, Bt, Ct = t
        decay = jnp.exp(dtt * A)                      # (b,H)
        state = (state * decay[:, :, None, None]
                 + jnp.einsum("bhn,bhp,bh->bhpn", Bt, xt, dtt))
        y = jnp.einsum("bhn,bhpn->bhp", Ct, state)
        return state, y

    init = jnp.zeros((b, H, P, N), jnp.float32)
    final, ys = jax.lax.scan(
        step, init,
        (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
         jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1) + xf * D[None, None, :, None]
    return y.astype(x.dtype), final


def init_mamba_params(rng, cfg: ModelConfig, stack: int, dtype):
    from repro.models.common import dense_init
    d = cfg.d_model
    ssm = cfg.ssm
    d_inner, H, P, G = dims(cfg)
    conv_ch = d_inner + 2 * G * ssm.state
    ks = jax.random.split(rng, 8)
    L = (stack,) if stack else ()
    p = {
        "norm": jnp.ones(L + (d,), dtype),
        "wz": dense_init(ks[0], L + (d, d_inner), dtype),
        "wx": dense_init(ks[1], L + (d, d_inner), dtype),
        "wbc": dense_init(ks[2], L + (d, 2 * G * ssm.state), dtype),
        "wdt": dense_init(ks[3], L + (d, H), dtype),
        "conv_w": dense_init(ks[4], L + (conv_ch, ssm.d_conv), dtype, 0.2),
        "conv_b": jnp.zeros(L + (conv_ch,), dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[5], L + (H,), jnp.float32)
                    * (jnp.log(ssm.dt_max) - jnp.log(ssm.dt_min))
                    + jnp.log(ssm.dt_min)))).astype(jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32))
                 * jnp.ones(L + (H,), jnp.float32),
        "Dp": jnp.ones(L + (H,), jnp.float32),
        "gnorm": jnp.ones(L + (d_inner,), dtype),
        "wo_ssm": dense_init(ks[6], L + (d_inner, d), dtype),
    }
    return p


def mamba_block(p: Dict, x: jax.Array, cfg: ModelConfig,
                linear_fn=None) -> jax.Array:
    """One pre-norm mamba2 block (train/prefill). x (B, S, d).
    linear_fn(p, name, x) lets the PEFT layer intercept projections."""
    from repro.models.common import rms_norm
    ssm = cfg.ssm
    d_inner, H, P, G = dims(cfg)
    if linear_fn is None:
        linear_fn = lambda pp, name, xx: xx @ pp[name]
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    z = linear_fn(p, "wz", h)
    xin = linear_fn(p, "wx", h)
    bc = h @ p["wbc"]
    dt_raw = h @ p["wdt"]
    conv_in = jnp.concatenate([xin, bc], axis=-1)
    conv_out = conv1d_causal(conv_in, p["conv_w"], p["conv_b"])
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    xin = conv_out[..., :d_inner]
    bc = conv_out[..., d_inner:]
    B, S, _ = x.shape
    Bmat = bc[..., :G * ssm.state].reshape(B, S, G, ssm.state)
    Cmat = bc[..., G * ssm.state:].reshape(B, S, G, ssm.state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = ssd_chunked(xin.reshape(B, S, H, P), dt, A, Bmat, Cmat, p["Dp"],
                       chunk=min(ssm.chunk, S))
    y = y.reshape(B, S, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["gnorm"], cfg.norm_eps)
    return x + linear_fn(p, "wo_ssm", y)


def init_mamba_cache(cfg: ModelConfig, stack: int, batch: int, dtype):
    ssm = cfg.ssm
    d_inner, H, P, G = dims(cfg)
    conv_ch = d_inner + 2 * G * ssm.state
    return {
        "conv": jnp.zeros((stack, batch, ssm.d_conv - 1, conv_ch), dtype),
        "ssm": jnp.zeros((stack, batch, H, P, ssm.state), jnp.float32),
    }


def mamba_decode_step(p: Dict, cache: Dict, x: jax.Array, cfg: ModelConfig,
                      linear_fn=None):
    """Single-token step. x (B, 1, d); cache {conv (B,w-1,C), ssm (B,H,P,N)}
    (per-layer slices). Returns (y (B,1,d), new_cache)."""
    from repro.models.common import rms_norm
    ssm = cfg.ssm
    d_inner, H, P, G = dims(cfg)
    if linear_fn is None:
        linear_fn = lambda pp, name, xx: xx @ pp[name]
    B = x.shape[0]
    h = rms_norm(x, p["norm"], cfg.norm_eps)[:, 0]            # (B, d)
    z = linear_fn(p, "wz", h)
    xin = linear_fn(p, "wx", h)
    bc = h @ p["wbc"]
    dt_raw = h @ p["wdt"]
    conv_in = jnp.concatenate([xin, bc], axis=-1)              # (B, C)
    hist = jnp.concatenate([cache["conv"], conv_in[:, None, :]], axis=1)
    conv_out = jnp.einsum("bwc,cw->bc", hist.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    conv_out = jax.nn.silu(conv_out).astype(x.dtype)
    # keep the cache dtype: concat promotes when compute dtype differs, and
    # scan carries (registry prefill) require a dtype-invariant cache
    new_conv = hist[:, 1:, :].astype(cache["conv"].dtype)
    xin = conv_out[..., :d_inner].reshape(B, H, P)
    bc = conv_out[..., d_inner:]
    Bmat = bc[..., :G * ssm.state].reshape(B, G, ssm.state)
    Cmat = bc[..., G * ssm.state:].reshape(B, G, ssm.state)
    rep = H // G
    Bh = jnp.repeat(Bmat, rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cmat, rep, axis=1).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A)                                    # (B,H)
    state = (cache["ssm"] * decay[:, :, None, None]
             + jnp.einsum("bhn,bhp,bh->bhpn", Bh, xin.astype(jnp.float32), dt))
    y = jnp.einsum("bhn,bhpn->bhp", Ch, state)
    y = y + xin.astype(jnp.float32) * p["Dp"][None, :, None]
    y = y.reshape(B, d_inner)
    y = rms_norm(y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["gnorm"], cfg.norm_eps)
    out = x + linear_fn(p, "wo_ssm", y)[:, None, :]
    return out, {"conv": new_conv, "ssm": state}
