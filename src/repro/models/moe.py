"""Token-choice top-k MoE with per-sequence static capacity (2-D parallel:
experts over `model`, sequences over `data`).

Slot assignment is PER SEQUENCE: dispatch buffers are (B, E, C_seq, d), so the
scatter carries the batch dim in both source and target — GSPMD partitions it
along `data` without any global redistribution, and the expert einsum runs
2-D-parallel. (A single global capacity pool needs a global cumsum over
tokens and an all-layout scatter; measured on olmoe train_4k: either 16x
redundant expert FLOPs — capacity dim unsharded — or 200s+ of collectives.)
The combine side needs no scatter at all: every (token, k) contribution is
gathered back and reduced over k.

Auxiliary load-balance loss (Switch): E * Σ_e f_e · P_e over all tokens.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig


def capacity(tokens_per_group: int, cfg: MoEConfig) -> int:
    c = int(math.ceil(cfg.capacity_factor * tokens_per_group * cfg.top_k
                      / cfg.num_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8 for tiling friendliness


def route(router_logits: jax.Array, cfg: MoEConfig):
    """router_logits (..., E) -> gates (..., k), ids (..., k), aux scalar."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    gates, ids = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    E = probs.shape[-1]
    flat_ids = ids.reshape(-1)
    f = jnp.zeros((E,), jnp.float32).at[flat_ids].add(1.0) / flat_ids.size
    p = probs.reshape(-1, E).mean(axis=0)
    aux = E * jnp.sum(f * p)
    return gates, ids, aux


def assign_slots(ids: jax.Array, num_experts: int, cap: int):
    """Greedy position-in-expert assignment honoring top-k priority order.
    ids (T, k) -> slots (T, k) int32, keep (T, k) bool."""
    T, k = ids.shape
    slots = []
    counts = jnp.zeros((num_experts,), jnp.int32)
    for j in range(k):  # k is small and static; unrolled
        oh = jax.nn.one_hot(ids[:, j], num_experts, dtype=jnp.int32)
        pos = jnp.cumsum(oh, axis=0) * oh                    # 1-based within oh
        slot = (pos.sum(-1) - 1) + counts[ids[:, j]]
        counts = counts + oh.sum(axis=0)
        slots.append(slot)
    slots = jnp.stack(slots, axis=1)
    keep = slots < cap
    return slots.astype(jnp.int32), keep


def moe_ffn(x: jax.Array, p: Dict[str, jax.Array], cfg: MoEConfig,
            gated: bool = True, constrain=None) -> Tuple[jax.Array, jax.Array]:
    """x (B, S, d); p: router (d,E), we_i/we_g (E,d,f), we_o (E,f,d).
    Returns (y (B,S,d), aux_loss)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    cns = constrain if constrain is not None else (lambda path, t: t)
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    gates, ids, aux = route(logits, cfg)                 # (B, S, k)
    cap = capacity(S, cfg)
    slots, keep = jax.vmap(
        lambda i: assign_slots(i, E, cap))(ids)          # (B, S, k)

    # ---- dispatch: batched scatter into (B, E, C, d) ----
    contrib = jnp.where(keep[..., None], x[:, :, None, :], 0)  # (B,S,k,d)

    def scatter_one(eb, sb, cb):
        buf = jnp.zeros((E, cap, d), x.dtype)
        return buf.at[eb.reshape(-1), sb.reshape(-1)].add(
            cb.reshape(-1, d).astype(x.dtype), mode="drop")

    buf = jax.vmap(scatter_one)(ids, slots, contrib)     # (B, E, C, d)
    buf = cns("moe/dispatch", buf)
    # ---- expert FFN (2-D parallel: B over data, E over model) ----
    h = jnp.einsum("becd,edf->becf", buf, p["we_i"])
    if gated:
        g = jnp.einsum("becd,edf->becf", buf, p["we_g"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype)
    out = jnp.einsum("becf,efd->becd", h, p["we_o"])
    out = cns("moe/dispatch", out)
    # ---- combine: batched gather + weighted reduce over k (no scatter) ----
    def gather_one(ob, eb, sb):
        return ob[eb.reshape(-1), sb.reshape(-1)].reshape(S, k, d)

    gathered = jax.vmap(gather_one)(out, ids, slots)     # (B, S, k, d)
    w = (gates * keep).astype(jnp.float32)               # (B, S, k)
    y = jnp.einsum("bskd,bsk->bsd", gathered.astype(jnp.float32), w)
    y = cns("moe/tokens", y)
    return y.astype(x.dtype), aux
