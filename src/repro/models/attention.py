"""GQA attention: direct path (small S), flash-algorithm chunked path
(online softmax over KV blocks, O(S·block) memory), the decode path over a
KV cache, and the shared-prefix tail-prefill path for the paged cache
(`prefix_attention`). Supports qk-norm, QKV bias, RoPE/M-RoPE.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q (B,Tq,K,G,dh), k (B,Tk,K,dh) -> (B,K,G,Tq,Tk) f32."""
    return jnp.einsum("bqkgd,btkd->bkgqt", q, k,
                      preferred_element_type=jnp.float32)


def direct_attention(q, k, v, *, causal: bool = True,
                     q_offset: int | jax.Array = 0,
                     kv_len: Optional[jax.Array] = None) -> jax.Array:
    """q (B,Sq,H,dh), k/v (B,Skv,K,dh). Suitable for small S and for decode.

    kv_len: optional dynamic valid-KV length (positions >= kv_len are
    masked). A scalar applies to the whole batch; a (B,) array masks each
    row at its own length — the ragged-validity path continuous-batching
    decode rides, where every slot of one fixed-shape cache sits at a
    different position.
    q_offset: global position of q[0] (for causal masking during chunking or
    cached decode)."""
    B, Sq, H, dh = q.shape
    K = k.shape[2]
    G = H // K
    scale = dh ** -0.5
    qs = q.reshape(B, Sq, K, G, dh) * scale
    s = _gqa_scores(qs, k)                                   # (B,K,G,Sq,Skv)
    Skv = k.shape[1]
    kv_pos = jnp.arange(Skv)
    mask = None
    if causal:
        q_pos = jnp.arange(Sq) + q_offset
        mask = q_pos[:, None] >= kv_pos[None, :]
    if kv_len is not None:
        kv_len = jnp.asarray(kv_len)
        if kv_len.ndim == 1:       # per-slot: broadcast over (K, G, Sq)
            kv_len = kv_len.reshape(B, 1, 1, 1, 1)
        valid = kv_pos[None, :] < kv_len
        mask = valid if mask is None else (mask & valid)
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(v.dtype), v)
    return out.reshape(B, Sq, H, dh)


def windowed_decode_attention(q, k, v, kv_len) -> jax.Array:
    """Multi-token decode attention for draft verification (DESIGN.md
    §Speculation): q (B,W,H,dh) holds a short window of W consecutive
    queries per slot — the last accepted token plus W-1 draft tokens — and
    k/v (B,Skv,K,dh) is the cache AFTER the window's own KV rows were
    written. `kv_len` (B,) is the valid length seen by query row 0 (its own
    row included); row j additionally sees the j window rows before it:

        query j attends columns  c < kv_len + j

    which is exactly the mask a step-by-step decode would apply, so W == 1
    reproduces `direct_attention(causal=False, kv_len=kv_len)` bit-for-bit
    (same einsum structure, same mask arithmetic). Masked columns contribute
    exact zeros at fp32; kv_len >= 1 guarantees no fully-masked row."""
    B, W, H, dh = q.shape
    K = k.shape[2]
    G = H // K
    scale = dh ** -0.5
    qs = q.reshape(B, W, K, G, dh) * scale
    s = _gqa_scores(qs, k)                                   # (B,K,G,W,Skv)
    kv_pos = jnp.arange(k.shape[1])
    lim = (jnp.asarray(kv_len).reshape(B, 1, 1, 1, 1)
           + jnp.arange(W).reshape(1, 1, 1, W, 1))
    s = jnp.where(kv_pos[None, None, None, None, :] < lim, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(v.dtype), v)
    return out.reshape(B, W, H, dh)


def prefix_attention(q, k, v, kw, vw, prefix_len) -> jax.Array:
    """Tail-prefill attention for shared-prefix paged serving (DESIGN.md
    §Paging): `q`/`k`/`v` are the Sq tail rows of a prompt whose first
    `prefix_len` tokens are already resident as KV in `kw`/`vw` (the page
    window gathered through the slot's block table, (B, W, K, dh) with
    W >= prefix_len; columns >= prefix_len are dirt and masked).

    Tail row i sits at global position prefix_len + i: it attends every
    valid window column (all global positions < prefix_len) and tail
    columns j <= i (causal). One concatenated score/softmax/value einsum —
    the same reduction structure as `direct_attention`, so a zero-length
    prefix (prefix_len == 0, fully-masked window) reproduces the plain
    causal prefill bit-for-bit at fp32: masked columns contribute exact
    zeros to the softmax."""
    B, Sq, H, dh = q.shape
    K = k.shape[2]
    G = H // K
    W = kw.shape[1]
    scale = dh ** -0.5
    qs = q.reshape(B, Sq, K, G, dh) * scale
    k_all = jnp.concatenate([kw.astype(k.dtype), k], axis=1)
    v_all = jnp.concatenate([vw.astype(v.dtype), v], axis=1)
    s = _gqa_scores(qs, k_all)                       # (B,K,G,Sq,W+Sq)
    col = jnp.arange(W + Sq)
    qpos = jnp.arange(Sq)
    win_ok = col[None, :] < prefix_len               # window: resident rows
    tail_ok = (col[None, :] - W) <= qpos[:, None]    # tail: causal
    mask = jnp.where(col[None, :] < W, win_ok, tail_ok)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(v.dtype), v_all)
    return out.reshape(B, Sq, H, dh)


def chunked_attention(q, k, v, *, causal: bool = True,
                      chunk_q: int = 512, chunk_kv: int = None) -> jax.Array:
    """Flash-attention algorithm in pure JAX: sequential scan over q blocks,
    inner scan over kv blocks with running (max, denom, acc). Peak memory is
    one (B,K,G,Tq,Tk) score block. Lowers to compile-size-constant HLO.
    """
    B, S, H, dh = q.shape
    K = k.shape[2]
    G = H // K
    chunk_kv = chunk_q if chunk_kv is None else chunk_kv
    assert chunk_q == chunk_kv, "diagonal-block masking needs equal chunks"
    if S % chunk_q or S % chunk_kv:
        return direct_attention(q, k, v, causal=causal)
    nq, nk = S // chunk_q, S // chunk_kv
    scale = dh ** -0.5
    qb = jnp.moveaxis(q.reshape(B, nq, chunk_q, K, G, dh), 1, 0) * scale
    kb = jnp.moveaxis(k.reshape(B, nk, chunk_kv, K, dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, chunk_kv, K, dh), 1, 0)
    # Masking via ONE trace-time triangular constant + scalar block flags.
    # (A per-block `where(q_pos >= kv_pos, ...)` mask looks loop-invariant to
    # XLA, which hoists the full (nq, nk, Tq, Tk) pred stack out of the scans
    # — measured >1GB on train_4k. Additive arithmetic on a shared constant
    # keeps the worst-case hoist at one (Tq, Tk) f32 block.)
    tri = jnp.where(jnp.arange(chunk_q)[:, None] >= jnp.arange(chunk_kv)[None, :],
                    0.0, NEG_INF).astype(jnp.float32)

    def outer(_, qblk_i):
        qblk, iq = qblk_i

        def inner(state, kvblk_j):
            m, l, acc = state
            kblk, vblk, jk = kvblk_j
            s = _gqa_scores(qblk, kblk)                      # (B,K,G,Tq,Tk)
            if causal:
                # block cases: jk < iq -> no mask; jk == iq -> triangular;
                # jk > iq -> fully masked (scalar flags, no pred tensors)
                diag = (jk == iq).astype(jnp.float32)
                future = (jk > iq).astype(jnp.float32)
                s = s + tri * diag + NEG_INF * future
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = (acc * corr[..., None]
                       + jnp.einsum("bkgqt,btkd->bkgqd", p,
                                    vblk.astype(jnp.float32)))
            return (m_new, l_new, acc_new), None

        init = (jnp.full((B, K, G, chunk_q), NEG_INF, jnp.float32),
                jnp.zeros((B, K, G, chunk_q), jnp.float32),
                jnp.zeros((B, K, G, chunk_q, dh), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            inner, init, (kb, vb, jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]         # (B,K,G,Tq,dh)
        return None, jnp.moveaxis(out, 3, 1)                 # (B,Tq,K,G,dh)

    _, blocks = jax.lax.scan(outer, None, (qb, jnp.arange(nq)))
    out = jnp.moveaxis(blocks, 0, 1)                         # (B,nq,Tq,K,G,dh)
    return out.reshape(B, S, H, dh).astype(v.dtype)


# ---------------------------------------------------------------------------
# Flash attention with a hand-written VJP (§Perf iteration A2, EXPERIMENTS.md)
#
# jax's autodiff of the chunked scan stores every (nq, nk, B, K, G, Tq, Tk)
# softmax block as a linearization residual — measured ≈4TB/step of HBM
# traffic on yi-9b train_4k. The flash backward saves only (m, l, o) per row
# and RECOMPUTES p per block, exactly like the TPU/GPU flash kernels.
# ---------------------------------------------------------------------------

def _tri_pairs(nq: int):
    """Static (iq, jk) index arrays covering jk <= iq, ordered by iq then jk
    — exactly the nq(nq+1)/2 causal block pairs. Fully-masked future blocks
    are never touched: ~2x less attention compute/traffic than masked-full,
    with static trip counts (scan-friendly, cost-analysis-exact)."""
    pairs = [(i, j) for i in range(nq) for j in range(i + 1)]
    iqs = jnp.array([p[0] for p in pairs], jnp.int32)
    jks = jnp.array([p[1] for p in pairs], jnp.int32)
    return iqs, jks


def _flash_fwd_scan(q, k, v, *, causal: bool, chunk: int):
    B, S, H, dh = q.shape
    K = k.shape[2]
    G = H // K
    nq = nk = S // chunk
    scale = dh ** -0.5
    qb = jnp.moveaxis(q.reshape(B, nq, chunk, K, G, dh), 1, 0)
    kb = jnp.moveaxis(k.reshape(B, nk, chunk, K, dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, chunk, K, dh), 1, 0)
    tri = jnp.where(jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :],
                    0.0, NEG_INF).astype(jnp.float32)
    if not causal:
        iqs = jnp.repeat(jnp.arange(nq, dtype=jnp.int32), nk)
        jks = jnp.tile(jnp.arange(nk, dtype=jnp.int32), nq)
    else:
        iqs, jks = _tri_pairs(nq)

    def step(carry, pair):
        m, l, acc, mbuf, lbuf, obuf = carry
        iq, jk = pair
        fresh = (jk == 0)
        # reset per-q-block state at the start of each row of pairs
        m = jnp.where(fresh, NEG_INF, m)
        l = jnp.where(fresh, 0.0, l)
        acc = jnp.where(fresh, 0.0, acc)
        qblk = jax.lax.dynamic_index_in_dim(qb, iq, 0, keepdims=False)
        kblk = jax.lax.dynamic_index_in_dim(kb, jk, 0, keepdims=False)
        vblk = jax.lax.dynamic_index_in_dim(vb, jk, 0, keepdims=False)
        s = _gqa_scores(qblk, kblk) * scale
        if causal:
            s = s + tri * (jk == iq).astype(jnp.float32)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = (acc * corr[..., None]
                   + jnp.einsum("bkgqt,btkd->bkgqd", p,
                                vblk.astype(jnp.float32)))
        # write-through: the last pair of each row leaves the final state
        out_blk = (acc_new
                   / jnp.maximum(l_new, 1e-30)[..., None]).astype(v.dtype)
        mbuf = jax.lax.dynamic_update_index_in_dim(mbuf, m_new, iq, 0)
        lbuf = jax.lax.dynamic_update_index_in_dim(lbuf, l_new, iq, 0)
        obuf = jax.lax.dynamic_update_index_in_dim(obuf, out_blk, iq, 0)
        return (m_new, l_new, acc_new, mbuf, lbuf, obuf), None

    init = (
        jnp.full((B, K, G, chunk), NEG_INF, jnp.float32),
        jnp.zeros((B, K, G, chunk), jnp.float32),
        jnp.zeros((B, K, G, chunk, dh), jnp.float32),
        jnp.zeros((nq, B, K, G, chunk), jnp.float32),
        jnp.zeros((nq, B, K, G, chunk), jnp.float32),
        jnp.zeros((nq, B, K, G, chunk, dh), v.dtype),
    )
    (_, _, _, m, l, obuf), _ = jax.lax.scan(step, init, (iqs, jks))
    out = jnp.transpose(obuf, (1, 0, 4, 2, 3, 5)).reshape(B, S, H, dh)
    return out.astype(v.dtype), m, l            # m, l: (nq, B, K, G, Tq)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q, k, v, causal: bool = True, chunk: int = 512):
    out, _, _ = _flash_fwd_scan(q, k, v, causal=causal, chunk=chunk)
    return out


def _flash_fwd(q, k, v, causal, chunk):
    out, m, l = _flash_fwd_scan(q, k, v, causal=causal, chunk=chunk)
    return out, (q, k, v, out, m, l)


def _flash_bwd(causal, chunk, res, do):
    q, k, v, out, m, l = res
    B, S, H, dh = q.shape
    K = k.shape[2]
    G = H // K
    nq = nk = S // chunk
    scale = dh ** -0.5
    qb = jnp.moveaxis(q.reshape(B, nq, chunk, K, G, dh), 1, 0)
    kb = jnp.moveaxis(k.reshape(B, nk, chunk, K, dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, chunk, K, dh), 1, 0)
    dob = jnp.moveaxis(do.reshape(B, nq, chunk, K, G, dh), 1, 0)
    ob = jnp.moveaxis(out.reshape(B, nq, chunk, K, G, dh), 1, 0)
    # D_i = rowsum(do * o)
    Db = jnp.einsum("nbqkgd,nbqkgd->nbkgq", dob.astype(jnp.float32),
                    ob.astype(jnp.float32))
    tri = jnp.where(jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :],
                    0.0, NEG_INF).astype(jnp.float32)
    if not causal:
        iqs = jnp.repeat(jnp.arange(nq, dtype=jnp.int32), nk)
        jks = jnp.tile(jnp.arange(nk, dtype=jnp.int32), nq)
    else:
        iqs, jks = _tri_pairs(nq)

    def step(carry, pair):
        dq_blk, dqbuf, dk_acc, dv_acc = carry
        iq, jk = pair
        dq_blk = jnp.where((jk == 0), 0.0, dq_blk)
        qblk = jax.lax.dynamic_index_in_dim(qb, iq, 0, keepdims=False)
        doblk = jax.lax.dynamic_index_in_dim(dob, iq, 0, keepdims=False)
        mi = jax.lax.dynamic_index_in_dim(m, iq, 0, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, iq, 0, keepdims=False)
        Di = jax.lax.dynamic_index_in_dim(Db, iq, 0, keepdims=False)
        kblk = jax.lax.dynamic_index_in_dim(kb, jk, 0, keepdims=False)
        vblk = jax.lax.dynamic_index_in_dim(vb, jk, 0, keepdims=False)
        s = _gqa_scores(qblk, kblk) * scale      # (B,K,G,Tq,Tk)
        if causal:
            s = s + tri * (jk == iq).astype(jnp.float32)
        p = jnp.exp(s - mi[..., None]) / jnp.maximum(li, 1e-30)[..., None]
        dp = jnp.einsum("bqkgd,btkd->bkgqt", doblk.astype(jnp.float32),
                        vblk.astype(jnp.float32))
        ds = p * (dp - Di[..., None])
        dq_blk = dq_blk + scale * jnp.einsum(
            "bkgqt,btkd->bqkgd", ds, kblk.astype(jnp.float32))
        dqbuf = jax.lax.dynamic_update_index_in_dim(dqbuf, dq_blk, iq, 0)
        dk_j = scale * jnp.einsum("bkgqt,bqkgd->btkd", ds,
                                  qblk.astype(jnp.float32))
        dv_j = jnp.einsum("bkgqt,bqkgd->btkd", p, doblk.astype(jnp.float32))
        dk_acc = jax.lax.dynamic_update_slice(
            dk_acc, jax.lax.dynamic_slice(
                dk_acc, (jk * chunk, 0, 0, 0),
                (chunk, B, K, dh)) + jnp.moveaxis(dk_j, 1, 0),
            (jk * chunk, 0, 0, 0))
        dv_acc = jax.lax.dynamic_update_slice(
            dv_acc, jax.lax.dynamic_slice(
                dv_acc, (jk * chunk, 0, 0, 0),
                (chunk, B, K, dh)) + jnp.moveaxis(dv_j, 1, 0),
            (jk * chunk, 0, 0, 0))
        return (dq_blk, dqbuf, dk_acc, dv_acc), None

    zeros_kv = jnp.zeros((S, B, K, dh), jnp.float32)
    init = (jnp.zeros((B, chunk, K, G, dh), jnp.float32),
            jnp.zeros((nq, B, chunk, K, G, dh), jnp.float32),
            zeros_kv, zeros_kv)
    (_, dqbuf, dk_acc, dv_acc), _ = jax.lax.scan(step, init, (iqs, jks))
    dq = jnp.moveaxis(dqbuf, 0, 1).reshape(B, S, H, dh).astype(q.dtype)
    dk = jnp.moveaxis(dk_acc, 0, 1).reshape(B, S, K, dh).astype(k.dtype)
    dv = jnp.moveaxis(dv_acc, 0, 1).reshape(B, S, K, dh).astype(v.dtype)
    return dq, dk, dv


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def attention(q, k, v, *, causal: bool = True,
              direct_threshold: int = 1024, chunk: int = 512,
              flash: bool = True) -> jax.Array:
    S = q.shape[1]
    if S <= direct_threshold:
        return direct_attention(q, k, v, causal=causal)
    if flash and S % chunk == 0:
        return flash_attention(q, k, v, causal, chunk)
    return chunked_attention(q, k, v, causal=causal)
