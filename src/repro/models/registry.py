"""Model registry: `build(cfg, peft)` returns a `Model` facade with a uniform
interface across families (dense/moe/audio/vlm transformer, pure-SSM, hybrid).

    model.init(rng)                      -> {"base": ..., "peft": ...}
    model.loss(params, batch)            -> scalar
    model.forward(params, batch)         -> (logits, aux)
    model.decode_step(params, cache, b)  -> (next_tokens, cache)
    model.init_cache(batch, max_len)     -> cache tree
    model.input_specs(shape)             -> (batch specs, cache specs | None)
    model.sites                          -> adapter sites (PEFT targets)
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, PEFTConfig, ShapeConfig
from repro.core import adapter as adapter_api
from repro.core import peft as peft_mod
from repro.core.peft import AdapterSite
from repro.kernels import api as kernel_api
from repro.models import mamba2, ssm_lm, transformer, zamba2


def add_time_dim(t: jax.Array) -> jax.Array:
    """Re-add the time dim to per-step tokens: (B,) -> (B, 1); codebook
    tokens (B, CB) -> (B, 1, CB). Shared by Model.prefill and the serve
    engine's decode loop so the two paths cannot diverge."""
    return t[:, None] if t.ndim == 1 else t[:, None, :]


def default_targets(cfg: ModelConfig) -> Tuple[str, ...]:
    """Paper default: attention q/v. Attention-free family: in/out proj."""
    if cfg.family == "ssm":
        return ("wx", "wo_ssm")
    return ("wq", "wv")


def resolve_default_targets(peft: PEFTConfig, cfg: ModelConfig) -> PEFTConfig:
    """Swap the generic ("wq", "wv") default for the family's real targets —
    the ONE place this special case lives (Model build and the serving
    AdapterBank both normalize through it)."""
    if peft.target_modules == ("wq", "wv") and cfg.family == "ssm":
        return peft.replace(target_modules=default_targets(cfg))
    return peft


def adapter_sites(cfg: ModelConfig) -> Tuple[AdapterSite, ...]:
    if cfg.family == "ssm":
        d_inner = cfg.ssm.expand * cfg.d_model
        return (
            AdapterSite("layers/wx", cfg.d_model, d_inner, cfg.num_layers),
            AdapterSite("layers/wo_ssm", d_inner, cfg.d_model, cfg.num_layers),
        )
    if cfg.family == "hybrid":
        d_inner = cfg.ssm.expand * cfg.d_model
        return (
            AdapterSite("shared/wq", cfg.d_model, cfg.attn_dim, zamba2.n_apps(cfg)),
            AdapterSite("shared/wv", cfg.d_model, cfg.kv_dim, zamba2.n_apps(cfg)),
            AdapterSite("layers/wx", cfg.d_model, d_inner, cfg.num_layers),
            AdapterSite("layers/wo_ssm", d_inner, cfg.d_model, cfg.num_layers),
        )
    return (
        AdapterSite("layers/wq", cfg.d_model, cfg.attn_dim, cfg.num_layers),
        AdapterSite("layers/wk", cfg.d_model, cfg.kv_dim, cfg.num_layers),
        AdapterSite("layers/wv", cfg.d_model, cfg.kv_dim, cfg.num_layers),
        AdapterSite("layers/wo", cfg.attn_dim, cfg.d_model, cfg.num_layers),
        AdapterSite("layers/wi", cfg.d_model, cfg.d_ff or cfg.d_model, cfg.num_layers),
    )


_FAMILY_MODULES = {
    "dense": transformer, "moe": transformer, "audio": transformer,
    "vlm": transformer, "ssm": ssm_lm, "hybrid": zamba2,
}


@dataclass
class Model:
    cfg: ModelConfig
    peft: PEFTConfig
    remat: str = "none"
    # optional sharding-constraint hook `f(param_path, x) -> x`, installed by
    # the launch layer (anchors merged W+ΔW stacks to the weight's spec)
    constrain: Optional[Callable] = None
    # serving adapter bank: {method name: PEFTConfig profile} — static config
    # closed over by the jitted graphs; the resident rows themselves travel
    # as params["bank"] arrays (see serve/engine.py AdapterBank)
    bank_profiles: Optional[Dict[str, PEFTConfig]] = None

    def __post_init__(self):
        self._mod = _FAMILY_MODULES[self.cfg.family]
        # resolve the method string exactly once, at model build — unknown
        # names fail here, not deep inside a traced graph
        self.method = adapter_api.resolve(self.peft.method)
        if self.method.has_site_params:
            # resolve per-arch default targets if user kept the generic default
            self.peft = resolve_default_targets(self.peft, self.cfg)
        self.sites = adapter_sites(self.cfg)
        # kernel-backend choice per targeted (site, op), resolved ONCE here
        # (DESIGN.md §Kernels) — an unknown kernel_backend fails at build,
        # and explain_kernels() reports what each hot path will run
        self.kernel_policy = kernel_api.KernelPolicy.build(
            self.method, self.sites, self.peft)

    def _bank_kwargs(self, params: Dict) -> Dict:
        if self.bank_profiles is None:
            return {}
        return {"bank": params.get("bank"),
                "bank_profiles": self.bank_profiles}

    # ---- params -----------------------------------------------------------
    def init(self, rng: jax.Array) -> Dict:
        k1, k2 = jax.random.split(rng)
        base = self._mod.init_params(k1, self.cfg)
        adapters = peft_mod.init_adapters(k2, self.sites, self.peft)
        return {"base": base, "peft": adapters}

    def init_shapes(self) -> Dict:
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    # ---- forward/loss -----------------------------------------------------
    def forward(self, params: Dict, batch: Dict):
        return self._mod.forward(params["base"], params["peft"], batch,
                                 self.cfg, self.peft, self.sites,
                                 remat=self.remat, constrain=self.constrain,
                                 **self._bank_kwargs(params))

    def loss(self, params: Dict, batch: Dict) -> jax.Array:
        return self._mod.loss_fn(params["base"], params["peft"], batch,
                                 self.cfg, self.peft, self.sites,
                                 remat=self.remat, constrain=self.constrain)

    # split-tree loss used by the train step (grads w.r.t. trainable only)
    def loss_from_parts(self, trainable: Dict, frozen_base: Dict,
                        frozen_adapters: Dict, batch: Dict) -> jax.Array:
        adapters = _merge_adapter_trees(trainable.get("peft", {}), frozen_adapters)
        base = frozen_base
        if "head" in trainable:
            base = dict(base)
            base["lm_head"] = trainable["head"]
        return self._mod.loss_fn(base, adapters, batch, self.cfg, self.peft,
                                 self.sites, remat=self.remat,
                                 constrain=self.constrain)

    # ---- decode -----------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                   per_slot: bool = False, paged: bool = False,
                   page_size: int = 16,
                   n_pages: Optional[int] = None) -> Dict:
        """paged=True allocates the page-pool cache (DESIGN.md §Paging):
        K/V in (L, n_pages, page_size, ...) pools plus the (B,) per-slot
        position vector — block tables travel per call, managed host-side
        by serve/paging.PagedKVCache (which also picks n_pages)."""
        if paged:
            if n_pages is None:
                raise ValueError("paged cache needs n_pages (the runtime "
                                 "takes it from serve.paging.PagedKVCache)")
            return self._slot_mod().init_paged_cache(self.cfg, batch,
                                                     n_pages, page_size,
                                                     dtype)
        if per_slot:
            return self._slot_mod().init_cache(self.cfg, batch, max_len,
                                               dtype, per_slot=True)
        return self._mod.init_cache(self.cfg, batch, max_len, dtype)

    # ---- per-slot cache (continuous-batching serving, DESIGN §Scheduler) --
    def _slot_mod(self):
        if not self.supports_slot_cache:
            raise NotImplementedError(
                f"family {self.cfg.family!r} ({self.cfg.name}) has no "
                "per-slot cache path — continuous batching currently covers "
                "the token-input transformer families (KV positions are "
                "maskable per slot; recurrent state is not)")
        return self._mod

    @property
    def supports_slot_cache(self) -> bool:
        """True when the family supports the per-slot decode cache: ragged
        per-slot kv_len masking over one fixed-shape KV cache plus the
        write_slot/reset_slots lifecycle (token-input transformer families;
        recurrent families carry un-maskable state, vlm feeds embeds)."""
        return (hasattr(self._mod, "write_slot_cache")
                and self.cfg.embed_inputs and not self.cfg.n_codebooks)

    def write_slot(self, cache: Dict, slot_cache: Dict, slot, length) -> Dict:
        """In-flight prefill: splice a primed batch-1 scratch cache into slot
        row `slot` (position <- `length`) while every other slot keeps
        decoding. `slot`/`length` trace as scalars — one compiled splice per
        scratch length serves all slots."""
        return self._slot_mod().write_slot_cache(cache, slot_cache, slot,
                                                 length)

    def reset_slots(self, cache: Dict, mask) -> Dict:
        """Retire the masked slots of a per-slot cache (positions -> 0)."""
        return self._slot_mod().reset_slots(cache, mask)

    def copy_page(self, cache: Dict, src, dst) -> Dict:
        """COW clone of one physical page of a paged cache (src -> dst)."""
        return self._slot_mod().copy_page(cache, src, dst)

    def prefill_paged(self, params: Dict, cache: Dict, batch: Dict):
        """Shared-prefix tail prefill into a paged cache: compute only the
        unshared tail of the prompt (batch["prefix_len"] tokens are reused
        from resident pages via batch["block_table"]) and splice its KV
        into the slot's pages. Returns (next_tokens, cache)."""
        fn = self._slot_mod().prefill_paged
        return fn(params["base"], params["peft"], cache, batch, self.cfg,
                  self.peft, self.sites, constrain=self.constrain,
                  **self._bank_kwargs(params))

    def decode_step(self, params: Dict, cache: Dict, batch: Dict):
        return self._mod.decode_step(params["base"], params["peft"], cache,
                                     batch, self.cfg, self.peft, self.sites,
                                     constrain=self.constrain,
                                     **self._bank_kwargs(params))

    def verify_step(self, params: Dict, cache: Dict, batch: Dict):
        """Speculative draft verification: one forward over batch["tokens"]
        (B, W) — the last accepted token plus W-1 drafts per slot — writing
        all W KV rows and returning the greedy continuation after each
        (DESIGN.md §Speculation). Cache `pos` is NOT advanced; the
        scheduler commits accepted counts via `advance_pos`."""
        fn = self._slot_mod().verify_step
        return fn(params["base"], params["peft"], cache, batch, self.cfg,
                  self.peft, self.sites, constrain=self.constrain,
                  **self._bank_kwargs(params))

    def advance_pos(self, cache: Dict, delta):
        """Per-slot position commit after verification (delta (B,) of
        accepted token counts, or a scalar for drafter rollback)."""
        return self._slot_mod().advance_pos(cache, delta)

    def prefill(self, params: Dict, cache: Dict, batch: Dict):
        """Fill a fresh cache from a whole (B, S[, CB]) prompt in one call.
        Transformer families run a parallel causal forward; recurrent
        families (ssm/hybrid) scan the decode step over the prompt inside
        one jittable graph. Returns (next_tokens, cache)."""
        fn = getattr(self._mod, "prefill", None)
        if fn is not None:
            return fn(params["base"], params["peft"], cache, batch, self.cfg,
                      self.peft, self.sites, constrain=self.constrain,
                      **self._bank_kwargs(params))
        tokens = batch["tokens"]
        extra = {k: batch[k] for k in ("adapter_slots",) if k in batch}

        def body(cache, tok):
            nt, cache = self.decode_step(params, cache,
                                         {"tokens": add_time_dim(tok), **extra})
            return cache, nt

        cache, nts = jax.lax.scan(body, cache, jnp.moveaxis(tokens, 1, 0))
        return jax.tree.map(lambda a: a[-1], nts), cache

    # ---- abstract input specs (dry-run) -------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind in ("train", "prefill"):
            if cfg.family == "vlm":
                batch = {
                    "embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16),
                    "positions": jax.ShapeDtypeStruct((3, B, S), i32),
                }
            elif cfg.n_codebooks:
                batch = {"tokens": jax.ShapeDtypeStruct((B, S, cfg.n_codebooks), i32)}
            else:
                batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
            if shape.kind == "train":
                lbl = ((B, S, cfg.n_codebooks) if cfg.n_codebooks else (B, S))
                batch["labels"] = jax.ShapeDtypeStruct(lbl, i32)
            return batch
        # decode: one new token against a seq_len cache
        if cfg.family == "vlm":
            batch = {
                "embeds": jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.bfloat16),
                "positions": jax.ShapeDtypeStruct((3, B, 1), i32),
            }
        elif cfg.n_codebooks:
            batch = {"tokens": jax.ShapeDtypeStruct((B, 1, cfg.n_codebooks), i32)}
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
        return batch

    def cache_specs(self, shape: ShapeConfig) -> Dict:
        return jax.eval_shape(
            functools.partial(self.init_cache, shape.global_batch,
                              shape.seq_len))

    # ---- kernels ------------------------------------------------------------
    def explain_kernels(self) -> str:
        """Which kernel backend each targeted (site, op) resolved to —
        the build-time `KernelPolicy` snapshot rendered for humans."""
        return self.kernel_policy.explain()

    # ---- accounting ---------------------------------------------------------
    def trainable_params(self) -> int:
        if self.method.trains_base:
            import numpy as _np
            shapes = jax.eval_shape(
                lambda: self._mod.init_params(jax.random.PRNGKey(0), self.cfg))
            return sum(int(_np.prod(l.shape)) for l in jax.tree.leaves(shapes))
        return peft_mod.count_trainable(self.sites, self.peft)


def _merge_adapter_trees(trainable: Dict, frozen: Dict) -> Dict:
    out = {}
    for name in set(trainable) | set(frozen):
        out[name] = {**frozen.get(name, {}), **trainable.get(name, {})}
    return out


def build(cfg: ModelConfig, peft: Optional[PEFTConfig] = None,
          remat: str = "none") -> Model:
    return Model(cfg, peft or PEFTConfig(), remat=remat)


def analysis_models(methods: Tuple[str, ...] = ("fourierft",),
                    archs: Optional[Tuple[str, ...]] = None):
    """Yield (arch_id, method, Model) for every registered config × method at
    reduced scale — the coverage surface `repro.analysis`'s sharding audit
    walks (`init_shapes()` is eval_shape-cheap; nothing is materialized).
    Unbuildable combinations (a method whose applicability predicate rejects
    the family) are skipped: absent params can't need a sharding rule."""
    import repro.configs as configs
    for arch in (archs or tuple(configs.ARCHS)):
        cfg = configs.reduced(configs.get(arch))
        for m in methods:
            try:
                yield arch, m, build(cfg, PEFTConfig(method=m))
            except (ValueError, NotImplementedError):
                continue
