"""Generic decoder-only LM covering the dense / moe / audio / vlm families.

- scan-over-layers with stacked (L, ...) params (compile time independent of
  depth; FourierFT coefficients stack naturally as (L, n)).
- PEFT integration at the linear level: `merged` strategy swaps W for
  W + ΔW before the scan; `factored` threads per-layer adapter slices through
  the scan and applies the rank-2n bypass inside each layer.
- decode path updates a stacked KV cache (L, B, Smax, K, hd).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, PEFTConfig
from repro.core import lora as lora_mod
from repro.core import peft as peft_mod
from repro.core.fourierft import factored_apply
from repro.core.basis import basis_scale
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models.common import (
    apply_rope, cross_entropy, dense_init, rms_norm,
)


# ---------------------------------------------------------------------------
# PEFT-aware linear
# ---------------------------------------------------------------------------

def make_linear(peft: PEFTConfig, aux_consts: Dict[str, Dict],
                constrain=None):
    """Returns linear(lp, name, x): y = x @ lp[name] + adapters.

    Factored adapters appear in `lp` as `{name}__c` / `{name}__la`+`{name}__lb`
    per-layer slices; frozen entry/basis constants come from aux_consts.
    `constrain` (launch-layer hook) implements FSDP: weight slices stored
    `data`-sharded are all-gathered here, inside the layer loop, where the
    gather is loop-variant and cannot be hoisted into a full-stack gather."""

    def linear(lp: Dict, name: str, x: jax.Array) -> jax.Array:
        w = lp[name]
        if constrain is not None and w.ndim >= 2:
            w = constrain("fsdp_gather/" + name, w)
        y = jnp.einsum("...d,df->...f", x, w)
        if name + "__b" in lp:
            y = y + lp[name + "__b"].astype(y.dtype)
        key_c = name + "__c"
        if key_c in lp:
            aux = aux_consts[name]
            d1, d2 = w.shape
            if "entries" in aux:
                y = y + factored_apply(x, lp[key_c], aux["entries"], d1, d2,
                                       peft.alpha).astype(y.dtype)
            else:
                scale = basis_scale(peft.basis, d1, d2, peft.alpha)
                proj = (x.astype(jnp.float32) @ aux["b1"]) * lp[key_c].astype(jnp.float32)
                y = y + (proj @ aux["b2"].T * scale).astype(y.dtype)
        if name + "__la" in lp:
            y = y + lora_mod.lora_apply(x, lp[name + "__la"], lp[name + "__lb"],
                                        peft.lora_alpha, peft.lora_r).astype(y.dtype)
        return y

    return linear


def apply_peft_to_layers(layers: Dict, adapters: Dict, sites, peft: PEFTConfig,
                         prefix: str = "layers/", constrain=None):
    """Returns (eff_layers, aux_consts). merged: W <- W + ΔW. factored: add
    per-layer adapter slices to the scanned tree (entries stay as constants).

    `constrain(path, x)`: optional sharding-constraint hook (set by the launch
    layer) pinning merged W+ΔW stacks to the weight's partition spec — without
    it GSPMD has no sharding anchor for the materialization einsum and falls
    back to involuntary full rematerialization (measured: +15GB temps on
    yi-6b train_4k)."""
    eff = dict(layers)
    aux_consts: Dict[str, Dict] = {}
    site_by_name = {s.name: s for s in sites}
    for full_name, ad in adapters.items():
        if not full_name.startswith(prefix):
            continue
        key = full_name[len(prefix):]
        site = site_by_name[full_name]
        if peft.method == "bitfit":
            bkey = key + "__b"
            eff[bkey] = (eff[bkey] + ad["delta_b"]) if bkey in eff else ad["delta_b"]
            continue
        if peft.strategy == "merged":
            dw = peft_mod.site_delta(ad, site, peft, eff[key].dtype)
            if constrain is not None:
                dw = constrain(full_name, dw)
            eff[key] = eff[key] + dw
        else:
            if peft.method == "fourierft":
                eff[key + "__c"] = ad["c"]
                aux_consts[key] = {k: v for k, v in ad.items() if k != "c"}
            elif peft.method == "lora":
                eff[key + "__la"] = ad["lora_a"]
                eff[key + "__lb"] = ad["lora_b"]
    return eff, aux_consts


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_params(rng: jax.Array, cfg: ModelConfig) -> Dict:
    dtype = jnp.dtype(cfg.param_dtype)
    d, L = cfg.d_model, cfg.num_layers
    ks = iter(jax.random.split(rng, 24))
    layers: Dict[str, jax.Array] = {
        "attn_norm": jnp.ones((L, d), dtype),
        "wq": dense_init(next(ks), (L, d, cfg.attn_dim), dtype),
        "wk": dense_init(next(ks), (L, d, cfg.kv_dim), dtype),
        "wv": dense_init(next(ks), (L, d, cfg.kv_dim), dtype),
        "wo": dense_init(next(ks), (L, cfg.attn_dim, d), dtype),
        "mlp_norm": jnp.ones((L, d), dtype),
    }
    if cfg.qkv_bias:
        layers["wq__b"] = jnp.zeros((L, cfg.attn_dim), dtype)
        layers["wk__b"] = jnp.zeros((L, cfg.kv_dim), dtype)
        layers["wv__b"] = jnp.zeros((L, cfg.kv_dim), dtype)
    if cfg.qk_norm:
        layers["q_norm"] = jnp.ones((L, cfg.head_dim), dtype)
        layers["k_norm"] = jnp.ones((L, cfg.head_dim), dtype)
    if cfg.moe is not None:
        e, f = cfg.moe.num_experts, cfg.moe.d_ff_expert
        layers["router"] = dense_init(next(ks), (L, d, e), jnp.float32)
        layers["we_i"] = dense_init(next(ks), (L, e, d, f), dtype)
        layers["we_g"] = dense_init(next(ks), (L, e, d, f), dtype)
        layers["we_o"] = dense_init(next(ks), (L, e, f, d), dtype)
    else:
        layers["wi"] = dense_init(next(ks), (L, d, cfg.d_ff), dtype)
        if cfg.gated_mlp:
            layers["wg"] = dense_init(next(ks), (L, d, cfg.d_ff), dtype)
        layers["wo_mlp"] = dense_init(next(ks), (L, cfg.d_ff, d), dtype)
    params: Dict = {"layers": layers, "final_norm": jnp.ones((d,), dtype)}
    if cfg.embed_inputs:
        if cfg.n_codebooks:
            params["embed"] = dense_init(next(ks), (cfg.n_codebooks, cfg.vocab, d), dtype)
        else:
            params["embed"] = dense_init(next(ks), (cfg.vocab, d), dtype)
    if cfg.n_codebooks:
        params["lm_head"] = dense_init(next(ks), (cfg.n_codebooks, d, cfg.vocab), dtype)
    else:
        params["lm_head"] = dense_init(next(ks), (d, cfg.vocab), dtype)
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _embed(params: Dict, cfg: ModelConfig, batch: Dict) -> jax.Array:
    if not cfg.embed_inputs:
        return batch["embeds"].astype(jnp.dtype(cfg.dtype))
    tokens = batch["tokens"]
    if cfg.n_codebooks:
        # (B, S, CB): sum of per-codebook embeddings
        embs = [jnp.take(params["embed"][cb], tokens[..., cb], axis=0)
                for cb in range(cfg.n_codebooks)]
        return functools.reduce(jnp.add, embs)
    return jnp.take(params["embed"], tokens, axis=0)


def _attn_block(lp: Dict, x: jax.Array, cfg: ModelConfig, linear,
                positions: jax.Array, *, cache_kv=None, cache_pos=None):
    """Pre-norm attention. If cache_kv=(k,v) is given, runs the decode path
    (append at cache_pos, attend over kv_len=cache_pos+1)."""
    B = x.shape[0]
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q = linear(lp, "wq", h).reshape(B, -1, cfg.n_heads, cfg.head_dim)
    k = linear(lp, "wk", h).reshape(B, -1, cfg.n_kv, cfg.head_dim)
    v = linear(lp, "wv", h).reshape(B, -1, cfg.n_kv, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"], cfg.norm_eps)
        k = rms_norm(k, lp["k_norm"], cfg.norm_eps)
    if cfg.rope_theta:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope)
    if cache_kv is None:
        att = attn_mod.attention(q, k, v, causal=True)
        new_kv = (k, v)        # post-RoPE, as stored by the decode path
    else:
        ck, cv = cache_kv
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cache_pos, 0, 0))
        att = attn_mod.direct_attention(q, ck, cv, causal=False,
                                        kv_len=cache_pos + 1)
        new_kv = (ck, cv)
    out = linear(lp, "wo", att.reshape(B, -1, cfg.attn_dim))
    return x + out, new_kv


def _mlp_block(lp: Dict, x: jax.Array, cfg: ModelConfig, linear,
               constrain=None):
    h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    if cfg.moe is not None:
        y, aux = moe_mod.moe_ffn(h, lp, cfg.moe, gated=cfg.gated_mlp,
                                 constrain=constrain)
        return x + y, aux
    hi = linear(lp, "wi", h)
    if cfg.gated_mlp:
        hg = linear(lp, "wg", h)
        hi = jax.nn.silu(hg.astype(jnp.float32)).astype(hi.dtype) * hi
    else:
        hi = jax.nn.gelu(hi.astype(jnp.float32)).astype(hi.dtype)
    return x + linear(lp, "wo_mlp", hi), jnp.float32(0.0)


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # "full": save nothing


def forward(params: Dict, adapters: Dict, batch: Dict, cfg: ModelConfig,
            peft: PEFTConfig, sites, *, remat: str = "none",
            constrain=None) -> Tuple[jax.Array, jax.Array]:
    """Train/prefill forward. Returns (logits, moe_aux_loss)."""
    x = _embed(params, cfg, batch)
    B, S = x.shape[0], x.shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    eff_layers, aux_consts = apply_peft_to_layers(
        params["layers"], adapters, sites, peft, constrain=constrain)
    linear = make_linear(peft, aux_consts, constrain)
    act = (lambda t: constrain("act/hidden", t)) if constrain else (lambda t: t)
    x = act(x)

    def body(carry, lp):
        x, aux = carry
        x = act(x)
        x, _ = _attn_block(lp, x, cfg, linear, positions)
        x, aux_l = _mlp_block(lp, x, cfg, linear, constrain)
        return (act(x), aux + aux_l), None

    (x, moe_aux), _ = jax.lax.scan(_remat(body, remat), (x, jnp.float32(0.0)),
                                   eff_layers)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.n_codebooks:
        logits = jnp.einsum("bsd,cdv->bscv", x, params["lm_head"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return logits, moe_aux / cfg.num_layers


def loss_fn(params: Dict, adapters: Dict, batch: Dict, cfg: ModelConfig,
            peft: PEFTConfig, sites, *, remat: str = "none",
            constrain=None) -> jax.Array:
    logits, moe_aux = forward(params, adapters, batch, cfg, peft, sites,
                              remat=remat, constrain=constrain)
    ce = cross_entropy(logits, batch["labels"])
    if cfg.moe is not None:
        ce = ce + cfg.moe.aux_loss_weight * moe_aux
    return ce


# ---------------------------------------------------------------------------
# Prefill: one causal forward over the whole prompt that also populates the
# KV cache — replaces token-by-token teacher-forced stepping in the serving
# engine (S sequential decode dispatches -> one call, and attention runs
# parallel over S instead of S times over a masked cache).
# ---------------------------------------------------------------------------

def prefill(params: Dict, adapters: Dict, cache: Dict, batch: Dict,
            cfg: ModelConfig, peft: PEFTConfig, sites,
            constrain=None) -> Tuple[jax.Array, Dict]:
    """Process a (B, S) prompt against a fresh cache (pos must be 0).
    Returns (next_tokens after the last prompt token, cache at pos=S)."""
    x = _embed(params, cfg, batch)
    B, S = x.shape[0], x.shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    eff_layers, aux_consts = apply_peft_to_layers(
        params["layers"], adapters, sites, peft, constrain=constrain)
    linear = make_linear(peft, aux_consts, constrain)

    # cache lives in the scan carry and is written in place per layer —
    # threading K/V through scan ys would materialize a second (L,B,S,K,hd)
    # stack next to the cache (see decode_step's carry note: ~3x-cache peak)
    def body(carry, lp_i):
        x, ck_all, cv_all = carry
        lp, li = lp_i
        x, (k, v) = _attn_block(lp, x, cfg, linear, positions)
        ck_all = jax.lax.dynamic_update_slice(
            ck_all, k.astype(ck_all.dtype)[None], (li, 0, 0, 0, 0))
        cv_all = jax.lax.dynamic_update_slice(
            cv_all, v.astype(cv_all.dtype)[None], (li, 0, 0, 0, 0))
        x, _ = _mlp_block(lp, x, cfg, linear, constrain)
        return (x, ck_all, cv_all), None

    (x, ck, cv), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"]),
        (eff_layers, jnp.arange(cfg.num_layers, dtype=jnp.int32)))
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    if cfg.n_codebooks:
        logits = jnp.einsum("bsd,cdv->bscv", x, params["lm_head"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    return next_tokens, {"k": ck, "v": cv, "pos": cache["pos"] + S}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict:
    L = cfg.num_layers
    return {
        "k": jnp.zeros((L, batch, max_len, cfg.n_kv, cfg.head_dim), dtype),
        "v": jnp.zeros((L, batch, max_len, cfg.n_kv, cfg.head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(params: Dict, adapters: Dict, cache: Dict, batch: Dict,
                cfg: ModelConfig, peft: PEFTConfig, sites,
                constrain=None) -> Tuple[jax.Array, Dict]:
    """One token for every sequence in the batch. batch: tokens (B, 1) (or
    embeds (B,1,d), positions (3,B,1) for vlm). Returns (next_tokens, cache)."""
    x = _embed(params, cfg, batch)
    B = x.shape[0]
    pos = cache["pos"]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(pos.astype(jnp.int32), (B, 1))
    eff_layers, aux_consts = apply_peft_to_layers(
        params["layers"], adapters, sites, peft, constrain=constrain)
    linear = make_linear(peft, aux_consts, constrain)

    # cache lives in the scan CARRY and is updated in place per layer —
    # xs/ys threading would materialize two extra cache-sized buffers
    # (measured: decode peak ≈3× cache size, OOM on the 32k×128 cells)
    def body(carry, lp_i):
        x, ck_all, cv_all = carry
        lp, li = lp_i
        ck = jax.lax.dynamic_index_in_dim(ck_all, li, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(cv_all, li, 0, keepdims=False)
        x, (ck, cv) = _attn_block(lp, x, cfg, linear, positions,
                                  cache_kv=(ck, cv), cache_pos=pos)
        ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, ck, li, 0)
        cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, cv, li, 0)
        x, _ = _mlp_block(lp, x, cfg, linear, constrain)
        return (x, ck_all, cv_all), None

    (x, ck, cv), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"]),
        (eff_layers, jnp.arange(cfg.num_layers, dtype=jnp.int32)))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.n_codebooks:
        logits = jnp.einsum("bsd,cdv->bscv", x, params["lm_head"])
        next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)  # (B, CB)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
        next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)  # (B,)
    new_cache = {"k": ck, "v": cv, "pos": pos + 1}
    return next_tokens, new_cache
