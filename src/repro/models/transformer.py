"""Generic decoder-only LM covering the dense / moe / audio / vlm families.

- scan-over-layers with stacked (L, ...) params (compile time independent of
  depth; FourierFT coefficients stack naturally as (L, n)).
- PEFT integration at the linear level: `merged` strategy swaps W for
  W + ΔW before the scan; `factored` threads per-layer adapter slices through
  the scan and applies the method's factored bypass inside each layer. All
  method math is behind the `AdapterMethod` protocol (core/adapter.py) — this
  module never looks at `peft.method` — and every ΔW materialization /
  factored / bank apply the protocol performs dispatches through the kernel
  registry (DESIGN.md §Kernels), so the merged hot path below runs the
  Pallas deltaw kernels on TPU without this module knowing.
- serving adapter bank: per-request resident adapters are gathered ONCE per
  call (outside the layer scan) and applied per slot via `bank_apply` (see
  DESIGN.md §Adapter API).
- decode path updates a stacked KV cache (L, B, Smax, K, hd). With a
  per-slot cache (init_cache(per_slot=True): pos is (B,) instead of a
  scalar) every row decodes at its own position under ragged kv_len
  masking, and write_slot_cache/reset_slots give the continuous-batching
  scheduler its in-flight prefill + slot recycling (DESIGN.md §Scheduler).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, PEFTConfig
from repro.core import adapter as adapter_api
from repro.kernels import api as kernel_api
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models.common import (
    apply_rope, cross_entropy, dense_init, rms_norm,
)


# ---------------------------------------------------------------------------
# PEFT-aware linear
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SiteApp:
    """One factored adapter application at a weight key: trainable leaves ride
    the scanned layer tree under `{key}{tag}{leaf}`, frozen aux arrays are
    captured here, and `banked` selects the row-batched `bank_apply` path."""
    tag: str
    method: adapter_api.AdapterMethod
    aux: Dict = field(default_factory=dict)
    peft: PEFTConfig = PEFTConfig()
    banked: bool = False


def make_linear(apps: Dict[str, List[SiteApp]], constrain=None):
    """Returns linear(lp, name, x): y = x @ lp[name] + bias + adapter apps.

    Each `SiteApp` at `name` reads its trainable per-layer slices out of `lp`
    (the scanned layer tree) and adds `method.factored_apply` — or
    `method.bank_apply` for per-request resident adapters — to y, under the
    app's own PEFTConfig (the global config has no say here).
    `constrain` (launch-layer hook) implements FSDP: weight slices stored
    `data`-sharded are all-gathered here, inside the layer loop, where the
    gather is loop-variant and cannot be hoisted into a full-stack gather."""

    def linear(lp: Dict, name: str, x: jax.Array) -> jax.Array:
        w = lp[name]
        if constrain is not None and w.ndim >= 2:
            w = constrain("fsdp_gather/" + name, w)
        y = jnp.einsum("...d,df->...f", x, w)
        if name + "__b" in lp:
            y = y + lp[name + "__b"].astype(y.dtype)
        d1, d2 = w.shape
        for app in apps.get(name, ()):
            tr = {leaf: lp[name + app.tag + leaf]
                  for leaf in app.method.trainable_leaves(app.peft)}
            fn = app.method.bank_apply if app.banked \
                else app.method.factored_apply
            y = y + fn(x, tr, app.aux, d1, d2, app.peft).astype(y.dtype)
        return y

    return linear


def _app_tag(kind: str, method_name: str) -> str:
    return f"__{kind}.{method_name}__"


def apply_peft_to_layers(layers: Dict, adapters: Dict, sites, peft: PEFTConfig,
                         prefix: str = "layers/", constrain=None,
                         bank: Optional[Dict] = None,
                         bank_profiles: Optional[Dict[str, PEFTConfig]] = None,
                         bank_slots: Optional[Dict] = None):
    """Returns (eff_layers, apps). merged (and method.mergeable): the method
    folds the site into the stacked tree (W <- W + ΔW; BitFit into the bias).
    factored: trainable leaves join the scanned tree under tagged keys, frozen
    aux stays constant, and `make_linear` applies the method inside each layer.

    `bank`/`bank_profiles`/`bank_slots`: serving adapter bank — for each
    method group, per-request rows are gathered from the (K+1, L, …) resident
    leaves with `bank_slots[method]` (B,) ONCE here, outside the scan, and
    enter the scanned tree as (L, B, …) leaves; row K is the reserved zero
    row, so requests not using a method contribute exactly zero (methods are
    linear in their trainables — see core/adapter.py).

    `constrain(path, x)`: optional sharding-constraint hook (set by the launch
    layer) pinning merged W+ΔW stacks to the weight's partition spec — without
    it GSPMD has no sharding anchor for the materialization einsum and falls
    back to involuntary full rematerialization (measured: +15GB temps on
    yi-6b train_4k)."""
    eff = dict(layers)
    apps: Dict[str, List[SiteApp]] = {}
    method = adapter_api.resolve(peft.method)
    site_by_name = {s.name: s for s in sites}
    for full_name, ad in adapters.items():
        if not full_name.startswith(prefix):
            continue
        key = full_name[len(prefix):]
        site = site_by_name[full_name]
        if peft.strategy == "merged" and method.mergeable:
            method.merge_site(eff, key, ad, site, peft, constrain=constrain,
                              path=full_name)
            continue
        tag = _app_tag("ad", method.name)
        trainable = set(method.trainable_leaves(peft))
        aux = {}
        for leaf, v in ad.items():
            if leaf in trainable:
                eff[key + tag + leaf] = v
            else:
                aux[leaf] = v
        apps.setdefault(key, []).append(SiteApp(tag, method, aux, peft))
    if bank and bank_slots is None:
        raise ValueError("adapter bank configured but the batch carries no "
                         "'adapter_slots' (Engine.generate builds them; "
                         "direct model calls must pass bank.slot_rows(...))")
    for mname in sorted(bank or ()):
        group = bank[mname]
        m = adapter_api.resolve(mname)
        prof = bank_profiles[mname]
        slots = bank_slots[mname]                      # (B,) rows incl. zero
        tag = _app_tag("bank", mname)
        for full_name, leaves in group["sites"].items():
            if not full_name.startswith(prefix):
                continue
            key = full_name[len(prefix):]
            for leaf, arr in leaves.items():           # (K+1, L, ...)
                gathered = jnp.take(arr, slots, axis=0)        # (B, L, ...)
                eff[key + tag + leaf] = jnp.moveaxis(gathered, 0, 1)
            apps.setdefault(key, []).append(
                SiteApp(tag, m, group["aux"].get(full_name, {}), prof,
                        banked=True))
    return eff, apps


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_params(rng: jax.Array, cfg: ModelConfig) -> Dict:
    dtype = jnp.dtype(cfg.param_dtype)
    d, L = cfg.d_model, cfg.num_layers
    ks = iter(jax.random.split(rng, 24))
    layers: Dict[str, jax.Array] = {
        "attn_norm": jnp.ones((L, d), dtype),
        "wq": dense_init(next(ks), (L, d, cfg.attn_dim), dtype),
        "wk": dense_init(next(ks), (L, d, cfg.kv_dim), dtype),
        "wv": dense_init(next(ks), (L, d, cfg.kv_dim), dtype),
        "wo": dense_init(next(ks), (L, cfg.attn_dim, d), dtype),
        "mlp_norm": jnp.ones((L, d), dtype),
    }
    if cfg.qkv_bias:
        layers["wq__b"] = jnp.zeros((L, cfg.attn_dim), dtype)
        layers["wk__b"] = jnp.zeros((L, cfg.kv_dim), dtype)
        layers["wv__b"] = jnp.zeros((L, cfg.kv_dim), dtype)
    if cfg.qk_norm:
        layers["q_norm"] = jnp.ones((L, cfg.head_dim), dtype)
        layers["k_norm"] = jnp.ones((L, cfg.head_dim), dtype)
    if cfg.moe is not None:
        e, f = cfg.moe.num_experts, cfg.moe.d_ff_expert
        layers["router"] = dense_init(next(ks), (L, d, e), jnp.float32)
        layers["we_i"] = dense_init(next(ks), (L, e, d, f), dtype)
        layers["we_g"] = dense_init(next(ks), (L, e, d, f), dtype)
        layers["we_o"] = dense_init(next(ks), (L, e, f, d), dtype)
    else:
        layers["wi"] = dense_init(next(ks), (L, d, cfg.d_ff), dtype)
        if cfg.gated_mlp:
            layers["wg"] = dense_init(next(ks), (L, d, cfg.d_ff), dtype)
        layers["wo_mlp"] = dense_init(next(ks), (L, cfg.d_ff, d), dtype)
    params: Dict = {"layers": layers, "final_norm": jnp.ones((d,), dtype)}
    if cfg.embed_inputs:
        if cfg.n_codebooks:
            params["embed"] = dense_init(next(ks), (cfg.n_codebooks, cfg.vocab, d), dtype)
        else:
            params["embed"] = dense_init(next(ks), (cfg.vocab, d), dtype)
    if cfg.n_codebooks:
        params["lm_head"] = dense_init(next(ks), (cfg.n_codebooks, d, cfg.vocab), dtype)
    else:
        params["lm_head"] = dense_init(next(ks), (d, cfg.vocab), dtype)
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _embed(params: Dict, cfg: ModelConfig, batch: Dict) -> jax.Array:
    if not cfg.embed_inputs:
        return batch["embeds"].astype(jnp.dtype(cfg.dtype))
    tokens = batch["tokens"]
    if cfg.n_codebooks:
        # (B, S, CB): sum of per-codebook embeddings
        embs = [jnp.take(params["embed"][cb], tokens[..., cb], axis=0)
                for cb in range(cfg.n_codebooks)]
        return functools.reduce(jnp.add, embs)
    return jnp.take(params["embed"], tokens, axis=0)


def _attn_block(lp: Dict, x: jax.Array, cfg: ModelConfig, linear,
                positions: jax.Array, *, cache_kv=None, cache_pos=None,
                paged=None):
    """Pre-norm attention. If cache_kv=(k,v) is given, runs the decode path
    (append at cache_pos, attend over kv_len=cache_pos+1). A scalar
    cache_pos is the lockstep batch; a (B,) cache_pos is the per-slot path
    (continuous batching): each row writes its token at its own position
    and attends its own ragged kv_len. `paged=(block_table, attn_fn)` makes
    cache_kv a PAGE POOL pair ((P, ps, K, hd) per layer): each row's token
    is scattered into the page its block-table row maps the position to,
    and `attn_fn` (the registry-resolved paged_attention backend) gathers
    K/V through the block table."""
    B = x.shape[0]
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q = linear(lp, "wq", h).reshape(B, -1, cfg.n_heads, cfg.head_dim)
    k = linear(lp, "wk", h).reshape(B, -1, cfg.n_kv, cfg.head_dim)
    v = linear(lp, "wv", h).reshape(B, -1, cfg.n_kv, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"], cfg.norm_eps)
        k = rms_norm(k, lp["k_norm"], cfg.norm_eps)
    if cfg.rope_theta:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope)
    if cache_kv is None:
        att = attn_mod.attention(q, k, v, causal=True)
        new_kv = (k, v)        # post-RoPE, as stored by the decode path
    elif paged is not None:
        bt, attn_fn = paged
        pk, pv = cache_kv
        ps = pk.shape[1]
        # clamp keeps retired slots in-bounds (their block-table rows point
        # at the slot's reserved scratch page — dirt, never readable); write
        # targets are unique: each slot's current write page is uniquely
        # owned (decode positions lie beyond any shared prefix) and scratch
        # pages are per-slot
        idx = jnp.minimum(cache_pos, bt.shape[1] * ps - 1)
        page = jnp.take_along_axis(bt, (idx // ps)[:, None], axis=1)[:, 0]
        off = idx % ps
        pk = pk.at[page, off].set(k[:, 0].astype(pk.dtype),
                                  unique_indices=True,
                                  mode="promise_in_bounds")
        pv = pv.at[page, off].set(v[:, 0].astype(pv.dtype),
                                  unique_indices=True,
                                  mode="promise_in_bounds")
        att = attn_fn(q, pk, pv, bt, cache_pos + 1)
        new_kv = (pk, pv)
    else:
        ck, cv = cache_kv
        if jnp.ndim(cache_pos) == 0:
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cache_pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cache_pos, 0, 0))
        else:
            # per-slot scatter: row i writes at its own position. Clamp keeps
            # retired slots in-bounds — their rows are dead (kv_len masks
            # them; the next prime overwrites them). rows is an iota, so the
            # scatter hints (sorted/unique/in-bounds) apply and XLA lowers
            # this close to the lockstep dynamic_update_slice.
            idx = jnp.minimum(cache_pos, ck.shape[1] - 1)
            rows = jnp.arange(B)
            ck = ck.at[rows, idx].set(k[:, 0].astype(ck.dtype),
                                      indices_are_sorted=True,
                                      unique_indices=True,
                                      mode="promise_in_bounds")
            cv = cv.at[rows, idx].set(v[:, 0].astype(cv.dtype),
                                      indices_are_sorted=True,
                                      unique_indices=True,
                                      mode="promise_in_bounds")
        att = attn_mod.direct_attention(q, ck, cv, causal=False,
                                        kv_len=cache_pos + 1)
        new_kv = (ck, cv)
    out = linear(lp, "wo", att.reshape(B, -1, cfg.attn_dim))
    return x + out, new_kv


def _mlp_block(lp: Dict, x: jax.Array, cfg: ModelConfig, linear,
               constrain=None):
    h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    if cfg.moe is not None:
        y, aux = moe_mod.moe_ffn(h, lp, cfg.moe, gated=cfg.gated_mlp,
                                 constrain=constrain)
        return x + y, aux
    hi = linear(lp, "wi", h)
    if cfg.gated_mlp:
        hg = linear(lp, "wg", h)
        hi = jax.nn.silu(hg.astype(jnp.float32)).astype(hi.dtype) * hi
    else:
        hi = jax.nn.gelu(hi.astype(jnp.float32)).astype(hi.dtype)
    return x + linear(lp, "wo_mlp", hi), jnp.float32(0.0)


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # "full": save nothing


def forward(params: Dict, adapters: Dict, batch: Dict, cfg: ModelConfig,
            peft: PEFTConfig, sites, *, remat: str = "none",
            constrain=None, bank=None,
            bank_profiles=None) -> Tuple[jax.Array, jax.Array]:
    """Train/prefill forward. Returns (logits, moe_aux_loss)."""
    x = _embed(params, cfg, batch)
    B, S = x.shape[0], x.shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    eff_layers, apps = apply_peft_to_layers(
        params["layers"], adapters, sites, peft, constrain=constrain,
        bank=bank, bank_profiles=bank_profiles,
        bank_slots=batch.get("adapter_slots"))
    linear = make_linear(apps, constrain)
    act = (lambda t: constrain("act/hidden", t)) if constrain else (lambda t: t)
    x = act(x)

    def body(carry, lp):
        x, aux = carry
        x = act(x)
        x, _ = _attn_block(lp, x, cfg, linear, positions)
        x, aux_l = _mlp_block(lp, x, cfg, linear, constrain)
        return (act(x), aux + aux_l), None

    (x, moe_aux), _ = jax.lax.scan(_remat(body, remat), (x, jnp.float32(0.0)),
                                   eff_layers)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.n_codebooks:
        logits = jnp.einsum("bsd,cdv->bscv", x, params["lm_head"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return logits, moe_aux / cfg.num_layers


def loss_fn(params: Dict, adapters: Dict, batch: Dict, cfg: ModelConfig,
            peft: PEFTConfig, sites, *, remat: str = "none",
            constrain=None) -> jax.Array:
    logits, moe_aux = forward(params, adapters, batch, cfg, peft, sites,
                              remat=remat, constrain=constrain)
    ce = cross_entropy(logits, batch["labels"])
    if cfg.moe is not None:
        ce = ce + cfg.moe.aux_loss_weight * moe_aux
    return ce


# ---------------------------------------------------------------------------
# Prefill: one causal forward over the whole prompt that also populates the
# KV cache — replaces token-by-token teacher-forced stepping in the serving
# engine (S sequential decode dispatches -> one call, and attention runs
# parallel over S instead of S times over a masked cache).
# ---------------------------------------------------------------------------

def prefill(params: Dict, adapters: Dict, cache: Dict, batch: Dict,
            cfg: ModelConfig, peft: PEFTConfig, sites,
            constrain=None, bank=None,
            bank_profiles=None) -> Tuple[jax.Array, Dict]:
    """Process a (B, S) prompt against a fresh cache (pos must be 0).
    Returns (next_tokens after the last prompt token, cache at pos=S).

    batch["true_len"] (B,), optional: per-row real prompt length for
    right-padded prompts — next_tokens are read at position true_len-1
    instead of S-1, which makes a padded prefill EXACT for the valid rows
    (causality keeps positions < true_len independent of the pad tail; the
    pad tail's KV rows must then be masked by the caller via per-slot
    kv_len, see the continuous scheduler's prime path)."""
    x = _embed(params, cfg, batch)
    B, S = x.shape[0], x.shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    eff_layers, apps = apply_peft_to_layers(
        params["layers"], adapters, sites, peft, constrain=constrain,
        bank=bank, bank_profiles=bank_profiles,
        bank_slots=batch.get("adapter_slots"))
    linear = make_linear(apps, constrain)

    # cache lives in the scan carry and is written in place per layer —
    # threading K/V through scan ys would materialize a second (L,B,S,K,hd)
    # stack next to the cache (see decode_step's carry note: ~3x-cache peak)
    def body(carry, lp_i):
        x, ck_all, cv_all = carry
        lp, li = lp_i
        x, (k, v) = _attn_block(lp, x, cfg, linear, positions)
        ck_all = jax.lax.dynamic_update_slice(
            ck_all, k.astype(ck_all.dtype)[None], (li, 0, 0, 0, 0))
        cv_all = jax.lax.dynamic_update_slice(
            cv_all, v.astype(cv_all.dtype)[None], (li, 0, 0, 0, 0))
        x, _ = _mlp_block(lp, x, cfg, linear, constrain)
        return (x, ck_all, cv_all), None

    (x, ck, cv), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"]),
        (eff_layers, jnp.arange(cfg.num_layers, dtype=jnp.int32)))
    true_len = batch.get("true_len")
    if true_len is None:
        x = x[:, -1:]
    else:
        x = x[jnp.arange(B), true_len - 1][:, None]
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.n_codebooks:
        logits = jnp.einsum("bsd,cdv->bscv", x, params["lm_head"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    return next_tokens, {"k": ck, "v": cv, "pos": cache["pos"] + S}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, per_slot: bool = False) -> Dict:
    """per_slot=True allocates a (B,) position vector instead of the scalar
    — the persistent continuous-batching cache where every slot advances
    independently (decode_step picks the per-slot path off pos's rank)."""
    L = cfg.num_layers
    return {
        "k": jnp.zeros((L, batch, max_len, cfg.n_kv, cfg.head_dim), dtype),
        "v": jnp.zeros((L, batch, max_len, cfg.n_kv, cfg.head_dim), dtype),
        "pos": jnp.zeros((batch,) if per_slot else (), jnp.int32),
    }


def write_slot_cache(cache: Dict, slot_cache: Dict, slot, length) -> Dict:
    """In-flight prefill splice: write one primed request's KV (a batch-1
    scratch cache, P <= max_len rows) into slot row `slot` of a live
    per-slot cache and set that slot's position to `length`. Every other
    slot's rows and position are untouched, so the rest of the batch keeps
    decoding across the insertion; `slot`/`length` are traced scalars, so
    one compiled splice per scratch length serves every slot."""
    if cache["pos"].ndim != 1:
        raise ValueError("write_slot_cache needs a per_slot=True cache")
    k = jax.lax.dynamic_update_slice(
        cache["k"], slot_cache["k"].astype(cache["k"].dtype),
        (0, slot, 0, 0, 0))
    v = jax.lax.dynamic_update_slice(
        cache["v"], slot_cache["v"].astype(cache["v"].dtype),
        (0, slot, 0, 0, 0))
    pos = cache["pos"].at[slot].set(jnp.asarray(length, jnp.int32))
    return {"k": k, "v": v, "pos": pos}


def reset_slots(cache: Dict, mask) -> Dict:
    """Retire slots: masked slots' positions return to 0 (their KV rows are
    left as-is — dead until the next write_slot_cache overwrites them, and
    unreadable meanwhile because kv_len masking never reaches them)."""
    if cache["pos"].ndim != 1:
        raise ValueError("reset_slots needs a per_slot=True cache")
    return {**cache, "pos": jnp.where(mask, 0, cache["pos"])}


# ---------------------------------------------------------------------------
# Paged KV cache (DESIGN.md §Paging): the per-slot decode path over a global
# pool of fixed-size pages instead of a dense (B, max_len) row per slot.
# Block tables and page lifecycle live host-side (serve/paging.py); this
# module owns the device math — pool init, COW page clone, the block-table
# decode path above, and the shared-prefix tail prefill.
# ---------------------------------------------------------------------------

def init_paged_cache(cfg: ModelConfig, batch: int, n_pages: int,
                     page_size: int, dtype=jnp.bfloat16) -> Dict:
    """Page-pool cache: K/V live in (L, n_pages, page_size, K, hd) pools
    shared by every slot; `pos` stays the per-slot (B,) position vector.
    Slots map logical positions onto pages via the `block_table` the
    runtime passes per decode/prefill call — the pool itself is
    slot-agnostic."""
    L = cfg.num_layers
    shape = (L, n_pages, page_size, cfg.n_kv, cfg.head_dim)
    return {
        "pk": jnp.zeros(shape, dtype),
        "pv": jnp.zeros(shape, dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def copy_page(cache: Dict, src, dst) -> Dict:
    """Copy-on-write clone: duplicate physical page `src` into `dst` across
    every layer of both pools (pos untouched). The shared original is never
    written again — the borrower's tail prefill / decode writes land in the
    clone (DESIGN.md §Paging, COW rules)."""
    out = dict(cache)
    for key in ("pk", "pv"):
        pool = cache[key]
        page = jax.lax.dynamic_slice_in_dim(pool, src, 1, axis=1)
        out[key] = jax.lax.dynamic_update_slice_in_dim(pool, page, dst,
                                                       axis=1)
    return out


def prefill_paged(params: Dict, adapters: Dict, cache: Dict, batch: Dict,
                  cfg: ModelConfig, peft: PEFTConfig, sites,
                  constrain=None, bank=None,
                  bank_profiles=None) -> Tuple[jax.Array, Dict]:
    """Shared-prefix tail prefill into the page pool: run ONLY the unshared
    tail of a prompt whose first `prefix_len` tokens are already resident
    in pages (reused via the prefix cache), writing the tail's KV through
    the block table. With prefix_len == 0 this is a full paged prefill —
    bit-identical (fp32) to the dense prefill + splice path.

    batch:
      tokens       (1, T)   right-padded tail tokens
      true_len     (1,)     optional real tail length (absent => T)
      block_table  (1, PPS) the slot's page map: shared prefix pages first,
                            then the slot's owned pages, scratch elsewhere
      window_table (1, WP)  leading slice of block_table covering the
                            resident prefix (WP pow2-bucketed by the
                            caller: the attention window costs
                            O(tail * WP*ps), not O(tail * max_len)).
                            ABSENT on a cold (no-reuse) prime — that is a
                            statically distinct graph which skips the page
                            window entirely (plain causal attention), so
                            0%-shared traffic pays no window-gather tax
      prefix_len   ()       reused prefix tokens already resident in pages
                            (present iff window_table is)
      slot         ()       slot row whose pos becomes prefix_len + true_len
      scratch_page ()       pad/overflow KV rows are routed to this page
                            (the slot's reserved scratch — dirt that decode
                            overwrites before it can ever be read)

    Returns (next_tokens (1,), cache) like `prefill`."""
    x = _embed(params, cfg, batch)
    B, T = x.shape[0], x.shape[1]
    wt = batch.get("window_table")
    with_window = wt is not None
    prefix_len = (jnp.asarray(batch["prefix_len"], jnp.int32) if with_window
                  else jnp.int32(0))
    positions = prefix_len + jnp.broadcast_to(
        jnp.arange(T, dtype=jnp.int32), (B, T))
    eff_layers, apps = apply_peft_to_layers(
        params["layers"], adapters, sites, peft, constrain=constrain,
        bank=bank, bank_profiles=bank_profiles,
        bank_slots=batch.get("adapter_slots"))
    linear = make_linear(apps, constrain)
    bt = batch["block_table"]                        # (1, PPS)
    ps = cache["pk"].shape[2]
    cap = bt.shape[1] * ps
    true_len = batch.get("true_len")
    tlen = (true_len[0] if true_len is not None
            else jnp.asarray(T, jnp.int32))
    # scatter targets: tail row j holds logical position prefix_len + j;
    # pad rows (j >= tlen) and overflow land in the slot's scratch page —
    # shared prefix pages are never written (tail positions start past
    # them), and decode overwrites any dirt before it becomes readable
    j = jnp.arange(T)
    logical = prefix_len + j
    valid = (j < tlen) & (logical < cap)
    safe = jnp.where(valid, logical, 0)
    w_page = jnp.where(valid, bt[0, safe // ps],
                       jnp.asarray(batch["scratch_page"], jnp.int32))
    w_off = jnp.where(valid, safe % ps, j % ps)

    def body(carry, lp_i):
        x, pk_all, pv_all = carry
        lp, li = lp_i
        h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        q = linear(lp, "wq", h).reshape(B, -1, cfg.n_heads, cfg.head_dim)
        k = linear(lp, "wk", h).reshape(B, -1, cfg.n_kv, cfg.head_dim)
        v = linear(lp, "wv", h).reshape(B, -1, cfg.n_kv, cfg.head_dim)
        if cfg.qk_norm:
            q = rms_norm(q, lp["q_norm"], cfg.norm_eps)
            k = rms_norm(k, lp["k_norm"], cfg.norm_eps)
        if cfg.rope_theta:
            q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope)
            k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope)
        pk = jax.lax.dynamic_index_in_dim(pk_all, li, 0, keepdims=False)
        pv = jax.lax.dynamic_index_in_dim(pv_all, li, 0, keepdims=False)
        if with_window:
            # resident-prefix window, gathered through the window table
            # BEFORE the tail writes (the window only reads columns
            # < prefix_len, which the tail never touches)
            win = wt.shape[1] * ps
            kw = jnp.take(pk, wt[0], axis=0).reshape(1, win, cfg.n_kv,
                                                     cfg.head_dim)
            vw = jnp.take(pv, wt[0], axis=0).reshape(1, win, cfg.n_kv,
                                                     cfg.head_dim)
            att = attn_mod.prefix_attention(q, k, v, kw, vw, prefix_len)
        else:
            att = attn_mod.attention(q, k, v, causal=True)
        x = x + linear(lp, "wo", att.reshape(B, -1, cfg.attn_dim))
        # page-granular splice of the tail's KV (no unique/sorted claims:
        # pad rows may collide inside the scratch page — dirt either way)
        pk = pk.at[w_page, w_off].set(k[0].astype(pk.dtype),
                                      mode="promise_in_bounds")
        pv = pv.at[w_page, w_off].set(v[0].astype(pv.dtype),
                                      mode="promise_in_bounds")
        pk_all = jax.lax.dynamic_update_index_in_dim(pk_all, pk, li, 0)
        pv_all = jax.lax.dynamic_update_index_in_dim(pv_all, pv, li, 0)
        x, _ = _mlp_block(lp, x, cfg, linear, constrain)
        return (x, pk_all, pv_all), None

    (x, pk, pv), _ = jax.lax.scan(
        body, (x, cache["pk"], cache["pv"]),
        (eff_layers, jnp.arange(cfg.num_layers, dtype=jnp.int32)))
    x = x[jnp.arange(B), jnp.broadcast_to(tlen, (B,)) - 1][:, None]
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    pos = cache["pos"].at[jnp.asarray(batch["slot"], jnp.int32)].set(
        prefix_len + tlen)
    return next_tokens, {"pk": pk, "pv": pv, "pos": pos}


def decode_step(params: Dict, adapters: Dict, cache: Dict, batch: Dict,
                cfg: ModelConfig, peft: PEFTConfig, sites,
                constrain=None, bank=None,
                bank_profiles=None) -> Tuple[jax.Array, Dict]:
    """One token for every sequence in the batch. batch: tokens (B, 1) (or
    embeds (B,1,d), positions (3,B,1) for vlm). Returns (next_tokens, cache).

    A paged cache (init_paged_cache: "pk"/"pv" page pools) rides the same
    per-slot path with batch["block_table"] (B, pages_per_seq) mapping each
    slot's logical positions onto pool pages; the attention backend is the
    registry-resolved `paged_attention` op (DESIGN.md §Paging)."""
    x = _embed(params, cfg, batch)
    B = x.shape[0]
    pos = cache["pos"]
    positions = batch.get("positions")
    if positions is None:
        if pos.ndim == 0:
            positions = jnp.broadcast_to(pos.astype(jnp.int32), (B, 1))
        else:                       # per-slot cache: row i sits at pos[i]
            positions = pos.astype(jnp.int32)[:, None]
    eff_layers, apps = apply_peft_to_layers(
        params["layers"], adapters, sites, peft, constrain=constrain,
        bank=bank, bank_profiles=bank_profiles,
        bank_slots=batch.get("adapter_slots"))
    linear = make_linear(apps, constrain)
    paged = None
    if "pk" in cache:
        from repro.kernels import paged_attention as paged_mod
        op = kernel_api.resolve_op(
            "paged_attention", paged_mod.OWNER, peft,
            d1=cache["pk"].shape[2], d2=cfg.head_dim)
        paged = (batch["block_table"], op.fn)
    kk, vk = ("pk", "pv") if paged is not None else ("k", "v")

    # cache lives in the scan CARRY and is updated in place per layer —
    # xs/ys threading would materialize two extra cache-sized buffers
    # (measured: decode peak ≈3× cache size, OOM on the 32k×128 cells)
    def body(carry, lp_i):
        x, ck_all, cv_all = carry
        lp, li = lp_i
        ck = jax.lax.dynamic_index_in_dim(ck_all, li, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(cv_all, li, 0, keepdims=False)
        x, (ck, cv) = _attn_block(lp, x, cfg, linear, positions,
                                  cache_kv=(ck, cv), cache_pos=pos,
                                  paged=paged)
        ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, ck, li, 0)
        cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, cv, li, 0)
        x, _ = _mlp_block(lp, x, cfg, linear, constrain)
        return (x, ck_all, cv_all), None

    (x, ck, cv), _ = jax.lax.scan(
        body, (x, cache[kk], cache[vk]),
        (eff_layers, jnp.arange(cfg.num_layers, dtype=jnp.int32)))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.n_codebooks:
        logits = jnp.einsum("bsd,cdv->bscv", x, params["lm_head"])
        next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)  # (B, CB)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
        next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)  # (B,)
    new_cache = {kk: ck, vk: cv, "pos": pos + 1}
    return next_tokens, new_cache


def advance_pos(cache: Dict, delta) -> Dict:
    """Host-driven per-slot position update for speculative decoding
    (DESIGN.md §Speculation): after a verify step the scheduler knows how
    many window tokens each slot accepted and advances `pos` by that delta
    (0 for retired slots); the drafter rolls its k probe steps back with a
    scalar -k. Clamped at 0 so retired slots (pos == 0) can never go
    negative and poison the scatter indices of the next step."""
    pos = cache["pos"] + jnp.asarray(delta, jnp.int32)
    return {**cache, "pos": jnp.maximum(pos, 0)}


def verify_step(params: Dict, adapters: Dict, cache: Dict, batch: Dict,
                cfg: ModelConfig, peft: PEFTConfig, sites,
                constrain=None, bank=None,
                bank_profiles=None) -> Tuple[jax.Array, Dict]:
    """Draft verification: one batched forward over a short window of W
    consecutive tokens per slot (DESIGN.md §Speculation). batch["tokens"]
    (B, W) holds [last accepted token, draft_1 .. draft_{W-1}] per row;
    row j sits at cache position pos + j. Returns (tokens (B, W), cache)
    where tokens[:, j] is the greedy continuation after consuming window
    token j — the scheduler accepts tokens[:, j] while draft_{j} ==
    tokens[:, j-1] and then calls `advance_pos` with the per-slot count.

    KV for ALL W window positions is written before attention (per layer),
    so the windowed paged_attention mask (col < kv_len + j) gives each
    query exactly the rows a step-by-step decode would see — greedy
    verification is bit-identical (fp32) to W sequential `decode_step`
    calls on the same drafts. `pos` is NOT advanced in-graph: acceptance is
    a host decision, and rejected rows simply stay past kv_len as dirt that
    the next window overwrites (rollback is bookkeeping, not data movement).

    Write routing (paged): position pos + j maps through the block table;
    entries past the slot's owned region default to its reserved scratch
    page, and absolute overflow (>= PPS*ps) is routed there explicitly via
    batch["scratch_pages"] (B,) — never clamped, so a deep slot's real rows
    can't be collided with. Dense caches scatter with mode="drop"."""
    x = _embed(params, cfg, batch)
    B, W = x.shape[0], x.shape[1]
    pos = cache["pos"]                              # (B,) per-slot
    positions = (pos.astype(jnp.int32)[:, None]
                 + jnp.arange(W, dtype=jnp.int32)[None, :])   # (B, W)
    eff_layers, apps = apply_peft_to_layers(
        params["layers"], adapters, sites, peft, constrain=constrain,
        bank=bank, bank_profiles=bank_profiles,
        bank_slots=batch.get("adapter_slots"))
    linear = make_linear(apps, constrain)
    paged = "pk" in cache
    kv_len = pos + 1                                # row-0 validity
    if paged:
        from repro.kernels import paged_attention as paged_mod
        op = kernel_api.resolve_op(
            "paged_attention", paged_mod.OWNER, peft,
            d1=cache["pk"].shape[2], d2=cfg.head_dim)
        bt = batch["block_table"]                   # (B, PPS)
        ps = cache["pk"].shape[2]
        cap = bt.shape[1] * ps
        scratch = batch.get("scratch_pages")
        if scratch is None:
            scratch = jnp.arange(B, dtype=jnp.int32)
        else:
            scratch = jnp.asarray(scratch, jnp.int32)
        valid = positions < cap
        safe = jnp.where(valid, positions, 0)
        w_page = jnp.where(valid, jnp.take_along_axis(bt, safe // ps, axis=1),
                           scratch[:, None])        # (B, W)
        w_off = jnp.where(valid, safe % ps,
                          jnp.arange(W, dtype=jnp.int32)[None, :] % ps)
    else:
        rows = jnp.arange(B)[:, None]               # (B, 1)
    kk, vk = ("pk", "pv") if paged else ("k", "v")

    def body(carry, lp_i):
        x, ck_all, cv_all = carry
        lp, li = lp_i
        h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        q = linear(lp, "wq", h).reshape(B, W, cfg.n_heads, cfg.head_dim)
        k = linear(lp, "wk", h).reshape(B, W, cfg.n_kv, cfg.head_dim)
        v = linear(lp, "wv", h).reshape(B, W, cfg.n_kv, cfg.head_dim)
        if cfg.qk_norm:
            q = rms_norm(q, lp["q_norm"], cfg.norm_eps)
            k = rms_norm(k, lp["k_norm"], cfg.norm_eps)
        if cfg.rope_theta:
            q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope)
            k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope)
        ck = jax.lax.dynamic_index_in_dim(ck_all, li, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(cv_all, li, 0, keepdims=False)
        if paged:
            # write-then-attend: all W rows land before the windowed mask
            # reads them (no unique claims: overflow rows may collide inside
            # the per-slot scratch page — dirt either way)
            ck = ck.at[w_page, w_off].set(k.astype(ck.dtype),
                                          mode="promise_in_bounds")
            cv = cv.at[w_page, w_off].set(v.astype(cv.dtype),
                                          mode="promise_in_bounds")
            att = op.fn(q, ck, cv, bt, kv_len)
        else:
            ck = ck.at[rows, positions].set(k.astype(ck.dtype), mode="drop")
            cv = cv.at[rows, positions].set(v.astype(cv.dtype), mode="drop")
            att = attn_mod.windowed_decode_attention(q, ck, cv, kv_len)
        x = x + linear(lp, "wo", att.reshape(B, W, cfg.attn_dim))
        ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, ck, li, 0)
        cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, cv, li, 0)
        x, _ = _mlp_block(lp, x, cfg, linear, constrain)
        return (x, ck_all, cv_all), None

    (x, ck, cv), _ = jax.lax.scan(
        body, (x, cache[kk], cache[vk]),
        (eff_layers, jnp.arange(cfg.num_layers, dtype=jnp.int32)))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (B, W)
    return tokens, {kk: ck, vk: cv, "pos": pos}
