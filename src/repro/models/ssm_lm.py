"""Pure-SSM LM (mamba2-2.7b): embedding -> scan of mamba2 blocks -> head.

FourierFT targets the in/out projections (wx / wo_ssm) — the architecture is
attention-free, so the paper's default q/v set is inapplicable; see DESIGN.md
§Arch-applicability.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, PEFTConfig
from repro.models import mamba2
from repro.models.common import cross_entropy, dense_init, rms_norm
from repro.models.transformer import (
    apply_peft_to_layers, make_linear, _remat,
)


def init_params(rng: jax.Array, cfg: ModelConfig) -> Dict:
    dtype = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "embed": dense_init(k1, (cfg.vocab, cfg.d_model), dtype),
        "layers": mamba2.init_mamba_params(k2, cfg, cfg.num_layers, dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": dense_init(k3, (cfg.d_model, cfg.vocab), dtype),
    }


def forward(params: Dict, adapters: Dict, batch: Dict, cfg: ModelConfig,
            peft: PEFTConfig, sites, *, remat: str = "none", constrain=None,
            bank=None, bank_profiles=None):
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    eff_layers, apps = apply_peft_to_layers(
        params["layers"], adapters, sites, peft, constrain=constrain,
        bank=bank, bank_profiles=bank_profiles,
        bank_slots=batch.get("adapter_slots"))
    linear = make_linear(apps, constrain)
    act = (lambda t: constrain("act/hidden", t)) if constrain else (lambda t: t)
    x = act(x)

    def body(x, lp):
        return act(mamba2.mamba_block(lp, act(x), cfg, linear_fn=linear)), None

    x, _ = jax.lax.scan(_remat(body, remat), x, eff_layers)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return logits, jnp.float32(0.0)


def loss_fn(params, adapters, batch, cfg, peft, sites, *, remat="none",
            constrain=None):
    logits, _ = forward(params, adapters, batch, cfg, peft, sites,
                        remat=remat, constrain=constrain)
    return cross_entropy(logits, batch["labels"])


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict:
    c = mamba2.init_mamba_cache(cfg, cfg.num_layers, batch, dtype)
    c["pos"] = jnp.zeros((), jnp.int32)
    return c


def decode_step(params: Dict, adapters: Dict, cache: Dict, batch: Dict,
                cfg: ModelConfig, peft: PEFTConfig, sites, constrain=None,
                bank=None, bank_profiles=None):
    x = jnp.take(params["embed"], batch["tokens"], axis=0)    # (B, 1, d)
    eff_layers, apps = apply_peft_to_layers(
        params["layers"], adapters, sites, peft, constrain=constrain,
        bank=bank, bank_profiles=bank_profiles,
        bank_slots=batch.get("adapter_slots"))
    linear = make_linear(apps, constrain)

    # caches in the scan carry (in-place per-layer update; see transformer.py)
    def body(carry, lp_i):
        x, conv_all, ssm_all = carry
        lp, li = lp_i
        c = {"conv": jax.lax.dynamic_index_in_dim(conv_all, li, 0, False),
             "ssm": jax.lax.dynamic_index_in_dim(ssm_all, li, 0, False)}
        x, new_c = mamba2.mamba_decode_step(lp, c, x, cfg, linear_fn=linear)
        conv_all = jax.lax.dynamic_update_index_in_dim(conv_all, new_c["conv"], li, 0)
        ssm_all = jax.lax.dynamic_update_index_in_dim(ssm_all, new_c["ssm"], li, 0)
        return (x, conv_all, ssm_all), None

    (x, conv_c, ssm_c), _ = jax.lax.scan(
        body, (x, cache["conv"], cache["ssm"]),
        (eff_layers, jnp.arange(cfg.num_layers, dtype=jnp.int32)))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    return next_tokens, {"conv": conv_c, "ssm": ssm_c, "pos": cache["pos"] + 1}
