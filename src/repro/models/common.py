"""Shared model building blocks: norms, RoPE (incl. M-RoPE), inits."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def head_rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head qk-norm (qwen3/olmoe): normalizes the trailing head_dim."""
    return rms_norm(x, w, eps)


def dense_init(rng: jax.Array, shape, dtype, scale: float = 0.02) -> jax.Array:
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def mrope_sections(head_dim: int) -> Tuple[int, int, int]:
    """Qwen2-VL section split of the rotary half-dim among (t, h, w) position
    streams — (16, 24, 24) for head_dim 128."""
    half = head_dim // 2
    hw = (3 * half) // 8
    return (half - 2 * hw, hw, hw)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               mrope: bool = False) -> jax.Array:
    """x: (B, S, N, head_dim). positions: (B, S) int32, or (3, B, S) for M-RoPE."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    inv = rope_freqs(head_dim, theta)                      # (half,)
    if mrope:
        sec = mrope_sections(head_dim)
        pos = positions.astype(jnp.float32)                 # (3, B, S)
        idx = jnp.concatenate([
            jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sec)])
        pos_per_dim = jnp.take(pos, idx, axis=0)            # (half, B, S)
        angles = jnp.einsum("hbs,h->bsh", pos_per_dim, inv)  # (B, S, half)
    else:
        angles = positions.astype(jnp.float32)[..., None] * inv  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]                    # (B, S, 1, half)
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  z_loss: float = 0.0) -> jax.Array:
    """Mean token CE in f32. logits (..., V), labels (...) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return jnp.mean(loss)
