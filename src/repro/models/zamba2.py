"""Zamba2-style hybrid: mamba2 blocks with ONE shared transformer block
(attention + MLP) applied before every group of `shared_every` mamba blocks.

Wiring is a nested scan — outer scan over groups (shared block + inner scan
over the group's mamba layers) — so the HLO contains exactly one shared-block
body and one mamba body regardless of depth, with no lax.cond branches
(compile-size- and cost-analysis-exact). A trailing partial group handles
L % shared_every != 0 (zamba2-7b: 81 = 13·6 + 3 ⇒ 14 shared applications).

Beyond-paper (in Zamba2's own spirit): each application owns a FourierFT
coefficient row on the shared q/v projections — the real model specializes
shared blocks with per-application LoRA; we use the paper's technique
(LoRA available via peft.method="lora"). Shared-site adapters are always
factored (materializing W+ΔW per application would defeat weight sharing).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, PEFTConfig
from repro.core import adapter as adapter_api
from repro.models import attention as attn_mod
from repro.models import mamba2
from repro.models.common import apply_rope, cross_entropy, dense_init, rms_norm
from repro.models.transformer import (
    SiteApp, _app_tag, apply_peft_to_layers, make_linear, _remat,
)


def _split(cfg: ModelConfig) -> Tuple[int, int]:
    every = cfg.zamba.shared_every
    return cfg.num_layers // every, cfg.num_layers % every


def n_apps(cfg: ModelConfig) -> int:
    n_full, tail = _split(cfg)
    return n_full + (1 if tail else 0)


def init_params(rng: jax.Array, cfg: ModelConfig) -> Dict:
    dtype = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    ks = iter(jax.random.split(rng, 12))
    shared = {
        "attn_norm": jnp.ones((d,), dtype),
        "wq": dense_init(next(ks), (d, cfg.attn_dim), dtype),
        "wk": dense_init(next(ks), (d, cfg.kv_dim), dtype),
        "wv": dense_init(next(ks), (d, cfg.kv_dim), dtype),
        "wo": dense_init(next(ks), (cfg.attn_dim, d), dtype),
        "mlp_norm": jnp.ones((d,), dtype),
        "wi": dense_init(next(ks), (d, cfg.d_ff), dtype),
        "wg": dense_init(next(ks), (d, cfg.d_ff), dtype),
        "wo_mlp": dense_init(next(ks), (cfg.d_ff, d), dtype),
    }
    return {
        "embed": dense_init(next(ks), (cfg.vocab, d), dtype),
        "layers": mamba2.init_mamba_params(next(ks), cfg, cfg.num_layers, dtype),
        "shared": shared,
        "final_norm": jnp.ones((d,), dtype),
        "lm_head": dense_init(next(ks), (d, cfg.vocab), dtype),
    }


def _shared_adapter_rows(adapters: Dict, peft: PEFTConfig):
    """-> ({tagged key: stacked rows (napps, ...)}, make_linear apps).

    Shared-site adapters stay factored regardless of method (materializing
    W+ΔW per application would defeat weight sharing) — the trainable leaves
    ride the per-application row dict, frozen aux rides the SiteApp."""
    method = adapter_api.resolve(peft.method)
    tag = _app_tag("ad", method.name)
    trainable = set(method.trainable_leaves(peft))
    rows: Dict[str, jax.Array] = {}
    apps: Dict[str, list] = {}
    for full_name, ad in adapters.items():
        if not full_name.startswith("shared/"):
            continue
        key = full_name.split("/")[-1]
        aux = {}
        for leaf, v in ad.items():
            if leaf in trainable:
                rows[key + tag + leaf] = v
            else:
                aux[leaf] = v
        apps.setdefault(key, []).append(SiteApp(tag, method, aux, peft))
    return rows, apps


def _shared_block(x, shared_params, ad_row, apps, cfg, peft, positions,
                  cache_kv=None, cache_pos=None):
    lp = dict(shared_params)
    lp.update(ad_row)
    linear = make_linear(apps)
    B = x.shape[0]
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q = linear(lp, "wq", h).reshape(B, -1, cfg.n_heads, cfg.head_dim)
    k = linear(lp, "wk", h).reshape(B, -1, cfg.n_kv, cfg.head_dim)
    v = linear(lp, "wv", h).reshape(B, -1, cfg.n_kv, cfg.head_dim)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if cache_kv is None:
        att = attn_mod.attention(q, k, v, causal=True)
        new_kv = None
    else:
        ck, cv = cache_kv                                  # (B, Smax, K, hd)
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, cache_pos, 0, 0))
        att = attn_mod.direct_attention(q, ck, cv, causal=False,
                                        kv_len=cache_pos + 1)
        new_kv = (ck, cv)
    x = x + linear(lp, "wo", att.reshape(B, -1, cfg.attn_dim))
    h2 = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    hi = linear(lp, "wi", h2)
    hg = linear(lp, "wg", h2)
    hi = jax.nn.silu(hg.astype(jnp.float32)).astype(hi.dtype) * hi
    x = x + linear(lp, "wo_mlp", hi)
    return x, new_kv


def _group_views(cfg: ModelConfig, tree):
    """Split stacked (L, ...) leaves into main (n_full, every, ...) and tail
    (tail_len, ...)."""
    n_full, tail_len = _split(cfg)
    every = cfg.zamba.shared_every
    main = jax.tree.map(
        lambda a: a[:n_full * every].reshape((n_full, every) + a.shape[1:]),
        tree)
    tail = jax.tree.map(lambda a: a[n_full * every:], tree) if tail_len else None
    return main, tail


def _row_views(cfg: ModelConfig, rows: Dict):
    n_full, tail_len = _split(cfg)
    main = {k: v[:n_full] for k, v in rows.items()}
    tail = {k: v[n_full] for k, v in rows.items()} if tail_len else None
    return main, tail


def forward(params: Dict, adapters: Dict, batch: Dict, cfg: ModelConfig,
            peft: PEFTConfig, sites, *, remat: str = "none", constrain=None,
            bank=None, bank_profiles=None):
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    mamba_adapters = {k: v for k, v in adapters.items()
                      if k.startswith("layers/")}
    eff_layers, apps = apply_peft_to_layers(
        params["layers"], mamba_adapters, sites, peft, constrain=constrain,
        bank=bank, bank_profiles=bank_profiles,
        bank_slots=batch.get("adapter_slots"))
    linear = make_linear(apps, constrain)
    act = (lambda t: constrain("act/hidden", t)) if constrain else (lambda t: t)
    rows, shared_apps = _shared_adapter_rows(adapters, peft)
    main_layers, tail_layers = _group_views(cfg, eff_layers)
    main_rows, tail_rows = _row_views(cfg, rows)

    def mamba_body(x, lp):
        return act(mamba2.mamba_block(lp, act(x), cfg, linear_fn=linear)), None

    def group_body(x, xs):
        gl, ad_row = xs
        x, _ = _shared_block(act(x), params["shared"], ad_row, shared_apps, cfg,
                             peft, positions)
        x, _ = jax.lax.scan(mamba_body, x, gl)
        return act(x), None

    x, _ = jax.lax.scan(_remat(group_body, remat), x, (main_layers, main_rows))
    if tail_layers is not None:
        x, _ = _shared_block(x, params["shared"], tail_rows, shared_apps, cfg,
                             peft, positions)
        x, _ = jax.lax.scan(mamba_body, x, tail_layers)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return logits, jnp.float32(0.0)


def loss_fn(params, adapters, batch, cfg, peft, sites, *, remat="none",
            constrain=None):
    logits, _ = forward(params, adapters, batch, cfg, peft, sites,
                        remat=remat, constrain=constrain)
    return cross_entropy(logits, batch["labels"])


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict:
    c = mamba2.init_mamba_cache(cfg, cfg.num_layers, batch, dtype)
    A = n_apps(cfg)
    c["attn_k"] = jnp.zeros((A, batch, max_len, cfg.n_kv, cfg.head_dim), dtype)
    c["attn_v"] = jnp.zeros((A, batch, max_len, cfg.n_kv, cfg.head_dim), dtype)
    c["pos"] = jnp.zeros((), jnp.int32)
    return c


def decode_step(params: Dict, adapters: Dict, cache: Dict, batch: Dict,
                cfg: ModelConfig, peft: PEFTConfig, sites, constrain=None,
                bank=None, bank_profiles=None):
    x = jnp.take(params["embed"], batch["tokens"], axis=0)    # (B, 1, d)
    B = x.shape[0]
    pos = cache["pos"]
    positions = jnp.broadcast_to(pos.astype(jnp.int32), (B, 1))
    mamba_adapters = {k: v for k, v in adapters.items()
                      if k.startswith("layers/")}
    eff_layers, apps = apply_peft_to_layers(
        params["layers"], mamba_adapters, sites, peft, constrain=constrain,
        bank=bank, bank_profiles=bank_profiles,
        bank_slots=batch.get("adapter_slots"))
    linear = make_linear(apps, constrain)
    rows, shared_apps = _shared_adapter_rows(adapters, peft)
    n_full, tail_len = _split(cfg)

    every = cfg.zamba.shared_every
    main_layers, tail_layers = _group_views(cfg, eff_layers)
    main_rows, tail_rows = _row_views(cfg, rows)

    # every cache stays in the carry, updated in place (see transformer.py)
    def mamba_body(carry, lp_i):
        x, conv_all, ssm_all = carry
        lp, li = lp_i
        c = {"conv": jax.lax.dynamic_index_in_dim(conv_all, li, 0, False),
             "ssm": jax.lax.dynamic_index_in_dim(ssm_all, li, 0, False)}
        x, nc = mamba2.mamba_decode_step(lp, c, x, cfg, linear_fn=linear)
        conv_all = jax.lax.dynamic_update_index_in_dim(conv_all, nc["conv"], li, 0)
        ssm_all = jax.lax.dynamic_update_index_in_dim(ssm_all, nc["ssm"], li, 0)
        return (x, conv_all, ssm_all), None

    def group_body(carry, xs):
        x, conv_all, ssm_all, ck_all, cv_all = carry
        gl, ad_row, gi = xs
        ck = jax.lax.dynamic_index_in_dim(ck_all, gi, 0, False)
        cv = jax.lax.dynamic_index_in_dim(cv_all, gi, 0, False)
        x, (ck, cv) = _shared_block(x, params["shared"], ad_row, shared_apps,
                                    cfg, peft, positions, cache_kv=(ck, cv),
                                    cache_pos=pos)
        ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, ck, gi, 0)
        cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, cv, gi, 0)
        (x, conv_all, ssm_all), _ = jax.lax.scan(
            mamba_body, (x, conv_all, ssm_all),
            (gl, gi * every + jnp.arange(every, dtype=jnp.int32)))
        return (x, conv_all, ssm_all, ck_all, cv_all), None

    carry = (x, cache["conv"], cache["ssm"], cache["attn_k"], cache["attn_v"])
    carry, _ = jax.lax.scan(
        group_body, carry,
        (main_layers, main_rows, jnp.arange(n_full, dtype=jnp.int32)))
    x, new_conv, new_ssm, new_k, new_v = carry
    if tail_len:
        tk = jax.lax.dynamic_index_in_dim(new_k, n_full, 0, False)
        tv = jax.lax.dynamic_index_in_dim(new_v, n_full, 0, False)
        x, (tk, tv) = _shared_block(x, params["shared"], tail_rows, shared_apps,
                                    cfg, peft, positions, cache_kv=(tk, tv),
                                    cache_pos=pos)
        new_k = jax.lax.dynamic_update_index_in_dim(new_k, tk, n_full, 0)
        new_v = jax.lax.dynamic_update_index_in_dim(new_v, tv, n_full, 0)
        (x, new_conv, new_ssm), _ = jax.lax.scan(
            mamba_body, (x, new_conv, new_ssm),
            (tail_layers, n_full * every + jnp.arange(tail_len, dtype=jnp.int32)))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    new_cache = {"conv": new_conv, "ssm": new_ssm, "attn_k": new_k,
                 "attn_v": new_v, "pos": pos + 1}
    return next_tokens, new_cache
