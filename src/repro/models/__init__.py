from repro.models import attention, common, mamba2, moe, registry, ssm_lm, transformer, zamba2
from repro.models.registry import Model, adapter_sites, build, default_targets
