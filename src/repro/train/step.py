"""Train-step factory: PEFT-filtered gradients, microbatch accumulation,
anomaly-guarded updates.

Parameters are split into (trainable, frozen): gradients are taken w.r.t. the
trainable subtree only, so XLA dead-code-eliminates every frozen-weight
gradient GEMM — the structural memory/compute win of PEFT. The frozen subtree
is passed as a separate argument (not captured) so the dry-run can shard and
donate it explicitly.

Anomaly guard (fault tolerance): non-finite or exploding loss/grad-norm skips
the update (params/opt unchanged) and increments `anomalies` in the state —
on real fleets this absorbs bit-flip/overflow steps without killing the run.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.core.peft import trainable_adapter_tree
from repro.models.registry import Model
from repro.optim import adamw, schedules


def split_params(model: Model, params: Dict) -> Tuple[Dict, Dict]:
    """-> (trainable, frozen). frozen = {"base":..., "peft":... (frozen leaves)}.
    The trainable/frozen boundary inside each adapter dict comes from the
    method's `trainable_leaves` protocol (core/adapter.py)."""
    peft = model.peft
    if model.method.trains_base:
        trainable = {"base": params["base"]}
        frozen = {"base": {}, "peft": {}}
        return trainable, frozen
    trainable: Dict = {"peft": trainable_adapter_tree(params["peft"], peft)}
    frozen_adapters = {
        site: {k: v for k, v in d.items()
               if k not in trainable["peft"].get(site, {})}
        for site, d in params["peft"].items()
    }
    base = params["base"]
    if peft.train_head:
        base = dict(base)
        trainable["head"] = base.pop("lm_head")
    return trainable, {"base": base, "peft": frozen_adapters}


def join_params(model: Model, trainable: Dict, frozen: Dict) -> Dict:
    if model.method.trains_base:
        return {"base": trainable["base"], "peft": {}}
    base = frozen["base"]
    if "head" in trainable:
        base = dict(base)
        base["lm_head"] = trainable["head"]
    peft_tree = {
        site: {**frozen["peft"].get(site, {}),
               **trainable.get("peft", {}).get(site, {})}
        for site in set(frozen["peft"]) | set(trainable.get("peft", {}))
    }
    return {"base": base, "peft": peft_tree}


def init_state(model: Model, tcfg: TrainConfig, rng: jax.Array) -> Tuple[Dict, Dict]:
    """-> (state, frozen). state = {step, trainable, opt, loss_ema, anomalies}
    (+ ef_residual when int8 error-feedback grad compression is on)."""
    params = model.init(rng)
    trainable, frozen = split_params(model, params)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "trainable": trainable,
        "opt": adamw.init(trainable),
        "loss_ema": jnp.zeros((), jnp.float32),
        "anomalies": jnp.zeros((), jnp.int32),
    }
    if tcfg.grad_compression == "int8_ef":
        from repro.dist import compression
        state["ef_residual"] = compression.init_residual(trainable)
    return state, frozen


def _loss_for(model: Model):
    if model.method.trains_base:
        def loss_f(trainable, frozen, batch):
            return model.loss({"base": trainable["base"], "peft": {}}, batch)
    else:
        def loss_f(trainable, frozen, batch):
            return model.loss_from_parts(trainable, frozen["base"],
                                         frozen["peft"], batch)
    return loss_f


def make_train_step(model: Model, tcfg: TrainConfig) -> Callable:
    # ΔW materialization inside the step dispatches through the kernel
    # registry (merge_site -> site_delta -> KernelOp, DESIGN.md §Kernels);
    # fail fast here — before any tracing — if the model's build-time policy
    # left a (site, op) pair without a usable backend.
    model.kernel_policy.validate()
    loss_f = _loss_for(model)

    def grads_of(trainable, frozen, batch):
        if tcfg.microbatch and tcfg.microbatch > 0:
            k = tcfg.microbatch

            def resh(key, x):
                if key == "positions" and x.ndim == 3:   # (3, B, S) m-rope
                    return x.reshape((3, k, x.shape[1] // k)
                                     + x.shape[2:]).swapaxes(0, 1)
                return x.reshape((k, x.shape[0] // k) + x.shape[1:])

            mb = {kk: resh(kk, v) for kk, v in batch.items()}

            def acc(carry, mbatch):
                l, g = jax.value_and_grad(loss_f)(trainable, frozen, mbatch)
                loss_acc, grad_acc = carry
                return (loss_acc + l,
                        jax.tree.map(jnp.add, grad_acc, g)), None

            zero = (jnp.float32(0.0),
                    jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                 trainable))
            (loss, grads), _ = jax.lax.scan(acc, zero, mb)
            scale = 1.0 / k
            return loss * scale, jax.tree.map(lambda g: g * scale, grads)
        return jax.value_and_grad(loss_f)(trainable, frozen, batch)

    compress = tcfg.grad_compression == "int8_ef"
    if compress:
        from repro.dist import compression

    def train_step(state: Dict, frozen: Dict, batch: Dict):
        loss, grads = grads_of(state["trainable"], frozen, batch)
        if compress:
            # what the cross-pod all-reduce would transport: int8 + carried
            # quantization residual (dist/compression.py)
            grads, new_residual = compression.compress_with_feedback(
                grads, state["ef_residual"])
        grads, gnorm = adamw.clip_by_global_norm(grads, tcfg.grad_clip)
        lr = schedules.lr_at(state["step"], tcfg)
        new_params, new_opt = adamw.update(grads, state["opt"],
                                           state["trainable"], lr, tcfg)
        bad = (~jnp.isfinite(loss)) | (~jnp.isfinite(gnorm)) \
            | (loss > tcfg.anomaly_threshold)
        keep_old = lambda new, old: jax.tree.map(
            lambda n, o: jnp.where(bad, o, n), new, old)
        state_out = {
            "step": state["step"] + 1,
            "trainable": keep_old(new_params, state["trainable"]),
            "opt": keep_old(new_opt, state["opt"]),
            "loss_ema": jnp.where(
                state["step"] == 0, loss,
                0.99 * state["loss_ema"] + 0.01 * jnp.where(bad, state["loss_ema"], loss)),
            "anomalies": state["anomalies"] + bad.astype(jnp.int32),
        }
        if compress:
            state_out["ef_residual"] = keep_old(new_residual,
                                                state["ef_residual"])
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr,
                   "skipped": bad.astype(jnp.int32)}
        return state_out, metrics

    return train_step


# ---------------------------------------------------------------------------
# Mesh placement (dist/plan.py sources; rules remain the default)
# ---------------------------------------------------------------------------

def _plan_source(plan):
    from repro.dist import plan as plan_mod
    if plan is None or isinstance(plan, plan_mod.PlanSource):
        return plan or plan_mod.RulesSource()
    # a ShardingPlan object or a plan-file path
    if isinstance(plan, str):
        return plan_mod.PlanTableSource(plan_mod.ShardingPlan.load(plan))
    return plan_mod.PlanTableSource(plan)


def shard_train_state(model: Model, state: Dict, frozen: Dict, mesh,
                      fsdp: bool = None, plan=None):
    """Place (state, frozen) on `mesh` per the resolved plan source
    (`plan`: None/rules | PlanSource | ShardingPlan | plan-file path).
    Returns (state, frozen, state_sharding, frozen_sharding)."""
    from repro.dist import sharding as shd
    src = _plan_source(plan)
    if fsdp is None:
        fsdp = shd.fsdp_default(model.cfg, mesh)
    st_sh = shd.named(state,
                      src.state_specs(state, mesh, model.cfg, fsdp), mesh)
    fr_sh = shd.named(frozen,
                      src.state_specs(frozen, mesh, model.cfg, fsdp), mesh)
    return (jax.device_put(state, st_sh), jax.device_put(frozen, fr_sh),
            st_sh, fr_sh)


def make_sharded_train_step(model: Model, tcfg: TrainConfig, mesh,
                            state: Dict, frozen: Dict, batch_example: Dict,
                            fsdp: bool = None, shardings=None, plan=None):
    """jit the train step with explicit mesh shardings and donated state.
    `batch_example` may be real arrays or ShapeDtypeStructs; its leading dim
    is the global batch. `shardings`: the (state_sharding, frozen_sharding)
    pair from shard_train_state — pass it so placement and jit in_shardings
    share one source of truth (recomputed from `fsdp`/`plan` only when
    absent). Returns (jitted_step, batch_sharding) — feed batches through
    `jax.device_put(batch, batch_sharding)` (train/loop.py does this when
    given `batch_sharding`)."""
    from repro.configs.base import ShapeConfig
    from repro.dist import sharding as shd
    src = _plan_source(plan)
    if shardings is not None:
        st_sh, fr_sh = shardings
    else:
        if fsdp is None:
            fsdp = shd.fsdp_default(model.cfg, mesh)
        st_sh = shd.named(state,
                          src.state_specs(state, mesh, model.cfg, fsdp),
                          mesh)
        fr_sh = shd.named(frozen,
                          src.state_specs(frozen, mesh, model.cfg, fsdp),
                          mesh)
    ref = batch_example.get("tokens", batch_example.get("embeds"))
    shape = ShapeConfig("runtime", int(ref.shape[1]), int(ref.shape[0]),
                        "train")
    b_sh = shd.named(batch_example,
                     src.batch_specs(batch_example, mesh, shape), mesh)
    step = make_train_step(model, tcfg)
    jitted = jax.jit(step, in_shardings=(st_sh, fr_sh, b_sh),
                     donate_argnums=(0,))
    return jitted, b_sh
