from repro.train import loop, step
from repro.train.step import init_state, make_train_step, split_params
