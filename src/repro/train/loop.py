"""Fault-tolerant training loop.

Features (see DESIGN §7): periodic async checkpointing with atomic publish and
keep-k retention, auto-resume from the latest checkpoint, SIGTERM-safe
preemption (checkpoint-then-exit), anomaly-step accounting (the skip itself
happens inside the jitted train_step), per-step wall-time EWMA with straggler
logging, and LR backoff after repeated anomalies.

Data is step-keyed (stateless), so resume/elastic events replay nothing.
"""
from __future__ import annotations

import contextlib
import signal
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import manager as ckpt
from repro.configs.base import TrainConfig


@dataclass
class LoopReport:
    steps_run: int = 0
    final_loss: float = float("nan")
    losses: List[float] = field(default_factory=list)
    anomalies: int = 0
    slow_steps: int = 0
    resumed_from: Optional[int] = None
    preempted: bool = False


def run(train_step: Callable, state: Dict, frozen: Dict, data,
        tcfg: TrainConfig, *, ckpt_dir: Optional[str] = None,
        ckpt_every: int = 0, keep: int = 3, resume: bool = True,
        log_every: int = 50, straggler_factor: float = 3.0,
        num_shards: int = 1, shard: int = 0,
        mesh=None, batch_sharding=None, state_sharding=None,
        log_fn: Callable[[str], None] = print) -> tuple[Dict, LoopReport]:
    """mesh / batch_sharding / state_sharding: mesh-aware mode (launch layer
    passes the trees from train/step.py:make_sharded_train_step). Batches are
    device_put onto `batch_sharding` before each step; checkpoint restores are
    re-placed onto `state_sharding` (elastic resume onto a new mesh)."""
    report = LoopReport()
    mgr = None
    if ckpt_dir and ckpt_every:
        mgr = ckpt.CheckpointManager(ckpt_dir, keep=keep)
        if resume and ckpt.available_steps(ckpt_dir):
            raw, at = ckpt.restore(ckpt_dir)
            # config toggles (e.g. grad_compression on/off) change the state
            # skeleton: keep fresh subtrees the checkpoint lacks (EF residual
            # restarts at zero), drop saved ones the config no longer carries
            if isinstance(raw, dict) and isinstance(state, dict):
                raw = {k: raw.get(k, state[k]) for k in state}
            state = jax.tree.map(lambda x, a: jnp.asarray(a, x.dtype),
                                 state, raw)
            if state_sharding is not None:
                state = jax.device_put(state, state_sharding)
            report.resumed_from = at
            log_fn(f"[loop] resumed from step {at}")

    preempt = {"flag": False}

    def _on_term(signum, frame):
        preempt["flag"] = True

    old_handler = signal.signal(signal.SIGTERM, _on_term)
    ewma = None
    ctx = mesh if mesh is not None else contextlib.nullcontext()
    try:
        start = int(jax.device_get(state["step"]))
        with ctx:
            for step in range(start, tcfg.total_steps):
                t0 = time.perf_counter()
                batch = data.batch_at(step, shard=shard,
                                      num_shards=num_shards)
                if batch_sharding is not None:
                    batch = jax.device_put(batch, batch_sharding)
                state, metrics = train_step(state, frozen, batch)
                loss = float(jax.device_get(metrics["loss"]))
                dt = time.perf_counter() - t0
                ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
                if dt > straggler_factor * ewma and step > start + 5:
                    report.slow_steps += 1
                    log_fn(f"[loop] straggler step {step}: {dt:.3f}s vs "
                           f"ewma {ewma:.3f}s")
                report.losses.append(loss)
                report.steps_run += 1
                if log_every and step % log_every == 0:
                    log_fn(f"[loop] step {step} loss {loss:.4f} "
                           f"({dt*1e3:.1f} ms)")
                if mgr and ckpt_every and (step + 1) % ckpt_every == 0:
                    mgr.save(step + 1, state)
                if preempt["flag"]:
                    log_fn(f"[loop] SIGTERM at step {step}: checkpointing "
                           "and exiting cleanly")
                    if mgr:
                        mgr.save(step + 1, state)
                    report.preempted = True
                    break
        if mgr and report.steps_run and not report.preempted:
            mgr.save(int(jax.device_get(state["step"])), state)  # final state
        report.final_loss = report.losses[-1] if report.losses else float("nan")
        report.anomalies = int(jax.device_get(state["anomalies"]))
        return state, report
    finally:
        signal.signal(signal.SIGTERM, old_handler)
        if mgr:
            mgr.close()
