"""Pallas TPU kernels for the paper's compute hot-spot: FourierFT ΔW
materialization and its backward projection. `ops.fourier_deltaw` is the
public entry; `ref` holds the literal-paper (ifft2) oracles."""
from repro.kernels import fourier_deltaw, ops, ref
from repro.kernels.ops import fourier_deltaw as _  # noqa: F401 (re-export check)
