"""Pluggable kernel backends for the adapter hot-spot ops (DESIGN.md
§Kernels): the (op, method, backend)-keyed registry + `KernelPolicy` live in
`api`; Pallas TPU kernels for FourierFT and DCT ΔW in `fourier_deltaw` /
`dct_deltaw`; the shared custom-VJP harness, circulant FFT apply, and the
standalone `fourier_deltaw` entry in `ops`; literal-paper oracles in `ref`."""
from repro.kernels import api, dct_deltaw, fourier_deltaw, ops, ref
from repro.kernels.api import (
    KernelOp, KernelPolicy, KernelUnavailableError, lookup,
    register_kernel_op, resolve_op,
)
from repro.kernels.ops import fourier_deltaw as _  # noqa: F401 (re-export check)
