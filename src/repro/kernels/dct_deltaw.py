"""Pallas TPU kernels for the DCT (LoCA-style, arXiv:2502.06820) ΔW and its
VJP — the cosine-only sibling of fourier_deltaw.py, reusing its integer
phase-block trick with half-integer row phases:

    ΔW[j,k] = α/(d1·d2) · Σ_l c_l · cos(π(2j+1)u_l/2d1) · cos(π(2k+1)v_l/2d2)
            = (C1 ⊙ c) @ C2ᵀ          (one MXU matmul per tile — no sin term)

Phase precision: cos(π(2j+1)u/2d) has period 4d in the integer product
(2j+1)·u, which is reduced exactly in int32 — (2j+1)·u < 2³¹ holds for every
row of the block-padded grid when d ≤ ops.DCT_INT32_SAFE_DIM (≈32.5k; the
bound includes the up-to-(bm−1)-row padding, unlike a naive d² estimate).
Vocab-sized grids route to the einsum reference via the op's `max_dim`.

Backward (`dc`): same tiling over the cotangent g; per tile
    dc += Σ_k (gᵀ C1)[k,:] ⊙ C2[k,:]
accumulated into one (n,) block across sequential grid steps.

VMEM at (bm, bn, n) = (256, 256, 1024): basis blocks 2·256·1024·4B = 2 MB +
0.25 MB tile accumulator — half the FourierFT kernel's footprint (no sin
blocks), comfortably double-bufferable in 16 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PI = 3.141592653589793

DEFAULT_BM = 256
DEFAULT_BN = 256

# Capability metadata for the repro.analysis kernel verifier (DESIGN.md
# §Analysis). phase "half": the row phase product is (2j+1)·u reduced mod 4d
# (max j = ceil(d/bm)·bm − 1, u < d), giving a derived int32-safe bound of
# 32768 — ops.DCT_INT32_SAFE_DIM (32500) declares tighter, which is fine;
# the verifier only fails bounds LOOSER than derived.
CAPS = {
    "kind": "deltaw_phase",
    "phase": "half",
    "bm": DEFAULT_BM,
    "bn": DEFAULT_BN,
    "trig_terms": 1,
    "n_ref": 1024,
}


def _cos_block(idx0: jax.Array, size: int, dim: int, uv: jax.Array,
               c: jax.Array | None):
    """Half-integer-phase cosine block for rows [idx0, idx0+size) of a
    `dim`-point DCT axis: cos(π(2j+1)u/2d), optionally pre-scaled by c."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (size, 1), 0) + idx0
    prod = (2 * rows + 1) * uv[None, :].astype(jnp.int32)   # exact in int32
    prod = jax.lax.rem(prod, jnp.int32(4 * dim))            # cos period: 4d
    cos = jnp.cos(prod.astype(jnp.float32) * (PI / (2.0 * dim)))
    if c is not None:
        cos = cos * c[None, :]
    return cos


def _deltaw_kernel(c_ref, u_ref, v_ref, o_ref, *, d1, d2, alpha, bm, bn):
    i = pl.program_id(0)
    j = pl.program_id(1)
    cb = _cos_block(i * bm, bm, d1, u_ref[...], c_ref[...])
    rb = _cos_block(j * bn, bn, d2, v_ref[...], None)
    acc = jax.lax.dot_general(cb, rb, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    o_ref[...] = acc * (alpha / (d1 * d2))


def deltaw_pallas(c: jax.Array, u: jax.Array, v: jax.Array, d1: int, d2: int,
                  alpha: float, *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                  interpret: bool = False) -> jax.Array:
    """c (n,) f32, u/v (n,) i32 (n padded to 128 | c zero-padded).
    Returns ΔW (d1p, d2p) f32 with d1p/d2p the block-padded dims."""
    n = c.shape[0]
    d1p = -(-d1 // bm) * bm
    d2p = -(-d2 // bn) * bn
    grid = (d1p // bm, d2p // bn)
    kernel = functools.partial(_deltaw_kernel, d1=d1, d2=d2, alpha=alpha,
                               bm=bm, bn=bn)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda i, j: (0,)),
            pl.BlockSpec((n,), lambda i, j: (0,)),
            pl.BlockSpec((n,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d1p, d2p), jnp.float32),
        interpret=interpret,
    )(c, u, v)


def _dc_kernel(g_ref, u_ref, v_ref, o_ref, *, d1, d2, alpha, bm, bn):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    g = g_ref[...].astype(jnp.float32)                    # (bm, bn)
    cb = _cos_block(i * bm, bm, d1, u_ref[...], None)
    rb = _cos_block(j * bn, bn, d2, v_ref[...], None)
    a = jax.lax.dot_general(g, cb, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (bn, n)
    o_ref[...] += jnp.sum(a * rb, axis=0) * (alpha / (d1 * d2))


def dc_pallas(g: jax.Array, u: jax.Array, v: jax.Array, d1: int, d2: int,
              alpha: float, *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
              interpret: bool = False) -> jax.Array:
    """g (d1p, d2p) f32 cotangent (zero-padded outside (d1, d2)) -> dc (n,)."""
    n = u.shape[0]
    d1p, d2p = g.shape
    grid = (d1p // bm, d2p // bn)
    kernel = functools.partial(_dc_kernel, d1=d1, d2=d2, alpha=alpha,
                               bm=bm, bn=bn)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((n,), lambda i, j: (0,)),
            pl.BlockSpec((n,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((n,), lambda i, j: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(g, u, v)
