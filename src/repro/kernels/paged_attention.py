"""Paged decode attention: K/V gathered through a block table (DESIGN.md
§Paging).

The continuous-batching runtime's paged KV cache stores rows in a global
pool of fixed-size pages, `(n_pages, page_size, K, hd)` per layer; each
decode slot maps its logical positions onto pages through a block-table row
`(pages_per_seq,)`. This module provides the decode attention over that
layout as a registry `KernelOp` keyed ``("paged_attention", "attention",
backend)``:

    einsum    — gather the slot's whole logical window with `jnp.take` and
                run the dense ragged-kv_len decode attention
                (`models.attention.direct_attention`). Reference backend and
                the fp32 bit-exactness anchor: the gathered window holds the
                same rows the dense per-slot cache holds, masked columns
                contribute exact zeros, so paged == dense bitwise.
    pallas    — TPU kernel: grid (B, pages_per_seq) with the block table as
                a scalar-prefetch argument, so each grid step DMAs exactly
                ONE page picked by `block_table[b, p]` (the gather happens
                in the index_map — no (B, max_len) window is ever
                materialized in HBM). Online-softmax accumulation across
                the page steps, flash-style.
    interpret — the same kernel under Pallas interpret mode (any platform;
                the CI conformance backend).

`OWNER` is the registry owner shim: `paged_attention` is model-side, not an
adapter-method op, so a module-level object carries the `name` /
`kernel_ops()` surface `kernels.api.ensure_method` collects from.

fn signature (all backends):

    fn(q, k_pages, v_pages, block_table, kv_len) -> out

    q           (B, W, H, dh)   a window of W consecutive query rows per
                                slot (W == 1 for plain decode; W == k+1 for
                                draft verification, DESIGN.md §Speculation)
    k_pages     (P, ps, K, dh)  one layer's page pool (post-RoPE K)
    v_pages     (P, ps, K, dh)
    block_table (B, PPS) int32  per-slot logical-page -> physical-page map
    kv_len      (B,)     int32  valid length seen by query row 0 (its own KV
                                row included); row j attends columns
                                < kv_len + j — causal inside the window,
                                ragged across slots. Dirt rows contribute
                                exact 0. W == 1 reduces to the single-query
                                decode mask (positions >= kv_len masked).
    out         (B, W, H, dh)   in v_pages.dtype
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.api import KernelOp
from repro.models import attention as attn_mod

NEG_INF = attn_mod.NEG_INF

# Capability metadata for the repro.analysis kernel verifier (DESIGN.md
# §Analysis): the declared online-softmax scratch layout, checked against
# the canonical derivation (running max/denom are one f32 per (kv_head,
# group, window-row) triple; the accumulator adds the head dim), plus
# reference dims for the VMEM-footprint check. Must match the
# `scratch_shapes` passed to pallas_call below — the verifier exists so
# a retile can't change one without the other.
CAPS = {
    "kind": "paged_attention",
    "scratch": {"m": ("K", "G", "W"), "l": ("K", "G", "W"),
                "acc": ("K", "G", "W", "dh")},
    "ref": {"K": 8, "G": 4, "W": 8, "dh": 128, "ps": 16},
}


# ---------------------------------------------------------------------------
# einsum reference
# ---------------------------------------------------------------------------

def paged_attention_einsum(q, k_pages, v_pages, block_table, kv_len):
    """Gather the logical window through the block table, then run the dense
    ragged decode attention. (B, PPS*ps) window rows at positions >= the
    per-query limit are dirt — masked to exact zeros, so this is
    bit-identical (fp32) to the dense per-slot cache path whenever the valid
    rows hold the same values. q_len == 1 keeps the original single-query
    path; q_len > 1 applies the in-window causal mask (col < kv_len + j)."""
    B, PPS = block_table.shape
    ps = k_pages.shape[1]
    k = jnp.take(k_pages, block_table, axis=0).reshape(
        B, PPS * ps, *k_pages.shape[2:])
    v = jnp.take(v_pages, block_table, axis=0).reshape(
        B, PPS * ps, *v_pages.shape[2:])
    if q.shape[1] == 1:
        return attn_mod.direct_attention(q, k, v, causal=False, kv_len=kv_len)
    return attn_mod.windowed_decode_attention(q, k, v, kv_len)


# ---------------------------------------------------------------------------
# Pallas kernel: one page per grid step, block table as scalar prefetch
# ---------------------------------------------------------------------------

def _paged_attn_kernel(bt_ref, kvlen_ref, q_ref, k_ref, v_ref, o_ref,
                       m_ref, l_ref, acc_ref, *, page_size):
    b = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (W, H, dh)
    k = k_ref[0]                                   # (ps, K, dh)
    v = v_ref[0]
    W, H, dh = q.shape
    K = k.shape[1]
    G = H // K
    qs = q.reshape(W, K, G, dh).astype(jnp.float32) * (dh ** -0.5)
    s = jnp.einsum("wkgd,tkd->kgwt", qs, k.astype(jnp.float32))
    cols = p * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, 1, page_size), 3)
    # query row j of the window sees kv_len + j columns (causal inside the
    # window, ragged across slots; W == 1 is the plain decode mask)
    lim = kvlen_ref[b] + jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, W, 1), 2)
    valid = cols < lim
    s = jnp.where(valid, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    # explicit mask after exp: a fully-masked page must contribute 0, not
    # exp(NEG_INF - NEG_INF) = 1, while m is still at its -inf init
    pexp = jnp.exp(s - m_new[..., None]) * valid.astype(jnp.float32)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + pexp.sum(axis=-1)
    acc_ref[...] = (acc_ref[...] * corr[..., None]
                    + jnp.einsum("kgwt,tkd->kgwd", pexp,
                                 v.astype(jnp.float32)))
    m_ref[...] = m_new

    @pl.when(p == pl.num_programs(1) - 1)
    def _done():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[...] = jnp.moveaxis(out, 2, 0).reshape(
            1, W, H, dh).astype(o_ref.dtype)


def paged_attention_pallas(q, k_pages, v_pages, block_table, kv_len, *,
                           interpret: bool = False):
    B, W, H, dh = q.shape
    _, ps, K, _ = k_pages.shape
    PPS = block_table.shape[1]
    G = H // K
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                     # block_table, kv_len
        grid=(B, PPS),
        in_specs=[
            pl.BlockSpec((1, W, H, dh), lambda b, p, bt, kl: (b, 0, 0, 0)),
            # the gather: each (b, p) grid step pulls the ONE physical page
            # the block table names for slot b's logical page p
            pl.BlockSpec((1, ps, K, dh),
                         lambda b, p, bt, kl: (bt[b, p], 0, 0, 0)),
            pl.BlockSpec((1, ps, K, dh),
                         lambda b, p, bt, kl: (bt[b, p], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, W, H, dh),
                               lambda b, p, bt, kl: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((K, G, W), jnp.float32),     # running max
            pltpu.VMEM((K, G, W), jnp.float32),     # running denom
            pltpu.VMEM((K, G, W, dh), jnp.float32),  # running accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_attn_kernel, page_size=ps),
        out_shape=jax.ShapeDtypeStruct((B, W, H, dh), v_pages.dtype),
        grid_spec=grid_spec,
        interpret=interpret,
    )(block_table, kv_len, q, k_pages, v_pages)


# ---------------------------------------------------------------------------
# Registry owner shim
# ---------------------------------------------------------------------------

class _PagedAttentionOwner:
    """Registry owner for the model-side paged_attention op: carries the
    `name`/`kernel_ops()` surface `api.ensure_method` collects, nothing
    else (no adapter state, no sites)."""
    name = "attention"
    has_site_params = False

    def kernel_ops(self):
        return (
            KernelOp("paged_attention", self.name, "einsum",
                     paged_attention_einsum,
                     note="block-table gather + dense ragged decode attn"),
            KernelOp("paged_attention", self.name, "pallas",
                     functools.partial(paged_attention_pallas,
                                       interpret=False),
                     platforms=("tpu",),
                     note="scalar-prefetch page gather, online softmax",
                     caps=CAPS),
            KernelOp("paged_attention", self.name, "interpret",
                     functools.partial(paged_attention_pallas,
                                       interpret=True),
                     caps=CAPS),
        )


OWNER = _PagedAttentionOwner()
