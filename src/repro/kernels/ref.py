"""Pure-jnp oracles for the FourierFT kernels.

`deltaw_ref` is the *literal paper computation* (Algorithm 1): scatter the n
coefficients into a dense spectral matrix, `ifft2`, real part, scale by α.
The kernels must match it bit-for-bit up to float tolerance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def deltaw_ref(c: jax.Array, entries: jax.Array, d1: int, d2: int,
               alpha: float) -> jax.Array:
    """c (n,) f32, entries (2, n) i32 -> ΔW (d1, d2) f32."""
    dense = jnp.zeros((d1, d2), jnp.complex64)
    dense = dense.at[entries[0], entries[1]].set(c.astype(jnp.complex64))
    return (alpha * jnp.fft.ifft2(dense).real).astype(jnp.float32)


def dc_ref(g: jax.Array, entries: jax.Array, alpha: float) -> jax.Array:
    """VJP oracle: dL/dc_l = α/(d1·d2) Σ_{j,k} g[j,k]·cos(2π(j·u_l/d1 + k·v_l/d2)).

    Equivalently the real part of the (forward) FFT of g sampled at the
    entries — which is how we compute it here, keeping the oracle on the
    spectral-transform side of the identity."""
    d1, d2 = g.shape
    spec = jnp.fft.fft2(g.astype(jnp.complex64))
    vals = spec[entries[0], entries[1]]
    return (alpha / (d1 * d2)) * vals.real.astype(jnp.float32)
