"""Pluggable kernel-op registry + backend policy (DESIGN.md §Kernels).

Every adapter hot-spot computation is a `KernelOp` keyed by
``(op, method, backend)``:

    op      — "deltaw" (dense ΔW materialization), "factored_apply"
              (y += x @ ΔW without ΔW), "bank_apply" (row-batched factored
              apply for the serving adapter bank), "paged_attention"
              (block-table decode attention for the paged KV cache)
    method  — the `AdapterMethod.name` that owns the math. Model-side ops
              (paged_attention) are owned by a non-adapter shim object with
              the same `name`/`kernel_ops()` surface
              (kernels/paged_attention.OWNER) — the registry only needs
              those two attributes
    backend — "pallas" (compiled TPU), "interpret" (Pallas interpret mode),
              "einsum" (pure-jnp reference)

Methods declare their implementations via `AdapterMethod.kernel_ops()`
(core/adapter.py); declarations are collected **lazily on first dispatch**
(`ensure_method`), never at import — the adapter and kernel packages import
each other's modules and eager registration would race the partially
initialized module namespaces.

Backend selection replaces the old ad-hoc `_use_pallas` string dispatch with
a capability model: each op declares `platforms`, an int32 phase bound
(`max_dim`), and an optional config predicate (`requires`); `resolve_op`
walks the requested policy's candidate chain and returns the first op whose
`supports()` passes. The einsum reference is always the terminal candidate,
so resolution degrades instead of failing (vocab-sized grids fall off the
Pallas int32 bound onto einsum even when "interpret" was requested).

`KernelPolicy` is the build-time snapshot: `Model.__post_init__` resolves
every targeted (site, op) pair once, warns when an explicitly requested
backend had to be downgraded, and renders the outcome via `explain()`.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Sequence, Tuple

OPS = ("deltaw", "factored_apply", "bank_apply", "paged_attention")
BACKENDS = ("pallas", "interpret", "einsum")

# candidate chain per requested policy; first supported op wins. "interpret"
# is debug-only: never auto-selected, and "pallas"/"interpret" both degrade
# to the einsum reference when the accelerated op's constraints fail.
CANDIDATES: Dict[str, Tuple[str, ...]] = {
    "auto": ("pallas", "einsum"),
    "pallas": ("pallas", "einsum"),
    "interpret": ("interpret", "einsum"),
    "einsum": ("einsum",),
}


class KernelUnavailableError(KeyError):
    """No registered backend for (op, method) satisfies the constraints."""


@dataclass(frozen=True)
class KernelOp:
    """One backend implementation of one op for one adapter method.

    fn signatures (all return float32; the dispatch site casts):
        deltaw:          fn(trainable, aux, d1, d2, peft) -> (stack?, d1, d2)
        factored_apply:  fn(x, trainable, aux, d1, d2, peft) -> (..., d2)
        bank_apply:      fn(x, trainable, aux, d1, d2, peft) -> (B, ..., d2)

    Constraints: `platforms` (None = any jax backend), `max_dim` (largest
    d1/d2 whose integer phase reduction stays exact in int32 — includes the
    kernel's block padding, see DESIGN.md §Kernels), `requires` (predicate on
    the PEFTConfig, e.g. FourierFT's Pallas path needs basis == "fourier").

    `caps` is the kernel's machine-checkable capability metadata (the
    module-level `CAPS` dict of the implementing kernel module): block
    sizes, phase kind, scratch shapes — everything `repro.analysis`'s
    kernel-capability verifier needs to RE-DERIVE `max_dim` and the VMEM
    footprint instead of trusting the declaration (DESIGN.md §Analysis).
    None means "nothing to verify" (einsum references, XLA-op backends).
    """
    op: str
    method: str
    backend: str
    fn: Callable
    platforms: Optional[Tuple[str, ...]] = None
    max_dim: Optional[int] = None
    requires: Optional[Callable] = None
    note: str = ""
    caps: Optional[Dict] = None

    def supports(self, d1: int, d2: int, peft=None,
                 platform: Optional[str] = None) -> Tuple[bool, str]:
        """-> (ok, reason-if-not). `peft=None` skips config predicates."""
        if self.platforms is not None and platform not in self.platforms:
            return False, f"platform {platform!r} not in {self.platforms}"
        if self.max_dim is not None and max(d1, d2) > self.max_dim:
            return False, (f"dim {max(d1, d2)} over int32 phase bound "
                           f"{self.max_dim}")
        if self.requires is not None and peft is not None \
                and not self.requires(peft):
            return False, "config constraint (requires)"
        return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_OPS: Dict[Tuple[str, str, str], KernelOp] = {}
_ENSURED: set = set()


def register_kernel_op(op: KernelOp) -> KernelOp:
    if op.op not in OPS:
        raise ValueError(f"unknown kernel op {op.op!r}; one of {OPS}")
    if op.backend not in BACKENDS:
        raise ValueError(f"unknown backend {op.backend!r}; one of {BACKENDS}")
    key = (op.op, op.method, op.backend)
    if key in _OPS:
        raise ValueError(f"kernel op {key} already registered")
    _OPS[key] = op
    return op


def _method_obj(method):
    """Accept an AdapterMethod instance or its registry name (resolved
    lazily — api.py must not import core.adapter at module level)."""
    if isinstance(method, str):
        from repro.core import adapter as adapter_api
        return adapter_api.resolve(method)
    return method


def ensure_method(method) -> None:
    """Collect `method.kernel_ops()` into the registry, once per method."""
    m = _method_obj(method)
    if m.name in _ENSURED:
        return
    _ENSURED.add(m.name)
    registered = []
    try:
        for op in m.kernel_ops():
            register_kernel_op(op)
            registered.append((op.op, op.method, op.backend))
    except BaseException:
        # roll back the partial pass entirely, so a retry after a transient
        # failure re-registers cleanly instead of hitting "already registered"
        for key in registered:
            _OPS.pop(key, None)
        _ENSURED.discard(m.name)
        raise


def lookup(op: str, method, backend: str) -> Optional[KernelOp]:
    m = _method_obj(method)
    ensure_method(m)
    return _OPS.get((op, m.name, backend))


def ops_for(method) -> Tuple[str, ...]:
    """Op names the method has any backend registered for."""
    m = _method_obj(method)
    ensure_method(m)
    return tuple(o for o in OPS
                 if any((o, m.name, b) in _OPS for b in BACKENDS))


def backends_for(op: str, method) -> Tuple[str, ...]:
    m = _method_obj(method)
    ensure_method(m)
    return tuple(b for b in BACKENDS if (op, m.name, b) in _OPS)


def all_ops() -> Tuple[KernelOp, ...]:
    """Every registered KernelOp, with every known owner's declarations
    collected first: all registered adapter methods plus the model-side
    paged-attention owner shim. This is the enumeration surface of
    `repro.analysis`'s kernel-capability verifier."""
    from repro.core import adapter as adapter_api
    for name in adapter_api.registered_methods():
        ensure_method(name)
    from repro.kernels import paged_attention
    ensure_method(paged_attention.OWNER)
    return tuple(_OPS[k] for k in sorted(_OPS))


def _platform() -> str:
    import jax
    return jax.default_backend()


def requested_backend(peft) -> str:
    return getattr(peft, "kernel_backend", None) or "auto"


def resolve_op(op: str, method, peft=None, d1: int = 0, d2: int = 0, *,
               backend: Optional[str] = None, platform: Optional[str] = None,
               missing_ok: bool = False) -> Optional[KernelOp]:
    """First registered op along the requested policy's candidate chain whose
    constraints pass. `backend` overrides `peft.kernel_backend`."""
    m = _method_obj(method)
    ensure_method(m)
    requested = backend or requested_backend(peft)
    if requested not in CANDIDATES:
        raise ValueError(f"unknown kernel backend {requested!r}; one of "
                         f"{sorted(CANDIDATES)}")
    platform = platform or _platform()
    for b in CANDIDATES[requested]:
        cand = _OPS.get((op, m.name, b))
        if cand is None:
            continue
        ok, _ = cand.supports(d1, d2, peft, platform)
        if ok:
            return cand
    if missing_ok:
        return None
    raise KernelUnavailableError(
        f"no kernel op for ({op!r}, {m.name!r}) under backend={requested!r} "
        f"on {platform}; registered backends: {backends_for(op, m)}")


# ---------------------------------------------------------------------------
# Policy: per-model resolution snapshot
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Resolution:
    site: str
    d1: int
    d2: int
    op: str
    backend: str          # "" when nothing resolved (validate() rejects)
    note: str = ""


@dataclass(frozen=True)
class KernelPolicy:
    """Backend choice for every targeted (site, op) pair of one model,
    resolved once at model build (models/registry.py)."""
    method: str
    requested: str
    platform: str
    resolutions: Tuple[Resolution, ...] = ()

    @classmethod
    def build(cls, method, sites: Sequence, peft,
              platform: Optional[str] = None) -> "KernelPolicy":
        m = _method_obj(method)
        ensure_method(m)
        requested = requested_backend(peft)
        if requested not in CANDIDATES:
            raise ValueError(f"unknown kernel backend {requested!r}; one of "
                             f"{sorted(CANDIDATES)}")
        platform = platform or _platform()
        res = []
        if getattr(m, "has_site_params", True):
            targets = getattr(peft, "target_modules", ())
            for s in sites:
                if s.name.split("/")[-1] not in targets:
                    continue
                for op in ops_for(m):
                    chosen = resolve_op(op, m, peft, s.d_in, s.d_out,
                                        platform=platform, missing_ok=True)
                    note = ""
                    first = CANDIDATES[requested][0]
                    if chosen is None or chosen.backend != first:
                        cand = _OPS.get((op, m.name, first))
                        why = (f"no {first} op registered" if cand is None
                               else cand.supports(s.d_in, s.d_out, peft,
                                                  platform)[1])
                        note = f"{first} unavailable: {why}"
                    res.append(Resolution(s.name, s.d_in, s.d_out, op,
                                          chosen.backend if chosen else "",
                                          note))
        policy = cls(m.name, requested, platform, tuple(res))
        if requested in ("pallas", "interpret"):
            # warn only where an op for the requested backend EXISTS but its
            # constraints rejected it — ops with no accelerated registration
            # (einsum-only math) fall through silently
            missed = sorted({f"{r.op}@{r.site}" for r in res
                             if r.backend != requested
                             and (r.op, m.name, requested) in _OPS})
            if missed:
                warnings.warn(
                    f"kernel_backend={requested!r} requested but unavailable "
                    f"for {missed} on {platform} — resolved to the fallback "
                    "chain (see Model.explain_kernels())", UserWarning,
                    stacklevel=3)
        return policy

    def backend_for(self, site: str, op: str) -> Optional[str]:
        for r in self.resolutions:
            if r.site == site and r.op == op:
                return r.backend or None
        return None

    def validate(self) -> "KernelPolicy":
        """Fail fast (pre-jit) on (site, op) pairs with no usable backend."""
        dead = [f"{r.op}@{r.site}" for r in self.resolutions if not r.backend]
        if dead:
            raise KernelUnavailableError(
                f"method {self.method!r}: no backend resolved for {dead} "
                f"under kernel_backend={self.requested!r} on {self.platform}")
        return self

    def explain(self) -> str:
        """Human-readable per-site resolution report (examples print this)."""
        head = (f"kernel policy: method={self.method} "
                f"requested={self.requested} platform={self.platform}")
        if not self.resolutions:
            return head + "\n  (no registered kernel ops for this method)"
        lines = [head]
        for r in self.resolutions:
            line = (f"  {r.site} ({r.d1}x{r.d2}) {r.op} -> "
                    f"{r.backend or 'UNRESOLVED'}")
            if r.note:
                line += f"  [{r.note}]"
            lines.append(line)
        return "\n".join(lines)
