"""Pallas TPU kernels for FourierFT ΔW materialization and its VJP.

Forward (`deltaw`): grid over (d1/bm, d2/bn) output tiles. Each tile builds its
cos/sin basis blocks *in VMEM* from integer phase arithmetic (no HBM-resident
(d, n) basis — saves 4·(d1+d2)·n·4 bytes of HBM traffic per materialization)
and accumulates two MXU matmuls:

    tile = (cosθ ⊙ c) @ cosφᵀ − (sinθ ⊙ c) @ sinφᵀ,  scaled by α/(d1·d2)

Phase precision: angles are reduced exactly in int32 — (j·u) mod d1 is exact
while j·u < 2³¹, i.e. for dims ≤ ops.FOURIER_INT32_SAFE_DIM (46336; j runs
over the block-padded rows, hence slightly under ⌊√2³¹⌋) — so cos/sin see
arguments in [0, 2π) with full f32 precision even for 8k×30k weights. The
registry's capability model (api.py `max_dim`) routes larger dims (vocab-sized
grids; not a default adaptation target) to the einsum path.

Backward (`dc`): same tiling over the incoming cotangent g; per tile
    dc += Σ_k cosφ[k,:] ⊙ (gᵀ cosθ)[k,:] − sinφ ⊙ (gᵀ sinθ)
accumulated into a single (n,) output block across sequential grid steps
(TPU grid order is sequential; interpret mode matches).

VMEM at (bm, bn, n) = (256, 256, 1024): basis blocks 4·256·1024·4B = 4MB,
tile accumulators 0.5MB — comfortably double-bufferable in 16MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TWO_PI = 6.283185307179586

DEFAULT_BM = 256
DEFAULT_BN = 256

# Machine-checkable capability metadata (repro.analysis kernel verifier,
# DESIGN.md §Analysis): enough to RE-DERIVE the int32 phase bound and the
# VMEM footprint from first principles, so ops.FOURIER_INT32_SAFE_DIM can
# never silently rot when someone retiles the kernel.
#   phase:       "linear" — row phase product is j·u, j over the
#                block-padded grid (max j = ceil(d/bm)·bm − 1), u < d
#   trig_terms:  cos AND sin basis blocks per axis (2·(bm+bn)·n floats)
#   n_ref:       reference spectral count for the VMEM budget check
CAPS = {
    "kind": "deltaw_phase",
    "phase": "linear",
    "bm": DEFAULT_BM,
    "bn": DEFAULT_BN,
    "trig_terms": 2,
    "n_ref": 1024,
}


def _phase_block(idx0: jax.Array, size: int, dim: int, uv: jax.Array,
                 c: jax.Array | None):
    """cos/sin basis block for rows [idx0, idx0+size) of a `dim`-point axis.

    uv: (n,) int32 spectral indices. Returns (cos (size,n), sin (size,n)),
    optionally pre-scaled by c."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (size, 1), 0) + idx0
    prod = rows * uv[None, :].astype(jnp.int32)          # exact in int32
    prod = jax.lax.rem(prod, jnp.int32(dim))
    ang = prod.astype(jnp.float32) * (TWO_PI / dim)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if c is not None:
        cos = cos * c[None, :]
        sin = sin * c[None, :]
    return cos, sin


def _deltaw_kernel(c_ref, u_ref, v_ref, o_ref, *, d1, d2, alpha, bm, bn):
    i = pl.program_id(0)
    j = pl.program_id(1)
    c = c_ref[...]
    ct, st = _phase_block(i * bm, bm, d1, u_ref[...], c)
    cp, sp = _phase_block(j * bn, bn, d2, v_ref[...], None)
    acc = jax.lax.dot_general(ct, cp, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    acc -= jax.lax.dot_general(st, sp, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    o_ref[...] = acc * (alpha / (d1 * d2))


def deltaw_pallas(c: jax.Array, u: jax.Array, v: jax.Array, d1: int, d2: int,
                  alpha: float, *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                  interpret: bool = False) -> jax.Array:
    """c (n,) f32, u/v (n,) i32 (n padded to 128 | c zero-padded).
    Returns ΔW (d1p, d2p) f32 with d1p/d2p the block-padded dims."""
    n = c.shape[0]
    d1p = -(-d1 // bm) * bm
    d2p = -(-d2 // bn) * bn
    grid = (d1p // bm, d2p // bn)
    kernel = functools.partial(_deltaw_kernel, d1=d1, d2=d2, alpha=alpha,
                               bm=bm, bn=bn)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda i, j: (0,)),
            pl.BlockSpec((n,), lambda i, j: (0,)),
            pl.BlockSpec((n,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d1p, d2p), jnp.float32),
        interpret=interpret,
    )(c, u, v)


def _dc_kernel(g_ref, u_ref, v_ref, o_ref, *, d1, d2, alpha, bm, bn):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    g = g_ref[...].astype(jnp.float32)                    # (bm, bn)
    ct, st = _phase_block(i * bm, bm, d1, u_ref[...], None)
    cp, sp = _phase_block(j * bn, bn, d2, v_ref[...], None)
    a = jax.lax.dot_general(g, ct, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bn, n)
    b = jax.lax.dot_general(g, st, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    contrib = jnp.sum(a * cp - b * sp, axis=0) * (alpha / (d1 * d2))
    o_ref[...] += contrib


def dc_pallas(g: jax.Array, u: jax.Array, v: jax.Array, d1: int, d2: int,
              alpha: float, *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
              interpret: bool = False) -> jax.Array:
    """g (d1p, d2p) f32 cotangent (zero-padded outside (d1, d2)) -> dc (n,)."""
    n = u.shape[0]
    d1p, d2p = g.shape
    grid = (d1p // bm, d2p // bn)
    kernel = functools.partial(_dc_kernel, d1=d1, d2=d2, alpha=alpha,
                               bm=bm, bn=bn)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((n,), lambda i, j: (0,)),
            pl.BlockSpec((n,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((n,), lambda i, j: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(g, u, v)
