"""Jitted public wrappers for the FourierFT kernels.

`fourier_deltaw(c, entries, d1, d2, alpha)` — differentiable (custom VJP wired
to the `dc` kernel), handles n/dim padding, vmaps over stacked layers, and
falls back to the einsum path when the Pallas path is unavailable (CPU
backend without interpret) or the int32 phase reduction would overflow
(dims ≥ 46341, i.e. vocab-sized grids).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import fourierft as _f
from repro.kernels import fourier_deltaw as _k

_INT32_SAFE_DIM = 46340  # max dim with exact (j*u) in int32


def _pad_n(c, entries):
    n = c.shape[-1]
    npad = -(-n // 128) * 128
    if npad == n:
        return c, entries
    pc = jnp.pad(c, [(0, 0)] * (c.ndim - 1) + [(0, npad - n)])
    pe = jnp.pad(entries, ((0, 0), (0, npad - n)))
    return pc, pe


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _deltaw(c, entries, d1, d2, alpha, interpret):
    return _deltaw_fwd(c, entries, d1, d2, alpha, interpret)[0]


def _deltaw_fwd(c, entries, d1, d2, alpha, interpret):
    cp, ep = _pad_n(c, entries)
    out = _k.deltaw_pallas(cp, ep[0], ep[1], d1, d2, alpha,
                           interpret=interpret)
    return out[:d1, :d2], (entries,)


def _deltaw_bwd(d1, d2, alpha, interpret, res, g):
    (entries,) = res
    n = entries.shape[1]
    _, ep = _pad_n(jnp.zeros((n,), jnp.float32), entries)
    bm, bn = _k.DEFAULT_BM, _k.DEFAULT_BN
    d1p, d2p = -(-d1 // bm) * bm, -(-d2 // bn) * bn
    gp = jnp.pad(g.astype(jnp.float32), ((0, d1p - d1), (0, d2p - d2)))
    dc = _k.dc_pallas(gp, ep[0], ep[1], d1, d2, alpha, interpret=interpret)
    return (dc[:n], None)


_deltaw.defvjp(_deltaw_fwd, _deltaw_bwd)


def _use_pallas(d1: int, d2: int, mode: str) -> tuple[bool, bool]:
    """-> (use_kernel, interpret)."""
    if mode == "never" or max(d1, d2) > _INT32_SAFE_DIM:
        return False, False
    if mode == "interpret":
        return True, True
    # auto: compiled Pallas on TPU, einsum elsewhere
    on_tpu = jax.default_backend() == "tpu"
    return (True, False) if on_tpu else (False, False)


def fourier_deltaw(c: jax.Array, entries: jax.Array, d1: int, d2: int,
                   alpha: float, *, use_pallas: str = "auto",
                   out_dtype=None) -> jax.Array:
    """ΔW for c (n,) -> (d1, d2), or stacked c (L, n) -> (L, d1, d2)."""
    use, interpret = _use_pallas(d1, d2, use_pallas)
    if not use:
        return _f.materialize_delta(c, entries, d1, d2, alpha,
                                    out_dtype=out_dtype)
    fn = lambda cc: _deltaw(cc.astype(jnp.float32), entries, d1, d2, alpha,
                            interpret)
    out = jax.vmap(fn)(c) if c.ndim == 2 else fn(c)
    return out.astype(out_dtype) if out_dtype is not None else out
