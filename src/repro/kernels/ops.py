"""Differentiable harnesses around the Pallas spectral kernels, plus the
non-Pallas accelerated paths, consumed by the kernel registry (api.py).

`make_deltaw_harness(fwd, bwd, bm, bn)` packages the custom-VJP + padding
plumbing once — n padded to the 128-lane boundary (entries padded directly;
padded columns carry c = 0 so they contribute nothing), output sliced back to
(d1, d2), cotangents zero-padded to the backward kernel's block grid, stacked
(L, n) coefficients vmapped — and is instantiated for both the FourierFT
kernels (fourier_deltaw.py) and the DCT kernels (dct_deltaw.py).

`circulant_apply_fft` is the circulant adapter's fast apply: x @ C is a
circular convolution, computed as irfft(rfft(x) ⊛ rfft(g)) in O(M log M)
instead of materializing the (d1, d2) gather — an XLA FFT, not a hand-written
Pallas kernel, registered under the accelerated backends by the method
(core/adapter.py).

`fourier_deltaw` remains the standalone entry for benchmarks/tests; it
dispatches through the registry like the adapter stack does.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import dct_deltaw as _dk
from repro.kernels import fourier_deltaw as _fk

# Largest dim whose integer phase product stays exact in int32 INCLUDING the
# kernels' row padding to the bm=256 block grid (j runs over padded rows):
#   fourier: j·u       with j ≤ d1p−1, u ≤ d1−1  → d ≤ 46336 (= 181·256)
#   dct:     (2j+1)·u  reduced mod 4d            → d ≤ 32500
# (The pre-registry code used 46340 = ⌊√2³¹⌋, which overflows for
# d ∈ [46337, 46340] once block padding pushes j past d — tightened here.)
FOURIER_INT32_SAFE_DIM = 46336
DCT_INT32_SAFE_DIM = 32500


def _pad_entries(entries: jax.Array) -> jax.Array:
    """Pad (2, n) int32 entries to the 128-lane boundary (zero entries)."""
    n = entries.shape[1]
    npad = -(-n // 128) * 128
    if npad == n:
        return entries
    return jnp.pad(entries, ((0, 0), (0, npad - n)))


def _pad_c(c: jax.Array, npad: int) -> jax.Array:
    """Zero-pad (n,) coefficients to npad — padded basis columns are then
    scaled by 0 and drop out of the tile matmuls exactly."""
    n = c.shape[-1]
    if npad == n:
        return c
    return jnp.pad(c, (0, npad - n))


def make_deltaw_harness(fwd_kernel, bwd_kernel, bm: int, bn: int):
    """Reusable custom-VJP + padding wrapper for (c, entries) -> ΔW spectral
    kernels.

    fwd_kernel(c, u, v, d1, d2, alpha, interpret=) -> (d1p, d2p) tile-padded
    ΔW; bwd_kernel(g, u, v, d1, d2, alpha, interpret=) -> (npad,) dc. The
    returned callable is `h(c, entries, d1, d2, alpha, *, interpret=False)`
    accepting c as (n,) or stacked (L, n)."""

    @functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
    def _deltaw(c, entries, d1, d2, alpha, interpret):
        return _fwd(c, entries, d1, d2, alpha, interpret)[0]

    def _fwd(c, entries, d1, d2, alpha, interpret):
        ep = _pad_entries(entries)
        cp = _pad_c(c, ep.shape[1])
        out = fwd_kernel(cp, ep[0], ep[1], d1, d2, alpha, interpret=interpret)
        return out[:d1, :d2], (entries,)

    def _bwd(d1, d2, alpha, interpret, res, g):
        (entries,) = res
        n = entries.shape[1]
        ep = _pad_entries(entries)
        d1p, d2p = -(-d1 // bm) * bm, -(-d2 // bn) * bn
        gp = jnp.pad(g.astype(jnp.float32), ((0, d1p - d1), (0, d2p - d2)))
        dc = bwd_kernel(gp, ep[0], ep[1], d1, d2, alpha, interpret=interpret)
        return (dc[:n], None)

    _deltaw.defvjp(_fwd, _bwd)

    def harness(c: jax.Array, entries: jax.Array, d1: int, d2: int,
                alpha: float, *, interpret: bool = False) -> jax.Array:
        fn = lambda cc: _deltaw(cc.astype(jnp.float32), entries, d1, d2,
                                alpha, interpret)
        return jax.vmap(fn)(c) if c.ndim == 2 else fn(c)

    return harness


fourier_deltaw_harness = make_deltaw_harness(
    _fk.deltaw_pallas, _fk.dc_pallas, _fk.DEFAULT_BM, _fk.DEFAULT_BN)
dct_deltaw_harness = make_deltaw_harness(
    _dk.deltaw_pallas, _dk.dc_pallas, _dk.DEFAULT_BM, _dk.DEFAULT_BN)


# ---------------------------------------------------------------------------
# Circulant fast apply
# ---------------------------------------------------------------------------

def circulant_apply_fft(x: jax.Array, kernel: jax.Array, d1: int, d2: int,
                        alpha: float) -> jax.Array:
    """y = x @ ΔW for ΔW[j,k] = α/(d1·d2)·g[(k−j) mod M], M = max(d1, d2),
    without materializing ΔW: zero-pad x to M, circularly convolve with g via
    rfft/irfft (O(M log M) per token vs O(d1·d2)), truncate to d2 columns.

    x (..., d1); kernel (..., M) broadcast-aligned against x's batch dims
    ((M,) per layer on the factored path, (B, 1, M) per-row on the bank
    path). Exactly zero for a zero kernel (zero spectrum ⊛ anything = 0),
    preserving the adapter bank's reserved-zero-row contract."""
    m = kernel.shape[-1]
    xf = x.astype(jnp.float32)
    if m != d1:
        xf = jnp.pad(xf, [(0, 0)] * (xf.ndim - 1) + [(0, m - d1)])
    spec = jnp.fft.rfft(xf, axis=-1) \
        * jnp.fft.rfft(kernel.astype(jnp.float32), axis=-1)
    y = jnp.fft.irfft(spec, n=m, axis=-1)[..., :d2]
    return y * (alpha / (d1 * d2))


# ---------------------------------------------------------------------------
# Standalone FourierFT entry (benchmarks / tests) — registry-dispatched
# ---------------------------------------------------------------------------

def fourier_deltaw(c: jax.Array, entries: jax.Array, d1: int, d2: int,
                   alpha: float, *, backend: str = "auto",
                   out_dtype=None) -> jax.Array:
    """ΔW for c (n,) -> (d1, d2), or stacked c (L, n) -> (L, d1, d2).

    `backend`: auto | pallas | interpret | einsum — resolved through the
    kernel registry exactly like `AdapterMethod.site_delta` (api.resolve_op),
    including the int32-bound einsum fallback for vocab-sized grids."""
    from repro.configs.base import PEFTConfig
    from repro.kernels import api
    peft = PEFTConfig(method="fourierft", alpha=alpha, kernel_backend=backend)
    op = api.resolve_op("deltaw", "fourierft", peft, d1, d2)
    out = op.fn({"c": c}, {"entries": entries}, d1, d2, peft)
    return out.astype(out_dtype) if out_dtype is not None else out
