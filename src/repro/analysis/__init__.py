"""Static hot-path analyzer (DESIGN.md §Analysis).

Four passes over the tree and its representative compiled graphs, one
baseline-gated CLI (`python -m repro.analysis`):

- `ast_lint`       — repo-specific Python source lint (tracer leaks,
                     host syncs in loops, RNG inside jit)
- `kernel_audit`   — KernelOp capability verifier (int32 phase bounds,
                     VMEM budgets, paged-attention scratch shapes)
- `sharding_audit` — every param leaf of every registered arch must
                     resolve through a named sharding rule table
- `hlo_lint`       — jaxpr/HLO lint of the train/serve graphs built by
                     `graphs` (host transfers × loop multiplicity,
                     f32-literal upcasts, wasted donations, recompile
                     budgets)

Findings diff against the committed `baseline.json`; only NEW findings
fail the gate (report.gate). See DESIGN.md §Analysis for the rule catalog
and the fix/suppress/baseline workflow.
"""
from repro.analysis.report import (              # noqa: F401
    DEFAULT_BASELINE, Finding, diff, gate, load_baseline, render,
    save_baseline, to_json,
)
