"""Kernel-capability verifier (DESIGN.md §Analysis).

For every `KernelOp` in the registry that carries `caps` metadata
(kernels/api.py), re-derive from first principles what the declaration
claims, and fail when the declaration is LOOSER than the derivation:

- **int32 phase bound** (`deltaw_phase` caps — fourier_deltaw.py,
  dct_deltaw.py): the kernels reduce an integer phase product exactly in
  int32 (`j·u` mod d for the linear Fourier phase, `(2j+1)·u` mod 4d for
  the half-integer DCT phase). `j` runs over the BLOCK-PADDED row grid
  (ceil(d/bm)·bm rows), so the safe bound is below the naive ⌊√2³¹⌋ — the
  derivation here searches the exact largest `d` whose worst-case product
  stays under 2³¹, and the op's declared `max_dim` must not exceed it.
  A declared bound BELOW derived is conservative and fine (DCT declares
  32500 against a derived 32768).

- **VMEM footprint**: basis blocks + tile accumulator at the declared
  block sizes (×2 for double buffering) must fit the 16 MB VMEM budget.

- **paged-attention scratch** (`paged_attention` caps): the declared
  online-softmax scratch dims must equal the canonical derivation —
  running max/denom one f32 per (K, G, W) triple, accumulator adding the
  head dim — and the per-grid-step working set must fit VMEM at the
  reference dims.

Ops without `caps` (einsum references, XLA-op backends) have nothing to
verify and are skipped.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.analysis.report import Finding

INT32_LIMIT = 2 ** 31
VMEM_BUDGET = 16 * 2 ** 20          # bytes per TPU core (v4/v5e class)
DOUBLE_BUFFER = 2


def _ceil_to(n: int, b: int) -> int:
    return -(-n // b) * b


def _phase_product(d: int, bm: int, phase: str) -> int:
    """Worst-case integer phase product at dim `d`: the largest row index
    of the BLOCK-PADDED grid times the largest spectral index (< d)."""
    jmax = _ceil_to(d, bm) - 1
    umax = d - 1
    if phase == "linear":                 # fourier: j*u mod d
        return jmax * umax
    if phase == "half":                   # dct: (2j+1)*u mod 4d
        return (2 * jmax + 1) * umax
    raise ValueError(f"unknown phase kind {phase!r}")


def derived_phase_bound(caps: Dict) -> int:
    """Largest d whose worst-case phase product stays exactly representable
    in int32. The product is nondecreasing in d, so bisect."""
    bm = caps["bm"]
    phase = caps["phase"]
    lo, hi = 1, 1 << 17                   # bounds comfortably past sqrt(2^31)
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if _phase_product(mid, bm, phase) < INT32_LIMIT:
            lo = mid
        else:
            hi = mid - 1
    return lo


def derived_deltaw_vmem(caps: Dict) -> int:
    """Per-grid-step VMEM bytes of the deltaw kernels at the declared block
    sizes: trig basis blocks for both axes, the (bm, bn) output tile, and
    the three (n,) entry vectors — doubled for double buffering."""
    bm, bn, n = caps["bm"], caps["bn"], caps["n_ref"]
    basis = caps["trig_terms"] * (bm + bn) * n * 4
    tile = bm * bn * 4
    entries = 3 * n * 4
    return DOUBLE_BUFFER * (basis + tile + entries)


_CANONICAL_SCRATCH = {"m": ("K", "G", "W"), "l": ("K", "G", "W"),
                      "acc": ("K", "G", "W", "dh")}


def derived_paged_vmem(caps: Dict) -> int:
    """Per-grid-step VMEM bytes of the paged-attention kernel at the caps'
    reference dims: q/out window blocks, one K and one V page, and the f32
    online-softmax scratch — doubled for double buffering."""
    r = caps["ref"]
    K, G, W, dh, ps = r["K"], r["G"], r["W"], r["dh"], r["ps"]
    H = K * G
    qo = 2 * W * H * dh * 4               # q + out, f32 upper bound
    pages = 2 * ps * K * dh * 4           # one K page + one V page
    scratch = (2 * K * G * W + K * G * W * dh) * 4
    return DOUBLE_BUFFER * (qo + pages) + scratch


def audit_op(op) -> List[Finding]:
    """Verify one KernelOp's declared capabilities against the derivation.
    Ops without caps return no findings (nothing declared to check)."""
    caps = getattr(op, "caps", None)
    if not caps:
        return []
    where = f"{op.op}/{op.method}/{op.backend}"
    out: List[Finding] = []
    kind = caps.get("kind")
    if kind == "deltaw_phase":
        derived = derived_phase_bound(caps)
        if op.max_dim is None:
            out.append(Finding(
                "kernels", "bound-missing", where,
                f"phase caps declared but no max_dim on the op — the int32 "
                f"bound (derived {derived}) is unenforced"))
        elif op.max_dim > derived:
            out.append(Finding(
                "kernels", "bound-loosened", where,
                f"declared max_dim {op.max_dim} exceeds the derived int32 "
                f"phase bound {derived} (phase={caps['phase']}, "
                f"bm={caps['bm']}): dims in ({derived}, {op.max_dim}] "
                f"overflow the integer phase product"))
        vmem = derived_deltaw_vmem(caps)
        if vmem > VMEM_BUDGET:
            out.append(Finding(
                "kernels", "vmem-over-budget", where,
                f"derived per-step VMEM {vmem} B exceeds the "
                f"{VMEM_BUDGET} B budget at blocks "
                f"({caps['bm']}, {caps['bn']}, n={caps['n_ref']})"))
    elif kind == "paged_attention":
        declared = {k: tuple(v) for k, v in caps.get("scratch", {}).items()}
        if declared != _CANONICAL_SCRATCH:
            out.append(Finding(
                "kernels", "scratch-mismatch", where,
                f"declared scratch {declared} != canonical online-softmax "
                f"scratch {_CANONICAL_SCRATCH}"))
        vmem = derived_paged_vmem(caps)
        if vmem > VMEM_BUDGET:
            out.append(Finding(
                "kernels", "vmem-over-budget", where,
                f"derived per-step VMEM {vmem} B exceeds the "
                f"{VMEM_BUDGET} B budget at ref dims {caps['ref']}"))
    else:
        out.append(Finding(
            "kernels", "unknown-caps", where,
            f"unrecognized caps kind {kind!r} — the verifier cannot check "
            "this declaration; teach kernel_audit.py the new kind"))
    return out


def audit_registry(ops=None) -> List[Finding]:
    """Audit every registered KernelOp (or an explicit iterable — tests
    pass seeded-regression ops directly)."""
    if ops is None:
        from repro.kernels import api
        ops = api.all_ops()
    out: List[Finding] = []
    for op in ops:
        out += audit_op(op)
    return out


def declared_constants_findings() -> List[Finding]:
    """Cross-check the module-level declared constants against the caps
    derivation: ops.FOURIER_INT32_SAFE_DIM must equal the derived linear
    bound exactly (it was derived by measurement in PR 4 — drift means the
    tiling changed), ops.DCT_INT32_SAFE_DIM must not exceed the derived
    half-phase bound."""
    from repro.kernels import dct_deltaw, fourier_deltaw, ops
    out: List[Finding] = []
    f_derived = derived_phase_bound(fourier_deltaw.CAPS)
    if ops.FOURIER_INT32_SAFE_DIM != f_derived:
        out.append(Finding(
            "kernels", "constant-drift", "ops.FOURIER_INT32_SAFE_DIM",
            f"declared {ops.FOURIER_INT32_SAFE_DIM} != derived {f_derived} "
            f"for the linear phase at bm={fourier_deltaw.CAPS['bm']}"))
    d_derived = derived_phase_bound(dct_deltaw.CAPS)
    if ops.DCT_INT32_SAFE_DIM > d_derived:
        out.append(Finding(
            "kernels", "constant-drift", "ops.DCT_INT32_SAFE_DIM",
            f"declared {ops.DCT_INT32_SAFE_DIM} exceeds derived {d_derived} "
            f"for the half phase at bm={dct_deltaw.CAPS['bm']}"))
    return out


def run() -> List[Finding]:
    """The full kernel pass: registry audit + declared-constant cross-check."""
    return audit_registry() + declared_constants_findings()
