"""Findings, reports, and the baseline gate (DESIGN.md §Analysis).

Every analyzer pass returns a flat list of `Finding`s. A finding's identity
is its `key` — ``section:rule:where`` — which is what the committed baseline
file (`analysis/baseline.json`) records: a known, justified finding that the
gate tolerates. The gate fails on NEW findings only (not in the baseline),
so the workflow for a finding is fix it, suppress it at the site
(`# repro: allow(<rule>)`, AST pass only), or baseline it WITH a written
justification — never ignore it.

Baseline format (versioned, human-editable)::

    {"version": 1,
     "findings": {"<section>:<rule>:<where>": "<justification>"}}

`--update-baseline` rewrites the file from the current findings, keeping
existing justifications and stamping new entries "TODO: justify".
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

BASELINE_VERSION = 1

# the committed repo baseline, importable by CI/tests/CLI alike
DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer finding. `section` is the pass ("hlo" | "kernels" |
    "ast" | "sharding"), `rule` the specific check, `where` a stable
    location string (file:line for AST, graph/op labels otherwise) — the
    three together are the baseline identity. `mult` carries loop
    multiplicity where it means something (HLO hot-loop findings)."""
    section: str
    rule: str
    where: str
    message: str
    mult: float = 1.0

    @property
    def key(self) -> str:
        return f"{self.section}:{self.rule}:{self.where}"

    def render(self) -> str:
        tail = f"  (x{self.mult:g} per call)" if self.mult > 1 else ""
        return f"[{self.section}/{self.rule}] {self.where}: {self.message}{tail}"


def load_baseline(path: Optional[str] = None) -> Dict[str, str]:
    """{finding key: justification}. A missing file is an empty baseline."""
    path = path or DEFAULT_BASELINE
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        data = json.load(f)
    if data.get("version") != BASELINE_VERSION:
        raise ValueError(f"{path}: unsupported baseline version "
                         f"{data.get('version')!r} (expected "
                         f"{BASELINE_VERSION})")
    return dict(data.get("findings", {}))


def save_baseline(findings: Sequence[Finding], path: Optional[str] = None,
                  old: Optional[Mapping[str, str]] = None) -> None:
    old = dict(old or {})
    entries = {f.key: old.get(f.key, "TODO: justify") for f in findings}
    path = path or DEFAULT_BASELINE
    with open(path, "w") as f:
        json.dump({"version": BASELINE_VERSION,
                   "findings": dict(sorted(entries.items()))}, f, indent=2)
        f.write("\n")


def diff(findings: Sequence[Finding],
         baseline: Mapping[str, str]) -> Tuple[List[Finding], List[str]]:
    """-> (new findings not in the baseline, stale baseline keys that no
    current finding matches). Stale keys don't fail the gate — they're a
    cleanup nudge printed with the report."""
    keys = {f.key for f in findings}
    new = [f for f in findings if f.key not in baseline]
    stale = sorted(k for k in baseline if k not in keys)
    return new, stale


def gate(findings: Sequence[Finding],
         baseline: Mapping[str, str]) -> int:
    """Exit code for the CI gate: 0 iff every finding is baselined."""
    new, _ = diff(findings, baseline)
    return 1 if new else 0


def to_json(findings: Sequence[Finding],
            baseline: Mapping[str, str]) -> Dict:
    """Machine-readable report (uploaded as a CI artifact and dumped next
    to BENCH_serve.json by benchmarks/bench_analysis.py)."""
    new, stale = diff(findings, baseline)
    counts: Dict[str, int] = {}
    for f in findings:
        counts[f.section] = counts.get(f.section, 0) + 1
    return {
        "version": BASELINE_VERSION,
        "counts": counts,
        "n_findings": len(findings),
        "n_new": len(new),
        "n_baselined": len(findings) - len(new),
        "new": [f.key for f in new],
        "stale_baseline": stale,
        "findings": [dataclasses.asdict(f) for f in findings],
    }


def render(findings: Sequence[Finding], baseline: Mapping[str, str]) -> str:
    """Human report: new findings first, then baselined ones, then stale
    baseline keys."""
    new, stale = diff(findings, baseline)
    newk = {f.key for f in new}
    lines: List[str] = []
    if new:
        lines.append(f"{len(new)} NEW finding(s) — fix, suppress, or "
                     "baseline with a justification:")
        lines += ["  " + f.render() for f in new]
    baselined = [f for f in findings if f.key not in newk]
    if baselined:
        lines.append(f"{len(baselined)} baselined finding(s):")
        lines += [f"  {f.render()}\n      justification: "
                  f"{baseline.get(f.key, '')}" for f in baselined]
    if stale:
        lines.append(f"{len(stale)} stale baseline entr(y/ies) — remove "
                     "from baseline.json:")
        lines += ["  " + k for k in stale]
    if not lines:
        lines.append("no findings.")
    return "\n".join(lines)
