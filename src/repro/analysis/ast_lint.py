"""Repo-specific Python AST lint (DESIGN.md §Analysis).

Rules (finding rule ids in parens):

- tracer-bool    — a Python truthiness/`int()`/`float()`/`bool()` coercion
                   of a DEVICE VALUE inside a traced function. `if x:` on a
                   tracer raises `ConcretizationTypeError` at trace time at
                   best; at worst (shape-dependent code that happens to run
                   under `eval_shape` only) it ships. Traced scope is
                   detected statically: functions decorated with
                   `jax.jit`/`functools.partial(jax.jit, …)`, functions
                   passed to `jax.jit`/`jax.lax.scan`/`while_loop`/
                   `fori_loop`/`cond`/`switch`/`vmap`/`grad`/
                   `value_and_grad`/`checkpoint`/`custom_vjp`, and anything
                   nested inside one.
- host-sync      — `np.asarray`/`np.array`/`jax.device_get`/`int`/`float`/
                   `bool` applied to a device expression ANYWHERE: a
                   device→host transfer point. Intended drain points carry
                   a `# repro: allow(host-sync)` suppression; everything
                   else is a candidate per-step stall.
- host-sync-in-loop — the same pattern lexically inside a `for`/`while`
                   body: the per-step round-trip that serialized the old
                   `SelfDrafter.propose` (serve/spec.py) — one transfer per
                   probe step instead of one per proposal.
- rng-in-jit     — `jax.random.PRNGKey(...)` inside a traced function: the
                   key is re-derived inside every call's graph, so "random"
                   is the same constant every step. Keys belong outside the
                   jit boundary, threaded in as arguments.

A "device expression" is (a) any call whose dotted callee starts with
`jnp.` / `jax.numpy.` / `jax.lax.` / `jax.nn.` / `jax.random.`, or (b) a
local name whose latest assignment was such a call (one hop — documented
limitation; the jaxpr/HLO pass owns whole-graph guarantees).

Suppressions: `# repro: allow(rule[, rule…])` on the finding's line or the
line directly above suppresses those rules for that line only.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.report import Finding

RULES = ("tracer-bool", "host-sync", "host-sync-in-loop", "rng-in-jit")

_ALLOW_RE = re.compile(r"#\s*repro:\s*allow\(([^)]*)\)")

# dotted-callee prefixes that produce device values
_DEVICE_PREFIXES = ("jnp.", "jax.numpy.", "jax.lax.", "jax.nn.",
                    "jax.random.")
# callees that force a device->host transfer of their argument
_SYNC_CALLEES = {"np.asarray", "np.array", "jax.device_get", "int", "float",
                 "bool"}
# tracing combinators: a function/lambda passed as any argument is traced
_TRACING_CALLEES = {
    "jax.jit", "jit", "jax.lax.scan", "jax.lax.while_loop",
    "jax.lax.fori_loop", "jax.lax.cond", "jax.lax.switch", "jax.vmap",
    "jax.grad", "jax.value_and_grad", "jax.checkpoint", "jax.custom_vjp",
    "jax.custom_jvp", "lax.scan", "lax.while_loop", "lax.fori_loop",
    "lax.cond", "lax.switch",
}


def _dotted(node: ast.AST) -> Optional[str]:
    """'jnp.stack' for Attribute chains, 'int' for bare Names."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_device_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    name = _dotted(node.func)
    return bool(name) and name.startswith(_DEVICE_PREFIXES)


def _contains_device_expr(node: ast.AST, device_names: Set[str]) -> bool:
    for sub in ast.walk(node):
        if _is_device_call(sub):
            return True
        if isinstance(sub, ast.Name) and sub.id in device_names:
            return True
    return False


def _allow_lines(src: str) -> Dict[int, Set[str]]:
    """{line number: {allowed rules}} from `# repro: allow(...)` comments."""
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(src.splitlines(), start=1):
        m = _ALLOW_RE.search(line)
        if m:
            out[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
    return out


def _decorated_traced(fn: ast.AST) -> bool:
    for dec in getattr(fn, "decorator_list", []):
        name = _dotted(dec.func if isinstance(dec, ast.Call) else dec)
        if name in ("jax.jit", "jit", "functools.partial"):
            if name == "functools.partial" and isinstance(dec, ast.Call):
                inner = _dotted(dec.args[0]) if dec.args else None
                if inner not in ("jax.jit", "jit"):
                    continue
            return True
    return False


class _Linter(ast.NodeVisitor):
    def __init__(self, filename: str, allow: Dict[int, Set[str]]):
        self.filename = filename
        self.allow = allow
        self.findings: List[Finding] = []
        self.traced_depth = 0
        self.loop_depth = 0
        # names whose latest assignment was a device-producing call; scoped
        # per function (saved/restored around def visits)
        self.device_names: Set[str] = set()
        # function defs passed to tracing combinators (collected in a first
        # pass over each module so `def body(...)` + `lax.scan(body, …)`
        # marks `body` traced regardless of statement order)
        self.traced_defs: Set[ast.AST] = set()

    # ---- reporting --------------------------------------------------------
    def _emit(self, rule: str, node: ast.AST, message: str) -> None:
        line = getattr(node, "lineno", 0)
        for probe in (line, line - 1):
            if rule in self.allow.get(probe, ()):  # inline suppression
                return
        self.findings.append(Finding(
            "ast", rule, f"{self.filename}:{line}", message))

    # ---- traced-scope bookkeeping ----------------------------------------
    def _collect_traced_defs(self, tree: ast.AST) -> None:
        """Names passed to tracing combinators anywhere in this module."""
        traced_names: Set[str] = set()
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            callee = _dotted(node.func)
            if callee not in _TRACING_CALLEES:
                continue
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                name = _dotted(arg)
                if name and "." not in name:
                    traced_names.add(name)
                if isinstance(arg, ast.Lambda):
                    self.traced_defs.add(arg)
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name in traced_names:
                self.traced_defs.add(node)

    def lint(self, tree: ast.AST) -> List[Finding]:
        self._collect_traced_defs(tree)
        self.visit(tree)
        return self.findings

    # ---- visitors ---------------------------------------------------------
    def _visit_fn(self, node) -> None:
        traced = (self.traced_depth > 0 or node in self.traced_defs
                  or _decorated_traced(node))
        saved_names, self.device_names = self.device_names, set()
        saved_loop, self.loop_depth = self.loop_depth, 0
        self.traced_depth += 1 if traced else 0
        self.generic_visit(node)
        self.traced_depth -= 1 if traced else 0
        self.device_names = saved_names
        self.loop_depth = saved_loop

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    def visit_Lambda(self, node: ast.Lambda) -> None:
        traced = self.traced_depth > 0 or node in self.traced_defs
        self.traced_depth += 1 if traced else 0
        self.generic_visit(node)
        self.traced_depth -= 1 if traced else 0

    def visit_Assign(self, node: ast.Assign) -> None:
        # bare names only (recursing through tuple/list unpacking) — an
        # attribute target like `self.x = jnp.f(...)` must NOT mark `self`
        names: List[ast.Name] = []

        def collect(t):
            if isinstance(t, ast.Name):
                names.append(t)
            elif isinstance(t, (ast.Tuple, ast.List)):
                for e in t.elts:
                    collect(e)
        for tgt in node.targets:
            collect(tgt)
        if _is_device_call(node.value):
            for name in names:
                self.device_names.add(name.id)
        else:
            for name in names:
                self.device_names.discard(name.id)
        self.generic_visit(node)

    def _loop(self, node) -> None:
        self.loop_depth += 1
        self.generic_visit(node)
        self.loop_depth -= 1

    visit_For = _loop
    visit_While = _loop

    def visit_If(self, node: ast.If) -> None:
        self._check_truthiness(node.test)
        self.generic_visit(node)

    def _check_truthiness(self, test: ast.AST) -> None:
        if self.traced_depth <= 0:
            return
        if _is_device_call(test) or (isinstance(test, ast.Name)
                                     and test.id in self.device_names):
            self._emit("tracer-bool", test,
                       "Python truthiness on a traced value — use "
                       "jnp.where / lax.cond, or hoist out of the jit")

    def visit_Call(self, node: ast.Call) -> None:
        callee = _dotted(node.func)
        if callee == "jax.random.PRNGKey" and self.traced_depth > 0:
            self._emit("rng-in-jit", node,
                       "PRNGKey built inside a traced function — the key "
                       "is a graph constant; thread it in as an argument")
        if callee in _SYNC_CALLEES and node.args:
            arg = node.args[0]
            if _contains_device_expr(arg, self.device_names):
                if callee in ("int", "float", "bool") \
                        and self.traced_depth > 0:
                    self._emit("tracer-bool", node,
                               f"{callee}() on a traced value inside a "
                               "traced function")
                elif self.loop_depth > 0:
                    self._emit("host-sync-in-loop", node,
                               f"{callee}(...) forces a device→host "
                               "transfer on every loop iteration — "
                               "accumulate on device, drain once")
                else:
                    self._emit("host-sync", node,
                               f"{callee}(...) on a device expression is a "
                               "device→host sync point")
        self.generic_visit(node)


def lint_source(src: str, filename: str = "<string>") -> List[Finding]:
    tree = ast.parse(src, filename=filename)
    return _Linter(filename, _allow_lines(src)).lint(tree)


def lint_file(path: str, root: Optional[str] = None) -> List[Finding]:
    with open(path) as f:
        src = f.read()
    rel = os.path.relpath(path, root) if root else path
    return lint_source(src, rel)


def lint_paths(paths: Iterable[str],
               root: Optional[str] = None) -> List[Finding]:
    """Lint every .py under the given files/directories (sorted, stable)."""
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for dirpath, _, names in os.walk(p):
                files += [os.path.join(dirpath, n) for n in names
                          if n.endswith(".py")]
        elif p.endswith(".py"):
            files.append(p)
    out: List[Finding] = []
    for f in sorted(files):
        out += lint_file(f, root=root)
    return out
