"""Sharding-coverage audit (DESIGN.md §Analysis).

`dist/sharding.py` places parameters by LEAF NAME, and any name matching no
rule table silently replicates. That fall-through is how the mamba2/hybrid
families initially shipped with undecided placements: the engine never
errored, it just replicated whatever it didn't recognize. This pass makes
the decision explicit — it walks `init_shapes()` (eval_shape; nothing
materializes) for every registered arch × a representative set of adapter
methods and flags every leaf whose `sharding.rule_kind` is None, i.e. a
parameter nobody placed. The fix is always to add the leaf name to one of
the four tables in dist/sharding.py (`_COLUMN`/`_ROW`/`_EXPERT`/
`_REPLICATE`), making replication a decision instead of an accident.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

from repro.analysis.report import Finding

# one method per distinct adapter-param leaf set: fourier/dct share "c"
# (+ spectral aux), lora has lora_a/lora_b, circulant has kernel+b1/b2,
# bitfit has delta_b — together they exercise every adapter leaf name.
DEFAULT_METHODS = ("fourierft", "dct", "lora", "circulant", "bitfit")


def _iter_leaves(tree, path=()):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _iter_leaves(v, path + (str(k),))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _iter_leaves(v, path + (str(i),))
    else:
        yield "/".join(path), tuple(getattr(tree, "shape", ()))


def audit_tree(tree, label: str) -> List[Finding]:
    """Flag every leaf of a param(-shape) tree that resolves through the
    silent replicate fall-through instead of a named rule table."""
    from repro.dist import sharding
    out: List[Finding] = []
    seen = set()
    for path, shape in _iter_leaves(tree):
        name = path.split("/")[-1]
        if sharding.rule_kind(path, shape) is not None or name in seen:
            continue
        seen.add(name)                 # one finding per leaf NAME per tree
        out.append(Finding(
            "sharding", "uncovered", f"{label}/{name}",
            f"param leaf {path!r} (shape {shape}) matches no rule table in "
            "dist/sharding.py — it replicates by fall-through, not by "
            "decision; add the name to _COLUMN/_ROW/_EXPERT/_REPLICATE"))
    return out


def run(methods: Tuple[str, ...] = DEFAULT_METHODS,
        archs: Optional[Tuple[str, ...]] = None) -> List[Finding]:
    """Audit every registered arch's param tree. The adapter-method sweep
    runs on the first arch only — adapter leaf names don't vary per family,
    and eval_shape per combination isn't free."""
    from repro.models import registry
    out: List[Finding] = []
    first_arch = None
    for arch, method, model in registry.analysis_models(
            methods=(methods[0],), archs=archs):
        first_arch = first_arch or arch
        out += audit_tree(model.init_shapes(), f"{arch}[{method}]")
    if first_arch is not None and len(methods) > 1:
        for arch, method, model in registry.analysis_models(
                methods=methods[1:], archs=(first_arch,)):
            out += audit_tree(model.init_shapes(), f"{arch}[{method}]")
    return out
