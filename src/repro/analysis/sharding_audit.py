"""Sharding-coverage audit (DESIGN.md §Analysis, §Sharding).

Placement is name-keyed, and any leaf matching no named decision silently
replicates (params) or rides the generic batch fall-through (caches/
batches). That fall-through is how the mamba2/hybrid families initially
shipped with undecided placements: the engine never errored, it just
replicated whatever it didn't recognize. This pass makes the decision
explicit — but since PR 10 it audits the RESOLVED PLAN, i.e. whatever
`dist/plan.PlanSource` actually produced for the cell (the rule table by
default, a searched or checked-in plan otherwise), via
`PlanSource.decision(section, path, shape)`. A plan-table hit counts as a
decision; a miss falls back to the source's fallback rules, and only a leaf
NO layer decided is flagged.

Coverage spans every tree serving and training place:

- param/state trees for every registered arch × a representative set of
  adapter methods ("state" section);
- decode caches — dense per-slot AND the paged page-pool — plus the serve
  batch leaves (block tables, adapter slot rows, scratch pages) and
  adapter-bank row stacks ("cache"/"batch"/"state" sections), so a searched
  plan can't silently leave a serving leaf unplaced.

The fix for a finding is to add the leaf name to the matching table in
dist/sharding.py (or ship a plan entry for it), making the placement a
decision instead of an accident.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

from repro.analysis.report import Finding

# one method per distinct adapter-param leaf set: fourier/dct share "c"
# (+ spectral aux), lora has lora_a/lora_b, circulant has kernel+b1/b2,
# bitfit has delta_b — together they exercise every adapter leaf name.
DEFAULT_METHODS = ("fourierft", "dct", "lora", "circulant", "bitfit")

# serve-coverage geometry (shapes only — nothing materializes)
_SERVE_SLOTS = 4
_SERVE_LEN = 64
_PAGE_SIZE = 8
_N_PAGES = 16
_BANK_K = 2


def _iter_leaves(tree, path=()):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _iter_leaves(v, path + (str(k),))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _iter_leaves(v, path + (str(i),))
    else:
        yield "/".join(path), tuple(getattr(tree, "shape", ()))


def _default_source():
    from repro.dist import plan as plan_mod
    return plan_mod.RulesSource()


def audit_tree(tree, label: str, section: str = "state",
               source=None) -> List[Finding]:
    """Flag every leaf of a (shape) tree that the resolved plan source left
    undecided — the silent fall-through instead of a named decision."""
    if source is None:
        source = _default_source()
    out: List[Finding] = []
    seen = set()
    for path, shape in _iter_leaves(tree):
        name = path.split("/")[-1]
        if source.decision(section, path, shape) is not None or name in seen:
            continue
        seen.add(name)                 # one finding per leaf NAME per tree
        out.append(Finding(
            "sharding", "uncovered", f"{label}/{name}",
            f"{section} leaf {path!r} (shape {shape}) has no placement "
            f"decision from the resolved plan source "
            f"({source.describe().get('source')}) — it falls through "
            "undecided; add the name to a dist/sharding.py table or ship a "
            "plan entry for it"))
    return out


def _serve_trees(model):
    """(tree, label-suffix, section) triples for the serving surfaces:
    dense + paged decode caches, the decode batch (incl. block table and
    scratch pages), and adapter-bank row stacks."""
    import jax
    import jax.numpy as jnp
    out = []
    slot_cache = bool(model.supports_slot_cache)
    try:
        dense = jax.eval_shape(lambda: model.init_cache(
            _SERVE_SLOTS, _SERVE_LEN, per_slot=slot_cache))
        out.append((dense, "cache", "cache"))
    except Exception:
        pass
    if slot_cache:
        try:
            paged = jax.eval_shape(lambda: model.init_cache(
                _SERVE_SLOTS, _SERVE_LEN, paged=True,
                page_size=_PAGE_SIZE, n_pages=_N_PAGES))
            out.append((paged, "paged-cache", "cache"))
        except Exception:
            pass
    i32 = jnp.int32
    pages_per_seq = _SERVE_LEN // _PAGE_SIZE
    batch = {
        "tokens": jax.ShapeDtypeStruct((_SERVE_SLOTS, 1), i32),
        "block_table": jax.ShapeDtypeStruct((_SERVE_SLOTS, pages_per_seq),
                                            i32),
        "adapter_slots": jax.ShapeDtypeStruct((_SERVE_SLOTS,), i32),
        "true_len": jax.ShapeDtypeStruct((_SERVE_SLOTS,), i32),
        "prefix_len": jax.ShapeDtypeStruct((), i32),
        "slot": jax.ShapeDtypeStruct((), i32),
        "scratch_pages": jax.ShapeDtypeStruct((_SERVE_SLOTS,), i32),
    }
    out.append((batch, "serve-batch", "batch"))
    # adapter-bank rows: the peft site leaves with the (K+1,) bank-row dim
    # prepended — name-keyed placement must still cover them
    peft_tree = model.init_shapes().get("peft")
    if peft_tree:
        bank = {
            path: jax.ShapeDtypeStruct((_BANK_K + 1,) + shape, jnp.float32)
            for path, shape in _iter_leaves(peft_tree)}
        out.append((bank, "bank-rows", "state"))
    return out


def run(methods: Tuple[str, ...] = DEFAULT_METHODS,
        archs: Optional[Tuple[str, ...]] = None,
        source=None) -> List[Finding]:
    """Audit every registered arch's param tree, plus the serving surfaces
    (caches/batch/bank) on the first arch. The adapter-method sweep runs on
    the first arch only — adapter leaf names don't vary per family, and
    eval_shape per combination isn't free. `source` defaults to the rules;
    pass a `PlanTableSource` to audit a searched/loaded plan instead."""
    from repro.models import registry
    if source is None:
        source = _default_source()
    out: List[Finding] = []
    first = serve_pick = None
    for arch, method, model in registry.analysis_models(
            methods=(methods[0],), archs=archs):
        first = first or (arch, model)
        # the serve surfaces (paged cache, block tables) need the slot-cache
        # families — audit them on the first arch that has one
        if serve_pick is None and bool(model.supports_slot_cache):
            serve_pick = (arch, model)
        out += audit_tree(model.init_shapes(), f"{arch}[{method}]",
                          source=source)
    if first is not None and len(methods) > 1:
        for arch, method, model in registry.analysis_models(
                methods=methods[1:], archs=(first[0],)):
            out += audit_tree(model.init_shapes(), f"{arch}[{method}]",
                              source=source)
    if first is not None:
        arch, model = serve_pick or first
        for tree, suffix, section in _serve_trees(model):
            out += audit_tree(tree, f"{arch}[{suffix}]", section=section,
                              source=source)
    return out
