"""Representative compiled graphs for the analyzer (DESIGN.md §Analysis).

The jaxpr/HLO pass needs actual graphs to lint. This module builds the two
hot paths the repo ships — the train step and the continuous-batching
serve loop — at reduced scale (2 layers, width 64, vocab 64: the same
tiny-model recipe the test suite uses; seconds on CPU) and feeds them
through hlo_lint:

- **train_step**: lowered + compiled with the real mesh shardings and
  donated state (train/step.make_sharded_train_step), checked for host
  transfers, f32-literal upcasts (the graph is bf16 by default — exactly
  where a stray np.float32 constant hurts), and wasted donations.
- **serve**: a micro traffic replay through ContinuousScheduler (paged,
  plus a SelfDrafter variant), checked against the scheduler's own
  `expected_compile_bounds()` recompile contract, and the decode graph's
  HLO/jaxpr linted for host transfers and callbacks — the decode loop is
  where one stray sync costs a stall PER TOKEN.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.analysis import hlo_lint
from repro.analysis.report import Finding


def _tiny_train_model():
    import repro.configs as C
    from repro.configs.base import PEFTConfig
    from repro.models import registry
    cfg = C.reduced(C.get("yi-6b")).replace(vocab=64)
    return registry.build(cfg, PEFTConfig(method="fourierft", n=16,
                                          alpha=10.0))


def _tiny_serve_model():
    import repro.configs as C
    from repro.configs.base import PEFTConfig
    from repro.models import registry
    # f32 like the serving tests: bit-exactness there pins this recipe
    cfg = C.reduced(C.get("yi-6b")).replace(vocab=64, dtype="float32",
                                            param_dtype="float32")
    return registry.build(cfg, PEFTConfig(method="none"))


def train_findings() -> List[Finding]:
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.configs.base import TrainConfig
    from repro.train import step as ts
    model = _tiny_train_model()
    tcfg = TrainConfig(total_steps=4)
    state, frozen = ts.init_state(model, tcfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "labels": jnp.zeros((2, 16), jnp.int32)}
    out: List[Finding] = []
    jaxpr = jax.make_jaxpr(ts.make_train_step(model, tcfg))(state, frozen,
                                                            batch)
    out += hlo_lint.lint_jaxpr(jaxpr, "train_step")
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    state, frozen, st_sh, fr_sh = ts.shard_train_state(model, state, frozen,
                                                       mesh, fsdp=False)
    jitted, b_sh = ts.make_sharded_train_step(model, tcfg, mesh, state,
                                              frozen, batch,
                                              shardings=(st_sh, fr_sh))
    batch = jax.device_put(batch, b_sh)
    txt = jitted.lower(state, frozen, batch).compile().as_text()
    out += hlo_lint.lint_hlo_text(txt, "train_step")
    n_donated = len(jax.tree_util.tree_leaves(state))
    out += hlo_lint.donation_findings(txt, "train_step", n_donated)
    return out


def serve_findings() -> List[Finding]:
    import jax
    import jax.numpy as jnp
    from repro.serve import ContinuousScheduler, Engine, Request, SelfDrafter
    model = _tiny_serve_model()
    params = model.init(jax.random.PRNGKey(0))
    out: List[Finding] = []

    def trace(budgets):
        return [Request(prompt=jnp.asarray([(3 * i + j) % 64
                                            for j in range(3 + i)],
                                           jnp.int32), max_new=b)
                for i, b in enumerate(budgets)]

    eng = Engine(model, params, batch_slots=2, max_len=32)
    sched = ContinuousScheduler(eng, page_size=8)
    sched.serve(trace([3, 2, 4]))
    out += hlo_lint.scheduler_recompile_findings(sched, "serve/paged")

    # the decode step exactly as the scheduler dispatches it
    batch = {"tokens": jnp.zeros((2, 1), jnp.int32),
             "block_table": sched.pager.block_table_device()}
    out += hlo_lint.lint_jaxpr(
        jax.make_jaxpr(model.decode_step)(eng.params, sched.cache, batch),
        "serve/decode")
    txt = eng._decode.lower(eng.params, sched.cache,
                            batch).compile().as_text()
    out += hlo_lint.lint_hlo_text(txt, "serve/decode")

    eng2 = Engine(model, params, batch_slots=2, max_len=32)
    sched2 = ContinuousScheduler(eng2, page_size=8, drafter=SelfDrafter(k=2))
    sched2.serve(trace([4, 3]))
    out += hlo_lint.scheduler_recompile_findings(sched2, "serve/spec")
    return out


def run() -> List[Finding]:
    return train_findings() + serve_findings()
