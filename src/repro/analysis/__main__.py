"""CLI for the static analyzer: `python -m repro.analysis [--all|passes]`.

Exit code 0 iff every finding is in the committed baseline (report.gate);
CI runs `--all --json analysis-report.json` as a blocking step. The AST
pass is pure source analysis (fast); `--graphs` traces/compiles the tiny
train/serve graphs (seconds on CPU); `--kernels`/`--sharding` sit in
between. `--update-baseline` rewrites the baseline from the current
findings, keeping existing justifications.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List

from repro.analysis import report
from repro.analysis.report import Finding


def _ast_findings(paths: List[str]) -> List[Finding]:
    from repro.analysis import ast_lint
    if not paths:
        import repro
        pkg = os.path.dirname(os.path.abspath(repro.__file__))
        return ast_lint.lint_paths([pkg], root=os.path.dirname(pkg))
    return ast_lint.lint_paths(paths)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis",
        description="static hot-path analyzer (DESIGN.md §Analysis)")
    ap.add_argument("--all", action="store_true",
                    help="run every pass (default when none selected)")
    ap.add_argument("--ast", action="store_true",
                    help="Python source lint over src/repro (or PATHS)")
    ap.add_argument("--kernels", action="store_true",
                    help="kernel capability verifier")
    ap.add_argument("--sharding", action="store_true",
                    help="sharding-coverage audit")
    ap.add_argument("--graphs", action="store_true",
                    help="jaxpr/HLO lint of the train/serve graphs")
    ap.add_argument("--json", metavar="PATH",
                    help="also write the machine-readable report here")
    ap.add_argument("--baseline", metavar="PATH",
                    help=f"baseline file (default {report.DEFAULT_BASELINE})")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from the current findings "
                         "(keeps existing justifications) and exit 0")
    ap.add_argument("paths", nargs="*",
                    help="files/dirs for the AST pass (default: src/repro)")
    args = ap.parse_args(argv)

    chosen = args.ast or args.kernels or args.sharding or args.graphs
    run_all = args.all or not chosen
    findings: List[Finding] = []
    if run_all or args.ast:
        findings += _ast_findings(args.paths)
    if run_all or args.kernels:
        from repro.analysis import kernel_audit
        findings += kernel_audit.run()
    if run_all or args.sharding:
        from repro.analysis import sharding_audit
        findings += sharding_audit.run()
    if run_all or args.graphs:
        from repro.analysis import graphs
        findings += graphs.run()

    baseline = report.load_baseline(args.baseline)
    if args.update_baseline:
        report.save_baseline(findings, args.baseline, old=baseline)
        print(f"baseline updated: {len(findings)} finding(s) -> "
              f"{args.baseline or report.DEFAULT_BASELINE}")
        return 0
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report.to_json(findings, baseline), f, indent=2)
            f.write("\n")
    print(report.render(findings, baseline))
    return report.gate(findings, baseline)


if __name__ == "__main__":
    sys.exit(main())
