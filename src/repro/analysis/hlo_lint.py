"""Jaxpr/HLO lint (DESIGN.md §Analysis).

Graph-level checks over what ACTUALLY lowered, complementing the source
lint in ast_lint.py (which sees intent, not the compiled artifact):

- **host transfers** (`host-transfer`, `host-transfer-in-loop`): infeed/
  outfeed/send/recv opcodes and host-callback custom-calls in optimized
  HLO, weighted by call-graph multiplicity via `dist.hlo.iter_instrs` — a
  callback inside a while body at trip count 1024 is 1024 stalls per step,
  which is the difference the `in_loop` variant exists to surface.
- **callbacks in jaxprs** (`callback`, `callback-in-loop`): io_callback/
  pure_callback/debug_callback primitives, caught at the jaxpr level too
  because jaxprs keep source provenance the optimized HLO loses.
- **fp32-literal upcasts** (`upcast-f32-literal`): a binary arithmetic eqn
  combining an f32 scalar literal with a value converted UP from bf16/f16
  — the classic `x * np.float32(c)` that silently drags a reduced-
  precision graph into f32 (a weak Python float stays bf16 and never
  trips this; only direct convert outputs are matched, so downstream
  ops of an intentional f32 accumulation region don't flood the report).
- **donation** (`donation-miss`): a module compiled with donated inputs
  whose `input_output_alias` header aliases fewer entry params than were
  donated. XLA silently drops unusable donations — the buffer stays live
  and peak memory is one full copy higher than the code claims.
- **recompiles** (`recompile-budget`): a jitted callable's signature count
  (`_cache_size()`) exceeding its declared bound — the static replacement
  for the old probe in tests/test_serve_paging.py, backed by the
  scheduler's own `expected_compile_bounds()` contract.
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional

from repro.analysis.report import Finding
from repro.dist import hlo

_TRANSFER_OPCODES = {"infeed", "outfeed", "send", "recv"}
_HOST_TARGET_MARKS = ("callback", "host", "infeed", "outfeed")
_CALLBACK_PRIMS = ("callback",)            # io_/pure_/debug_callback
_LOOP_PRIMS = {"while", "scan"}
_BINARY_ARITH = {"add", "sub", "mul", "div", "max", "min"}
_SMALL_FLOATS = ("bfloat16", "float16")


# ---------------------------------------------------------------------------
# HLO text
# ---------------------------------------------------------------------------

def lint_hlo_text(txt: str, label: str) -> List[Finding]:
    """Host-transfer findings over optimized HLO (`compiled.as_text()`).
    Findings aggregate per (opcode-or-target, in_loop) so baseline keys stay
    stable across recompiles; `mult` carries total per-call executions."""
    comps, entry = hlo.parse_module(txt)
    agg: Dict[tuple, float] = {}
    for ins, mult, in_loop in hlo.iter_instrs(comps, entry):
        what = None
        base = ins.opcode[:-5] if ins.opcode.endswith("-done") else ins.opcode
        base = base[:-6] if base.endswith("-start") else base
        if base in _TRANSFER_OPCODES:
            what = base
        elif ins.opcode == "custom-call":
            target = hlo.custom_call_target(ins) or ""
            if any(m in target for m in _HOST_TARGET_MARKS):
                what = target
        if what is not None:
            agg[(what, in_loop)] = agg.get((what, in_loop), 0.0) + mult
    out: List[Finding] = []
    for (what, in_loop), mult in sorted(agg.items()):
        rule = "host-transfer-in-loop" if in_loop else "host-transfer"
        detail = ("inside a compiled loop body — it stalls every iteration"
                  if in_loop else "a device→host round-trip per call")
        out.append(Finding("hlo", rule, f"{label}/{what}",
                           f"host transfer '{what}' in the compiled module: "
                           f"{detail}", mult=mult))
    return out


# ---------------------------------------------------------------------------
# jaxprs
# ---------------------------------------------------------------------------

def _sub_jaxprs(params):
    for v in params.values():
        for cand in (v if isinstance(v, (list, tuple)) else (v,)):
            inner = getattr(cand, "jaxpr", cand)
            if hasattr(inner, "eqns"):
                yield inner


def _is_literal(v) -> bool:
    return hasattr(v, "val") and not hasattr(v, "count")


def lint_jaxpr(jaxpr, label: str) -> List[Finding]:
    """Callback + upcast findings over a (closed) jaxpr. Findings aggregate
    per (rule, primitive, in_loop); `mult` counts occurrences (jaxprs carry
    no trip counts — the HLO pass owns multiplicity)."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    agg: Dict[tuple, float] = {}

    def rec(jx, in_loop: bool) -> None:
        upcast = set()                    # outvars of small-float → f32 converts
        for eqn in jx.eqns:
            prim = eqn.primitive.name
            if any(mark in prim for mark in _CALLBACK_PRIMS):
                key = ("callback-in-loop" if in_loop else "callback", prim)
                agg[key] = agg.get(key, 0.0) + 1
            if prim == "convert_element_type":
                v = eqn.invars[0]
                src = getattr(getattr(v, "aval", None), "dtype", None)
                dst = getattr(getattr(eqn.outvars[0], "aval", None),
                              "dtype", None)
                if str(src) in _SMALL_FLOATS and str(dst) == "float32":
                    upcast.add(eqn.outvars[0])
            if prim in _BINARY_ARITH and len(eqn.invars) == 2:
                a, b = eqn.invars
                for lit, other in ((a, b), (b, a)):
                    if (_is_literal(lit)
                            and str(getattr(lit.aval, "dtype", "")) == "float32"
                            and not getattr(lit.aval, "shape", ())
                            and not _is_literal(other)
                            and other in upcast):
                        key = ("upcast-f32-literal", prim)
                        agg[key] = agg.get(key, 0.0) + 1
            for sub in _sub_jaxprs(eqn.params):
                rec(sub, in_loop or prim in _LOOP_PRIMS)

    rec(jaxpr, False)
    out: List[Finding] = []
    for (rule, prim), mult in sorted(agg.items()):
        msg = {
            "callback": f"'{prim}' primitive in the traced graph — a host "
                        "round-trip baked into the compiled step",
            "callback-in-loop": f"'{prim}' inside a scan/while body — a host "
                                "stall on every loop iteration",
            "upcast-f32-literal": "f32 scalar literal combined with a value "
                                  "upcast from bf16/f16 — this op runs in "
                                  "f32; cast the constant down (or keep it "
                                  "a weak Python float), or baseline if "
                                  "the f32 region is deliberate",
        }[rule]
        out.append(Finding("hlo", rule, f"{label}/{prim}", msg, mult=mult))
    return out


# ---------------------------------------------------------------------------
# donation / recompiles
# ---------------------------------------------------------------------------

def donation_findings(txt: str, label: str, n_donated: int) -> List[Finding]:
    """Compare a compiled module's `input_output_alias` header against how
    many flat inputs the call site donated. Fewer aliased params than
    donated means XLA dropped donations as unusable."""
    aliased = hlo.aliased_params(txt)
    if n_donated and len(aliased) < n_donated:
        return [Finding(
            "hlo", "donation-miss", label,
            f"{n_donated} inputs donated but only {len(aliased)} aliased in "
            "input_output_alias — the rest stay live (wasted donation; "
            "check dtype/shape/sharding match between donated input and "
            "output)")]
    return []


def signature_count(jitfn) -> int:
    """Number of compiled signatures a jitted callable holds."""
    return int(jitfn._cache_size())


def recompile_findings(counts: Mapping[str, int],
                       bounds: Mapping[str, int],
                       label: str) -> List[Finding]:
    """Flag every compiled graph whose signature count exceeds its declared
    bound (see ContinuousScheduler.expected_compile_bounds)."""
    out: List[Finding] = []
    for name in sorted(counts):
        bound = bounds.get(name)
        if bound is not None and counts[name] > bound:
            out.append(Finding(
                "hlo", "recompile-budget", f"{label}/{name}",
                f"{counts[name]} compiled signatures for '{name}' exceeds "
                f"the declared bound {bound} — a shape leaked past the pow2 "
                "bucketing (serve/scheduler/runtime.py _bucket)"))
    return out


def scheduler_recompile_findings(sched, label: str = "serve") -> List[Finding]:
    """Recompile audit of a live ContinuousScheduler after it has served
    traffic: actual signature counts vs its own declared bounds."""
    return recompile_findings(sched.compiled_signatures(),
                              sched.expected_compile_bounds(), label)
