"""LR schedules: warmup + {linear, cosine, constant}."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import TrainConfig


def lr_at(step, cfg: TrainConfig):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.maximum(cfg.warmup_steps, 1)
    # (step + 1): the very first step trains at lr/warmup, not zero
    warm_frac = jnp.minimum((step + 1.0) / warm, 1.0)
    total = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) / total, 0.0, 1.0)
    if cfg.schedule == "linear":
        decay = 1.0 - prog
    elif cfg.schedule == "cosine":
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    else:
        decay = 1.0
    return cfg.learning_rate * warm_frac * decay
