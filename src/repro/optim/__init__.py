from repro.optim import adamw, schedules
