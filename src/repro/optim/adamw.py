"""AdamW with decoupled weight decay, from scratch (no optax in env).

Functional: `init(params) -> opt_state`, `update(grads, opt_state, params,
lr, cfg) -> (new_params, new_opt_state)`. Works on arbitrary pytrees.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


def init(params) -> Dict:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.float32(0.0)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def update(grads, opt_state: Dict, params, lr, cfg: TrainConfig) -> Tuple:
    count = opt_state["count"] + 1
    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      opt_state["mu"], grads)
    nu = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        opt_state["nu"], grads)
    c1 = 1 - b1 ** count.astype(jnp.float32)
    c2 = 1 - b2 ** count.astype(jnp.float32)

    def upd(p, m, v):
        step = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        if cfg.weight_decay:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "count": count}
