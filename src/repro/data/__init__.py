from repro.data.synthetic import SyntheticClassification, SyntheticLM
