"""Deterministic, seekable synthetic data pipeline.

Every batch is a pure function of (seed, step) via stateless PRNG folding —
the property that makes the whole fault-tolerance story work: any host can
regenerate any shard of any step after a restart, elastic rescale, or
straggler re-assignment, with no iterator state to checkpoint and no data
loss/replay.

Sequences are drawn from a fixed first-order Markov "teacher" (seeded
transition table), so models measurably learn; fine-tuning benchmarks use a
second teacher seed as the "downstream task".
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def markov_table(vocab: int, task_seed: int, concentration: float = 1.5):
    key = jax.random.PRNGKey(task_seed)
    logits = jax.random.normal(key, (vocab, vocab)) * concentration
    return logits


def sample_markov(key: jax.Array, table: jax.Array, batch: int, seq: int):
    vocab = table.shape[0]
    k0, key = jax.random.split(key)
    first = jax.random.randint(k0, (batch,), 0, vocab)

    def step(tok, k):
        nxt = jax.random.categorical(k, table[tok])
        return nxt, nxt

    _, rest = jax.lax.scan(step, first, jax.random.split(key, seq - 1))
    return jnp.concatenate([first[None], rest], axis=0).T.astype(jnp.int32)


@dataclass
class SyntheticLM:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    task_seed: int = 1
    codebooks: int = 0

    def __post_init__(self):
        self._table = markov_table(self.vocab, self.task_seed)
        self._sample = jax.jit(
            lambda key: sample_markov(key, self._table, self.batch,
                                      self.seq + 1))

    def batch_at(self, step: int, shard: int = 0, num_shards: int = 1) -> Dict:
        """Batch for global `step`; `shard`/`num_shards` carve the global
        batch deterministically for multi-host loading."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        toks = self._sample(key)                      # (B, seq+1)
        if num_shards > 1:
            per = self.batch // num_shards
            toks = toks[shard * per:(shard + 1) * per]
        tokens, labels = toks[:, :-1], toks[:, 1:]
        if self.codebooks:
            tokens = jnp.repeat(tokens[..., None], self.codebooks, axis=-1)
            labels = jnp.repeat(labels[..., None], self.codebooks, axis=-1)
        return {"tokens": tokens, "labels": labels}


@dataclass
class SyntheticClassification:
    """K-class Gaussian-blob classification (paper Appendix C.2 setting)."""
    num_classes: int = 8
    dim: int = 2
    noise: float = 0.4
    seed: int = 0

    def dataset(self, n_per_class: int = 64):
        rng = np.random.default_rng(self.seed)
        angles = np.linspace(0, 2 * np.pi, self.num_classes, endpoint=False)
        centers = np.stack([np.cos(angles), np.sin(angles)], -1) * 2.0
        if self.dim > 2:
            centers = np.concatenate(
                [centers, np.zeros((self.num_classes, self.dim - 2))], -1)
        xs, ys = [], []
        for c in range(self.num_classes):
            xs.append(centers[c] + rng.normal(size=(n_per_class, self.dim))
                      * self.noise)
            ys.append(np.full(n_per_class, c))
        x = np.concatenate(xs).astype(np.float32)
        y = np.concatenate(ys).astype(np.int32)
        perm = rng.permutation(len(y))
        return jnp.asarray(x[perm]), jnp.asarray(y[perm])
