"""Alpha-beta cluster cost model over the (pod, data, model) mesh
(DESIGN.md §Sharding).

The planner (`dist/planner.py`) needs to compare candidate placements
WITHOUT compiling anything, so this module prices the primitives a placement
implies — collectives per kind and axis, HBM traffic, resharding between
layouts — from a handful of per-axis link constants, alpa-style
(PAPERS.md "Alpa"; SNIPPETS.md Snippet 1):

    cost(collective over axes A, n bytes)
        = alpha(A) + chunk_factor(kind, |A|) * n / beta(A)

where `alpha` is the per-launch latency (summed over the axes the replica
group spans — a (pod, data) group pays the DCN hop), `beta` the bandwidth of
the SLOWEST link in the group, and `chunk_factor` the textbook ring terms:
2(n-1)/n for all-reduce, (n-1)/n for all-gather / reduce-scatter /
all-to-all, 1 for collective-permute.

Calibration: the default constants are the same v5e numbers the dry-run
roofline uses (`launch/dryrun_lib`: HBM 819 GB/s, ICI 50 GB/s single-link
pessimistic, bf16 peak 197 TF/s) plus a slower `pod` link for the cross-pod
DCN hop. The point is NOT absolute accuracy — it is that predictions
rank-correlate with the per-kind collective traffic and HBM-bound terms
`dist/hlo.py` measures on compiled modules; `benchmarks/bench_analysis.py`
reports that correlation over the `dryrun_baseline_v0` fleet on every run
(`sharding_plan_*` rows in BENCH_analysis.json).

`MeshSpec` is an abstract mesh — axis names and sizes only, no devices — so
planning/scoring runs anywhere (the 256-chip production cells plan fine on a
laptop). It duck-types the two attributes `dist/sharding.py`'s helpers read
(`axis_names`, `devices.shape`), so the rule engine evaluates against it
unchanged; materializing a plan (`named()`) still needs a real Mesh.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, Optional, Sequence, Tuple, Union

import numpy as np

# v5e per-chip constants — deliberately identical to launch/dryrun_lib's
# roofline so predicted and analyzer-measured terms live on one scale.
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
HBM_BYTES = 16e9           # capacity
ICI_BW = 50e9              # bytes/s per link (pessimistic single-link)
DCN_BW = 12.5e9            # cross-pod link (slower, higher-latency hop)


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """One mesh axis's interconnect: per-collective launch latency and
    per-device link bandwidth."""
    alpha_s: float
    beta_bytes_s: float


DEFAULT_LINKS: Dict[str, LinkSpec] = {
    "pod": LinkSpec(alpha_s=2e-5, beta_bytes_s=DCN_BW),
    "data": LinkSpec(alpha_s=1e-6, beta_bytes_s=ICI_BW),
    "model": LinkSpec(alpha_s=1e-6, beta_bytes_s=ICI_BW),
}

# chunk factors for ring algorithms, as a function of group size n
_CHUNK = {
    "all-reduce": lambda n: 2.0 * (n - 1) / n,
    "all-gather": lambda n: (n - 1) / n,
    "reduce-scatter": lambda n: (n - 1) / n,
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}


class _AbstractDevices:
    """Shape/size stand-in for `Mesh.devices` (never holds devices)."""

    __slots__ = ("shape", "size")

    def __init__(self, shape: Tuple[int, ...]):
        self.shape = tuple(int(s) for s in shape)
        self.size = int(np.prod(self.shape)) if self.shape else 1


class MeshSpec:
    """Abstract mesh: ordered {axis name: size}. Duck-types the subset of
    `jax.sharding.Mesh` that `dist/sharding.py` reads (`axis_names`,
    `devices.shape`/`.size`), so rule evaluation and planning never need
    real devices."""

    def __init__(self, axes: Dict[str, int]):
        self.axes = {str(k): int(v) for k, v in axes.items()}
        self.axis_names = tuple(self.axes)
        self.devices = _AbstractDevices(tuple(self.axes.values()))

    @classmethod
    def from_mesh(cls, mesh) -> "MeshSpec":
        """From a real Mesh (or another MeshSpec — idempotent)."""
        if isinstance(mesh, MeshSpec):
            return mesh
        return cls(dict(zip(mesh.axis_names, mesh.devices.shape)))

    @classmethod
    def from_string(cls, spec: str) -> "MeshSpec":
        """'16x16' -> (data 16, model 16); a 3-dim spec adds 'pod' — the
        same convention as launch/mesh.parse_mesh_shape."""
        dims = tuple(int(x) for x in spec.split("x"))
        if not 1 <= len(dims) <= 3:
            raise ValueError(f"mesh spec {spec!r}: want 1-3 dims")
        names = ("pod", "data", "model")[-len(dims):]
        return cls(dict(zip(names, dims)))

    def axis_size(self, axis: str) -> int:
        return self.axes.get(axis, 1)

    @property
    def size(self) -> int:
        return self.devices.size

    def __repr__(self) -> str:
        return f"MeshSpec({self.axes})"


def _axes_of(spec_entry) -> Tuple[str, ...]:
    """Axis names of one PartitionSpec dim entry (None | str | tuple)."""
    if spec_entry is None:
        return ()
    if isinstance(spec_entry, str):
        return (spec_entry,)
    return tuple(spec_entry)


def spec_axes(spec) -> Tuple[str, ...]:
    """All mesh axes a PartitionSpec shards over, in appearance order."""
    out = []
    for entry in tuple(spec):
        out.extend(_axes_of(entry))
    return tuple(out)


def shard_factor(spec, mesh: MeshSpec) -> int:
    """Product of mesh-axis sizes a spec shards over — how many ways the
    array is split (per-device bytes = total bytes / shard_factor)."""
    f = 1
    for a in spec_axes(spec):
        f *= mesh.axis_size(a)
    return f


class ClusterEnv:
    """Prices collectives, HBM traffic, and layout transitions on one
    abstract mesh. All costs are SECONDS PER PARTICIPATING DEVICE; byte
    arguments are the FULL logical payload unless noted."""

    def __init__(self, mesh: Union[MeshSpec, object],
                 links: Optional[Dict[str, LinkSpec]] = None,
                 hbm_bw: float = HBM_BW, hbm_bytes: float = HBM_BYTES,
                 peak_flops: float = PEAK_FLOPS):
        self.mesh = MeshSpec.from_mesh(mesh)
        self.links = dict(DEFAULT_LINKS)
        if links:
            self.links.update(links)
        self.hbm_bw = hbm_bw
        self.hbm_bytes = hbm_bytes
        self.peak_flops = peak_flops

    # ---- link aggregation --------------------------------------------------
    def group_size(self, axes: Iterable[str]) -> int:
        n = 1
        for a in axes:
            n *= self.mesh.axis_size(a)
        return n

    def _link(self, axes: Sequence[str]) -> LinkSpec:
        """Effective link for a replica group spanning `axes`: latencies sum
        (every hop is paid) and the slowest link bounds bandwidth."""
        axes = [a for a in axes if self.mesh.axis_size(a) > 1]
        if not axes:
            return LinkSpec(0.0, math.inf)
        specs = [self.links.get(a, DEFAULT_LINKS["data"]) for a in axes]
        return LinkSpec(sum(s.alpha_s for s in specs),
                        min(s.beta_bytes_s for s in specs))

    def collective_cost(self, kind: str, nbytes: float,
                        axes: Sequence[str]) -> float:
        """Seconds for one `kind` collective of `nbytes` (full payload per
        participating device) over the mesh axes `axes`. Groups of size 1
        are free — the collective compiles away."""
        n = self.group_size(axes)
        if n <= 1 or nbytes <= 0:
            return 0.0
        link = self._link(axes)
        chunk = _CHUNK.get(kind, _CHUNK["all-gather"])(n)
        return link.alpha_s + chunk * nbytes / link.beta_bytes_s

    def all_reduce_cost(self, nbytes: float, axes: Sequence[str]) -> float:
        return self.collective_cost("all-reduce", nbytes, axes)

    def all_gather_cost(self, nbytes: float, axes: Sequence[str]) -> float:
        """`nbytes` is the FULL gathered size (each device contributes
        nbytes/n and receives the rest)."""
        return self.collective_cost("all-gather", nbytes, axes)

    def reduce_scatter_cost(self, nbytes: float, axes: Sequence[str]) -> float:
        return self.collective_cost("reduce-scatter", nbytes, axes)

    def all_to_all_cost(self, nbytes: float, axes: Sequence[str]) -> float:
        """`nbytes` is the per-device buffer being exchanged."""
        return self.collective_cost("all-to-all", nbytes, axes)

    def collective_permute_cost(self, nbytes: float,
                                axes: Sequence[str]) -> float:
        return self.collective_cost("collective-permute", nbytes, axes)

    # ---- resharding ---------------------------------------------------------
    def resharding_cost(self, nbytes: float, src, dst) -> float:
        """Seconds to move an `nbytes` (full logical size) array from layout
        `src` to layout `dst` (PartitionSpecs). The usual alpa cases:

        - identical layouts: free;
        - sharded -> replicated on some axes: all-gather of the full bytes
          over the lost axes;
        - replicated -> sharded: free (a local slice);
        - same axes, different dims (e.g. column->row): all-to-all of the
          per-device shard over the moved axes.
        """
        src_t, dst_t = tuple(src), tuple(dst)
        if src_t == dst_t:
            return 0.0
        src_by_axis = {a: i for i, e in enumerate(src_t)
                       for a in _axes_of(e)}
        dst_by_axis = {a: i for i, e in enumerate(dst_t)
                       for a in _axes_of(e)}
        lost = [a for a in src_by_axis if a not in dst_by_axis]
        moved = [a for a in src_by_axis
                 if a in dst_by_axis and src_by_axis[a] != dst_by_axis[a]]
        cost = 0.0
        if lost:
            cost += self.all_gather_cost(nbytes, lost)
        if moved:
            per_dev = nbytes / max(self.group_size(src_by_axis), 1)
            cost += self.all_to_all_cost(per_dev, moved)
        return cost

    # ---- roofline terms -----------------------------------------------------
    def compute_s(self, flops_per_device: float) -> float:
        return flops_per_device / self.peak_flops

    def memory_s(self, bytes_per_device: float) -> float:
        return bytes_per_device / self.hbm_bw


@dataclasses.dataclass
class PlanCost:
    """End-to-end predicted cost of one placement for one workload step.
    Comparable across candidate plans of the same cell; `total_s` is the
    roofline max plus the collective term (collectives overlap poorly with
    compute on the hot paths we care about)."""
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    resident_bytes: float = 0.0        # per-device HBM residency
    collective_bytes: float = 0.0      # per-device, per step
    by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def total_s(self) -> float:
        return max(self.compute_s, self.memory_s) + self.collective_s

    def add_collective(self, kind: str, seconds: float, nbytes: float) -> None:
        self.collective_s += seconds
        self.collective_bytes += nbytes
        if nbytes:
            self.by_kind[kind] = self.by_kind.get(kind, 0.0) + nbytes

    def to_json(self) -> Dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "total_s": self.total_s,
            "resident_bytes": self.resident_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_bytes_by_kind": dict(self.by_kind),
        }


def default_env(mesh) -> ClusterEnv:
    """The calibrated default: v5e roofline constants + DCN pod link."""
    return ClusterEnv(mesh)
