"""Distributed-execution layer (DESIGN.md §Dist).

Three pieces, deliberately decoupled from any model module:

- `sharding`  — named-sharding rule engine mapping parameter / optimizer /
                batch / KV-cache trees onto a (pod, data, model) mesh,
                with optional FSDP over `data`.
- `hlo`       — compiled-HLO analyzer: per-device flops / bytes / collective
                traffic with full while/scan trip-count multiplicity (XLA's
                own cost_analysis visits loop bodies once).
- `compression` — int8 error-feedback gradient compression for the
                cross-pod all-reduce (opt-in via TrainConfig.grad_compression).
"""
from repro.dist import compression, hlo, sharding

__all__ = ["compression", "hlo", "sharding"]
