"""Compiled-HLO analyzer (DESIGN.md §Dist).

XLA's `compiled.cost_analysis()` visits while bodies exactly once, which makes
it useless for scanned programs (a 32-layer scan reports 1 layer of flops).
This module re-derives per-device cost from the optimized HLO text with full
call-graph multiplicity:

- `while` bodies scale by the trip count (`backend_config known_trip_count`
  when present, else the constant bound in the condition's ROOT compare);
- `fusion` / `call` / `conditional` computations are inlined at the caller's
  multiplicity (conditional branches are all charged — an upper bound);
- `reduce`/`sort`/collective `to_apply` reducers are NOT recursed into (they
  run per element and are charged at the call site instead).

Byte accounting reports two bounds (DESIGN.md §9):

- `bytes` — CPU-fusion-granularity upper bound: every non-trivial
  instruction reads its operands and writes its output;
- `bytes_min` — TPU-fusion-ideal lower bound: only materializing ops
  (dot/conv/reduce/collectives/copies/slice-updates/gather/scatter/
  custom-call) touch HBM; elementwise chains are assumed fully fused.

Collective traffic is the output size of each collective × multiplicity,
broken down by kind in `bytes_by_kind` / `count_by_kind` — all-to-all and
collective-permute get their own buckets, never lumped into a generic
"collective" bin (the planner's cost model prices each kind differently).
`group_by_kind` additionally records the largest replica-group size seen per
kind (both `{{0,1},…}` literal and `[G,S]<=[N]` iota forms; permute pairs
count as groups of 2), which is what calibrates the cost model's
chunk-factor n against compiled reality.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

# operand tokens: optional inline shape, then %var
_OPERAND_RE = re.compile(r"(?:([\w\-]+\[[\d,]*\](?:\{[\d,]*\})?)\s+)?%([\w\.\-]+)")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "and", "or", "xor", "not", "negate", "abs", "sign", "exponential",
    "exponential-minus-one", "log", "log-plus-one", "tanh", "sqrt", "rsqrt",
    "cbrt", "sine", "cosine", "tan", "atan2", "logistic", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "is-finite", "erf",
    "select", "clamp", "compare", "convert", "remainder", "shift-left",
    "shift-right-arithmetic", "shift-right-logical", "popcnt", "clz",
    "stochastic-convert", "real", "imag", "complex",
}

_ZERO_COST = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "reshape", "after-all", "opt-barrier", "domain",
    "partition-id", "replica-id", "iota", "broadcast", "transpose",
}

_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast",
}

# materializing ops for the fusion-ideal lower bound
_MATERIALIZE = {
    "dot", "convolution", "reduce", "reduce-window", "copy", "sort",
    "dynamic-slice", "dynamic-update-slice", "slice", "pad", "concatenate",
    "gather", "scatter", "custom-call", "rng", "rng-bit-generator",
    "cholesky", "triangular-solve", "fft",
} | _COLLECTIVES


def _shape_elems(s: str) -> int:
    n = 0
    for _, dims in _SHAPE_RE.findall(s):
        e = 1
        for d in dims.split(","):
            if d:
                e *= int(d)
        n += e
    return n


def _shape_bytes(s: str) -> int:
    """Byte size of an HLO shape string: 'f32[128,256]{1,0}', 'bf16[2,4]',
    tuples '(f32[4], s32[2,2])', scalars 'pred[]'. Layout suffixes are
    ignored; unknown element types (token, opaque) count 0."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(s):
        width = _DTYPE_BYTES.get(dtype)
        if width is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * width
    return total


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    operands: List[Tuple[Optional[str], str]]   # (inline shape | None, var)
    attrs: str
    body: str = ""                              # raw operand text


@dataclasses.dataclass
class ModuleStats:
    flops: float = 0.0
    dot_flops: float = 0.0
    bytes: float = 0.0
    bytes_min: float = 0.0
    collective_bytes: float = 0.0
    bytes_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    count_by_kind: Dict[str, int] = dataclasses.field(default_factory=dict)
    group_by_kind: Dict[str, int] = dataclasses.field(default_factory=dict)


_GROUPS_LITERAL_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
_PERMUTE_PAIRS_RE = re.compile(r"source_target_pairs=\{\{")


def replica_group_size(attrs: str) -> Optional[int]:
    """Replica-group size of a collective from its attrs. Handles the
    literal form `replica_groups={{0,1},{2,3}}` (size = first group's
    length), the iota form `replica_groups=[G,S]<=[N]` (size = S), and
    collective-permute's `source_target_pairs` (pairwise: 2)."""
    m = _GROUPS_IOTA_RE.search(attrs)
    if m:
        return int(m.group(2))
    m = _GROUPS_LITERAL_RE.search(attrs)
    if m:
        return len(m.group(1).split(","))
    if _PERMUTE_PAIRS_RE.search(attrs):
        return 2
    return None


def _split_shape(rest: str) -> Tuple[str, str]:
    """Split '<shape> <rest>' where shape may be a parenthesized tuple."""
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                return rest[:i + 1], rest[i + 1:].strip()
    shape, _, tail = rest.partition(" ")
    return shape, tail


def _paren_body(s: str) -> Tuple[str, str]:
    """s starts at '('; return (inside, after)."""
    depth = 0
    for i, ch in enumerate(s):
        depth += ch == "("
        depth -= ch == ")"
        if depth == 0:
            return s[1:i], s[i + 1:]
    return s[1:], ""


def _parse_instr(line: str) -> Optional[Instr]:
    ls = line.strip()
    if ls.startswith("ROOT "):
        ls = ls[5:]
    if not ls.startswith("%") or " = " not in ls:
        return None
    name, _, rest = ls.partition(" = ")
    shape, rest = _split_shape(rest)
    m = re.match(r"([\w\-]+)\(", rest)
    if not m:
        return None
    opcode = m.group(1)
    body, attrs = _paren_body(rest[m.end() - 1:])
    operands = [(s, v) for s, v in _OPERAND_RE.findall(body)]
    return Instr(name.lstrip("%"), shape, opcode,
                 [(s or None, v) for s, v in operands], attrs, body)


def _parse_module(txt: str) -> Tuple[Dict[str, List[Instr]], Optional[str]]:
    comps: Dict[str, List[Instr]] = {}
    entry = None
    cur: Optional[List[Instr]] = None
    for raw in txt.splitlines():
        line = raw.rstrip()
        ls = line.strip()
        if not ls:
            continue
        if ls.endswith("{") and " = " not in ls:
            m = re.match(r"(ENTRY\s+)?%?([\w\.\-]+)\s*\(", ls)
            if m:
                cur = []
                comps[m.group(2)] = cur
                if m.group(1):
                    entry = m.group(2)
            continue
        if ls == "}":
            cur = None
            continue
        if cur is not None:
            instr = _parse_instr(ls)
            if instr is not None:
                cur.append(instr)
    if entry is None and comps:                  # bare snippet fallback
        entry = next(iter(comps))
    return comps, entry


def _called(attrs: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w\.\-]+)", attrs)
    return m.group(1) if m else None


def _trip_count(instr: Instr, comps: Dict[str, List[Instr]]) -> int:
    """While trip count: backend_config known_trip_count if the compiler
    resolved it, else the constant bound in the condition's ROOT compare
    (scan/fori_loop lower to `iter < C`). Unknown bounds count once."""
    m = re.search(r'known_trip_count[^}]*"n"\s*:\s*"(\d+)"', instr.attrs)
    if m:
        return int(m.group(1))
    cond = _called(instr.attrs, "condition")
    if cond and cond in comps:
        for ins in comps[cond]:
            if ins.opcode == "compare":
                for _, var in ins.operands:
                    val = _const_value(var, comps[cond])
                    if val is not None and val > 0:
                        if "direction=LE" in ins.attrs:
                            return val + 1
                        return val
    return 1


def _const_value(var: str, instrs: List[Instr]) -> Optional[int]:
    for ins in instrs:
        if ins.name == var and ins.opcode == "constant":
            m = re.fullmatch(r"\s*(-?\d+)\s*", ins.body)
            if m:
                return int(m.group(1))
    return None


def _operand_bytes(instr: Instr, table: Dict[str, str]) -> int:
    total = 0
    for shp, var in instr.operands:
        s = shp or table.get(var)
        if s:
            total += _shape_bytes(s)
    return total


def _operand_shape(instr: Instr, idx: int,
                   table: Dict[str, str]) -> Optional[str]:
    if idx >= len(instr.operands):
        return None
    shp, var = instr.operands[idx]
    return shp or table.get(var)


def _dims_of(shape: str) -> List[int]:
    m = _SHAPE_RE.search(shape or "")
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _dot_flops(instr: Instr, table: Dict[str, str]) -> float:
    out = _shape_elems(instr.shape)
    lhs = _operand_shape(instr, 0, table)
    contract = 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.attrs)
    if m and lhs:
        dims = _dims_of(lhs)
        for d in m.group(1).split(","):
            if d and int(d) < len(dims):
                contract *= dims[int(d)]
    return 2.0 * out * contract


def _conv_flops(instr: Instr, table: Dict[str, str]) -> float:
    out = _shape_elems(instr.shape)
    rhs = _operand_shape(instr, 1, table)
    kdims = _dims_of(rhs) if rhs else []
    kernel = 1
    for d in kdims:
        kernel *= d
    # divide out the kernel's output-feature dim when identifiable
    m = re.search(r"dim_labels=\w+_(\w+)->", instr.attrs)
    if m and kdims and "o" in m.group(1):
        kernel //= max(kdims[m.group(1).index("o")], 1)
    return 2.0 * out * kernel


def _walk(comp: str, mult: float, comps: Dict[str, List[Instr]],
          stats: ModuleStats, is_entry: bool) -> None:
    instrs = comps.get(comp, [])
    table = {i.name: i.shape for i in instrs}
    for ins in instrs:
        op = ins.opcode
        out_b = _shape_bytes(ins.shape)
        kind = op[:-6] if op.endswith("-start") else op
        if op.endswith("-done"):
            continue
        if kind in _COLLECTIVES:
            stats.collective_bytes += mult * out_b
            stats.bytes_by_kind[kind] = (stats.bytes_by_kind.get(kind, 0.0)
                                         + mult * out_b)
            stats.count_by_kind[kind] = (stats.count_by_kind.get(kind, 0)
                                         + int(round(mult)))
            gs = replica_group_size(ins.attrs)
            if gs is not None:
                stats.group_by_kind[kind] = max(
                    stats.group_by_kind.get(kind, 0), gs)
            stats.bytes += mult * (out_b + _operand_bytes(ins, table))
            stats.bytes_min += mult * out_b
            continue
        if op == "while":
            trip = _trip_count(ins, comps)
            body = _called(ins.attrs, "body")
            cond = _called(ins.attrs, "condition")
            if body:
                _walk(body, mult * trip, comps, stats, False)
            if cond:
                _walk(cond, mult * trip, comps, stats, False)
            continue
        if op == "conditional":
            branches = []
            if "branch_computations" in ins.attrs:
                blob = ins.attrs.split("branch_computations", 1)[1]
                blob = blob.split("}", 1)[0]
                branches = re.findall(r"%([\w\.\-]+)", blob)
            branches += [b for b in (_called(ins.attrs, "true_computation"),
                                     _called(ins.attrs, "false_computation"))
                         if b]
            for branch in branches:
                _walk(branch, mult, comps, stats, False)
            continue
        if op == "fusion":
            called = _called(ins.attrs, "calls")
            if called:
                _walk(called, mult, comps, stats, False)
            stats.bytes += mult * (out_b + _operand_bytes(ins, table))
            stats.bytes_min += mult * (out_b + _operand_bytes(ins, table))
            continue
        if op == "call":
            called = _called(ins.attrs, "to_apply")
            if called:
                _walk(called, mult, comps, stats, False)
            continue
        if op == "parameter":
            if is_entry:
                stats.bytes += out_b
                stats.bytes_min += out_b
            continue
        if op == "dot":
            f = _dot_flops(ins, table)
            stats.dot_flops += mult * f
            stats.flops += mult * f
            stats.bytes += mult * (out_b + _operand_bytes(ins, table))
            stats.bytes_min += mult * (out_b + _operand_bytes(ins, table))
            continue
        if op == "convolution":
            f = _conv_flops(ins, table)
            stats.dot_flops += mult * f
            stats.flops += mult * f
            stats.bytes += mult * (out_b + _operand_bytes(ins, table))
            stats.bytes_min += mult * (out_b + _operand_bytes(ins, table))
            continue
        if op in _ZERO_COST:
            continue
        if op in ("reduce", "reduce-window"):
            stats.flops += mult * max(_shape_elems(
                _operand_shape(ins, 0, table) or ins.shape), _shape_elems(ins.shape))
            stats.bytes += mult * (out_b + _operand_bytes(ins, table))
            stats.bytes_min += mult * (out_b + _operand_bytes(ins, table))
            continue
        if op in _ELEMENTWISE:
            stats.flops += mult * _shape_elems(ins.shape)
            stats.bytes += mult * (out_b + _operand_bytes(ins, table))
            continue
        # everything else (copies, slices, custom-calls, rng, …)
        stats.bytes += mult * (out_b + _operand_bytes(ins, table))
        if op in _MATERIALIZE:
            stats.bytes_min += mult * (out_b + _operand_bytes(ins, table))


def analyze_module(txt: str) -> ModuleStats:
    """Analyze optimized HLO text (`compiled.as_text()`); for SPMD-partitioned
    modules the result is already per-device."""
    comps, entry = _parse_module(txt)
    stats = ModuleStats()
    if entry is not None:
        _walk(entry, 1.0, comps, stats, True)
    return stats


# ---------------------------------------------------------------------------
# Structural accessors for the static analyzer (repro.analysis)
# ---------------------------------------------------------------------------

def parse_module(txt: str) -> Tuple[Dict[str, List[Instr]], Optional[str]]:
    """Public parse: `compiled.as_text()` -> ({computation: [Instr]}, entry).
    Same parser the cost walk uses; repro.analysis lints over it."""
    return _parse_module(txt)


def iter_instrs(comps: Dict[str, List[Instr]], entry: Optional[str]):
    """Yield (instr, multiplicity, in_loop) over the entry call graph with
    the same inlining rules as the cost walk: while bodies/conditions at the
    trip-count multiplicity (and flagged `in_loop`), fusion/call/conditional
    computations at the caller's multiplicity, reduce/sort `to_apply`
    reducers not recursed. Cycles are cut (each computation is entered once
    per distinct call path, bounded by the acyclic HLO call graph)."""
    if entry is None:
        return

    def rec(comp: str, mult: float, in_loop: bool, seen: Tuple[str, ...]):
        if comp in seen:
            return
        seen = seen + (comp,)
        for ins in comps.get(comp, []):
            op = ins.opcode
            if op == "while":
                trip = _trip_count(ins, comps)
                yield ins, mult, in_loop
                for key in ("body", "condition"):
                    c = _called(ins.attrs, key)
                    if c:
                        yield from rec(c, mult * trip, True, seen)
                continue
            if op == "conditional":
                branches = []
                if "branch_computations" in ins.attrs:
                    blob = ins.attrs.split("branch_computations", 1)[1]
                    blob = blob.split("}", 1)[0]
                    branches = re.findall(r"%([\w\.\-]+)", blob)
                branches += [b for b in
                             (_called(ins.attrs, "true_computation"),
                              _called(ins.attrs, "false_computation")) if b]
                yield ins, mult, in_loop
                for b in branches:
                    yield from rec(b, mult, in_loop, seen)
                continue
            if op == "fusion":
                c = _called(ins.attrs, "calls")
                if c:
                    yield from rec(c, mult, in_loop, seen)
                yield ins, mult, in_loop
                continue
            if op == "call":
                c = _called(ins.attrs, "to_apply")
                if c:
                    yield from rec(c, mult, in_loop, seen)
                yield ins, mult, in_loop
                continue
            yield ins, mult, in_loop

    yield from rec(entry, 1.0, False, ())


def custom_call_target(instr: Instr) -> Optional[str]:
    """custom_call_target of a custom-call Instr, None otherwise."""
    m = re.search(r'custom_call_target="([^"]+)"', instr.attrs)
    return m.group(1) if m else None


def aliased_params(txt: str) -> set:
    """Entry parameter numbers the module's `input_output_alias` header maps
    an output onto. XLA drops donated-but-unusable buffers from the header
    entirely (the donation was wasted — the input buffer stays live), which
    is exactly what repro.analysis's donation pass checks for."""
    m = re.search(r"input_output_alias=\{", txt)
    if not m:
        return set()
    i = m.end() - 1
    depth = 0
    for j in range(i, len(txt)):
        depth += txt[j] == "{"
        depth -= txt[j] == "}"
        if depth == 0:
            break
    blob = txt[i:j + 1]
    return {int(p) for p in re.findall(r"\((\d+),\s*\{", blob)}


def entry_param_count(txt: str) -> int:
    """Number of `parameter(N)` instructions in the entry computation."""
    comps, entry = _parse_module(txt)
    if entry is None:
        return 0
    return sum(1 for ins in comps.get(entry, [])
               if ins.opcode == "parameter")
