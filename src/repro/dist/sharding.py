"""Named-sharding rule engine (DESIGN.md §Dist).

Mesh axes (any subset may be present):

    pod    — cross-pod data parallelism (slow DCN/ICI hop)
    data   — in-pod data parallelism; also the FSDP shard axis
    model  — tensor/expert parallelism

Rules are keyed on the *leaf name* (the last `/` path component), so the same
engine covers every tree we place: raw param trees `{"base":…, "peft":…}`,
train states `{step, trainable, opt, …}`, frozen trees, optimizer moments
(they inherit the rule of the weight they mirror, because `mu/…/wq` ends in
`wq`), and adapter trees (whose leaves — `c`, `entries`, `b1`, `lora_a`, … —
match no weight rule and replicate; FourierFT coefficients are ~n·L numbers,
sharding them would cost more in collectives than it saves).

Weight table (trailing dims; leading stack dims (L,) / (L,E) stay unsharded
so per-layer loop slices keep their spec):

    column-parallel (`model` on last dim):    wq wk wv wi wg wz wx wbc wdt
                                              lm_head/head conv_b  (+ biases)
    row-parallel (`model` on 2nd-to-last):    wo wo_mlp wo_ssm embed conv_w
    expert-parallel (`model` on expert dim):  we_i we_g we_o
    replicated:                               norms, router, A_log, dt_bias,
                                              Dp, adapter leaves
                                              (c/entries/b1/b2/lora_*/kernel/
                                              delta_b), scalars
    (serving adapter-bank rows are spliced into params at generate() time as
    uncommitted host arrays and rely on jit default placement — they do not
    pass through this rule table)

FSDP (opt-in, default from `fsdp_default`): additionally shards the largest
free matrix dim of big weights over `data`; the launch layer re-gathers
per-layer slices inside the scan via the "fsdp_gather/<name>" constraint hook
(see launch/dryrun_lib.make_constrain and models/transformer.make_linear).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

# Batch dims shard over these axes, outermost first.
BATCH_AXES = ("pod", "data")

# FSDP default threshold: shard base weights over `data` when the
# model-parallel-sharded copy alone would eat this fraction of v5e HBM.
HBM_BYTES = 16e9
FSDP_FRACTION = 0.35

# Only weights at least this many elements participate in FSDP sharding
# (below it the per-layer all-gather latency outweighs the memory win).
FSDP_MIN_ELEMS = 1 << 16

_COLUMN = {"wq", "wk", "wv", "wi", "wg", "wz", "wx", "wbc", "wdt",
           "lm_head", "head", "conv_b"}
_ROW = {"wo", "wo_mlp", "wo_ssm", "embed", "conv_w"}
_EXPERT = {"we_i", "we_g", "we_o"}
# Leaves that replicate BY DECISION, not by fall-through: norms and small
# per-layer vectors (sharding them buys nothing and costs collectives),
# the MoE router (d × num_experts — num_experts is tiny), SSM per-head
# scalars, and adapter leaves (FourierFT coefficients are ~n·L numbers).
# `repro.analysis`'s sharding-coverage audit flags any param leaf matching
# NONE of the four tables — add new leaf names here (or to a sharded
# table) rather than relying on the silent replicate fall-through.
_REPLICATE = {
    # norms (all families)
    "attn_norm", "mlp_norm", "final_norm", "norm", "gnorm",
    "q_norm", "k_norm",
    # moe router, ssm per-head parameters
    "router", "A_log", "dt_bias", "Dp",
    # adapter leaves (core/adapter.py methods)
    "c", "entries", "b1", "b2", "kernel", "lora_a", "lora_b", "delta_b",
}


def axis_size(mesh: Mesh, axis: str) -> int:
    """Size of `axis` in `mesh`, 1 if absent."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(sizes.get(axis, 1))


def _maybe(n: int, mesh: Mesh, axis: str) -> Optional[str]:
    """`axis` if present, non-trivial, and divides `n` — else None
    (replicate rather than produce an invalid uneven sharding)."""
    s = axis_size(mesh, axis)
    return axis if (s > 1 and n % s == 0) else None


def batch_axes(mesh: Mesh, global_batch: int) -> Tuple[str, ...]:
    """Longest (pod, data) prefix whose combined size divides `global_batch`.
    Empty tuple means the batch dim replicates."""
    out, prod = [], 1
    for a in BATCH_AXES:
        s = axis_size(mesh, a)
        if s <= 1:
            continue
        if global_batch % (prod * s):
            break
        out.append(a)
        prod *= s
    return tuple(out)


def _backbone_param_estimate(cfg: ModelConfig) -> int:
    """Analytic backbone size (excl. embed/lm_head) for the FSDP heuristic."""
    d, L = cfg.d_model, cfg.num_layers
    attn = d * (cfg.attn_dim + 2 * cfg.kv_dim) + cfg.attn_dim * d \
        if cfg.n_heads else 0
    if cfg.moe is not None:
        mlp = cfg.moe.num_experts * 3 * d * cfg.moe.d_ff_expert \
            + d * cfg.moe.num_experts
    elif cfg.d_ff:
        mlp = (3 if cfg.gated_mlp else 2) * d * cfg.d_ff
    else:
        mlp = 0
    if cfg.ssm is not None:
        d_inner = cfg.ssm.expand * d
        ssm = (2 * d * d_inner + 2 * d * cfg.ssm.n_groups * cfg.ssm.state
               + d_inner * d)
        if cfg.family == "hybrid":
            return L * ssm + attn + mlp        # shared block stored once
        return L * ssm
    return L * (attn + mlp)


def fsdp_default(cfg: ModelConfig, mesh: Mesh) -> bool:
    """FSDP on iff the TP-sharded bf16 weight copy would exceed the HBM
    budget fraction and there is a non-trivial `data` axis to shard over."""
    if axis_size(mesh, "data") <= 1:
        return False
    per_dev = 2.0 * _backbone_param_estimate(cfg) / axis_size(mesh, "model")
    return per_dev > FSDP_FRACTION * HBM_BYTES


def rule_kind(path: str, shape: Tuple[int, ...]) -> Optional[str]:
    """Which rule table a param leaf resolves through: "expert" | "column" |
    "row" | "replicate" | "scalar", or None when the name matches NO table
    and the spec comes from the silent replicate fall-through. None is what
    `repro.analysis`'s sharding-coverage audit flags: a new model family's
    weight that nobody decided a placement for."""
    name = path.split("/")[-1]
    base = name[:-3] if name.endswith("__b") else name
    if not shape:
        return "scalar"
    if base in _EXPERT:
        # a named-but-underdimensioned leaf (e.g. a 1-D bias of a sharded
        # weight) replicates BY the table's dim gate — covered, not a gap
        return "expert" if len(shape) >= 3 else "replicate"
    if base in _COLUMN:
        return "column"
    if base in _ROW:
        return "row" if len(shape) >= 2 else "replicate"
    if base in _REPLICATE:
        return "replicate"
    return None


def _param_rule(path: str, shape: Tuple[int, ...], mesh: Mesh,
                cfg: ModelConfig, fsdp: bool = False) -> P:
    """Partition spec for one parameter leaf (see module docstring table)."""
    name = path.split("/")[-1]
    base = name[:-3] if name.endswith("__b") else name
    ndim = len(shape)
    spec = [None] * ndim
    if base in _EXPERT and ndim >= 3:
        spec[-3] = _maybe(shape[-3], mesh, "model")
    elif base in _COLUMN and ndim >= 1:
        spec[-1] = _maybe(shape[-1], mesh, "model")
    elif base in _ROW and ndim >= 2:
        spec[-2] = _maybe(shape[-2], mesh, "model")
    if (fsdp and ndim >= 2 and base not in ("embed", "lm_head", "head")
            and int(np.prod(shape)) >= FSDP_MIN_ELEMS):
        free = [d for d in (ndim - 2, ndim - 1)
                if spec[d] is None and _maybe(shape[d], mesh, "data")]
        if free:
            spec[max(free, key=lambda d: shape[d])] = "data"
    return P(*spec)


def _walk_specs(tree, fn, path=()):
    if isinstance(tree, dict):
        return {k: _walk_specs(v, fn, path + (k,)) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        seq = [_walk_specs(v, fn, path + (str(i),))
               for i, v in enumerate(tree)]
        return tuple(seq) if isinstance(tree, tuple) else seq
    return fn("/".join(path), tree)


def state_specs(tree, mesh: Mesh, cfg: ModelConfig, fsdp: bool = False):
    """Specs for any state-like tree: params, (trainable, frozen), full train
    state incl. optimizer moments. Leaves are matched by name; unknown names
    (adapter leaves, counters, EMAs) replicate."""
    def rule(path, leaf):
        shape = tuple(getattr(leaf, "shape", ()))
        if not shape:
            return P()
        return _param_rule(path, shape, mesh, cfg, fsdp=fsdp)
    return _walk_specs(tree, rule)


# Batch leaves with an explicitly decided placement (batch_rule_kind).
# Everything here rides the batch axes on dim 0 unless batch_leaf_spec
# special-cases it; a batch leaf NOT named here falls through and the
# sharding-coverage audit flags it.
_BATCH_LEAVES = {
    "tokens", "labels", "embeds", "positions", "block_table",
    "adapter_slots", "true_len", "prefix_len", "slot",
    "scratch_page", "scratch_pages",
}


def batch_leaf_spec(path: str, shp: Tuple[int, ...], b) -> P:
    """Spec for one input-batch leaf given the chosen batch axes `b`
    (an axis tuple, or None to replicate the batch dim)."""
    nd = len(shp)
    if not nd:
        return P()
    name = path.split("/")[-1]
    if name == "positions" and nd == 3:
        return P(None, b, *([None] * (nd - 2)))
    if name == "block_table":
        # (B, pages_per_seq) slot->page map rides the batch axes; the
        # (pages_per_seq,) prefill-time row replicates
        return P(b, None) if nd == 2 else P(*([None] * nd))
    return P(b, *([None] * (nd - 1)))


def batch_rule_kind(path: str, shape: Tuple[int, ...]) -> Optional[str]:
    """Coverage classifier for input-batch leaves (mirrors `rule_kind` for
    params): "batch" | "replicate" | "scalar" for decided names, None for a
    leaf nobody placed."""
    name = path.split("/")[-1]
    if not shape:
        return "scalar"
    if name == "block_table" and len(shape) != 2:
        return "replicate"
    if name in _BATCH_LEAVES:
        return "batch"
    return None


def batch_specs(batch: Dict, mesh: Mesh, shape: ShapeConfig):
    """Input batches shard their batch dim over (pod, data). The vlm
    `positions` leaf is (3, B, S) — batch lives on dim 1."""
    bax = batch_axes(mesh, shape.global_batch)
    b = bax if bax else None

    def rule(path, leaf):
        return batch_leaf_spec(path, tuple(getattr(leaf, "shape", ())), b)
    return _walk_specs(batch, rule)


def cache_leaf_spec(path: str, shp: Tuple[int, ...], mesh: Mesh, b) -> P:
    """Spec for one decode-cache leaf given the chosen batch axes `b`."""
    nd = len(shp)
    name = path.split("/")[-1]
    if nd == 5 and name in ("pk", "pv"):
        # paged page pool (L, n_pages, page_size, K, hd): pages are a
        # GLOBAL pool shared by every slot (block tables map slots onto
        # them), so the page dim replicates — only the KV-head dim
        # follows the projection sharding like the dense cache
        return P(None, None, None, _maybe(shp[3], mesh, "model"), None)
    if nd >= 4 and name in ("k", "v", "attn_k", "attn_v"):
        return P(None, b, None, _maybe(shp[3], mesh, "model"),
                 *([None] * (nd - 4)))
    if name == "conv" and nd == 4:
        return P(None, b, None, _maybe(shp[3], mesh, "model"))
    if name == "ssm" and nd == 5:
        return P(None, b, _maybe(shp[2], mesh, "model"), None, None)
    if name == "pos" and nd == 1:
        # per-slot position vector of the persistent continuous-batching
        # cache: (B,) — rides the batch axes like the rows it indexes
        return P(b)
    if nd >= 2:
        return P(None, b, *([None] * (nd - 2)))
    return P()


# Cache leaves with a decided placement: attention KV (dense + paged +
# hybrid), SSM conv window / state, and the per-slot position vector.
_CACHE_LEAVES = {"k", "v", "attn_k", "attn_v", "pk", "pv", "conv", "ssm",
                 "pos"}


def cache_rule_kind(path: str, shape: Tuple[int, ...]) -> Optional[str]:
    """Coverage classifier for decode-cache leaves: which named cache rule
    places this leaf, or None when it would ride the generic batch-dim-1
    fall-through nobody decided."""
    name = path.split("/")[-1]
    if not shape:
        return "scalar"
    if name in ("pk", "pv"):
        return "paged-pool" if len(shape) == 5 else None
    if name in ("k", "v", "attn_k", "attn_v"):
        return "kv" if len(shape) >= 4 else None
    if name == "conv":
        return "conv" if len(shape) == 4 else None
    if name == "ssm":
        return "ssm" if len(shape) == 5 else None
    if name == "pos":
        return "slot-pos" if len(shape) <= 1 else None
    return None


def cache_specs(cache: Dict, mesh: Mesh, cfg: ModelConfig,
                shape: ShapeConfig):
    """Decode caches: batch over (pod, data); the head-like dim over `model`
    to match the projection sharding (KV heads for attention caches, SSM
    heads for state caches, conv channels for the conv window)."""
    bax = batch_axes(mesh, shape.global_batch)
    b = bax if bax else None

    def rule(path, leaf):
        return cache_leaf_spec(path, tuple(getattr(leaf, "shape", ())),
                               mesh, b)
    return _walk_specs(cache, rule)


def named(tree, specs, mesh: Mesh):
    """Map a spec tree into NamedShardings. `tree` is accepted (and ignored)
    so call sites read `named(state, state_specs(state, …), mesh)` — the
    specs tree already mirrors the state tree's structure."""
    del tree
    if isinstance(specs, P):                    # P is a tuple subclass
        return NamedSharding(mesh, specs)
    if isinstance(specs, dict):
        return {k: named(None, v, mesh) for k, v in specs.items()}
    if isinstance(specs, (list, tuple)):
        seq = [named(None, v, mesh) for v in specs]
        return tuple(seq) if isinstance(specs, tuple) else seq
    return NamedSharding(mesh, specs)
