"""int8 error-feedback gradient compression (DESIGN.md §Dist).

For cross-pod data parallelism the per-step gradient all-reduce payload is
the binding constraint (see benchmarks/bench_grad_comm.py): FourierFT's
coefficient gradients are tiny, but full-FT / head-training payloads are not.
Symmetric per-tensor int8 quantization cuts the payload 4x; the quantization
residual is carried to the next step (error feedback), so the *accumulated*
update stays unbiased — the classic EF-SGD argument (residuals stay bounded
while the signal accumulates; property-tested in tests/test_dist.py).

Opt-in: set `TrainConfig.grad_compression = "int8_ef"` — train/step.py then
threads an `ef_residual` tree through the state and compresses gradients
before the optimizer update (i.e. what would be sent on the wire).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8: returns (q int8, scale f32 scalar) with
    x ≈ q · scale and |x - q·scale| ≤ scale/2 elementwise."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12).astype(jnp.float32) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_residual(tree) -> Dict:
    """Zero error-feedback residual matching a gradient tree (f32)."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), tree)


def compress_with_feedback(grads, residual) -> Tuple[Dict, Dict]:
    """Per-leaf: y = g + residual; send quantize(y); carry y - sent.
    Returns (sent_grads — what the all-reduce would transport — and the new
    residual tree)."""
    def one(g, r):
        y = g.astype(jnp.float32) + r
        q, scale = quantize_int8(y)
        # the residual must track what the optimizer actually receives —
        # including the g.dtype down-cast rounding — or low-precision grads
        # (bf16) accumulate a persistent bias the EF property promises away
        sent = dequantize(q, scale).astype(g.dtype)
        return sent, y - sent.astype(jnp.float32)
    flat = jax.tree.map(one, grads, residual)
    sent = jax.tree.map(lambda pair: pair[0], flat,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_r = jax.tree.map(lambda pair: pair[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return sent, new_r


def payload_bytes(tree) -> Tuple[int, int]:
    """(f32 payload, int8+scale payload) for a gradient tree — the wire-size
    comparison used by bench_grad_comm."""
    n = sum(int(x.size) for x in jax.tree.leaves(tree))
    leaves = len(jax.tree.leaves(tree))
    return 4 * n, n + 4 * leaves
