"""Placement planner: search per-leaf layouts against the cluster cost model
(DESIGN.md §Sharding).

For one (model, mesh, workload) cell the planner

  1. walks the same trees the sharding-coverage audit walks — param/state
     leaves, decode-cache leaves, input-batch leaves (all abstract; nothing
     materializes);
  2. enumerates per-leaf candidate placements: replicate, column/row/expert
     model-sharding (name-gated like the rule table, so a norm is never
     column-sharded), and fsdp data-scatter variants — ALWAYS including the
     spec the rule table itself would pick;
  3. scores each candidate with `dist/cost_model.ClusterEnv`: matmul compute
     split over (batch × model) shards, weight-stream HBM traffic, gradient
     all-reduce for trainable leaves, fsdp gather/scatter, and the
     Megatron-style activation collective charge for tensor-parallel weights
     (column pairs with the row that follows it, so it carries half an
     all-reduce; rows carry a full one; experts carry the dispatch/combine
     all-to-all);
  4. takes the per-leaf argmin — exact, because the objective decomposes
     leafwise once the batch-shard prefix is fixed — inside an outer loop
     over every valid (pod, data) batch prefix, then repairs HBM-capacity
     overflows by flipping the largest-resident leaves to their cheapest
     smaller-resident candidate;
  5. emits a ranked, serializable `ShardingPlan` whose winner is
     min(search, rules) — the search can never score worse than the rule
     table under its own cost model, by construction.

The interesting regime split this captures: at production scale
tensor-parallel wins (compute/HBM dominate, activation all-reduces are
bandwidth-cheap relative to the savings), while at smoke scale the same
all-reduces are pure per-launch latency and replicate-everywhere wins —
which is why a searched smoke-cell plan beats the rule table on
analyzer-measured collective bytes (see tests/test_sharding_plan.py).

CLI (the CI gate):

    python -m repro.dist.planner --arch yi-6b --reduced --mesh 4x2 \
        --shape train_4k --out plan.json --check-search-beats-rules
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd
from repro.dist.cost_model import (ClusterEnv, MeshSpec, PlanCost,
                                   shard_factor, spec_axes)
from repro.dist.plan import (PlanSource, PlanTableSource, RulesSource,
                             ShardingPlan, leaf_key)

# Optimizer-state multiplier for trainable leaves: bf16/fp32 param + two
# fp32 Adam moments ≈ 3x the param bytes resident.
TRAIN_STATE_MULT = 3.0
# Soft capacity penalty: each byte over HBM is priced as this many extra
# HBM round-trips (it really means host offload / OOM — make it dominate).
CAPACITY_PENALTY = 32.0


@dataclasses.dataclass
class _Leaf:
    section: str
    path: str
    shape: Tuple[int, ...]
    nbytes: float
    elems: float
    trainable: bool = False

    @property
    def name(self) -> str:
        n = self.path.split("/")[-1]
        return n[:-3] if n.endswith("__b") else n


def _iter_leaves(tree, path=()):
    # PartitionSpec subclasses tuple — it's a leaf here, never a container
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _iter_leaves(v, path + (str(k),))
    elif isinstance(tree, (list, tuple)) and not isinstance(tree, P):
        for i, v in enumerate(tree):
            yield from _iter_leaves(v, path + (str(i),))
    else:
        yield "/".join(path), tree


def _leaves(tree, section: str) -> List[_Leaf]:
    out = []
    for path, leaf in _iter_leaves(tree):
        shape = tuple(int(s) for s in getattr(leaf, "shape", ()))
        elems = float(np.prod(shape)) if shape else 1.0
        try:
            itemsize = np.dtype(leaf.dtype).itemsize
        except (TypeError, AttributeError):
            itemsize = 2
        out.append(_Leaf(section, path, shape, elems * itemsize, elems,
                         trainable=path.startswith("peft")))
    return out


# ---------------------------------------------------------------------------
# Candidates
# ---------------------------------------------------------------------------

def _with_fsdp(spec_entries: List, shape, mesh) -> Optional[P]:
    """Add the fsdp `data` scatter on the largest free matrix dim, the same
    way `_param_rule` does."""
    nd = len(shape)
    free = [d for d in (nd - 2, nd - 1)
            if spec_entries[d] is None and shd._maybe(shape[d], mesh, "data")]
    if not free:
        return None
    entries = list(spec_entries)
    entries[max(free, key=lambda d: shape[d])] = "data"
    return P(*entries)


def state_candidates(leaf: _Leaf, mesh, cfg,
                     rules_spec: P) -> Dict[str, P]:
    """Per-leaf candidate placements, keyed by a human-readable strategy
    label. Always contains the rule table's own choice."""
    shape, nd, base = leaf.shape, len(leaf.shape), leaf.name
    cands: Dict[str, P] = {"rules": rules_spec}
    if not nd:
        return cands
    cands["replicate"] = P(*([None] * nd))
    sharded: Dict[str, List] = {}
    if base in shd._EXPERT and nd >= 3 and shd._maybe(shape[-3], mesh, "model"):
        e = [None] * nd
        e[-3] = "model"
        sharded["expert"] = e
    if base in shd._COLUMN and shd._maybe(shape[-1], mesh, "model"):
        e = [None] * nd
        e[-1] = "model"
        sharded["column"] = e
    if base in shd._ROW and nd >= 2 and shd._maybe(shape[-2], mesh, "model"):
        e = [None] * nd
        e[-2] = "model"
        sharded["row"] = e
    for label, entries in sharded.items():
        cands[label] = P(*entries)
    # fsdp variants (not for the token tables — same gate as _param_rule)
    if (nd >= 2 and base not in ("embed", "lm_head", "head")
            and leaf.elems >= shd.FSDP_MIN_ELEMS):
        for label, entries in [("replicate", [None] * nd)] + \
                list(sharded.items()):
            f = _with_fsdp(entries, shape, mesh)
            if f is not None:
                cands[f"{label}+fsdp"] = f
    # dedupe identical specs (keep first label)
    seen, out = set(), {}
    for label, spec in cands.items():
        key = tuple(spec)
        if key not in seen:
            seen.add(key)
            out[label] = spec
    return out


# ---------------------------------------------------------------------------
# Leaf cost
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Ctx:
    env: ClusterEnv
    cfg: object
    workload: str              # train | prefill | decode
    tokens: float              # per step, global
    batch_div: int             # chosen batch-shard product
    act_bytes_local: float     # per-device activation slab (B_loc·S·d·2)

    @property
    def train(self) -> bool:
        return self.workload == "train"


def _matmul_class(name: str) -> Optional[str]:
    if name in shd._EXPERT:
        return "expert"
    if name in shd._COLUMN:
        return "column"
    if name in shd._ROW:
        return "row"
    return None


def leaf_cost(leaf: _Leaf, spec: P, ctx: _Ctx) -> PlanCost:
    """Price one leaf under one placement (see module docstring, step 3)."""
    env, cost = ctx.env, PlanCost()
    axes = spec_axes(spec)
    model_div = env.group_size(a for a in axes if a == "model")
    storage_div = shard_factor(spec, env.mesh)
    fsdp = "data" in axes

    if leaf.section == "state":
        mm = _matmul_class(leaf.name)
        is_matmul = mm is not None and len(leaf.shape) >= 2
        elems_eff = leaf.elems
        if mm == "expert" and getattr(ctx.cfg, "moe", None) is not None:
            elems_eff *= ctx.cfg.moe.top_k / ctx.cfg.moe.num_experts
        if is_matmul:
            flops_mult = 6.0 if ctx.train else 2.0
            cost.compute_s = env.compute_s(
                flops_mult * elems_eff * ctx.tokens
                / (ctx.batch_div * model_div))
        # weight stream: the (gathered, model-sharded) copy is read once per
        # step; fsdp changes residency, not the bytes the matmul reads
        cost.memory_s = env.memory_s(leaf.nbytes / model_div)
        mult = TRAIN_STATE_MULT if (ctx.train and leaf.trainable) else 1.0
        cost.resident_bytes = leaf.nbytes / storage_div * mult
        if fsdp:
            n = leaf.nbytes / model_div
            passes = 2 if ctx.train else 1      # fwd + bwd re-gather
            cost.add_collective(
                "all-gather", passes * env.all_gather_cost(n, ("data",)),
                passes * n)
            if ctx.train and leaf.trainable:
                cost.add_collective(
                    "reduce-scatter",
                    env.reduce_scatter_cost(n, ("data",)), n)
        if ctx.train and leaf.trainable:
            dp_axes = [a for a in shd.BATCH_AXES
                       if a not in axes and env.mesh.axis_size(a) > 1]
            if dp_axes:
                n = leaf.nbytes / storage_div
                cost.add_collective(
                    "all-reduce", env.all_reduce_cost(n, dp_axes), n)
        if is_matmul and model_div > 1:
            stack = leaf.shape[0] if len(leaf.shape) >= 3 else 1
            passes = 2 if ctx.train else 1
            n = ctx.act_bytes_local
            if mm == "expert":
                # dispatch + combine all-to-all per pass
                sec = 2 * env.all_to_all_cost(n, ("model",))
                cost.add_collective("all-to-all", stack * passes * sec,
                                    stack * passes * 2 * n)
            else:
                # Megatron pairing: the column half of a column->row pair
                # carries half the pair's all-reduce, the row carries a
                # full one (rows also appear unpaired: embed, wo_ssm)
                discount = 0.5 if mm == "column" else 1.0
                sec = discount * env.all_reduce_cost(n, ("model",))
                cost.add_collective("all-reduce", stack * passes * sec,
                                    stack * passes * discount * n)
        return cost

    # cache / batch leaves: storage + (decode) per-step stream
    cost.resident_bytes = leaf.nbytes / storage_div
    if leaf.section == "cache" and ctx.workload == "decode":
        cost.memory_s = env.memory_s(leaf.nbytes / storage_div)
    return cost


def _merge(total: PlanCost, leaf: PlanCost) -> None:
    total.compute_s += leaf.compute_s
    total.memory_s += leaf.memory_s
    total.collective_s += leaf.collective_s
    total.resident_bytes += leaf.resident_bytes
    total.collective_bytes += leaf.collective_bytes
    for k, v in leaf.by_kind.items():
        total.by_kind[k] = total.by_kind.get(k, 0.0) + v


def _objective(total: PlanCost, env: ClusterEnv) -> float:
    over = max(0.0, total.resident_bytes - env.hbm_bytes)
    return total.total_s + CAPACITY_PENALTY * env.memory_s(over)


# ---------------------------------------------------------------------------
# Whole-cell planning
# ---------------------------------------------------------------------------

def _batch_choices(mesh: MeshSpec, global_batch: int) -> List[Tuple[str, ...]]:
    """Every valid (pod, data) batch prefix, shortest first."""
    out: List[Tuple[str, ...]] = [()]
    prod = 1
    for a in shd.BATCH_AXES:
        s = mesh.axis_size(a)
        if s <= 1:
            continue
        if global_batch % (prod * s):
            break
        prod *= s
        out.append(out[-1] + (a,))
    return out


def _ctx_for(env: ClusterEnv, cfg, shape, workload: str,
             bchoice: Tuple[str, ...]) -> _Ctx:
    bdiv = env.group_size(bchoice) or 1
    if workload == "decode":
        tokens = float(shape.global_batch)
        act = shape.global_batch * cfg.d_model * 2.0 / bdiv
    else:
        tokens = float(shape.global_batch) * shape.seq_len
        act = shape.global_batch * shape.seq_len * cfg.d_model * 2.0 / bdiv
    return _Ctx(env=env, cfg=cfg, workload=workload, tokens=tokens,
                batch_div=bdiv, act_bytes_local=act)


def _model_trees(model, shape, workload: str):
    state = model.init_shapes()
    cache = None
    if workload == "decode":
        try:
            cache = model.cache_specs(shape)
        except Exception:
            cache = None
    try:
        batch = model.input_specs(shape)
    except Exception:
        batch = None
    return state, cache, batch


def _aux_specs(leaf: _Leaf, mesh, b) -> P:
    if leaf.section == "cache":
        return shd.cache_leaf_spec(leaf.path, leaf.shape, mesh, b)
    return shd.batch_leaf_spec(leaf.path, leaf.shape, b)


def _score_assignment(state_specs: Dict[str, P], leaves: List[_Leaf],
                      aux: List[_Leaf], ctx: _Ctx,
                      b) -> PlanCost:
    total = PlanCost()
    for leaf in leaves:
        _merge(total, leaf_cost(leaf, state_specs[leaf.path], ctx))
    for leaf in aux:
        _merge(total, leaf_cost(leaf, _aux_specs(leaf, ctx.env.mesh, b), ctx))
    return total


def plan_model(model, mesh, shape=None, workload: Optional[str] = None,
               env: Optional[ClusterEnv] = None) -> ShardingPlan:
    """Search placements for one (model, mesh, workload) cell and return the
    winning plan (ranked alternatives in `meta["ranked"]`)."""
    if shape is None:
        raise ValueError("plan_model needs a ShapeConfig (batch/seq sizes "
                         "drive every cost term)")
    mesh_spec = MeshSpec.from_mesh(mesh)
    env = env or ClusterEnv(mesh_spec)
    cfg = model.cfg
    workload = workload or ("train" if shape.kind == "train" else shape.kind)
    fsdp_rules = shd.fsdp_default(cfg, mesh_spec)

    state_tree, cache_tree, batch_tree = _model_trees(model, shape, workload)
    state_leaves = _leaves(state_tree, "state")
    aux_leaves = ([] if cache_tree is None
                  else _leaves(cache_tree, "cache"))
    batch_leaves = [] if batch_tree is None else _leaves(batch_tree, "batch")
    aux_leaves += batch_leaves

    rules_specs = {
        leaf.path: shd._param_rule(leaf.path, leaf.shape, mesh_spec, cfg,
                                   fsdp=fsdp_rules)
        for leaf in state_leaves}
    b_rules = shd.batch_axes(mesh_spec, shape.global_batch)

    ranked = []        # (label, bchoice, specs, PlanCost)

    # -- rules, scored under the same model ---------------------------------
    ctx_rules = _ctx_for(env, cfg, shape, workload, b_rules)
    cost_rules = _score_assignment(rules_specs, state_leaves, aux_leaves,
                                   ctx_rules, b_rules or None)
    ranked.append(("rules", b_rules, rules_specs, cost_rules))

    # -- search: per-leaf argmin per batch choice, then capacity repair -----
    for bchoice in _batch_choices(mesh_spec, shape.global_batch):
        ctx = _ctx_for(env, cfg, shape, workload, bchoice)
        chosen: Dict[str, P] = {}
        options: Dict[str, Dict[str, Tuple[P, PlanCost]]] = {}
        for leaf in state_leaves:
            cands = state_candidates(leaf, mesh_spec, cfg,
                                     rules_specs[leaf.path])
            priced = {lab: (spec, leaf_cost(leaf, spec, ctx))
                      for lab, spec in cands.items()}
            options[leaf.path] = priced
            best = min(priced.values(),
                       key=lambda sc: (sc[1].total_s, sc[1].resident_bytes))
            chosen[leaf.path] = best[0]
        total = _score_assignment(chosen, state_leaves, aux_leaves, ctx,
                                  bchoice or None)
        # capacity repair: flip the largest-resident leaves to their
        # cheapest smaller-resident candidate until the cell fits
        guard = 0
        while total.resident_bytes > env.hbm_bytes and guard < 64:
            guard += 1
            cur = {leaf.path: leaf_cost(leaf, chosen[leaf.path], ctx)
                   for leaf in state_leaves}
            flips = []
            for leaf in state_leaves:
                have = cur[leaf.path]
                better = [(spec, c) for spec, c in options[leaf.path].values()
                          if c.resident_bytes < have.resident_bytes]
                if better:
                    spec, c = min(better, key=lambda sc: sc[1].total_s)
                    flips.append((have.resident_bytes - c.resident_bytes,
                                  leaf.path, spec))
            if not flips:
                break
            _, path, spec = max(flips)
            chosen[path] = spec
            total = _score_assignment(chosen, state_leaves, aux_leaves, ctx,
                                      bchoice or None)
        label = "search[b=" + (",".join(bchoice) or "-") + "]"
        ranked.append((label, bchoice, chosen, total))

    ranked.sort(key=lambda r: _objective(r[3], env))
    win_label, win_b, win_specs, win_cost = ranked[0]

    plan = ShardingPlan(meta={}, tables={})
    for leaf in state_leaves:
        key = leaf_key(leaf.path, leaf.shape)
        if key not in plan.tables.get("state", {}):
            plan.put("state", leaf.path, leaf.shape, win_specs[leaf.path])
    b = tuple(win_b) or None
    for leaf in aux_leaves:
        section = leaf.section
        if leaf_key(leaf.path, leaf.shape) not in plan.tables.get(section, {}):
            plan.put(section, leaf.path, leaf.shape,
                     _aux_specs(leaf, mesh_spec, b))
    plan.meta = {
        "arch": getattr(cfg, "name", "?"),
        "method": getattr(getattr(model, "peft", None), "method", None),
        "mesh": dict(mesh_spec.axes),
        "workload": workload,
        "shape": getattr(shape, "name", "?"),
        "strategy": win_label,
        "batch_axes": list(win_b),
        "fsdp_rules": bool(fsdp_rules),
        "cost": win_cost.to_json(),
        "ranked": [{"strategy": lab,
                    "batch_axes": list(bc),
                    "objective_s": _objective(c, env),
                    **c.to_json()}
                   for lab, bc, _, c in ranked],
    }
    return plan


# ---------------------------------------------------------------------------
# Scoring an existing source (rules, file, ...) under the same cost model
# ---------------------------------------------------------------------------

def score_source(model, mesh, shape, source: PlanSource,
                 workload: Optional[str] = None,
                 env: Optional[ClusterEnv] = None) -> PlanCost:
    """Predicted cost of whatever placements `source` resolves for this
    cell — the number the fleet validation correlates against the
    `dist/hlo.py` analyzer terms."""
    mesh_spec = MeshSpec.from_mesh(mesh)
    env = env or ClusterEnv(mesh_spec)
    cfg = model.cfg
    workload = workload or ("train" if shape.kind == "train" else shape.kind)
    fsdp = shd.fsdp_default(cfg, mesh_spec)
    state_tree, cache_tree, batch_tree = _model_trees(model, shape, workload)

    spec_by_path: Dict[Tuple[str, str], P] = {}
    for section, tree, specs in (
            ("state", state_tree,
             source.state_specs(state_tree, mesh_spec, cfg, fsdp=fsdp)),
            ("cache", cache_tree,
             None if cache_tree is None
             else source.cache_specs(cache_tree, mesh_spec, cfg, shape)),
            ("batch", batch_tree,
             None if batch_tree is None
             else source.batch_specs(batch_tree, mesh_spec, shape))):
        if specs is None:
            continue
        for path, spec in _iter_leaves(specs):
            spec_by_path[(section, path)] = spec

    # infer the batch-shard choice from the token leaf's dim-0 axes
    b_axes: Tuple[str, ...] = shd.batch_axes(mesh_spec, shape.global_batch)
    for (section, path), spec in spec_by_path.items():
        if section == "batch" and path.split("/")[-1] == "tokens":
            entries = tuple(spec)
            if entries:
                e = entries[0]
                b_axes = (() if e is None else
                          (e,) if isinstance(e, str) else tuple(e))
            break
    ctx = _ctx_for(env, cfg, shape, workload, b_axes)

    total = PlanCost()
    for section, tree in (("state", state_tree), ("cache", cache_tree),
                          ("batch", batch_tree)):
        if tree is None:
            continue
        for leaf in _leaves(tree, section):
            spec = spec_by_path.get((section, leaf.path))
            if spec is None:
                continue
            _merge(total, leaf_cost(leaf, spec, ctx))
    return total


def spec_diff(source_a: PlanSource, source_b: PlanSource, model, mesh, cfg,
              shape, workload: str) -> List[Dict]:
    """Leaf-level placement differences between two sources (for the CI
    plan-table diff and the fleet trend rows)."""
    mesh_spec = MeshSpec.from_mesh(mesh)
    fsdp = shd.fsdp_default(cfg, mesh_spec)
    state_tree, cache_tree, batch_tree = _model_trees(model, shape, workload)
    out: List[Dict] = []
    for section, tree, get in (
            ("state", state_tree,
             lambda s: s.state_specs(state_tree, mesh_spec, cfg, fsdp=fsdp)),
            ("cache", cache_tree,
             lambda s: s.cache_specs(cache_tree, mesh_spec, cfg, shape)),
            ("batch", batch_tree,
             lambda s: s.batch_specs(batch_tree, mesh_spec, shape))):
        if tree is None:
            continue
        a = dict(_iter_leaves(get(source_a)))
        bb = dict(_iter_leaves(get(source_b)))

        def norm(spec):
            # a dim entry ('data',) and 'data' are the same placement
            if spec is None:
                return None
            return tuple(e[0] if isinstance(e, tuple) and len(e) == 1 else e
                         for e in tuple(spec))

        for path in sorted(set(a) | set(bb)):
            na, nb = norm(a.get(path)), norm(bb.get(path))
            if na != nb:
                out.append({"section": section, "path": path,
                            "a": None if na is None else list(na),
                            "b": None if nb is None else list(nb)})
    return out


# ---------------------------------------------------------------------------
# CLI — the CI gate runs this on two small cells
# ---------------------------------------------------------------------------

def _build_model(arch: str, reduced: bool, method: str, remat: str):
    import repro.configs as configs
    from repro.configs.base import PEFTConfig
    from repro.models.registry import build
    cfg = configs.get(arch)
    if reduced:
        cfg = configs.reduced(cfg)
    n = 16 if reduced else 1000
    peft = (PEFTConfig(method="none") if method == "none"
            else PEFTConfig(method=method, n=n, alpha=300.0,
                            strategy="merged"))
    return cfg, build(cfg, peft, remat=remat)


def main(argv: Optional[Sequence[str]] = None) -> int:
    import repro.configs as configs
    ap = argparse.ArgumentParser(
        description="search sharding placements for one cell")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="4x2",
                    help="DxM or PxDxM abstract mesh (no devices needed)")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--method", default="fourierft")
    ap.add_argument("--out", default=None, help="write the winning plan JSON")
    ap.add_argument("--check-search-beats-rules", action="store_true",
                    help="exit 1 unless the winner scores <= rules "
                         "under the cost model (the CI gate)")
    ap.add_argument("--diff-rules", action="store_true",
                    help="print the leafwise spec differences between the "
                         "searched plan and the rule table")
    ap.add_argument("--audit", action="store_true",
                    help="run the sharding-coverage audit against the "
                         "searched plan (exit 1 on findings)")
    args = ap.parse_args(argv)

    shape = configs.shape_for(args.shape)
    workload = "train" if shape.kind == "train" else shape.kind
    remat = "full" if workload == "train" else "none"
    cfg, model = _build_model(args.arch, args.reduced, args.method, remat)
    mesh = MeshSpec.from_string(args.mesh)
    plan = plan_model(model, mesh, shape=shape, workload=workload)

    ranked = plan.meta["ranked"]
    for row in ranked:
        print(f"{row['strategy']:>24}  objective={row['objective_s']:.3e}s  "
              f"coll={row['collective_bytes']:.3e}B  "
              f"resident={row['resident_bytes']:.3e}B")
    if args.out:
        plan.save(args.out)
        print(f"wrote {args.out} (strategy={plan.meta['strategy']})")
    if args.diff_rules:
        diffs = spec_diff(RulesSource(), PlanTableSource(plan),
                          model, mesh, cfg, shape, workload)
        for d in diffs:
            print(f"diff {d['section']}:{d['path']}  "
                  f"rules={d['a']}  plan={d['b']}")
        print(f"{len(diffs)} spec diffs vs rules")
    if args.audit:
        from repro.analysis import sharding_audit
        src = PlanTableSource(plan)
        state_tree, cache_tree, batch_tree = _model_trees(model, shape,
                                                          workload)
        findings = sharding_audit.audit_tree(
            state_tree, f"{args.arch}[plan]", source=src)
        if cache_tree is not None:
            findings += sharding_audit.audit_tree(
                cache_tree, f"{args.arch}[plan-cache]", section="cache",
                source=src)
        if batch_tree is not None:
            findings += sharding_audit.audit_tree(
                batch_tree, f"{args.arch}[plan-batch]", section="batch",
                source=src)
        for f in findings:
            print(f"AUDIT {f.where}: {f.message}", file=sys.stderr)
        if findings:
            return 1
        print("audit: every leaf of the searched plan has a decision")
    if args.check_search_beats_rules:
        rules = next(r for r in ranked if r["strategy"] == "rules")
        best = ranked[0]
        if best["objective_s"] > rules["objective_s"] * (1 + 1e-9):
            print("FAIL: searched plan scores worse than the rule table",
                  file=sys.stderr)
            return 1
        print("ok: search <= rules under the cost model")
    return 0


if __name__ == "__main__":
    sys.exit(main())
