"""Sharding plans and plan sources (DESIGN.md §Sharding).

`dist/sharding.py`'s rule table used to be the ONLY way a tree got placed.
This module turns it into one source among several behind a small interface:

    PlanSource.state_specs / cache_specs / batch_specs
        — drop-in for the legacy `sharding.state_specs` etc., returning the
          same PartitionSpec trees those functions return;
    PlanSource.decision(section, path, shape)
        — which named decision covered a leaf (None = silent fall-through),
          what `analysis/sharding_audit` now audits instead of re-deriving
          from the rule table;
    PlanSource.describe()
        — provenance metadata the dry-run harness records per cell.

Sources:

    RulesSource      — the hand-written table, byte-identical to the
                       pre-refactor functions (it IS those functions);
                       the compatibility default everywhere.
    PlanTableSource  — a serialized `ShardingPlan` (searched by
                       `dist/planner.py` or loaded from a checked-in file),
                       falling back to the rules for any leaf the table
                       doesn't name.

`resolve(arg, ...)` maps the CLI surface (`--sharding-plan
rules|search|<path>`) onto a source; "search" runs the planner once at model
build and serving/training just use the winner.

Plan tables are keyed `(section, "<leaf-name>|<ndim>")` — the same
name-keyed matching philosophy as the rule engine, which is what lets one
table cover params, optimizer moments (`mu/…/wq` ends in `wq`), and frozen
trees alike. Stored specs are sanitized against the actual leaf shape and
mesh at apply time (axes that don't exist or don't divide are dropped to
replicate), so a plan searched on one mesh degrades safely instead of
erroring on another.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Tuple

from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd

SECTIONS = ("state", "cache", "batch")
PLAN_VERSION = 1


# ---------------------------------------------------------------------------
# Spec (de)serialization
# ---------------------------------------------------------------------------

def encode_spec(spec) -> List:
    """PartitionSpec -> JSON-able nested list (dim entries: None | axis |
    [axes...])."""
    out = []
    for entry in tuple(spec):
        if entry is None or isinstance(entry, str):
            out.append(entry)
        else:
            out.append(list(entry))
    return out


def decode_spec(enc) -> P:
    entries = []
    for e in enc:
        if e is None or isinstance(e, str):
            entries.append(e)
        else:
            entries.append(tuple(e))
    return P(*entries)


def sanitize_spec(spec, shape: Tuple[int, ...], mesh) -> P:
    """Clamp a stored spec to a leaf/mesh: pad/trim rank, drop axes that are
    absent from the mesh or whose product doesn't divide the dim (replicate
    instead of producing an invalid uneven sharding)."""
    entries = list(tuple(spec))[:len(shape)]
    entries += [None] * (len(shape) - len(entries))
    out = []
    for dim, entry in zip(shape, entries):
        axes = (() if entry is None
                else (entry,) if isinstance(entry, str) else tuple(entry))
        axes = [a for a in axes if shd.axis_size(mesh, a) > 1]
        prod = 1
        for a in axes:
            prod *= shd.axis_size(mesh, a)
        if not axes or dim % prod:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(tuple(axes))
    return P(*out)


def leaf_key(path: str, shape: Tuple[int, ...]) -> str:
    """Table key for a leaf: name|ndim, with the fsdp-stage suffix stripped
    the same way the rule engine strips it."""
    name = path.split("/")[-1]
    if name.endswith("__b"):
        name = name[:-3]
    return f"{name}|{len(shape)}"


# ---------------------------------------------------------------------------
# ShardingPlan
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShardingPlan:
    """A serializable placement: per-section spec tables plus provenance
    (which strategy produced it, on what mesh/model/workload, at what
    predicted cost, and how the alternatives ranked)."""
    meta: Dict
    tables: Dict[str, Dict[str, List]]
    version: int = PLAN_VERSION

    def spec_for(self, section: str, path: str,
                 shape: Tuple[int, ...]) -> Optional[P]:
        enc = self.tables.get(section, {}).get(leaf_key(path, shape))
        return None if enc is None else decode_spec(enc)

    def put(self, section: str, path: str, shape: Tuple[int, ...],
            spec) -> None:
        self.tables.setdefault(section, {})[leaf_key(path, shape)] = \
            encode_spec(spec)

    def to_json(self) -> Dict:
        return {"version": self.version, "meta": self.meta,
                "tables": {s: dict(sorted(t.items()))
                           for s, t in sorted(self.tables.items())}}

    @classmethod
    def from_json(cls, obj: Dict) -> "ShardingPlan":
        if obj.get("version", 1) != PLAN_VERSION:
            raise ValueError(f"unsupported plan version {obj.get('version')}")
        return cls(meta=dict(obj.get("meta", {})),
                   tables={s: dict(t)
                           for s, t in obj.get("tables", {}).items()})

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "ShardingPlan":
        with open(path) as f:
            return cls.from_json(json.load(f))


# ---------------------------------------------------------------------------
# Sources
# ---------------------------------------------------------------------------

class PlanSource:
    """Where placements come from. Implementations must return spec trees
    with EXACTLY the same structure/semantics as the legacy
    `sharding.state_specs`/`cache_specs`/`batch_specs`."""

    kind = "abstract"

    def state_specs(self, tree, mesh, cfg, fsdp: bool = False):
        raise NotImplementedError

    def cache_specs(self, cache, mesh, cfg, shape):
        raise NotImplementedError

    def batch_specs(self, batch, mesh, shape):
        raise NotImplementedError

    def decision(self, section: str, path: str,
                 shape: Tuple[int, ...]) -> Optional[str]:
        """Which named decision covers this leaf (None = nobody placed it;
        the audit flags those)."""
        raise NotImplementedError

    def param_spec(self, path: str, shape: Tuple[int, ...], mesh, cfg,
                   fsdp: bool = False):
        """Per-leaf state spec — the sharding-constraint hook
        (launch/dryrun_lib.make_constrain) anchors in-graph weights to the
        same placement the plan chose for their storage."""
        raise NotImplementedError

    def describe(self) -> Dict:
        return {"source": self.kind}


class RulesSource(PlanSource):
    """The hand-written rule table — byte-identical to the pre-refactor
    module functions because it delegates to them."""

    kind = "rules"

    def state_specs(self, tree, mesh, cfg, fsdp: bool = False):
        return shd.state_specs(tree, mesh, cfg, fsdp=fsdp)

    def cache_specs(self, cache, mesh, cfg, shape):
        return shd.cache_specs(cache, mesh, cfg, shape)

    def batch_specs(self, batch, mesh, shape):
        return shd.batch_specs(batch, mesh, shape)

    def decision(self, section, path, shape):
        if section == "state":
            return shd.rule_kind(path, shape)
        if section == "cache":
            return shd.cache_rule_kind(path, shape)
        if section == "batch":
            return shd.batch_rule_kind(path, shape)
        raise ValueError(f"unknown section {section!r}")

    def param_spec(self, path, shape, mesh, cfg, fsdp: bool = False):
        return shd._param_rule(path, shape, mesh, cfg, fsdp=fsdp)


class PlanTableSource(PlanSource):
    """Specs from a `ShardingPlan` table; any leaf the table doesn't name
    falls back to the rules (so a partial plan is always safe to apply)."""

    kind = "plan"

    def __init__(self, plan: ShardingPlan,
                 fallback: Optional[PlanSource] = None):
        self.plan = plan
        self.fallback = fallback or RulesSource()

    def _resolved(self, section, path, shape, mesh, fallback_spec):
        spec = self.plan.spec_for(section, path, shape)
        if spec is None:
            return fallback_spec()
        return sanitize_spec(spec, shape, mesh)

    def state_specs(self, tree, mesh, cfg, fsdp: bool = False):
        def rule(path, leaf):
            shape = tuple(getattr(leaf, "shape", ()))
            if not shape:
                return P()
            return self._resolved(
                "state", path, shape, mesh,
                lambda: shd._param_rule(path, shape, mesh, cfg, fsdp=fsdp))
        return shd._walk_specs(tree, rule)

    def cache_specs(self, cache, mesh, cfg, shape):
        b = shd.batch_axes(mesh, shape.global_batch) or None

        def rule(path, leaf):
            shp = tuple(getattr(leaf, "shape", ()))
            return self._resolved(
                "cache", path, shp, mesh,
                lambda: shd.cache_leaf_spec(path, shp, mesh, b))
        return shd._walk_specs(cache, rule)

    def batch_specs(self, batch, mesh, shape):
        b = shd.batch_axes(mesh, shape.global_batch) or None

        def rule(path, leaf):
            shp = tuple(getattr(leaf, "shape", ()))
            return self._resolved(
                "batch", path, shp, mesh,
                lambda: shd.batch_leaf_spec(path, shp, b))
        return shd._walk_specs(batch, rule)

    def decision(self, section, path, shape):
        if self.plan.spec_for(section, path, shape) is not None:
            return "plan"
        return self.fallback.decision(section, path, shape)

    def param_spec(self, path, shape, mesh, cfg, fsdp: bool = False):
        spec = self.plan.spec_for("state", path, shape)
        if spec is None:
            return self.fallback.param_spec(path, shape, mesh, cfg,
                                            fsdp=fsdp)
        return sanitize_spec(spec, shape, mesh)

    def describe(self) -> Dict:
        meta = self.plan.meta
        return {"source": self.kind,
                "strategy": meta.get("strategy"),
                "plan_meta": {k: meta[k]
                              for k in ("arch", "mesh", "workload", "shape")
                              if k in meta}}


# ---------------------------------------------------------------------------
# CLI surface
# ---------------------------------------------------------------------------

def resolve(arg: Optional[str], *, model=None, mesh=None, shape=None,
            workload: Optional[str] = None) -> PlanSource:
    """Map `--sharding-plan rules|search|<path>` onto a source.

    "search" runs the planner once against `model` on `mesh` (abstract —
    no compilation) and applies the winning plan; a path loads a checked-in
    plan file. Resolution happens once at model build; everything downstream
    just consumes the source.
    """
    if arg in (None, "", "rules"):
        return RulesSource()
    if arg == "search":
        if model is None or mesh is None:
            raise ValueError("--sharding-plan search needs a built model "
                             "and a mesh to plan against")
        from repro.dist import planner
        plan = planner.plan_model(model, mesh, shape=shape,
                                  workload=workload)
        return PlanTableSource(plan)
    return PlanTableSource(ShardingPlan.load(arg))
