"""Dry-run library: lower + compile every (arch × shape × mesh) cell with full
production shardings, extract memory / cost / collective analyses, and derive
the roofline terms (DESIGN §9).

Importable without touching jax device state — `launch/dryrun.py` (the script)
sets XLA_FLAGS for 512 host devices before importing this.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import math
import os
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

import repro.configs as configs
from repro.configs.base import ModelConfig, PEFTConfig, ShapeConfig, TrainConfig
from repro.dist import hlo as hlo_mod
from repro.dist import plan as plan_mod
from repro.dist import sharding as shd
from repro.dist.sharding import axis_size
from repro.models.registry import Model, build
from repro.train import step as train_step_mod

# TPU v5e per-chip constants (assignment brief)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link (pessimistic single-link charge)
HBM_BYTES = 16e9           # v5e HBM capacity

ACT_BUDGET_BYTES = 4e9     # per-device activation-boundary budget for auto-microbatch


def long_context_skip(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k runs only for sub-quadratic (SSM/hybrid) archs."""
    return shape.name == "long_500k" and not cfg.subquadratic


def auto_microbatch(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> int:
    """Pick gradient-accumulation factor so the per-device scan-boundary
    activation set (L · B_mb_local · S · d · 2B) fits the budget."""
    baxes = shd.batch_axes(mesh, shape.global_batch)
    nshard = int(np.prod([shd.axis_size(mesh, a) for a in baxes])) or 1
    b_loc = shape.global_batch // nshard
    budget = ACT_BUDGET_BYTES / (2 if cfg.moe is not None else 1)
    per_mb = lambda k: (cfg.num_layers * max(b_loc // k, 1) * shape.seq_len
                        * cfg.d_model * 2)
    k = 1
    while k < b_loc and per_mb(k) > budget:
        k *= 2
    return 0 if k == 1 else k


def _strip_axis(spec: P, axis: str) -> P:
    """Drop one mesh axis from a PartitionSpec (the gathered copy of an
    fsdp-scattered weight loses its `data` shard)."""
    out = []
    for e in tuple(spec):
        if e == axis:
            out.append(None)
        elif isinstance(e, tuple):
            kept = tuple(a for a in e if a != axis)
            out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
        else:
            out.append(e)
    return P(*out)


def make_constrain(mesh: Mesh, cfg: ModelConfig, fsdp: bool = False,
                   source: Optional[plan_mod.PlanSource] = None):
    """Sharding-constraint hook: (a) merged ΔW stacks pinned to the weight's
    storage spec (whatever the resolved plan `source` chose — rules when
    None); (b) under FSDP, per-layer weight slices gathered over `data`
    inside the layer loop ("fsdp_gather/<name>" paths)."""
    source = source or plan_mod.RulesSource()
    # sequence-parallel residual stream: shard S over `model` at layer
    # boundaries for large-d archs. The remat boundary saves (L, B_mb, S, d)
    # then shard 16x (qwen2-vl-72b: 5.4GB -> 0.34GB per stack per device);
    # the TP all-reduce after wo/wo_mlp becomes reduce-scatter + all-gather
    # (same bytes), and norms run on S/16 shards.
    # scoped to qwen2-vl-72b: smaller archs fit without SP, and GSPMD-auto
    # SP costs extra reshard collectives (proper manual SP via shard_map is
    # the identified next step; see DESIGN.md §Dist)
    seq_parallel = cfg.d_model >= 8000

    def constrain(path: str, x):
        if path == "moe/dispatch":
            # 2-D expert-parallel: sequences over `data`, experts over
            # `model`. (E-only sharding leaves capacity global -> 16x
            # redundant expert FLOPs; global-capacity 2-D needs an
            # all-layout scatter -> 200s collectives. Measured, olmoe.)
            bax = shd.batch_axes(mesh, x.shape[0])
            spec = P(bax if bax else None,
                     shd._maybe(x.shape[1], mesh, "model"), None, None)
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
        if path == "moe/tokens":
            bax = shd.batch_axes(mesh, x.shape[0])
            spec = P(bax if bax else None, None, None)
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
        if path.startswith("act/"):
            # activations at layer boundaries: (B, S, d) batch-sharded,
            # everything else replicated. Without this anchor GSPMD's scan
            # fixpoint settles on partially-replicated activations
            # (measured: 8x redundant projection flops on yi-6b).
            bax = shd.batch_axes(mesh, x.shape[0])
            sax = ("model" if (seq_parallel and x.ndim == 3
                               and x.shape[1] % axis_size(mesh, "model") == 0
                               and x.shape[1] > 1) else None)
            spec = P(bax if bax else None, sax,
                     *([None] * (x.ndim - 2)))
        elif path.startswith("fsdp_gather/"):
            if not fsdp:
                return x
            spec = _strip_axis(
                source.param_spec(path[len("fsdp_gather/"):], x.shape, mesh,
                                  cfg, fsdp=False), "data")
        else:
            spec = source.param_spec(path, x.shape, mesh, cfg, fsdp=fsdp)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return constrain


def peft_for(cfg: ModelConfig, kind: str) -> PEFTConfig:
    """train: the paper's technique (n=1000, merged). serve: adapters merged
    offline (method none) except hybrid shared-block adapters (factored by
    construction)."""
    if kind == "train":
        # (strategy note, DESIGN §2: factored costs 4n(d1+d2) vs merged's
        # 2·d1·d2 per token — but under full remat the factored path is
        # recomputed 3x while merged's dW_eff GEMM runs once; measured on
        # qwen2-vl-72b train: factored = +52% compute, no memory win.
        # merged stays the default.)
        return PEFTConfig(method="fourierft", n=1000, alpha=300.0,
                          strategy="merged")
    if cfg.family == "hybrid":
        return PEFTConfig(method="fourierft", n=1000, alpha=300.0,
                          strategy="factored")
    return PEFTConfig(method="none")


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeConfig
    model: Model
    step_fn: object
    args: Tuple            # abstract args (ShapeDtypeStruct trees)
    in_shardings: Tuple
    donate: Tuple[int, ...]
    plan_source: Optional[plan_mod.PlanSource] = None


def build_cell(arch: str, shape_name: str, mesh: Mesh,
               *, peft: Optional[PEFTConfig] = None,
               remat: str = "full",
               microbatch: Optional[int] = None,
               sharding_plan: Optional[str] = None) -> Cell:
    """sharding_plan: rules|search|<plan.json> (or an already-resolved
    PlanSource) — which source places every tree of this cell."""
    cfg = configs.get(arch)
    shape = configs.shape_for(shape_name)
    fsdp = shd.fsdp_default(cfg, mesh)
    if long_context_skip(cfg, shape):
        raise ValueError(f"{arch} skips {shape_name} (full attention; see "
                         "DESIGN.md §Arch-applicability)")
    workload = shape.kind if shape.kind != "train" else "train"
    if shape.kind == "train":
        p = peft or peft_for(cfg, "train")
        model = build(cfg, p, remat=remat)
        src = (sharding_plan if isinstance(sharding_plan, plan_mod.PlanSource)
               else plan_mod.resolve(sharding_plan, model=model, mesh=mesh,
                                     shape=shape, workload=workload))
        model.constrain = make_constrain(mesh, cfg, fsdp, source=src)
        tcfg = TrainConfig(microbatch=(auto_microbatch(cfg, shape, mesh)
                                       if microbatch is None else microbatch))
        tstep = train_step_mod.make_train_step(model, tcfg)
        state, frozen = jax.eval_shape(
            lambda: train_step_mod.init_state(model, tcfg,
                                              jax.random.PRNGKey(0)))
        batch = model.input_specs(shape)
        state_sh = shd.named(state, src.state_specs(state, mesh, cfg, fsdp), mesh)
        frozen_sh = shd.named(frozen, src.state_specs(frozen, mesh, cfg, fsdp), mesh)
        batch_sh = shd.named(batch, src.batch_specs(batch, mesh, shape), mesh)
        return Cell(arch, shape, model, tstep, (state, frozen, batch),
                    (state_sh, frozen_sh, batch_sh), (0,), src)
    p = peft or peft_for(cfg, "serve")
    model = build(cfg, p, remat="none")
    src = (sharding_plan if isinstance(sharding_plan, plan_mod.PlanSource)
           else plan_mod.resolve(sharding_plan, model=model, mesh=mesh,
                                 shape=shape, workload=workload))
    model.constrain = make_constrain(mesh, cfg, fsdp, source=src)
    if shape.kind == "prefill":
        def prefill_step(params, batch):
            logits, _ = model.forward(params, batch)
            return logits[:, -1].astype(jnp.float32)

        params = model.init_shapes()
        batch = model.input_specs(shape)
        params_sh = shd.named(params, src.state_specs(params, mesh, cfg, fsdp), mesh)
        batch_sh = shd.named(batch, src.batch_specs(batch, mesh, shape), mesh)
        return Cell(arch, shape, model, prefill_step, (params, batch),
                    (params_sh, batch_sh), (), src)

    # decode
    def serve_step(params, cache, batch):
        return model.decode_step(params, cache, batch)

    params = model.init_shapes()
    cache = model.cache_specs(shape)
    batch = model.input_specs(shape)
    params_sh = shd.named(params, src.state_specs(params, mesh, cfg, fsdp), mesh)
    cache_sh = shd.named(cache, src.cache_specs(cache, mesh, cfg, shape), mesh)
    batch_sh = shd.named(batch, src.batch_specs(batch, mesh, shape), mesh)
    return Cell(arch, shape, model, serve_step, (params, cache, batch),
                (params_sh, cache_sh, batch_sh), (1,), src)


def lower_cell(cell: Cell):
    jitted = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                     donate_argnums=cell.donate)
    return jitted.lower(*cell.args)


# ---------------------------------------------------------------------------
# Accounting
# ---------------------------------------------------------------------------

def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)
               if hasattr(x, "size"))


def backbone_params(model: Model) -> Tuple[int, int]:
    """(N_total_backbone, N_active_backbone) — excludes embed/lm_head."""
    shapes = jax.eval_shape(
        lambda: model._mod.init_params(jax.random.PRNGKey(0), model.cfg))
    total = active = 0
    cfg = model.cfg
    for path, leaf in _walk(shapes):
        last = path.split("/")[-1]
        if last in ("embed", "lm_head"):
            continue
        n = int(np.prod(leaf.shape))
        total += n
        if last.startswith("we_") and cfg.moe is not None:
            active += n * cfg.moe.top_k // cfg.moe.num_experts
        else:
            active += n
    return total, active


def _walk(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _walk(v, f"{prefix}{k}/")
    else:
        yield prefix[:-1], tree


def model_flops(model: Model, shape: ShapeConfig) -> float:
    """Useful-work convention: 6·N_active·tokens (train), 2·N_active·tokens
    (prefill/decode forward)."""
    _, n_active = backbone_params(model)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch      # decode: 1 token/seq


def analyze(cell: Cell, lowered, compiled, mesh: Mesh,
            compile_seconds: float) -> Dict:
    chips = mesh.devices.size
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):     # jax ≤ 0.4.x returns [dict]
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    # NOTE: XLA's cost_analysis visits while bodies once (no trip-count
    # scaling) -- useless for scanned programs. We re-derive from the HLO
    # with full call-graph multiplicity (dist/hlo.py) and keep XLA's numbers
    # for reference.
    stats = hlo_mod.analyze_module(compiled.as_text())
    flops_dev = float(stats.flops)
    bytes_dev = float(stats.bytes_min)
    bytes_dev_upper = float(stats.bytes)
    coll_dev = float(stats.collective_bytes)

    t_compute = flops_dev / PEAK_FLOPS
    # memory term uses the TPU-fusion-ideal bound (elementwise chains fused);
    # the CPU-fusion-granularity upper bound is reported alongside.
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "memory_s_upper": bytes_dev_upper / HBM_BW,
             "collective_s": t_coll}
    dominant = max(
        {k: terms[k] for k in ("compute_s", "memory_s", "collective_s")},
        key=terms.get)

    mf = model_flops(cell.model, cell.shape)
    useful_ratio = mf / (flops_dev * chips) if flops_dev else 0.0
    bound = max(t_compute, t_memory, t_coll)
    ideal = mf / (chips * PEAK_FLOPS)
    roofline_frac = ideal / bound if bound > 0 else 0.0

    peak = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
            + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    # provenance + predicted cost of whichever plan source placed this cell
    # (what BENCH_analysis.json's sharding_plan_* rows correlate against the
    # analyzer terms above)
    plan_info = None
    if cell.plan_source is not None:
        plan_info = dict(cell.plan_source.describe())
        try:
            from repro.dist import planner
            plan_info["predicted"] = planner.score_source(
                cell.model, mesh, cell.shape, cell.plan_source).to_json()
        except Exception as e:               # prediction must never sink a run
            plan_info["predicted_error"] = f"{type(e).__name__}: {e}"
    return {
        "arch": cell.arch,
        "shape": cell.shape.name,
        "kind": cell.shape.kind,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "chips": chips,
        "flops_per_device": flops_dev,
        "dot_flops_per_device": float(stats.dot_flops),
        "bytes_per_device": bytes_dev,
        "bytes_per_device_upper": bytes_dev_upper,
        "collective_bytes_per_device": coll_dev,
        "collectives": stats.bytes_by_kind,
        "collective_counts": stats.count_by_kind,
        "xla_cost_analysis": {
            "flops_unscaled": float(cost.get("flops", 0.0)),
            "bytes_unscaled": float(cost.get("bytes accessed", 0.0)),
        },
        "terms": terms,
        "dominant": dominant,
        "model_flops": mf,
        "useful_flops_ratio": useful_ratio,
        "roofline_fraction": roofline_frac,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": peak,
            "fits_hbm": bool(peak < HBM_BYTES),
        },
        "compile_seconds": compile_seconds,
        "sharding_plan": plan_info,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Optional[str] = None, *,
             peft: Optional[PEFTConfig] = None,
             variant: str = "baseline",
             remat: str = "full",
             microbatch: Optional[int] = None,
             mesh_shape: Optional[str] = None,
             save_hlo: bool = False,
             sharding_plan: Optional[str] = None) -> Dict:
    """mesh_shape: optional "DxM" remap of the same chips (perf variants);
    the required dry-run meshes stay (16,16) / (2,16,16).
    sharding_plan: rules|search|<plan.json> — plan source for every tree."""
    from repro.launch.mesh import (
        make_mesh, make_production_mesh, parse_mesh_shape)
    if mesh_shape:
        dims, axes = parse_mesh_shape(mesh_shape)
        mesh = make_mesh(dims, axes)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    cell = build_cell(arch, shape_name, mesh, peft=peft, remat=remat,
                      microbatch=microbatch, sharding_plan=sharding_plan)
    t0 = time.time()
    with mesh:
        lowered = lower_cell(cell)
        compiled = lowered.compile()
    dt = time.time() - t0
    result = analyze(cell, lowered, compiled, mesh, dt)
    result["variant"] = variant
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}__{shape_name}__{'multi' if multi_pod else 'single'}"
        if variant != "baseline":
            tag += f"__{variant}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(result, f, indent=1)
        if save_hlo:
            with open(os.path.join(out_dir, tag + ".hlo.txt"), "w") as f:
                f.write(compiled.as_text())
    return result
