"""Mesh construction — the ONE shared path for every launcher (train, serve,
dryrun, tests). Functions (not module-level constants) so importing never
touches jax device state; the dry-run sets XLA_FLAGS for 512 host devices
before any jax import.

Version compat: `axis_types=(AxisType.Auto, …)` keeps GSPMD auto-propagation
explicit on new jax; jax ≤ 0.4.x predates the kwarg (Auto is the only
behavior), so we pass it only when the installed jax supports it.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax


def _axis_type_kwargs(n: int) -> dict:
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    try:
        import inspect
        if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
            return {}
    except (TypeError, ValueError):
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> jax.sharding.Mesh:
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_type_kwargs(len(tuple(axes))))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def parse_mesh_shape(spec: str) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """'4x2' -> ((4, 2), ('data', 'model')); a 3-dim spec adds 'pod'."""
    dims = tuple(int(x) for x in spec.split("x"))
    if not 1 <= len(dims) <= 3:
        raise ValueError(f"mesh spec {spec!r}: want 1-3 'x'-separated dims")
    axes = ("pod", "data", "model")[-len(dims):]
    return dims, axes


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = jax.device_count()
    if n % model:
        raise ValueError(f"model parallelism {model} does not divide "
                         f"device count {n}")
    data = n // model
    return make_mesh((data, model), ("data", "model"))
