"""Production meshes. Function (not module-level constant) so importing never
touches jax device state; the dry-run sets XLA_FLAGS before any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = jax.device_count()
    data = n // model
    return make_mesh((data, model), ("data", "model"))
