import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

Lowers + compiles every (architecture × input shape) cell on the production
meshes — (16, 16) single-pod and (2, 16, 16) multi-pod — with full sharding,
printing memory_analysis() and cost_analysis() and writing per-cell JSON for
the roofline report (DESIGN.md §9; render with repro.launch.roofline).

Usage:
    python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both --out results/dryrun
    (--all runs each cell in a fresh subprocess: isolated, resumable)
"""
import argparse
import json
import subprocess
import sys


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default="results/dryrun")
    ap.add_argument("--variant", type=str, default="baseline")
    ap.add_argument("--remat", type=str, default="full")
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--mesh-shape", type=str, default=None,
                    help="perf-variant mesh remap, e.g. 64x4")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--sharding-plan", type=str, default="rules",
                    help="rules|search|<plan.json>: plan source for every "
                         "tree of the cell (dist/plan.py)")
    ap.add_argument("--force", action="store_true",
                    help="re-run cells that already have a result JSON")
    return ap.parse_args(argv)


def cell_done(out_dir, arch, shape, mesh, variant):
    tag = f"{arch}__{shape}__{mesh}"
    if variant != "baseline":
        tag += f"__{variant}"
    return os.path.exists(os.path.join(out_dir, tag + ".json"))


def main(argv=None):
    args = parse_args(argv)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    if args.all:
        import repro.configs as configs
        from repro.launch import dryrun_lib
        failures = []
        for multi in meshes:
            mname = "multi" if multi else "single"
            for arch in configs.ARCH_IDS:
                cfg = configs.get(arch)
                for shape in configs.SHAPES:
                    if dryrun_lib.long_context_skip(cfg, shape):
                        print(f"SKIP {arch} {shape.name} {mname} "
                              "(full attention; DESIGN.md)")
                        continue
                    if not args.force and cell_done(args.out, arch,
                                                    shape.name, mname,
                                                    args.variant):
                        print(f"done {arch} {shape.name} {mname} (cached)")
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape.name,
                           "--mesh", mname, "--out", args.out,
                           "--variant", args.variant,
                           "--remat", args.remat]
                    if args.microbatch is not None:
                        cmd += ["--microbatch", str(args.microbatch)]
                    if args.sharding_plan != "rules":
                        cmd += ["--sharding-plan", args.sharding_plan]
                    if args.save_hlo:
                        cmd += ["--save-hlo"]
                    print(f"RUN  {arch} {shape.name} {mname} ...", flush=True)
                    r = subprocess.run(cmd)
                    if r.returncode != 0:
                        failures.append((arch, shape.name, mname))
                        print(f"FAIL {arch} {shape.name} {mname}", flush=True)
        if failures:
            print("FAILURES:", failures)
            sys.exit(1)
        print("all cells compiled OK")
        return

    from repro.launch import dryrun_lib
    assert args.arch and args.shape
    for multi in meshes:
        res = dryrun_lib.run_cell(
            args.arch, args.shape, multi, args.out, variant=args.variant,
            remat=args.remat, microbatch=args.microbatch,
            mesh_shape=args.mesh_shape, save_hlo=args.save_hlo,
            sharding_plan=args.sharding_plan)
        print(json.dumps(
            {k: res[k] for k in ("arch", "shape", "mesh", "terms", "dominant",
                                 "roofline_fraction", "useful_flops_ratio")},
            indent=1))
        print("memory_analysis:", json.dumps(res["memory"], indent=1))
        print("cost_analysis: flops/dev=%.3e bytes/dev=%.3e coll/dev=%.3e"
              % (res["flops_per_device"], res["bytes_per_device"],
                 res["collective_bytes_per_device"]))


if __name__ == "__main__":
    main()
