# NOTE: repro.launch.dryrun sets XLA_FLAGS on import (by design, per the
# dry-run contract); import repro.launch.dryrun_lib from library code instead.
from repro.launch import mesh
