"""Roofline report: renders the per-cell dry-run JSONs into the DESIGN.md §9
roofline / dry-run tables.

Usage: python -m repro.launch.roofline --dir results/dryrun_baseline_v0
           [--mesh 16x16] [--variant baseline] [--summary]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

import repro.configs as configs

HINTS = {
    "compute_s": "raise MXU utilization: bigger per-device tiles, remove "
                 "remat recompute, fuse adapter materialization",
    "memory_s": "cut HBM traffic: flash-style attention backward (recompute "
                "p instead of spilling (nq,nk) probability blocks), bf16 "
                "cotangents, larger microbatches",
    "collective_s": "cut ICI traffic: shard kv heads instead of per-block "
                    "all-gathers, overlap collectives with compute, "
                    "reduce-scatter gradient flow",
}


def load(dir_: str) -> List[Dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def render(rows: List[Dict], mesh: str = "16x16",
           variant: str = "baseline") -> str:
    rows = [r for r in rows if r["mesh"] == mesh
            and r.get("variant", "baseline") == variant]
    order = {a: i for i, a in enumerate(configs.ARCH_IDS)}
    shape_order = {s.name: i for i, s in enumerate(configs.SHAPES)}
    rows.sort(key=lambda r: (order.get(r["arch"], 99),
                             shape_order.get(r["shape"], 9)))
    lines = [
        "| arch | shape | compute | memory [min..up] | collective | dominant "
        "| MODEL_FLOPS | useful ratio | roofline frac | HBM fit |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        t = r["terms"]
        dom = r["dominant"].replace("_s", "")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['compute_s'])} "
            f"| {fmt_s(t['memory_s'])}..{fmt_s(t.get('memory_s_upper', t['memory_s']))} "
            f"| {fmt_s(t['collective_s'])} | {dom} "
            f"| {r['model_flops']:.2e} | {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.4f} "
            f"| {'Y' if r['memory']['fits_hbm'] else 'N'} |")
    # skips
    for arch in configs.ARCH_IDS:
        cfg = configs.get(arch)
        if not cfg.subquadratic:
            lines.append(f"| {arch} | long_500k | — | — | — | SKIP "
                         f"(full attention; DESIGN.md §Arch-applicability) "
                         f"| — | — | — | — |")
    return "\n".join(lines)


def render_dryrun(rows: List[Dict], mesh: str) -> str:
    rows = [r for r in rows if r["mesh"] == mesh
            and r.get("variant", "baseline") == "baseline"]
    order = {a: i for i, a in enumerate(configs.ARCH_IDS)}
    shape_order = {s.name: i for i, s in enumerate(configs.SHAPES)}
    rows.sort(key=lambda r: (order.get(r["arch"], 99),
                             shape_order.get(r["shape"], 9)))
    lines = [
        "| arch | shape | bytes/dev (args+temp) | flops/dev | coll bytes/dev "
        "| collectives | compile |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        m = r["memory"]
        coll = "; ".join(f"{k}:{int(v)}" for k, v in
                         sorted(r.get("collective_counts", {}).items()))
        lines.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {(m['argument_bytes']+m['temp_bytes'])/1e9:.2f}GB "
            f"| {r['flops_per_device']:.2e} "
            f"| {r['collective_bytes_per_device']:.2e} | {coll or '—'} "
            f"| {r['compile_seconds']:.0f}s |")
    return "\n".join(lines)


def dominant_summary(rows: List[Dict], mesh: str) -> str:
    rows = [r for r in rows if r["mesh"] == mesh
            and r.get("variant", "baseline") == "baseline"]
    lines = []
    for r in rows:
        lines.append(f"- **{r['arch']} × {r['shape']}**: dominated by "
                     f"{r['dominant'].replace('_s','')} — {HINTS[r['dominant']]}.")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun_baseline_v0")
    ap.add_argument("--mesh", default="16x16",
                    help="mesh tag to filter rows by (see launch/mesh.py "
                         "parse_mesh_shape for the DxM spec format)")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--summary", action="store_true",
                    help="also print the per-cell dominant-term summary")
    args = ap.parse_args()
    rows = load(args.dir)
    print(render(rows, args.mesh, args.variant))
    if args.summary:
        print()
        print(dominant_summary(rows, args.mesh))


if __name__ == "__main__":
    main()
