"""Training launcher: `python -m repro.launch.train --arch <id> [...]`.

Runs the fault-tolerant FourierFT fine-tuning loop on the local device(s).
On a real fleet the same entrypoint runs per host under the cluster launcher
(jax.distributed.initialize is a no-op single-host); the data pipeline is
step-keyed so any host can (re)compute its shard for any step, and
`--resume auto` picks up the newest checkpoint after preemption/restart.

Laptop-scale demo:
    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --reduced \
        --steps 100 --ckpt-dir /tmp/ft --method fourierft --n 128
"""
from __future__ import annotations

import argparse

import jax

import repro.configs as configs
from repro.configs.base import PEFTConfig, ShapeConfig, TrainConfig
from repro.core import adapter as adapter_api
from repro.data import SyntheticLM
from repro.dist import plan as plan_mod
from repro.launch.mesh import make_host_mesh
from repro.models import build
from repro.train import loop, step as train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-runnable)")
    ap.add_argument("--method", default="fourierft",
                    choices=adapter_api.registered_methods())
    ap.add_argument("--n", type=int, default=1000)
    ap.add_argument("--alpha", type=float, default=300.0)
    ap.add_argument("--lora-r", type=int, default=8)
    ap.add_argument("--strategy", default="merged",
                    choices=["merged", "factored"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--remat", default="none", choices=["none", "dots", "full"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--task-seed", type=int, default=7)
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="TP axis size; remaining devices form `data`")
    ap.add_argument("--fsdp", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="--fsdp forces FSDP on, --no-fsdp off; default "
                         "auto per dist.sharding.fsdp_default")
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--sharding-plan", default="rules",
                    help="rules|search|<plan.json>: where placements come "
                         "from (dist/plan.py); search runs the planner once "
                         "at startup")
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = configs.reduced(cfg).replace(vocab=min(cfg.vocab, 512))
    peft = PEFTConfig(method=args.method, n=args.n, alpha=args.alpha,
                      lora_r=args.lora_r, strategy=args.strategy)
    model = build(cfg, peft, remat=args.remat)
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 10, 1),
                       microbatch=args.microbatch, seed=args.seed,
                       grad_compression=args.grad_compression)
    # everything routes through the mesh path: a 1x1 host mesh degenerates to
    # the single-device behavior, larger device counts shard for free
    mesh = make_host_mesh(model=args.model_parallel)
    print(f"arch={cfg.name} method={args.method} "
          f"mesh={'x'.join(map(str, mesh.devices.shape))} "
          f"trainable={model.trainable_params():,}")
    state, frozen = train_step.init_state(model, tcfg,
                                          jax.random.PRNGKey(args.seed))
    fsdp = args.fsdp                       # None = auto
    data = SyntheticLM(vocab=cfg.vocab, batch=args.batch, seq=args.seq,
                       seed=args.seed, task_seed=args.task_seed,
                       codebooks=cfg.n_codebooks)
    plan_src = plan_mod.resolve(
        args.sharding_plan, model=model, mesh=mesh,
        shape=ShapeConfig("runtime", args.seq, args.batch, "train"),
        workload="train")
    if plan_src.kind != "rules":
        print(f"sharding plan: {plan_src.describe()}")
    state, frozen, state_sh, frozen_sh = train_step.shard_train_state(
        model, state, frozen, mesh, fsdp=fsdp, plan=plan_src)
    step_fn, batch_sh = train_step.make_sharded_train_step(
        model, tcfg, mesh, state, frozen, data.batch_at(0),
        shardings=(state_sh, frozen_sh), plan=plan_src)
    state, report = loop.run(
        step_fn, state, frozen, data, tcfg, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every if args.ckpt_dir else 0,
        resume=not args.no_resume, log_every=max(args.steps // 20, 1),
        mesh=mesh, batch_sharding=batch_sh, state_sharding=state_sh)
    print(f"done: steps={report.steps_run} final_loss={report.final_loss:.4f} "
          f"anomalies={report.anomalies} slow_steps={report.slow_steps}"
          + (f" (resumed from {report.resumed_from})"
             if report.resumed_from else ""))


if __name__ == "__main__":
    main()
