"""OpenAI-compatible serving gateway launcher (DESIGN.md §Gateway):
`python -m repro.launch.api --arch <id> [...]`.

Boots the continuous-batching runtime (paged KV cache, optional adapter
bank and speculative decoding — the same flags as `repro.launch.serve
--continuous`) behind the asyncio HTTP gateway: `/v1/chat/completions`
and `/v1/completions` with SSE streaming, per-tenant adapter routing via
the `model` field (`adapter:<id>` names resolve through the bank, loading
non-resident tenants from `--bank-dir` checkpoints at admission),
backpressure 429s past `--max-queue`, and `/metrics` in Prometheus text.

`build_scheduler(args)` is importable: `benchmarks/loadgen.py --verify`
rebuilds the identical engine from the same CLI flags and replays the
collected traffic in-process to assert the gateway's streams were
bit-identical, and `bench_serve_gateway` boots in-process cells with it.

Laptop-scale demo:
    PYTHONPATH=src python -m repro.launch.api --arch yi-6b --reduced \
        --port 8080
    curl -N localhost:8080/v1/chat/completions -d '{"model": "base", \
        "messages": [{"role": "user", "content": "hi"}], "stream": true}'
"""
from __future__ import annotations

import argparse
import asyncio
import signal

import jax

import repro.configs as configs
from repro.configs.base import PEFTConfig
from repro.launch.mesh import make_host_mesh
from repro.models import build


def add_model_args(ap: argparse.ArgumentParser) -> None:
    """Engine/scheduler flags, shared verbatim with `loadgen --verify` so
    the replay check rebuilds exactly the served model."""
    ap.add_argument("--arch", default="yi-6b", choices=list(configs.ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slots (continuous batch width)")
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--dense-cache", action="store_true",
                    help="dense per-slot KV cache instead of paged")
    ap.add_argument("--bank-dir", default=None,
                    help="adapter-only export dir: serve a multi-tenant "
                         "bank routed by model name (adapter:<id>)")
    ap.add_argument("--bank-capacity", type=int, default=8)
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop token (finish_reason 'stop'); default none")
    ap.add_argument("--speculative", action="store_true")
    ap.add_argument("--drafter", default="self", choices=("self", "ngram"))
    ap.add_argument("--draft-k", type=int, default=4)
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="TP axis size; remaining devices replicate/batch")
    # tiered-memory serving (DESIGN.md §Tiering)
    ap.add_argument("--n-pages", type=int, default=None,
                    help="constrain the device page pool (default: enough "
                         "for every slot at max_len)")
    ap.add_argument("--preempt", action="store_true",
                    help="evict lower-class slots under pressure instead "
                         "of deferring higher-class admissions")
    ap.add_argument("--preempt-mode", default="auto",
                    choices=("auto", "swap", "recompute"),
                    help="victim KV disposition (auto = cost estimate)")
    ap.add_argument("--host-kv-pages", type=int, default=0,
                    help="host-RAM KV tier capacity in pages (0 disables): "
                         "swap-preempt snapshots and demoted prefix pages")
    ap.add_argument("--host-adapter-slots", type=int, default=0,
                    help="host-RAM adapter tier rows (0 disables): bank "
                         "evictions spill here; admission refills without "
                         "re-reading the checkpoint")
    ap.add_argument("--sharding-plan", default="rules",
                    help="rules|search|<plan.json>: where placements come "
                         "from (dist/plan.py); search runs the planner once "
                         "at startup")


def _model_cfg(args):
    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = configs.reduced(cfg).replace(vocab=min(cfg.vocab, 512))
    return cfg


def export_demo_bank(args, directory: str) -> None:
    """Write two synthetic tenants (`t0` fourierft, `t1` lora) compatible
    with the model the flags build — gives the CI gateway smoke and laptop
    demos something to route at (`--models base,adapter:t0,adapter:t1`)
    without a training run."""
    import jax.numpy as jnp

    from repro.checkpoint import adapters as adapter_ckpt
    from repro.core import adapter as adapter_api
    from repro.core import peft as peft_mod

    model = build(_model_cfg(args), PEFTConfig(method="none"))
    profiles = {
        "fourierft": PEFTConfig(method="fourierft", n=16, alpha=25.0,
                                param_dtype="float32"),
        "lora": PEFTConfig(method="lora", lora_r=2, param_dtype="float32"),
    }
    for i, (tid, m) in enumerate(zip(("t0", "t1"), ("fourierft", "lora"))):
        prof = profiles[m]
        tree = peft_mod.init_adapters(
            jax.random.PRNGKey(args.seed + 10 + i), model.sites, prof)
        tree = jax.tree.map(
            lambda x: x + 0.05 if jnp.issubdtype(x.dtype, jnp.floating)
            else x, tree)
        trainable = set(adapter_api.resolve(m).trainable_leaves(prof))
        tree = {s: {k: v for k, v in d.items() if k in trainable}
                for s, d in tree.items()}
        adapter_ckpt.export_adapter(directory, tid, tree, prof)
    print(f"exported demo tenants "
          f"{adapter_ckpt.list_adapters(directory)} -> {directory}")


def build_scheduler(args):
    """(ContinuousScheduler, resident tenant ids) from parsed model args —
    deterministic in the flags: two builds from equal flags serve
    bit-identical streams (the gateway CI check leans on this)."""
    from repro.checkpoint import adapters as adapter_ckpt
    from repro.serve import (
        AdapterBank, ContinuousScheduler, Engine, NGramDrafter, SelfDrafter,
        TieringConfig,
    )

    cfg = _model_cfg(args)
    model = build(cfg, PEFTConfig(method="none"))
    params = model.init(jax.random.PRNGKey(args.seed))
    mesh = make_host_mesh(model=args.model_parallel)

    bank, tenant_ids = None, []
    if args.bank_dir:
        tenant_ids = list(adapter_ckpt.list_adapters(args.bank_dir))
        if not tenant_ids:
            raise SystemExit(f"no adapter exports under {args.bank_dir}")
        profiles = {}
        for tid in tenant_ids:
            tp = adapter_ckpt.read_manifest(args.bank_dir, tid)
            profiles.setdefault(tp.method, tp)
        bank = AdapterBank(model, profiles, capacity=args.bank_capacity,
                           checkpoint_dir=args.bank_dir)
        for tid in tenant_ids:                 # warm the bank up front;
            if len(bank.resident_ids) >= args.bank_capacity:
                break                          # the rest load at admission
            try:
                bank.load_from_checkpoint(tid)
            except (ValueError, KeyError) as e:
                print(f"skipping tenant {tid!r}: {e}")

    engine = Engine(model, params, batch_slots=args.slots,
                    max_len=args.max_len, mesh=mesh, bank=bank,
                    plan=args.sharding_plan)
    drafter = None
    if args.speculative:
        drafter = (SelfDrafter(k=args.draft_k) if args.drafter == "self"
                   else NGramDrafter(k=args.draft_k))
    tiering = None
    if args.preempt or args.host_kv_pages or args.host_adapter_slots:
        tiering = TieringConfig(host_kv_pages=args.host_kv_pages,
                                host_adapter_slots=args.host_adapter_slots,
                                preempt=args.preempt,
                                mode=args.preempt_mode)
    sched = ContinuousScheduler(engine, eos_id=args.eos_id,
                                paged=not args.dense_cache,
                                page_size=args.page_size,
                                n_pages=args.n_pages, drafter=drafter,
                                tiering=tiering)
    return sched, tenant_ids


async def _run(args) -> None:
    from repro.serve.gateway import GatewayServer

    sched, tenant_ids = build_scheduler(args)
    server = GatewayServer(
        sched, eos_id=args.eos_id, max_queue=args.max_queue,
        min_free_page_frac=args.min_free_page_frac,
        retry_after_s=args.retry_after,
        request_timeout_s=args.timeout,
        default_max_new=args.default_max_new)
    await server.start(args.host, args.port)
    print(f"gateway listening on {server.url} "
          f"({len(tenant_ids)} tenants, {sched.n_slots} slots, "
          f"max_len {sched.max_len})", flush=True)
    stop = asyncio.Event()
    loop = asyncio.get_running_loop()
    for sig in (signal.SIGINT, signal.SIGTERM):
        try:
            loop.add_signal_handler(sig, stop.set)
        except NotImplementedError:            # non-unix event loops
            pass
    await stop.wait()
    print("gateway shutting down", flush=True)
    await server.close()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    add_model_args(ap)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080,
                    help="0 picks an ephemeral port (printed at startup)")
    ap.add_argument("--max-queue", type=int, default=32,
                    help="queued-request watermark: at/above it new "
                         "requests get 429 + Retry-After")
    ap.add_argument("--min-free-page-frac", type=float, default=0.0,
                    help="page-pool watermark: with a non-empty queue and "
                         "less than this fraction free, 429 (0 disables)")
    ap.add_argument("--retry-after", type=float, default=1.0,
                    help="Retry-After seconds advertised on 429")
    ap.add_argument("--timeout", type=float, default=None,
                    help="per-request deadline in seconds (cancels the "
                         "request mid-stream on overrun)")
    ap.add_argument("--default-max-new", type=int, default=16)
    ap.add_argument("--export-demo-bank", metavar="DIR", default=None,
                    help="write two synthetic tenants for the model flags "
                         "into DIR and exit (no server)")
    args = ap.parse_args(argv)
    if args.export_demo_bank:
        export_demo_bank(args, args.export_demo_bank)
        return
    asyncio.run(_run(args))


if __name__ == "__main__":
    main()
