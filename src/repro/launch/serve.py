"""Serving launcher: `python -m repro.launch.serve --arch <id> [...]`.

Loads base weights (+ optional adapter checkpoint for ANY registered
`AdapterMethod`), merges every mergeable ΔW into the base (zero-latency
serving, paper §3.1), and decodes a batch of demo prompts through the slot
engine. With `--bank-dir`, instead serves a multi-tenant adapter bank: every
adapter-only export in the directory (checkpoint/adapters.py) is loaded
resident and the demo prompts round-robin over the tenants in one
heterogeneous batch.

With `--continuous`, replays a staggered-arrival, mixed-`max_new` traffic
trace through the continuous-batching scheduler (DESIGN.md §Scheduler)
instead of one lockstep batch: requests are admitted into slots as they
arrive (in-flight prefill over the live decode batch), every slot stops at
its own budget and is recycled immediately, and the run prints per-request
outputs plus serving metrics (TTFT, mean batch occupancy, tokens/s).
`--trace-n` sets the number of replayed requests and `--arrival-every`
their spacing on the decode-step clock; combine with `--bank-dir` to
replay multi-tenant traffic with LRU residency handled at admission, and
with `--speculative [--drafter self|ngram] [--draft-k K]` to decode
draft-then-verify (DESIGN.md §Speculation) and print acceptance metrics.

Laptop-scale demo:
    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
        --adapters /tmp/ft   # dir written by repro.launch.train
    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
        --bank-dir /tmp/tenants --bank-capacity 8
    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
        --continuous --trace-n 12 --arrival-every 2
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.checkpoint import adapters as adapter_ckpt
from repro.checkpoint import manager as ckpt
from repro.configs.base import PEFTConfig
from repro.core import adapter as adapter_api
from repro.launch.mesh import make_host_mesh
from repro.models import build
from repro.serve import AdapterBank, Engine
from repro.train.step import join_params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--method", default="fourierft",
                    choices=adapter_api.registered_methods())
    ap.add_argument("--n", type=int, default=1000)
    ap.add_argument("--alpha", type=float, default=300.0)
    ap.add_argument("--adapters", default=None,
                    help="checkpoint dir from repro.launch.train")
    ap.add_argument("--bank-dir", default=None,
                    help="adapter-only export dir: serve a multi-tenant bank")
    ap.add_argument("--bank-capacity", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--continuous", action="store_true",
                    help="replay a staggered-arrival trace through the "
                         "continuous-batching scheduler (slot recycling + "
                         "in-flight prefill) and print serving metrics")
    ap.add_argument("--trace-n", type=int, default=12,
                    help="--continuous: number of replayed requests")
    ap.add_argument("--arrival-every", type=float, default=2.0,
                    help="--continuous: arrival gap in decode steps")
    ap.add_argument("--dense-cache", action="store_true",
                    help="--continuous: dense per-slot KV cache instead of "
                         "the default paged cache (DESIGN.md §Paging)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="--continuous: paged-cache page size (tokens)")
    ap.add_argument("--speculative", action="store_true",
                    help="--continuous: draft-then-verify speculative "
                         "decoding (DESIGN.md §Speculation); greedy outputs "
                         "stay token-identical to the plain loop")
    ap.add_argument("--drafter", default="self", choices=("self", "ngram"),
                    help="--speculative: base-row self-drafter (reuses the "
                         "bank's zero row) or host-side n-gram prompt lookup")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="--speculative: draft tokens per slot per step")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="--continuous: constrain the device page pool")
    ap.add_argument("--preempt", action="store_true",
                    help="--continuous: tiered scheduling — evict "
                         "lower-class slots under pressure (DESIGN.md "
                         "§Tiering)")
    ap.add_argument("--host-kv-pages", type=int, default=0,
                    help="--continuous: host-RAM KV tier pages (0 off)")
    ap.add_argument("--analyze", action="store_true",
                    help="--continuous: after the replay, audit the live "
                         "scheduler's jit signature counts against its "
                         "declared compile bounds (repro.analysis recompile "
                         "pass) and exit non-zero on any finding")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="TP axis size; remaining devices replicate/batch")
    ap.add_argument("--sharding-plan", default="rules",
                    help="rules|search|<plan.json>: where placements come "
                         "from (dist/plan.py); search runs the planner once "
                         "at startup")
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = configs.reduced(cfg).replace(vocab=min(cfg.vocab, 512))
    # bank-only serving runs over the clean base: random-init adapters of a
    # live method would otherwise be merged into it before the bank attaches
    if args.bank_dir and not args.adapters:
        peft = PEFTConfig(method="none")
    else:
        peft = PEFTConfig(method=args.method, n=args.n, alpha=args.alpha)
    model = build(cfg, peft)
    params = model.init(jax.random.PRNGKey(args.seed))
    if args.adapters:
        state, at = ckpt.restore(args.adapters)
        trainable = state["trainable"]
        _, frozen = __import__("repro.train.step", fromlist=["split_params"]) \
            .split_params(model, params)
        params = join_params(model, trainable, frozen)
        print(f"loaded adapters from step {at}")
    mesh = make_host_mesh(model=args.model_parallel)

    bank = None
    tenant_ids = []
    if args.bank_dir:
        tenant_ids = list(adapter_ckpt.list_adapters(args.bank_dir))
        if not tenant_ids:
            raise SystemExit(f"no adapter exports under {args.bank_dir}")
        profiles = {}
        for tid in tenant_ids:
            tp = adapter_ckpt.read_manifest(args.bank_dir, tid)
            profiles.setdefault(tp.method, tp)
        bank = AdapterBank(model, profiles, capacity=args.bank_capacity,
                           checkpoint_dir=args.bank_dir)
        for tid in tenant_ids:
            if len(bank.resident_ids) >= args.bank_capacity:
                break
            try:
                bank.load_from_checkpoint(tid)
            except (ValueError, KeyError) as e:
                # e.g. same method exported under a different n/seed than the
                # group profile — serve the compatible tenants, don't die
                print(f"skipping tenant {tid!r}: {e}")
        if not bank.resident_ids:
            raise SystemExit("no loadable tenants for the bank profiles")
        tenant_ids = list(bank.resident_ids)   # demo serves residents only
        print(f"bank: {len(tenant_ids)} resident tenants over "
              f"groups {sorted(bank.profiles)}")

    slots = max(2, len(tenant_ids)) if bank else 2
    engine = Engine(model, params, batch_slots=slots, max_len=args.max_len,
                    mesh=mesh, bank=bank, plan=args.sharding_plan)
    prompts = [(jnp.arange(4 + i, dtype=jnp.int32) + 3 * i) % cfg.vocab
               for i in range(slots)]
    if cfg.n_codebooks:
        prompts = [jnp.tile(p[:, None], (1, cfg.n_codebooks)) for p in prompts]
    if args.continuous:
        from repro.serve import (
            ContinuousScheduler, NGramDrafter, SelfDrafter, TieringConfig,
        )
        from repro.serve.engine import Request
        drafter = None
        if args.speculative:
            drafter = (SelfDrafter(k=args.draft_k) if args.drafter == "self"
                       else NGramDrafter(k=args.draft_k))
        tiering = None
        if args.preempt or args.host_kv_pages:
            tiering = TieringConfig(host_kv_pages=args.host_kv_pages,
                                    preempt=args.preempt)
        sched = ContinuousScheduler(engine, paged=not args.dense_cache,
                                    page_size=args.page_size,
                                    n_pages=args.n_pages,
                                    drafter=drafter, tiering=tiering)
        n = args.trace_n
        reqs = [Request(prompt=prompts[i % len(prompts)],
                        max_new=1 + (5 * i + 3) % args.max_new,
                        adapter_id=(tenant_ids[i % len(tenant_ids)]
                                    if tenant_ids else None))
                for i in range(n)]
        arrivals = [i * args.arrival_every for i in range(n)]
        sched.serve(reqs, arrivals)
        for i, r in enumerate(reqs):
            tag = f" [{r.adapter_id}]" if r.adapter_id else ""
            print(f"request {i}{tag} (arrival {arrivals[i]:g}, "
                  f"max_new {r.max_new}): {r.out}")
        s = sched.metrics.summary()
        print(f"continuous: {s['n_requests']:.0f} requests, "
              f"{s['total_tokens']:.0f} tokens in {s['steps']:.0f} steps | "
              f"occupancy {s['occupancy_mean']:.2f}, "
              f"ttft {s['ttft_steps_mean']:.1f} steps (p90 "
              f"{s['ttft_steps_p90']:.1f}), "
              f"{s['tokens_per_s']:.0f} tok/s")
        if "spec_accept_rate" in s:
            print(f"speculative ({args.drafter}, k={args.draft_k}): "
                  f"{s['spec_tokens_per_step']:.2f} tokens/step/slot, "
                  f"accept rate {s['spec_accept_rate']:.2f}, "
                  f"{s['spec_drafts_wasted']:.0f} drafts wasted over "
                  f"{s['spec_slot_steps']:.0f} slot-steps")
        if args.analyze:
            from repro.analysis import hlo_lint
            found = hlo_lint.scheduler_recompile_findings(sched)
            sigs = sched.compiled_signatures()
            print("analyze: compiled signatures "
                  + ", ".join(f"{k}={v}" for k, v in sorted(sigs.items())))
            for f in found:
                print(f.render())
            if found:
                raise SystemExit(1)
            print("analyze: recompile audit clean")
        return

    ids = [tenant_ids[i % len(tenant_ids)] if tenant_ids else None
           for i in range(slots)] if bank else None
    outs = engine.generate(prompts, max_new=args.max_new, adapter_ids=ids)
    for i, o in enumerate(outs):
        tag = f" [{ids[i]}]" if ids else ""
        print(f"prompt {i}{tag}: {o.tolist()}")


if __name__ == "__main__":
    main()
