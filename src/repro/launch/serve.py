"""Serving launcher: `python -m repro.launch.serve --arch <id> [...]`.

Loads base weights (+ optional FourierFT adapter checkpoint), merges ΔW into
the base (zero-latency serving, paper §3.1), and decodes a batch of demo
prompts through the slot engine.

Laptop-scale demo:
    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
        --adapters /tmp/ft   # dir written by repro.launch.train
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.checkpoint import manager as ckpt
from repro.configs.base import PEFTConfig
from repro.launch.mesh import make_host_mesh
from repro.models import build
from repro.serve import Engine
from repro.train.step import join_params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--method", default="fourierft")
    ap.add_argument("--n", type=int, default=1000)
    ap.add_argument("--alpha", type=float, default=300.0)
    ap.add_argument("--adapters", default=None,
                    help="checkpoint dir from repro.launch.train")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="TP axis size; remaining devices replicate/batch")
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = configs.reduced(cfg).replace(vocab=min(cfg.vocab, 512))
    peft = PEFTConfig(method=args.method, n=args.n, alpha=args.alpha)
    model = build(cfg, peft)
    params = model.init(jax.random.PRNGKey(args.seed))
    if args.adapters:
        state, at = ckpt.restore(args.adapters)
        trainable = state["trainable"]
        _, frozen = __import__("repro.train.step", fromlist=["split_params"]) \
            .split_params(model, params)
        params = join_params(model, trainable, frozen)
        print(f"loaded adapters from step {at}")
    mesh = make_host_mesh(model=args.model_parallel)
    engine = Engine(model, params, batch_slots=2, max_len=args.max_len,
                    mesh=mesh)
    prompts = [jnp.arange(6, dtype=jnp.int32) % cfg.vocab,
               (jnp.arange(4, dtype=jnp.int32) + 3) % cfg.vocab]
    if cfg.n_codebooks:
        prompts = [jnp.tile(p[:, None], (1, cfg.n_codebooks)) for p in prompts]
    outs = engine.generate(prompts, max_new=args.max_new)
    for i, o in enumerate(outs):
        print(f"prompt {i}: {o.tolist()}")


if __name__ == "__main__":
    main()
