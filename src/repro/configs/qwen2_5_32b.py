"""qwen2.5-32b [dense] — GQA with QKV bias (hf:Qwen/Qwen2.5 family).

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv=8,
    head_dim=128,
    d_ff=27648,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1000000.0,
)
