"""Config dataclasses for models, PEFT, shapes, and meshes.

Every assigned architecture gets one module in this package defining `CONFIG`.
`repro.configs.get(arch_id)` is the registry entry point.
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    state: int
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    d_conv: int = 4
    chunk: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class ZambaConfig:
    """Hybrid wiring: a shared attention+MLP block applied every `shared_every`
    mamba blocks (weights shared; per-application LoRA like the real Zamba2)."""
    shared_every: int = 6
    shared_lora_r: int = 0  # 0 = no per-application LoRA on the shared block


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    vocab: int
    # attention (ignored for pure-ssm)
    n_heads: int = 0
    n_kv: int = 0
    head_dim: int = 0
    d_ff: int = 0
    qk_norm: bool = False
    qkv_bias: bool = False
    mrope: bool = False          # multimodal 3-D RoPE (qwen2-vl)
    rope_theta: float = 10000.0
    gated_mlp: bool = True       # SwiGLU vs GELU MLP
    # extras
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    zamba: Optional[ZambaConfig] = None
    n_codebooks: int = 0         # musicgen: parallel codebook embeddings/heads
    embed_inputs: bool = True    # False for VLM stub (input = patch embeddings)
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # numerics
    dtype: str = "bfloat16"      # activation dtype
    param_dtype: str = "bfloat16"
    # long-context capability flag (drives long_500k skip logic)
    subquadratic: bool = False

    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv * self.head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class PEFTConfig:
    method: str = "fourierft"     # fourierft | lora | bitfit | none | full
    # --- FourierFT ---
    n: int = 1000
    alpha: float = 300.0
    entry_seed: int = 2024        # paper: value 2024 shared across layers
    freq_bias: bool = False       # Eq. 5 Gaussian band-pass sampling
    fc: float = 0.0               # favored central frequency
    bandwidth: float = 200.0
    basis: str = "fourier"        # fourier | random | orthogonal (Table 6)
    strategy: str = "merged"      # merged | factored (see DESIGN §2)
    # kernel-backend policy (DESIGN §Kernels): auto = compiled Pallas where a
    # registered op supports the site, einsum elsewhere; interpret is the
    # debug backend; einsum forces the reference path.
    kernel_backend: str = "auto"  # auto | pallas | interpret | einsum
    use_pallas: Optional[str] = None  # DEPRECATED -> kernel_backend (shim)
    # --- LoRA baseline ---
    lora_r: int = 8
    lora_alpha: float = 16.0
    # --- common ---
    target_modules: Tuple[str, ...] = ("wq", "wv")
    train_head: bool = False
    param_dtype: str = "float32"  # adapters train in f32

    def __post_init__(self):
        if self.use_pallas is not None:
            mapped = _USE_PALLAS_TO_BACKEND.get(self.use_pallas)
            if mapped is None:
                raise ValueError(
                    f"legacy use_pallas={self.use_pallas!r}; one of "
                    f"{sorted(_USE_PALLAS_TO_BACKEND)} (or use kernel_backend)")
            warnings.warn(
                "PEFTConfig.use_pallas is deprecated; it selected nothing "
                "since the kernel registry landed — use kernel_backend="
                f"{mapped!r} (DESIGN.md §Kernels)", DeprecationWarning,
                stacklevel=3)
            object.__setattr__(self, "kernel_backend", mapped)
            object.__setattr__(self, "use_pallas", None)
        if self.kernel_backend not in ("auto", "pallas", "interpret",
                                       "einsum"):
            raise ValueError(
                f"unknown kernel_backend {self.kernel_backend!r}; one of "
                "('auto', 'pallas', 'interpret', 'einsum')")

    def replace(self, **kw) -> "PEFTConfig":
        return dataclasses.replace(self, **kw)


# legacy tri-state -> registry backend policy
_USE_PALLAS_TO_BACKEND = {"auto": "auto", "never": "einsum",
                          "interpret": "interpret"}


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


# The assigned LM shape set (identical across the 10 archs).
SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-3
    head_learning_rate: float = 1e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: str = "linear"      # linear | cosine | constant
    weight_decay: float = 0.0
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    grad_clip: float = 1.0
    microbatch: int = 0           # 0 = no accumulation
    remat: str = "full"           # full | dots | none
    anomaly_threshold: float = 1e4
    seed: int = 0
    # gradient all-reduce compression: none | int8_ef (dist/compression.py)
    grad_compression: str = "none"

    def replace(self, **kw) -> "TrainConfig":
        return dataclasses.replace(self, **kw)


def shape_for(name: str) -> ShapeConfig:
    return SHAPES_BY_NAME[name]
