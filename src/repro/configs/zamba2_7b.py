"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks (arXiv:2411.15242).

81L d_model=3584 32H (MHA kv=32) d_ff=14336 vocab=32000, ssm_state=64.
A single shared transformer block (attention + MLP) is applied every 6 mamba
blocks (weights shared across applications, each application with its own KV
cache; the real model adds per-application LoRA on the shared weights — we
support that via zamba.shared_lora_r). Sub-quadratic: runs long_500k.
"""
from repro.configs.base import ModelConfig, SSMConfig, ZambaConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv=32,
    head_dim=112,
    d_ff=14336,
    vocab=32000,
    ssm=SSMConfig(state=64, head_dim=64, expand=2, n_groups=1),
    zamba=ZambaConfig(shared_every=6, shared_lora_r=0),
    subquadratic=True,
)
