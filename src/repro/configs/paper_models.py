"""Model configs used by the FourierFT paper itself (for Table 1 accounting and
the paper-faithful benchmarks). Only the dimensions relevant to adapter parameter
accounting need to be exact; see benchmarks/bench_table1_params.py.

Paper Table 1 tunes only the query and value projections (L_t = 2 * num_layers
adapted matrices), and for RoBERTa/ViT additionally a fully-trained
classification head that is excluded from the reported counts.
"""
from repro.configs.base import ModelConfig

# d1 = d2 = d_model for the q/v projections of all these models.
ROBERTA_BASE = ModelConfig(
    name="roberta-base", family="dense", num_layers=12, d_model=768,
    n_heads=12, n_kv=12, head_dim=64, d_ff=3072, vocab=50265,
    gated_mlp=False, rope_theta=0.0,
)
ROBERTA_LARGE = ROBERTA_BASE.replace(
    name="roberta-large", num_layers=24, d_model=1024, n_heads=16, n_kv=16,
    d_ff=4096,
)
GPT2_MEDIUM = ModelConfig(
    name="gpt2-medium", family="dense", num_layers=24, d_model=1024,
    n_heads=16, n_kv=16, head_dim=64, d_ff=4096, vocab=50257,
    gated_mlp=False, rope_theta=0.0,
)
GPT2_LARGE = GPT2_MEDIUM.replace(
    name="gpt2-large", num_layers=36, d_model=1280, n_heads=20, n_kv=20,
    d_ff=5120,
)
LLAMA2_7B = ModelConfig(
    name="llama2-7b", family="dense", num_layers=32, d_model=4096,
    n_heads=32, n_kv=32, head_dim=128, d_ff=11008, vocab=32000,
)
LLAMA2_13B = LLAMA2_7B.replace(
    name="llama2-13b", num_layers=40, d_model=5120, n_heads=40, n_kv=40,
    d_ff=13824,
)
VIT_BASE = ModelConfig(
    name="vit-base", family="dense", num_layers=12, d_model=768,
    n_heads=12, n_kv=12, head_dim=64, d_ff=3072, vocab=1000,
    gated_mlp=False, rope_theta=0.0,
)
VIT_LARGE = VIT_BASE.replace(
    name="vit-large", num_layers=24, d_model=1024, n_heads=16, n_kv=16,
    d_ff=4096,
)

PAPER_MODELS = {
    m.name: m
    for m in (ROBERTA_BASE, ROBERTA_LARGE, GPT2_MEDIUM, GPT2_LARGE,
              LLAMA2_7B, LLAMA2_13B, VIT_BASE, VIT_LARGE)
}
