"""mamba2-2.7b [ssm] — SSD, state-space duality (arXiv:2405.21060).

64L d_model=2560 (attention-free) vocab=50280, ssm_state=128, expand=2,
head_dim=64 (=> 80 heads). Sub-quadratic: runs long_500k.
FourierFT targets in_proj/out_proj (attention-free; see DESIGN §Arch-applicability).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(state=128, head_dim=64, expand=2, n_groups=1),
    subquadratic=True,
)
