"""Architecture config registry: `get(arch_id)`, `reduced(cfg)` for smoke tests."""
from __future__ import annotations

import dataclasses

from repro.configs.base import (
    ModelConfig, MoEConfig, PEFTConfig, SSMConfig, ShapeConfig, TrainConfig,
    ZambaConfig, SHAPES, SHAPES_BY_NAME, shape_for,
)

from repro.configs import (
    musicgen_medium, yi_9b, qwen3_4b, yi_6b, qwen2_5_32b, qwen2_vl_72b,
    zamba2_7b, olmoe_1b_7b, phi3_5_moe, mamba2_2_7b,
)
from repro.configs.paper_models import PAPER_MODELS

ARCHS = {
    "musicgen-medium": musicgen_medium.CONFIG,
    "yi-9b": yi_9b.CONFIG,
    "qwen3-4b": qwen3_4b.CONFIG,
    "yi-6b": yi_6b.CONFIG,
    "qwen2.5-32b": qwen2_5_32b.CONFIG,
    "qwen2-vl-72b": qwen2_vl_72b.CONFIG,
    "zamba2-7b": zamba2_7b.CONFIG,
    "olmoe-1b-7b": olmoe_1b_7b.CONFIG,
    "phi3.5-moe-42b-a6.6b": phi3_5_moe.CONFIG,
    "mamba2-2.7b": mamba2_2_7b.CONFIG,
}

ARCH_IDS = tuple(ARCHS)


def get(arch: str) -> ModelConfig:
    if arch in ARCHS:
        return ARCHS[arch]
    if arch in PAPER_MODELS:
        return PAPER_MODELS[arch]
    raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS) + sorted(PAPER_MODELS)}")


def reduced(cfg: ModelConfig, *, layers: int = 2, width: int = 64,
            vocab: int = 256) -> ModelConfig:
    """Shrink a config to smoke-test size, preserving its structural family
    (GQA ratio, MoE top-k, SSM shape, hybrid wiring, codebooks, qk-norm, ...)."""
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=layers,
        d_model=width,
        vocab=vocab,
    )
    if cfg.n_heads:
        n_heads = max(4, min(cfg.n_heads, 4))
        # preserve GQA grouping: keep kv ratio if grouped, else MHA
        ratio = max(1, cfg.n_heads // max(cfg.n_kv, 1))
        n_kv = max(1, n_heads // min(ratio, n_heads))
        kw.update(n_heads=n_heads, n_kv=n_kv, head_dim=max(8, width // n_heads))
    if cfg.d_ff:
        kw.update(d_ff=width * 2)
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(
            num_experts=min(8, cfg.moe.num_experts),
            top_k=min(2, cfg.moe.top_k),
            d_ff_expert=width,
            capacity_factor=cfg.moe.capacity_factor,
        )
        kw["d_ff"] = width
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, state=16, head_dim=16, chunk=16)
    if cfg.zamba is not None:
        kw["zamba"] = dataclasses.replace(cfg.zamba, shared_every=2)
        kw["num_layers"] = max(layers, 4)
    return cfg.replace(**kw)


__all__ = [
    "ModelConfig", "MoEConfig", "PEFTConfig", "SSMConfig", "ShapeConfig",
    "TrainConfig", "ZambaConfig", "SHAPES", "SHAPES_BY_NAME", "shape_for",
    "ARCHS", "ARCH_IDS", "PAPER_MODELS", "get", "reduced",
]
