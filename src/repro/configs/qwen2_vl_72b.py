"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution (arXiv:2409.12191).

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064. Backbone only: the
vision frontend is a STUB — input_specs() provides precomputed patch/text
embeddings (B, S, d_model) plus 3-D M-RoPE position ids (3, B, S).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv=8,
    head_dim=128,
    d_ff=29568,
    vocab=152064,
    qkv_bias=True,
    mrope=True,
    embed_inputs=False,
    rope_theta=1000000.0,
)
