"""olmoe-1b-7b [moe] — 64 experts top-8 (arXiv:2409.02060).

16L d_model=2048 16H (MHA kv=16) d_ff=1024/expert vocab=50304. OLMoE uses
qk-norm and gated SwiGLU experts.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    head_dim=128,
    d_ff=1024,
    vocab=50304,
    qk_norm=True,
    moe=MoEConfig(num_experts=64, top_k=8, d_ff_expert=1024),
)
