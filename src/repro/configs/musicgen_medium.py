"""musicgen-medium [audio] — decoder-only over EnCodec tokens (arXiv:2306.05284).

48L d_model=1536 24H (MHA, kv=24) d_ff=6144 vocab=2048, 4 codebooks.
MusicGen uses standard (non-gated) GELU FFN and full MHA. The EnCodec audio
frontend is a STUB per the assignment: inputs are the 4 codebook token ids per
frame; embeddings are summed, and 4 parallel LM heads predict each codebook.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv=24,
    head_dim=64,
    d_ff=6144,
    vocab=2048,
    n_codebooks=4,
    gated_mlp=False,
    rope_theta=10000.0,
)
