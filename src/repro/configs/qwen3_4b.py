"""qwen3-4b [dense] — qk_norm, GQA (hf:Qwen/Qwen3-4B family).

36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936. Qwen3 uses head_dim=128
(attention dim 4096 > d_model) and per-head RMS q/k-norm, no QKV bias.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    num_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv=8,
    head_dim=128,
    d_ff=9728,
    vocab=151936,
    qk_norm=True,
    rope_theta=1000000.0,
)
