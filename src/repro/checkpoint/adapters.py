"""Portable adapter-only checkpoints (the paper's storage claim, on disk).

One tenant = one `<dir>/<adapter_id>/` holding exactly the trainable leaves
(`adapter.npz`) plus a JSON manifest carrying the PEFTConfig. Frozen state
(FourierFT/DCT spectral entries, ablation bases) is NOT stored — it is keyed
by method + entry seed and regenerates deterministically at import via the
method's `init_site`, so a FourierFT tenant really is n·(2+L) numbers on the
wire (paper §3.2). The serving AdapterBank's LRU reload path goes through
`import_adapter`.

Export is atomic (tmp + os.replace), mirroring checkpoint/manager.py.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import tempfile
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.configs.base import PEFTConfig
from repro.core import adapter as adapter_api
from repro.core.adapter import AdapterSite

_MANIFEST = "manifest.json"
_LEAVES = "adapter.npz"
_SEP = "::"          # site names contain "/", npz keys are "<site>::<leaf>"

# ids become directory names: one path component, no traversal, and no
# ".tmp-" (reserved for in-flight exports, filtered by list_adapters)
_ID_RE = re.compile(r"^[A-Za-z0-9][A-Za-z0-9._-]*$")


def _check_id(adapter_id: str) -> str:
    if not _ID_RE.match(adapter_id) or ".tmp-" in adapter_id:
        raise ValueError(
            f"bad adapter_id {adapter_id!r}: must match {_ID_RE.pattern} "
            "and not contain '.tmp-'")
    return adapter_id


def export_adapter(directory: str, adapter_id: str, adapters: Dict,
                   peft: PEFTConfig) -> str:
    """Write `<directory>/<adapter_id>/` from a {site: {leaf: array}} tree.
    Only the method's trainable leaves are stored; frozen aux present in the
    tree is dropped (regenerable from the manifest's method + entry seed)."""
    _check_id(adapter_id)
    method = adapter_api.resolve(peft.method)
    trainable = set(method.trainable_leaves(peft))
    arrays = {}
    for site, tree in adapters.items():
        for leaf, v in tree.items():
            if leaf in trainable:
                arrays[f"{site}{_SEP}{leaf}"] = np.asarray(jax.device_get(v))
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, adapter_id)
    tmp = tempfile.mkdtemp(prefix=f"{adapter_id}.tmp-", dir=directory)
    try:
        np.savez(os.path.join(tmp, _LEAVES), **arrays)
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump({"adapter_id": adapter_id, "format": 1,
                       "peft": dataclasses.asdict(peft)}, f)
        if os.path.exists(final):
            import shutil
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        import shutil
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def _peft_from_manifest(d: Dict) -> PEFTConfig:
    d = dict(d)
    d["target_modules"] = tuple(d.get("target_modules", ("wq", "wv")))
    # manifests written before the kernel registry carry the legacy
    # `use_pallas` tri-state: migrate it onto kernel_backend silently here
    # (the PEFTConfig constructor shim warns — appropriate for live code,
    # noise for every import of an old export)
    legacy = d.pop("use_pallas", None)
    if legacy is not None and "kernel_backend" not in d:
        mapped = {"auto": "auto", "never": "einsum",
                  "interpret": "interpret"}.get(legacy)
        if mapped is None:
            raise ValueError(
                f"adapter manifest carries unknown legacy use_pallas="
                f"{legacy!r}; expected one of ('auto', 'never', 'interpret')")
        d["kernel_backend"] = mapped
    return PEFTConfig(**d)


def read_manifest(directory: str, adapter_id: str) -> PEFTConfig:
    """PEFTConfig of an export without touching its arrays (cheap profile
    discovery over large tenant directories)."""
    path = os.path.join(directory, _check_id(adapter_id), _MANIFEST)
    with open(path) as f:
        return _peft_from_manifest(json.load(f)["peft"])


def import_adapter(directory: str, adapter_id: str,
                   sites: Optional[Sequence[AdapterSite]] = None,
                   ) -> Tuple[Dict, PEFTConfig]:
    """-> ({site: {leaf: array}}, PEFTConfig). With `sites`, frozen aux leaves
    (entries / bases) are regenerated per site so the tree is directly usable
    as params["peft"]; without, only the stored trainables are returned (the
    AdapterBank path — its groups already hold the shared aux)."""
    path = os.path.join(directory, _check_id(adapter_id))
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    peft = _peft_from_manifest(manifest["peft"])
    method = adapter_api.resolve(peft.method)
    out: Dict[str, Dict] = {}
    with np.load(os.path.join(path, _LEAVES)) as z:
        for key in z.files:
            site, leaf = key.rsplit(_SEP, 1)
            out.setdefault(site, {})[leaf] = jax.numpy.asarray(z[key])
    if sites is not None:
        trainable = set(method.trainable_leaves(peft))
        by_name = {s.name: s for s in sites}
        for site_name, tree in out.items():
            ref = method.init_site(jax.random.PRNGKey(0), by_name[site_name],
                                   peft)
            for leaf, v in ref.items():
                if leaf not in trainable:
                    tree[leaf] = v
    return out, peft


def list_adapters(directory: str) -> Tuple[str, ...]:
    if not os.path.isdir(directory):
        return ()
    out = []
    for name in sorted(os.listdir(directory)):
        if ".tmp-" in name:
            continue
        if os.path.exists(os.path.join(directory, name, _MANIFEST)):
            out.append(name)
    return tuple(out)
