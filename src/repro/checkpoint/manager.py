"""Checkpointing without orbax: async, atomic, keep-k, elastic re-shard.

Layout:  <dir>/step_<N>/
             manifest.json   — tree skeleton + leaf metadata
             <leaf_id>.npy   — one file per array leaf
         <dir>/step_<N>.tmp-* during write; atomic os.replace on publish.

Elastic restore: leaves are stored as full logical arrays; `restore(...,
shardings=...)` device_puts onto ANY mesh (different device count / topology
than the saver's) — the re-shard path exercised by tests/test_checkpoint.py.
Multi-host note: on a real fleet each host writes only its addressable shards
(`save(..., process_index)` namespaces files); this container is single-host
so the full-array path is the one exercised.

Async: a worker thread drains a queue of (step, host_arrays) snapshots;
`device_get` happens on the caller thread (consistent snapshot), file I/O off
the critical path. SIGTERM-safe: `close()` flushes the queue.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import tempfile
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# numpy cannot serialize ml_dtypes (bfloat16 etc.); store a same-width uint
# view and record the logical dtype in the manifest.
_EXT_DTYPES = {
    "bfloat16": (np.uint16, ml_dtypes.bfloat16),
    "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn),
    "float8_e5m2": (np.uint8, ml_dtypes.float8_e5m2),
}


def _encode(arr: np.ndarray):
    name = arr.dtype.name
    if name in _EXT_DTYPES:
        return arr.view(_EXT_DTYPES[name][0]), name
    return arr, None


def _decode(arr: np.ndarray, name):
    if name:
        return arr.view(_EXT_DTYPES[name][1])
    return arr


def _flatten(tree, prefix=""):
    """-> list[(path, leaf)], json-able skeleton."""
    if isinstance(tree, dict):
        skel, leaves = {}, []
        for k in sorted(tree):
            s, l = _flatten(tree[k], f"{prefix}{k}/")
            skel[k] = s
            leaves.extend(l)
        return skel, leaves
    if isinstance(tree, (list, tuple)):
        skel, leaves = [], []
        for i, v in enumerate(tree):
            s, l = _flatten(v, f"{prefix}{i}/")
            skel.append(s)
            leaves.extend(l)
        return ({"__tuple__": skel} if isinstance(tree, tuple) else skel), leaves
    path = prefix[:-1]
    return {"__leaf__": path}, [(path, tree)]


def _unflatten(skel, leaves: Dict[str, Any]):
    if isinstance(skel, dict):
        if "__leaf__" in skel:
            return leaves[skel["__leaf__"]]
        if "__tuple__" in skel:
            return tuple(_unflatten(s, leaves) for s in skel["__tuple__"])
        return {k: _unflatten(v, leaves) for k, v in skel.items()}
    if isinstance(skel, list):
        return [_unflatten(s, leaves) for s in skel]
    raise TypeError(skel)


def _leaf_file(path: str) -> str:
    return path.replace("/", "__") + ".npy"


def save_sync(directory: str, step: int, tree) -> str:
    """Blocking save with atomic publish. Returns the final path."""
    skel, leaves = _flatten(tree)
    host = [(p, np.asarray(jax.device_get(v))) for p, v in leaves]
    return _write(directory, step, skel, host)


def _write(directory: str, step: int, skel, host_leaves) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=f"step_{step:08d}.tmp-", dir=directory)
    try:
        dtypes = {}
        for p, arr in host_leaves:
            enc, name = _encode(arr)
            if name:
                dtypes[p] = name
            np.save(os.path.join(tmp, _leaf_file(p)), enc)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "skeleton": skel,
                       "leaves": [p for p, _ in host_leaves],
                       "ext_dtypes": dtypes}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def available_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and ".tmp-" not in name:
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                out.append(int(name[5:]))
    return sorted(out)


def restore(directory: str, step: Optional[int] = None, *,
            shardings=None, target=None):
    """Load a checkpoint. `shardings`: optional pytree of NamedSharding (same
    structure) — arrays are device_put onto it (elastic re-shard). `target`:
    optional abstract tree to cast dtypes/validate shapes against."""
    steps = available_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    step = steps[-1] if step is None else step
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = {}
    ext = manifest.get("ext_dtypes", {})
    for p in manifest["leaves"]:
        leaves[p] = _decode(np.load(os.path.join(path, _leaf_file(p))),
                            ext.get(p))
    tree = _unflatten(manifest["skeleton"], leaves)
    if target is not None:
        tree = jax.tree.map(
            lambda t, a: np.asarray(a, dtype=t.dtype), target, tree)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree, step


class CheckpointManager:
    """Async keep-k manager with atomic publishes."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: queue.Queue = queue.Queue()
        self._err: Optional[BaseException] = None
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()

    def _run(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                step, skel, host_leaves = item
                _write(self.directory, step, skel, host_leaves)
                self._prune()
            except BaseException as e:  # surfaced on next save/close
                self._err = e
            finally:
                self._q.task_done()

    def _prune(self):
        steps = available_steps(self.directory)
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def save(self, step: int, tree):
        if self._err:
            raise self._err
        skel, leaves = _flatten(tree)
        host = [(p, np.asarray(jax.device_get(v))) for p, v in leaves]
        self._q.put((step, skel, host))

    def wait(self):
        """Block until every queued save has been published."""
        self._q.join()
        if self._err:
            raise self._err

    def close(self):
        self._q.put(None)
        self._worker.join(timeout=120)
        if self._err:
            raise self._err
