from repro.checkpoint import adapters, manager
