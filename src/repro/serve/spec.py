"""Drafters for speculative decoding on the continuous runtime (DESIGN.md
§Speculation).

A drafter proposes `k` tokens per slot per scheduler step; the runtime
verifies all of them (plus the mandatory next token) in ONE batched
`verify_step` forward and accepts the longest prefix that greedy decoding
would have produced — so speculative output is token-identical to the
non-speculative path, and a step emits between 1 and k+1 tokens per slot.

Two implementations:

`SelfDrafter` — the adapter-free base model as its own drafter. The
    `AdapterBank` already reserves a zero row every gather can hit
    (FourierFT deltas are ADDED to the frozen base, so row `zero_row` IS
    the base model): drafting runs k ordinary decode steps through the
    SAME compiled per-slot decode graph with every slot's adapter gather
    forced to the zero row — no extra weights, no extra compilation. The
    draft diverges from the tenant model only where the spectral delta
    changes the argmax, which is exactly why acceptance is high for
    parameter-efficient adapters. Probe steps advance the cache `pos` by
    k and write base-model KV at pos..pos+k-1; `propose` rolls `pos` back
    (scalar `advance_pos(-k)`) and the verify forward overwrites every
    probed row with tenant-model KV before anything can read it.

`NGramDrafter` — prompt-lookup drafting, entirely host-side: each slot
    keeps its token history (prompt + generated) and proposes the
    continuation of the most recent PRIOR occurrence of the trailing
    n-gram. Zero device cost per proposal; wins over self-drafting when
    outputs quote their inputs (extraction, code edits) or when k probe
    decode steps cost more than they save.
"""
from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp
import numpy as np


class Drafter:
    """Protocol + no-op history hooks. A drafter is bound to ONE scheduler
    (`bind`), proposes an (n_slots, k) int32 token block per step
    (`propose`; rows of FREE slots are ignored; device OR host array — the
    runtime folds either into its single per-step transfer), and observes
    the slot lifecycle through `on_prime` / `on_tokens` / `on_release`."""

    k: int = 4

    def bind(self, sched) -> None:
        self._sched = sched

    def propose(self) -> np.ndarray:
        raise NotImplementedError

    def on_prime(self, slot: int, prompt: np.ndarray,
                 first_token: int) -> None:
        pass

    def on_tokens(self, slot: int, tokens: List[int]) -> None:
        pass

    def on_release(self, slot: int) -> None:
        pass


class SelfDrafter(Drafter):
    """Base-row self-drafting: k greedy decode steps with all adapter
    gathers pointed at the bank's reserved zero row (== the frozen base
    model). Reuses the scheduler's compiled decode graph; the proposal
    stays ON DEVICE — the probe loop feeds each step's output straight
    back as the next input and never reads a token to the host, so the
    k-step chain dispatches asynchronously and the runtime's verify drain
    is the step's only sync point."""

    def __init__(self, k: int = 4):
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        self.k = k

    def bind(self, sched) -> None:
        super().bind(sched)
        self._zero_slots = None

    def propose(self) -> jnp.ndarray:
        s = self._sched
        params, extra = s.engine.params, {}
        if s.pager is not None:
            extra["block_table"] = s.pager.block_table_device()
        if s.bank is not None:
            if self._zero_slots is None:      # all-None ids -> zero rows
                self._zero_slots = s.bank.slot_rows([None] * s.n_slots,
                                                    s.n_slots)
            extra["adapter_slots"] = self._zero_slots
            params = {**params, "bank": s.bank.params}
        cache = s.cache
        toks = s.engine.commit_tokens(np.asarray(s._last, np.int32)[:, None])
        outs = []
        for _ in range(self.k):
            nt, cache = s._decode(params, cache, {"tokens": toks, **extra})
            outs.append(nt)
            toks = nt[:, None]
        # roll the probe steps back: pos is the only state that must not
        # move (probe KV rows sit past kv_len until verify rewrites them)
        s.cache = s._advance(cache, jnp.int32(-self.k))
        return jnp.stack(outs, axis=1)


class NGramDrafter(Drafter):
    """Prompt-lookup drafting: propose the continuation of the most recent
    PRIOR occurrence of the trailing n-gram of each slot's history, trying
    suffix lengths `ngram` down to 1, repeating the last token when the
    match runs short (or no match exists — proposal quality only affects
    acceptance, never correctness)."""

    def __init__(self, k: int = 4, ngram: int = 3, max_history: int = 4096):
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        if ngram < 1:
            raise ValueError(f"ngram must be >= 1, got {ngram}")
        self.k = k
        self.ngram = ngram
        self.max_history = max_history

    def bind(self, sched) -> None:
        super().bind(sched)
        self._hist: Dict[int, List[int]] = {}

    def on_prime(self, slot: int, prompt: np.ndarray,
                 first_token: int) -> None:
        self._hist[slot] = [int(t) for t in prompt] + [int(first_token)]

    def on_tokens(self, slot: int, tokens: List[int]) -> None:
        h = self._hist.get(slot)
        if h is not None:
            h.extend(tokens)
            if len(h) > self.max_history:
                del h[:len(h) - self.max_history]

    def on_release(self, slot: int) -> None:
        self._hist.pop(slot, None)

    def _lookup(self, h: List[int]) -> List[int]:
        for n in range(min(self.ngram, len(h) - 1), 0, -1):
            pat = h[-n:]
            # most recent PRIOR occurrence: continuation must predate the
            # suffix itself (i + n < len(h))
            for i in range(len(h) - n - 1, -1, -1):
                if h[i:i + n] == pat:
                    cont = h[i + n:i + n + self.k]
                    return cont + [cont[-1]] * (self.k - len(cont))
        return [h[-1]] * self.k

    def propose(self) -> np.ndarray:
        s = self._sched
        out = np.zeros((s.n_slots, self.k), np.int32)
        for slot, h in self._hist.items():
            out[slot] = self._lookup(h)
        return out
