"""Slot lifecycle for the serving runtimes (DESIGN.md §Scheduler).

State machine (per slot of the fixed-shape batch):

    FREE --acquire--> ACTIVE --note_token x N--> (budget 0 | EOS) --release--> FREE

`SlotManager` owns the invariants both runtimes rely on:

- **no double assignment** — acquire only ever hands out a FREE slot and
  refuses a rid that is already active (RuntimeError, not silent reuse);
- **exact budgets** — a request records precisely min(max_new, tokens
  through EOS) tokens: note_token decrements the budget and reports
  completion the step it hits zero or emits `eos_id`;
- **recycling is immediate** — release returns the slot to FREE the same
  scheduler step its request completes, and the `on_release` hook fires
  inside that transition: the paged runtime frees the slot's KV pages
  there, so page lifetime is exactly slot lifetime (DESIGN.md §Paging).

The lockstep engine (Engine.generate_requests) and the continuous runtime
(scheduler.runtime) both complete requests through note_token/release, so
"stop contributing once budget or EOS is hit" is one shared code path.

Capacity invariant the runtimes' admission guards derive from: the final
cache position a request WRITES is `prompt_len + taken - 2` and the
deepest it READS is `prompt_len + taken - 1` (the last generated token is
never written) — so a request fits a max_len cache iff
`prompt_len + max_new - 1 <= max_len`, one token more than the historical
`prompt_len + max_new <= max_len` guard admitted.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, List, Optional

FREE = "FREE"
ACTIVE = "ACTIVE"


@dataclass
class SlotState:
    state: str = FREE
    rid: Optional[int] = None          # request id of the occupant
    adapter_id: Optional[str] = None   # bank tenant the occupant gathers
    budget: int = 0                    # tokens still owed (> 0 iff ACTIVE)
    taken: int = 0                     # tokens recorded for the occupant
    prompt_len: int = 0                # cache row position = prompt_len +
                                       # taken - 1 (last token never written);
                                       # the jax cache's pos vector is the
                                       # source of truth


class SlotManager:
    """Tracks per-slot occupancy/budget for a fixed pool of decode slots.

    on_release: optional hook `f(slot, snapshot)` fired as a slot recycles
    (ACTIVE -> FREE) — the paged runtime frees the slot's KV pages here."""

    def __init__(self, n_slots: int, eos_id: Optional[int] = None,
                 on_release: Optional[Callable] = None):
        if n_slots < 1:
            raise ValueError(f"need at least one slot, got {n_slots}")
        self.eos_id = eos_id
        self.on_release = on_release
        self._slots = [SlotState() for _ in range(n_slots)]

    def __len__(self) -> int:
        return len(self._slots)

    def state(self, slot: int) -> SlotState:
        return self._slots[slot]

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s.state == FREE]

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s.state == ACTIVE]

    def any_active(self) -> bool:
        return any(s.state == ACTIVE for s in self._slots)

    def occupancy(self) -> float:
        return len(self.active_slots()) / len(self._slots)

    def adapter_ids(self) -> List[Optional[str]]:
        """Per-slot tenant ids (None for FREE slots / bank-less requests) —
        exactly the `adapter_slots` gather order of the decode batch, and
        the pin set protecting live tenants from LRU eviction."""
        return [s.adapter_id if s.state == ACTIVE else None
                for s in self._slots]

    def acquire(self, rid: int, budget: int,
                adapter_id: Optional[str] = None,
                prompt_len: int = 0, slot: Optional[int] = None) -> int:
        """Assign the lowest FREE slot to request `rid` — or the explicit
        `slot` (the paged runtime plans page tables against a specific slot
        before acquiring; passing it here makes the pairing a contract
        instead of an ordering assumption). Raises RuntimeError when no
        slot is free, the requested slot isn't, or `rid` is already
        assigned (a double assignment would interleave two requests'
        tokens in one KV row)."""
        if budget < 1:
            raise ValueError(f"budget must be >= 1, got {budget}")
        if any(s.state == ACTIVE and s.rid == rid for s in self._slots):
            raise RuntimeError(f"request {rid} is already assigned a slot")
        if slot is not None:
            if self._slots[slot].state != FREE:
                raise RuntimeError(f"requested slot {slot} is not FREE")
        else:
            free = self.free_slots()
            if not free:
                raise RuntimeError("no free slot")
            slot = free[0]
        self._slots[slot] = SlotState(state=ACTIVE, rid=rid,
                                      adapter_id=adapter_id, budget=budget,
                                      taken=0, prompt_len=prompt_len)
        return slot

    def note_token(self, slot: int, token: Optional[int] = None) -> bool:
        """Record one generated token for `slot`; True when the request is
        done (budget exhausted, or `token` == eos_id — the EOS token itself
        is included in the output). `token` may be None only when the
        manager has no eos_id (budget-only completion needs no values)."""
        s = self._slots[slot]
        if s.state != ACTIVE:
            raise RuntimeError(f"note_token on {s.state} slot {slot}")
        if self.eos_id is not None and token is None:
            raise RuntimeError("eos_id is set: note_token needs the token")
        s.taken += 1
        s.budget -= 1
        return s.budget <= 0 or (self.eos_id is not None
                                 and token == self.eos_id)

    def note_window(self, slot: int, tokens: List[int]) -> tuple:
        """Record an ACCEPTED speculative window for `slot` (DESIGN.md
        §Speculation): consume `tokens` in order, stopping the moment the
        budget hits zero or a token is `eos_id` — the same per-token rule
        `note_token` applies, so a verify step emitting [t1..tn] is
        accounted exactly like n sequential decode steps. Returns
        (n_emitted, done): the runtime must emit only the first n_emitted
        tokens (the rest are clamped overshoot) and release the slot when
        done."""
        if not tokens:
            raise ValueError("note_window needs at least one token")
        for n, tok in enumerate(tokens, start=1):
            if self.note_token(slot, tok):
                return n, True
        return len(tokens), False

    def release(self, slot: int) -> SlotState:
        """Recycle `slot` (ACTIVE -> FREE); returns the occupant's final
        state snapshot. Fires `on_release` after the transition."""
        s = self._slots[slot]
        if s.state != ACTIVE:
            raise RuntimeError(f"release of {s.state} slot {slot}")
        snapshot = dataclasses.replace(s)
        self._slots[slot] = SlotState()
        if self.on_release is not None:
            self.on_release(slot, snapshot)
        return snapshot
