"""Continuous-batching runtime over the slot Engine (DESIGN.md §Scheduler).

One persistent fixed-shape KV cache: by default a PAGED cache (DESIGN.md
§Paging) — K/V in a global pool of fixed-size pages, each slot mapping its
logical positions onto pages through a block-table row, with page-aligned
prompt prefixes reused across requests (same tenant / bare base) so the
prime prefill computes only the unshared tail; `paged=False` keeps the
dense per-slot cache (`Model.init_cache(..., per_slot=True)`). Either way
every slot decodes at its own position/ragged kv_len, requests are
admitted into FREE slots the moment a slot, the tenant's bank row, AND (if
paged) the request's worst-case page count are available, and a slot is
recycled — its pages freed — the very step its request completes.
In-flight prefill primes a single slot while the other slots keep
decoding. All steady-state shapes are fixed: the decode graph NEVER
recompiles as requests come and go (the block table is a same-shape array
per call); prefill/splice compile once per pow2 prompt bucket.

Admission is adapter-bank-aware: a request's tenant is touched when
resident, loaded via `load_from_checkpoint` when not, with the tenants of
live slots pinned against LRU eviction (evicting one would zero the bank
row under a decoding batch). A request whose tenant cannot be made
resident right now waits, without head-of-line blocking the rest of the
queue.

Outputs are EXACT per request — bit-identical (fp32) to
`Engine.generate` run one request at a time: the prime prefill computes the
prompt at its true positions (`true_len` logits gather), pad-tail KV rows
are never readable (per-slot kv_len), and every decode einsum is
row-parallel.

Two throughput paths sit on top of the plain per-step decode loop:

- **Speculative decoding** (`drafter=`, DESIGN.md §Speculation): a
  `serve.spec.Drafter` proposes k tokens per slot; ONE `verify_step`
  forward (windowed paged_attention, q_len = k+1) scores all of them, the
  host accepts the longest greedy-consistent prefix per slot (EOS and
  budget clamp inside the window), and `advance_pos` commits per-slot
  deltas — rejection is position bookkeeping, never data movement, and the
  fixed (n_slots, k+1) verify shape never recompiles.
- **Buffered EOS detection**: the plain loop no longer syncs on every
  step's tokens. Decode feeds its own device output back as the next
  step's input; emitted tokens buffer on device and drain in one transfer
  when a budget completion is due (host-known, so budget-only traffic
  keeps its exact step timing), when the async per-slot EOS done-flag
  comes back set, or every `eos_sync_every` steps — so EOS-enabled decode
  no longer blocks on a host round-trip each step.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.engine import BankFullError, Engine, Request
from repro.serve.paging import PagedKVCache, PrefixCache, PrimePlan
from repro.serve.scheduler.metrics import ServingMetrics
from repro.serve.scheduler.queue import RequestQueue, ScheduledRequest
from repro.serve.scheduler.slots import SlotManager
from repro.serve.tiering import (
    PRIORITIES, HostAdapterTier, HostPagePool, TieringConfig, VictimInfo,
    choose_mode, choose_victim, priority_rank,
)

Event = Tuple  # ("admit", rid, slot, t) | ("token", rid, tok, t)
               # | ("done", rid, toks, t) | ("preempt", rid, slot, t)
               # | ("resume", rid, slot, t)


@dataclass
class ResumeState:
    """How a preempted request comes back (queue.ScheduledRequest.resume):
    "swap" restores the host snapshot of its KV pages; "recompute"
    re-prefills prompt + everything already emitted. Either way the
    resumed stream is bit-identical to an unpreempted run (DESIGN.md
    §Tiering)."""
    mode: str


def _bucket(n: int, lo: int = 8) -> int:
    """Next power of two >= n (floored at `lo`): bounds prime-prefill
    compilations at log2(max_len) graphs under arbitrary prompt lengths."""
    b = lo
    while b < n:
        b <<= 1
    return b


class ContinuousScheduler:
    """Continuous-batching front end over an Engine's model/params/bank.

    eos_id:  optional stop token — a slot completes on emitting it (the
             token is included in the output). Detected from the buffered
             device-side done-flag (no per-step host round-trip); at most
             `eos_sync_every` decode steps run past an EOS before the
             drain discards the overshoot.
    policy:  RequestQueue admission order ("fcfs" | "resident_first").
    bucket:  pad prime prefills to pow2 prompt buckets (bounded compile
             count); False compiles per distinct prompt length instead.
    paged:   block-table page-pool cache with shared-prefix reuse
             (DESIGN.md §Paging; the default) vs the dense per-slot cache.
             Outputs are bit-identical (fp32) either way.
    page_size / n_pages: paged-cache geometry (n_pages defaults to the
             zero-sharing worst case plus prefix-cache headroom, see
             serve/paging.PagedKVCache).
    drafter: optional `serve.spec.Drafter` — switches the decode loop to
             draft-then-verify speculative decoding (DESIGN.md
             §Speculation). Greedy outputs stay token-identical to the
             non-speculative path; `metrics` grows acceptance counters.
    eos_sync_every: max decode steps between token drains when eos_id is
             set and no completion is otherwise due (bounds both EOS
             detection latency and wasted overshoot steps).
    tiering: optional `serve.tiering.TieringConfig` — priority classes,
             preempt-and-resume under page/bank pressure, and host-RAM
             tiers for KV pages and adapter-bank rows (DESIGN.md
             §Tiering). Preemption needs the paged cache; the adapter
             host tier works either way. Resumed streams are bit-
             identical (fp32) to an unpreempted run.

    Streaming API: `events()` yields ("admit", rid, slot, t),
    ("token", rid, token, t) and ("done", rid, tokens, t) tuples as they
    happen; `serve(requests, arrivals)` replays a trace and returns the
    requests with `.out` filled. `metrics` accumulates TTFT / occupancy /
    tokens-per-s (ServingMetrics).
    """

    def __init__(self, engine: Engine, eos_id: Optional[int] = None,
                 policy: str = "fcfs", bucket: bool = True,
                 paged: bool = True, page_size: int = 16,
                 n_pages: Optional[int] = None, drafter=None,
                 eos_sync_every: int = 4,
                 tiering: Optional[TieringConfig] = None):
        if not engine.model.supports_slot_cache:
            raise NotImplementedError(
                f"{engine.model.cfg.name}: continuous batching needs the "
                "per-slot cache path (token-input transformer families)")
        self.engine = engine
        self.model = engine.model
        self.bank = engine.bank
        self.n_slots = engine.batch
        self.max_len = engine.max_len
        self.eos_id = eos_id
        self.bucket = bucket
        self.queue = RequestQueue(policy)
        self.pager: Optional[PagedKVCache] = None
        if paged:
            self.pager = PagedKVCache(self.n_slots, self.max_len,
                                      page_size=page_size, n_pages=n_pages)
        self.slots = SlotManager(self.n_slots, eos_id=eos_id,
                                 on_release=self._release_pages)
        self.metrics = ServingMetrics()
        self.t = 0.0                           # decode-step clock
        self._decode = engine._decode          # shared jit: per-slot trace
        self._prefill = engine._prefill        # shared jit: (1, P) traces
        self._write = jax.jit(self.model.write_slot, donate_argnums=(0,))
        self._reset = jax.jit(self.model.reset_slots, donate_argnums=(0,))
        if paged:
            self.cache = engine._fresh_cache(
                paged=True, page_size=self.pager.page_size,
                n_pages=self.pager.n_pages)
            self._prefill_paged = jax.jit(self.model.prefill_paged,
                                          donate_argnums=(1,))
            self._copy_page = jax.jit(self.model.copy_page,
                                      donate_argnums=(0,))
        else:
            self.cache = engine._fresh_cache(per_slot=True)
        self._cache_dtype = jnp.dtype(self.model.cfg.dtype)
        self._sr: List[Optional[ScheduledRequest]] = [None] * self.n_slots
        self._plans: Dict[int, PrimePlan] = {}
        self._prefix_keys: Dict[int, list] = {}   # rid -> memoized hashes
        self._last = [0] * self.n_slots        # per-slot last token (host)
        self._outs: Dict[int, List[int]] = {}
        self._stale = set()                    # freed, not yet reset slots
        # buffered decode state (plain loop): device token feedback plus
        # not-yet-drained step outputs and the async EOS done-flag
        self.eos_sync_every = max(1, int(eos_sync_every))
        self._pending: List[Tuple] = []        # (t, nt_dev, [(slot, sr)..])
        self._toks_dev = None                  # (B, 1) next-step tokens
        self._flag_dev = None                  # (B,) device done-flags
        self._flag_prev = None                 # last flag snapshot in flight
        if eos_id is not None:
            eid = int(eos_id)
            self._or_eos = jax.jit(lambda f, nt: f | (nt == eid))
        # speculative decoding (DESIGN.md §Speculation)
        self.drafter = drafter
        if drafter is not None:
            self._verify = jax.jit(self.model.verify_step)
            drafter.bind(self)
        self._advance = jax.jit(self.model.advance_pos,
                                donate_argnums=(0,))
        if paged:
            # verify-window overflow writes route to the slot's reserved
            # scratch page (paging.py: scratch page of slot i is page i)
            self._scratch_pages = jnp.arange(self.n_slots, dtype=jnp.int32)
        # tiering (DESIGN.md §Tiering): host pools + page-pool move ops
        self.tiering = tiering
        self.host_kv: Optional[HostPagePool] = None
        self.host_adapters: Optional[HostAdapterTier] = None
        self._no_admit: set = set()        # preempted this admission round
        if tiering is not None and self.pager is not None:
            # page-pool spill/fill: model-agnostic ops on the paged cache
            # dict (pk/pv pools + per-slot pos) — gathers are dispatched
            # BEFORE the pages are freed/donated, so stream order reads
            # the old contents; fills donate the cache like every other
            # cache-threading jit here
            self._spill_pages = jax.jit(
                lambda c, idx: (jnp.take(c["pk"], idx, axis=1),
                                jnp.take(c["pv"], idx, axis=1)))
            self._fill_pages = jax.jit(
                lambda c, k, v, idx: {**c,
                                      "pk": c["pk"].at[:, idx].set(k),
                                      "pv": c["pv"].at[:, idx].set(v)},
                donate_argnums=(0,))
            self._set_pos = jax.jit(
                lambda c, slot, pos: {**c,
                                      "pos": c["pos"].at[slot].set(pos)},
                donate_argnums=(0,))
            if tiering.host_kv_pages > 0:
                self.host_kv = HostPagePool(tiering.host_kv_pages)
                # touch (not just probe): planned fill keys become MRU so
                # the same plan's demotions displace older entries first
                self.pager.host_has = self.host_kv.touch_prefix
                self.pager.prefix_cache.on_evict = self._demote_prefix_page
        if tiering is not None and tiering.host_adapter_slots > 0 \
                and self.bank is not None:
            # the closure reads self.metrics at call time, so the counter
            # survives reset_metrics() swapping the metrics object
            self.host_adapters = HostAdapterTier(
                tiering.host_adapter_slots,
                on_spill=lambda: self.metrics.on_adapter_spill())
            self.bank.host_tier = self.host_adapters

    # ---- submission -------------------------------------------------------
    def submit(self, request: Request, arrival: float = 0.0) -> int:
        """Queue a request; `arrival` is on the decode-step clock (traffic
        replay). Returns the request id used in events/metrics."""
        if request.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {request.max_new}")
        S = int(request.prompt.shape[0])
        if S < 1:
            raise ValueError("empty (length-0) prompt")
        # cache-position bound (slots.py invariant: the LAST generated token
        # is never written, so the final position used is S + max_new - 2
        # and the deepest read is kv_len = S + max_new - 1). The previous
        # `S + max_new > max_len` guard rejected feasible requests by one
        # token — a request may generate through exactly max_len positions.
        if S + request.max_new - 1 > self.max_len:
            raise ValueError(
                f"prompt ({S}) + max_new ({request.max_new}) needs "
                f"{S + request.max_new - 1} cache positions, exceeding the "
                f"persistent cache's max_len ({self.max_len})")
        if request.adapter_id is not None and self.bank is None:
            raise ValueError("request has an adapter_id but the engine "
                             "has no bank")
        if request.priority not in PRIORITIES:
            raise ValueError(f"unknown priority {request.priority!r}; "
                             f"one of {PRIORITIES}")
        rid = self.queue.push(request, arrival)
        self.metrics.on_arrival(rid, float(arrival),
                                priority=request.priority)
        self.metrics.queue_depth = len(self.queue)
        return rid

    def reset_metrics(self) -> None:
        """Fresh per-run metrics AND a rewound decode-step clock for a new
        trace replay (compiled graphs stay warm). Only meaningful between
        drains — rewinding under live requests would corrupt their stamps.
        The monotonic cumulative counters (requests admitted/cancelled/…,
        ServingMetrics.COUNTERS) carry over: a /metrics scrape must never
        see them dip."""
        if self.slots.any_active() or len(self.queue):
            raise RuntimeError("reset_metrics with requests in flight")
        self.metrics = ServingMetrics(carry=self.metrics)
        self.t = 0.0

    # ---- admission --------------------------------------------------------
    def _ensure_resident(self, sr: ScheduledRequest) -> bool:
        """Make the request's tenant bank-resident (admission side effect).
        False = defer: the bank is full of pinned (live) tenants."""
        aid = sr.request.adapter_id
        if aid is None:
            return True
        if aid in self.bank.resident_ids:
            self.bank.touch(aid)
            return True
        pinned = [a for a in self.slots.adapter_ids() if a is not None]
        try:
            if self.host_adapters is not None:
                # host tier first: a hit skips the checkpoint read entirely
                if self.bank.load_from_host(aid, pinned=pinned) is not None:
                    self.metrics.on_adapter_host_hit()
                    return True
            self.bank.load_from_checkpoint(aid, pinned=pinned)
        except BankFullError:
            return False
        return True

    def _effective(self, sr: ScheduledRequest) -> Tuple[np.ndarray, int]:
        """(prompt, max_new) as the admission path sees them. A resumed
        request re-enters with prompt + everything already emitted as its
        effective prompt and only its remaining budget left — identical
        page totals and the exact slot invariants of an unpreempted run
        at the same point (DESIGN.md §Tiering)."""
        prompt = np.asarray(sr.request.prompt)
        if sr.resume is None:
            return prompt, sr.request.max_new
        done = self._outs[sr.rid]
        return (np.concatenate([prompt, np.asarray(done, np.int32)]),
                sr.request.max_new - len(done))

    def _try_admit(self, sr: ScheduledRequest) -> bool:
        """Admission callback for the queue: bank residency first, then (if
        paged) the page plan — matching the prefix cache and allocating the
        slot's worst-case pages up-front, so decode never allocates. False
        defers the request without head-of-line blocking the queue.

        Resumes ride the same path: a swap-resume allocates all its pages
        privately (`plan_resume` — the snapshot holds the exact KV); a
        recompute-resume plans its EFFECTIVE prompt through the ordinary
        prefix-matching admission, so it may share cached prefix pages
        ("recompute-from-prefix")."""
        if sr.rid in self._no_admit:
            return False       # just preempted: re-admitting it this round
                               # would thrash it against its preemptor
        if not self._ensure_resident(sr):
            return False
        if self.pager is None:
            return True
        prompt, max_new = self._effective(sr)
        if sr.resume is not None and sr.resume.mode == "swap":
            total = -(-(int(prompt.shape[0]) + max_new - 1)
                      // self.pager.page_size)
            plan = self.pager.plan_resume(self.slots.free_slots()[0], total)
            if plan is None:
                return False
            self._plans[sr.rid] = plan
            return True
        memo = self._prefix_keys.get(sr.rid)
        if memo is None:                     # hash + host-copy once;
            memo = (prompt, PrefixCache.chain_keys(  # deferred requests
                prompt, self.pager.page_size,        # are re-offered
                sr.request.adapter_id))              # every cycle
            self._prefix_keys[sr.rid] = memo
        prompt, keys = memo
        plan = self.pager.plan_admit(
            self.slots.free_slots()[0], prompt, max_new,
            adapter_id=sr.request.adapter_id, keys=keys)
        if plan is None:
            return False
        self._plans[sr.rid] = plan
        self._prefix_keys.pop(sr.rid, None)
        return True

    def _release_pages(self, slot: int, snapshot) -> None:
        """SlotManager release hook: a recycled slot frees its pages the
        same scheduler step its request completes."""
        if self.pager is not None:
            self.pager.release(slot)

    def _bucketed_prompt(self, tokens, n: int) -> Tuple[int, Dict]:
        """(padded length P, {tokens, true_len?}) for a batch-1 prefill:
        pow2-bucketed, clamped to max_len (the bucket of a near-max prompt
        can overshoot a non-pow2 cache), `true_len` present iff padded —
        the ONE place both prime flavors get their prefill shapes from."""
        P = min(_bucket(n), self.max_len) if self.bucket else n
        batch: Dict = {"tokens":
                       jnp.zeros((1, P), jnp.int32).at[0, :n].set(tokens)}
        if P != n:
            batch["true_len"] = jnp.full((1,), n, jnp.int32)
        return P, batch

    def _promote_fills(self, plan: PrimePlan, prompt) -> None:
        """Copy the plan's host-matched chunks back into their owned device
        pages before the prime (one batched H2D + scatter; padded rows land
        in the slot's scratch page). The entries stay host-resident — LRU
        ages them out.

        A fill can vanish between plan and promote: `plan_admit`'s own
        eviction demotes device prefix pages into the host pool, and when
        the pool is full those demotions displace its LRU entries — the
        planner touches its fill keys to MRU, but enough same-plan
        demotions can still reach them. The chain shares from the front,
        so everything past the first missing chunk is unusable: truncate
        the fills there and extend the tail back over the lost chunks —
        the prime recomputes them into the already-owned pages, keeping
        the stream exact at a recompute cost."""
        n = len(plan.fills)
        width = _bucket(n, lo=1)
        k = v = idx = None
        filled = 0
        for i, (c, key) in enumerate(plan.fills):
            hit = self.host_kv.get_prefix(key)
            if hit is None:
                self.metrics.on_kv_fill_degraded(n - i)
                plan.prefix_len = c * self.pager.page_size
                plan.tail = np.asarray(prompt)[plan.prefix_len:]
                del plan.fills[i:]
                break
            hk, hv = hit
            if k is None:
                k = np.zeros((hk.shape[0], width) + hk.shape[2:], hk.dtype)
                v = np.zeros_like(k)
                idx = np.full((width,), plan.scratch_page, np.int32)
            k[:, i], v[:, i] = hk[:, 0], hv[:, 0]
            idx[i] = plan.block_row[c]
            filled += 1
        if not filled:
            return
        self.cache = self._fill_pages(self.cache, jnp.asarray(k),
                                      jnp.asarray(v), jnp.asarray(idx))
        self.metrics.on_kv_fill(filled)
        self.metrics.on_prefix_host_hit(filled)

    def _prime(self, sr: ScheduledRequest, slot: int,
               prompt=None) -> int:
        """In-flight prefill: run the prompt through a batch-1 scratch
        prefill and splice its KV into `slot` of the live cache. Returns the
        first generated token. On the paged cache, only the UNSHARED TAIL of
        the prompt is computed (`Model.prefill_paged`): reused prefix pages
        enter the tail's attention through the block-table window, after the
        COW clone when the plan calls for one. `prompt` overrides the
        request's own (recompute-resume primes prompt + emitted)."""
        prompt = sr.request.prompt if prompt is None else prompt
        params = self.engine.params
        extra: Dict = {}
        if self.bank is not None:
            extra["adapter_slots"] = self.bank.slot_rows(
                [sr.request.adapter_id], 1)
            params = {**params, "bank": self.bank.params}
        t0 = time.perf_counter()
        if self.pager is not None:
            plan = self._plans.pop(sr.rid)
            if plan.cow is not None:
                self.cache = self._copy_page(self.cache, *plan.cow)
            if plan.fills:
                self._promote_fills(plan, prompt)
            _, batch = self._bucketed_prompt(jnp.asarray(plan.tail),
                                             int(plan.tail.shape[0]))
            batch.update(block_table=jnp.asarray(plan.block_row[None]),
                         slot=jnp.int32(slot),
                         scratch_page=jnp.int32(plan.scratch_page), **extra)
            if plan.prefix_len:
                # warm prime: the attention window gathers only the pow2
                # bucket of the PREFIX pages (compile count stays log-
                # bounded) — not the full pages_per_seq window, which would
                # cost O(tail * max_len) at long max_len. Cold primes omit
                # both keys and take the statically window-free graph.
                ps = self.pager.page_size
                wp = min(_bucket(-(-plan.prefix_len // ps), lo=1),
                         self.pager.pages_per_seq)
                batch["window_table"] = jnp.asarray(
                    plan.block_row[None, :wp])
                batch["prefix_len"] = jnp.int32(plan.prefix_len)
            nt, self.cache = self._prefill_paged(params, self.cache, batch)
        else:
            S = int(prompt.shape[0])
            P, batch = self._bucketed_prompt(prompt, S)
            batch.update(extra)
            scratch = self.model.init_cache(1, P, dtype=self._cache_dtype)
            nt, scratch = self._prefill(params, scratch, batch)
            self.cache = self._write(
                self.cache, {"k": scratch["k"], "v": scratch["v"]}, slot, S)
        tok = int(np.asarray(nt).reshape(-1)[0])
        if self.pager is not None:
            # publish the prompt's chunks for future sharing only past the
            # host sync above (async dispatch errors surface there) — a
            # failed prime must not leave prefix-cache entries pointing at
            # never-filled pages
            self.pager.register_prompt(plan)
        self.metrics.on_prime(sr.rid, time.perf_counter() - t0)
        return tok

    def _admit_ready(self) -> Iterator[Event]:
        self._no_admit = set()
        try:
            while len(self.queue):
                resident = self.bank.resident_ids if self.bank else ()
                sr = None
                if self.slots.free_slots():
                    sr = self.queue.pop_next(self.t, self._try_admit,
                                             resident=resident)
                if sr is not None:
                    yield from self._admit_one(sr)
                    continue
                # blocked: no free slot, or every arrived request deferred
                # on pages/bank. Deferral was the only option pre-tiering;
                # with preemption on, evict a strictly-lower-class victim
                # for the head-of-policy-order candidate and retry.
                if (self.tiering is None or not self.tiering.preempt
                        or self.pager is None):
                    return
                cand = self.queue.peek_next(self.t, resident=resident)
                if cand is None or cand.rid in self._no_admit:
                    return
                evs = self._preempt_for(cand)
                if evs is None:
                    return
                yield from evs
                if not any(e[0] in ("preempt", "done") for e in evs):
                    return    # drained tokens only: nothing was freed, so
                              # retrying admission would spin
        finally:
            self._no_admit = set()

    def _admit_one(self, sr: ScheduledRequest) -> Iterator[Event]:
        """Acquire + prime one accepted request (fresh or resumed)."""
        resume = sr.resume
        prompt, max_new = self._effective(sr)
        plan = self._plans.get(sr.rid)
        slot = self.slots.acquire(sr.rid, budget=max_new,
                                  adapter_id=sr.request.adapter_id,
                                  prompt_len=int(prompt.shape[0]),
                                  slot=plan.slot if plan else None)
        self._sr[slot] = sr
        if resume is not None and resume.mode == "swap":
            # restore the snapshot: no prefill, no token — the slot picks
            # up exactly where the victim stopped (pos = S_eff - 1, next
            # input = the last emitted token), so the next decode emits
            # the same token an unpreempted run would have
            sr.resume = None
            plan = self._plans.pop(sr.rid)
            k, v, n_used = self.host_kv.pop_snapshot(sr.rid)
            idx = np.full((k.shape[1],), plan.scratch_page, np.int32)
            idx[:n_used] = plan.block_row[:n_used]
            self.cache = self._fill_pages(self.cache, jnp.asarray(k),
                                          jnp.asarray(v), jnp.asarray(idx))
            self.cache = self._set_pos(self.cache, jnp.int32(slot),
                                       jnp.int32(int(prompt.shape[0]) - 1))
            self.metrics.on_kv_fill(n_used)
            tok = self._outs[sr.rid][-1]
            self._last[slot] = tok
            if self._toks_dev is not None:
                self._toks_dev = self._toks_dev.at[slot, 0].set(tok)
            if self.drafter is not None:
                self.drafter.on_prime(slot, prompt[:-1], tok)
            self.metrics.on_resume(sr.rid, self.t)
            yield ("resume", sr.rid, slot, self.t)
            return
        if resume is not None:
            sr.resume = None
            self.metrics.on_resume(sr.rid, self.t)
        else:
            self.metrics.on_admit(sr.rid, self.t)
        tok = self._prime(sr, slot, prompt=prompt)
        if resume is None:
            self._outs[sr.rid] = [tok]
        else:
            # recompute-resume: the prime re-prefilled prompt + emitted
            # and produced the NEXT token of the stream
            self._outs[sr.rid].append(tok)
        self._last[slot] = tok
        if self._toks_dev is not None:
            # mid-buffer admission: in-flight slots' next tokens live
            # only on device, so splice the new slot's first token in
            # instead of rebuilding from the (stale) host view
            self._toks_dev = self._toks_dev.at[slot, 0].set(tok)
        if self.drafter is not None:
            self.drafter.on_prime(slot, np.asarray(prompt), tok)
        self.metrics.on_token(sr.rid, self.t)
        self.queue.note_usage(sr.request.adapter_id, 1)
        yield (("resume" if resume is not None else "admit"),
               sr.rid, slot, self.t)
        yield ("token", sr.rid, tok, self.t)
        if self.slots.note_token(slot, tok):
            yield self._finish(slot)

    def _demote_prefix_page(self, key: bytes, page: int) -> None:
        """PrefixCache on_evict hook: instead of dropping a cold prefix
        page, gather its KV (dispatched BEFORE the page returns to the
        free list — stream order reads the old contents even if a later
        prime reuses the page) and hand the in-flight copy to the host
        tier; `settle()` materializes it after the round's device work."""
        k, v = self._spill_pages(self.cache,
                                 jnp.full((1,), page, jnp.int32))
        k.copy_to_host_async()
        v.copy_to_host_async()
        if self.host_kv.put_prefix(key, k, v):
            self.metrics.on_kv_spill(1)

    def _preempt_for(self, cand: ScheduledRequest) -> Optional[List[Event]]:
        """Evict one strictly-lower-class victim slot so `cand` can admit
        (DESIGN.md §Tiering). Returns the events produced (the pre-evict
        drain may finish slots), or None when nothing is eligible. The
        victim's KV leaves by snapshot-to-host ("swap") or is dropped for
        re-prefill at resume ("recompute"), per the cost estimate; either
        way it re-enters the queue with its rid, arrival, and emitted
        tokens intact, and resumes bit-identical."""
        # drain first: the host view of emitted tokens must be current
        # before sizing/snapshotting a victim, and a buffered completion
        # may free a slot outright — in which case just retry admission
        # (a slot that was ALREADY free means the candidate is blocked on
        # pages/bank, and eviction below is still the right move)
        free_before = len(self.slots.free_slots())
        evs = list(self._drain())
        if len(self.slots.free_slots()) > free_before:
            return evs
        crank = priority_rank(cand.request.priority)
        occupants = []
        for slot in self.slots.active_slots():
            vsr = self._sr[slot]
            if vsr is None:
                continue
            st = self.slots.state(slot)
            occupants.append(VictimInfo(
                slot=slot,
                rank=priority_rank(vsr.request.priority),
                prompt_len=int(vsr.request.prompt.shape[0]),
                emitted=len(self._outs[vsr.rid]),
                # rows actually written: pos = prompt_len + taken - 1
                used_pages=-(-(st.prompt_len + st.taken - 1)
                             // self.pager.page_size)))
        victim = choose_victim(crank, occupants)
        if victim is None:
            return evs if evs else None
        vsr = self._sr[victim.slot]
        mode = choose_mode(self.tiering, victim, self.pager.page_size,
                           host_can_swap=self.host_kv is not None)
        if mode == "swap":
            # gather the victim's used pages (padded to a pow2 width with
            # its scratch page — harmless dirt both ways) and pin the
            # in-flight copy in the host pool; a pool too full of other
            # snapshots degrades to recompute, never to waiting
            n_used = victim.used_pages
            width = _bucket(n_used, lo=1)
            idx = np.full((width,), victim.slot, np.int32)
            idx[:n_used] = self.pager.block_tables[victim.slot][:n_used]
            k, v = self._spill_pages(self.cache, jnp.asarray(idx))
            k.copy_to_host_async()
            v.copy_to_host_async()
            if self.host_kv.put_snapshot(vsr.rid, k, v, n_used):
                self.metrics.on_kv_spill(n_used)
            else:
                mode = "recompute"
        vsr.resume = ResumeState(mode)
        self._sr[victim.slot] = None
        self._last[victim.slot] = 0
        self.slots.release(victim.slot)   # frees pages via on_release —
        self._stale.add(victim.slot)      # AFTER the spill gather above
        if self.drafter is not None:
            self.drafter.on_release(victim.slot)
        self._prefix_keys.pop(vsr.rid, None)   # resume re-hashes eff prompt
        self.metrics.on_preempt(vsr.rid, self.t, mode)
        self.queue.requeue(vsr)
        self._no_admit.add(vsr.rid)
        evs.append(("preempt", vsr.rid, victim.slot, self.t))
        return evs

    def _finish(self, slot: int, t: Optional[float] = None) -> Event:
        t = self.t if t is None else t
        sr = self._sr[slot]
        self._sr[slot] = None
        self._last[slot] = 0
        self.slots.release(slot)
        self._stale.add(slot)          # reset is batched into the next step
        if self.drafter is not None:
            self.drafter.on_release(slot)
        toks = self._outs.pop(sr.rid)
        sr.request.out = toks
        self.metrics.on_finish(sr.rid, t)
        return ("done", sr.rid, toks, t)

    # ---- cancellation ------------------------------------------------------
    def cancel(self, rid: int) -> bool:
        """Abort request `rid` wherever it is — the client-disconnect path
        (DESIGN.md §Gateway). A queued request is withdrawn; an ACTIVE one
        releases its slot THIS step: `SlotManager.release` fires the
        on_release hook (freeing the slot's KV pages), the tenant's bank
        row is unpinned the moment the slot leaves `slots.adapter_ids()`,
        and any not-yet-drained buffered tokens for the slot are discarded
        by the drain's occupancy check (the same mechanism that drops
        post-EOS overshoot). Returns True iff the request was found live;
        its `.out` holds the tokens emitted before the abort."""
        sr = self.queue.remove(rid)
        if sr is not None:       # still queued: never admitted, or waiting
            self._prefix_keys.pop(rid, None)   # to resume after preemption
            if self.host_kv is not None:
                self.host_kv.drop_snapshot(rid)
            sr.request.out = self._outs.pop(rid, [])
            self.metrics.on_cancel(rid, self.t)
            self.metrics.queue_depth = len(self.queue)
            return True
        for slot in self.slots.active_slots():
            sr = self._sr[slot]
            if sr is None or sr.rid != rid:
                continue
            self._sr[slot] = None              # buffered overshoot for this
            self._last[slot] = 0               # slot now drains to nowhere
            self.slots.release(slot)           # frees pages via on_release
            self._stale.add(slot)
            if self.drafter is not None:
                self.drafter.on_release(slot)
            sr.request.out = self._outs.pop(rid, [])
            if not self.slots.any_active():
                # nothing left to drain for: drop the buffered-decode state
                # now instead of carrying dead device work into the next
                # admission cycle
                self._pending.clear()
                self._flag_dev = None
                self._flag_prev = None
            self.metrics.on_cancel(rid, self.t)
            return True
        return False

    # ---- decode -----------------------------------------------------------
    def _flush_stale(self) -> None:
        """One batched reset for slots freed since the last step; slots that
        were already re-primed (write_slot set their position) drop out."""
        stale = self._stale & set(self.slots.free_slots())
        self._stale.clear()
        if stale:
            mask = np.zeros((self.n_slots,), bool)
            mask[list(stale)] = True
            self.cache = self._reset(self.cache, mask)

    def _batch_inputs(self) -> Tuple[Dict, Dict]:
        """(params, extra) for a full-batch decode/verify dispatch."""
        params, extra = self.engine.params, {}
        if self.pager is not None:
            extra["block_table"] = self.pager.block_table_device()
        if self.bank is not None:
            extra["adapter_slots"] = self.bank.slot_rows(
                self.slots.adapter_ids(), self.n_slots)
            params = {**params, "bank": self.bank.params}
        return params, extra

    def _min_budget_left(self) -> int:
        """Tokens until the EARLIEST budget completion among active slots,
        counted from the last drain — once the buffer holds that many
        steps, a completion is inside it and must be processed (so
        budget-only traffic drains at exactly its completion steps and
        keeps the unbuffered loop's scheduling timing)."""
        budgets = [self.slots.state(s).budget
                   for s in self.slots.active_slots()]
        return min(budgets) if budgets else 0

    def _decode_once(self) -> Iterator[Event]:
        self._flush_stale()
        active = self.slots.active_slots()
        params, extra = self._batch_inputs()
        if self._toks_dev is None:
            self._toks_dev = self.engine.commit_tokens(
                np.asarray(self._last, np.int32)[:, None])
        nt, self.cache = self._decode(params, self.cache,
                                      {"tokens": self._toks_dev, **extra})
        # feed the device output straight back as the next step's input —
        # the host never sees tokens until a drain
        self._toks_dev = nt[:, None]
        self.t += 1
        self.metrics.on_step(len(active), self.n_slots)
        self._pending.append((self.t, nt, [(s, self._sr[s]) for s in active]))
        sync = self._min_budget_left() <= len(self._pending)
        if self.eos_id is not None:
            if self._flag_dev is None:
                self._flag_dev = jnp.zeros((self.n_slots,), jnp.bool_)
            self._flag_dev = self._or_eos(self._flag_dev, nt)
            # the PREVIOUS flag snapshot has had a full decode dispatch to
            # come back (copy_to_host_async below) — reading it now is
            # effectively free, and one step of detection latency only
            # delays the drain, never correctness
            if self._flag_prev is not None \
                    and bool(np.asarray(self._flag_prev).any()):
                sync = True
            self._flag_dev.copy_to_host_async()
            self._flag_prev = self._flag_dev
            if len(self._pending) >= self.eos_sync_every:
                sync = True
        if sync:
            yield from self._drain()

    def _drain(self) -> Iterator[Event]:
        """Fetch every buffered step's tokens in ONE device transfer and
        replay them through the per-token accounting, stamped with their
        original step times. Slots that complete mid-buffer stop
        contributing from that step on (their later buffered tokens — the
        decode overshoot — are discarded, exactly what the unbuffered loop
        never generated; the device rows were dirt past their kv_len)."""
        if not self._pending:
            return
        pending, self._pending = self._pending, []
        self._flag_dev = None
        self._flag_prev = None
        # THE drain: one transfer per buffer  # repro: allow(host-sync)
        arr = np.asarray(jnp.stack([nt for _, nt, _ in pending]))
        for i, (t, _, occupants) in enumerate(pending):
            for slot, sr in occupants:
                if self._sr[slot] is not sr:   # finished at an earlier step
                    continue
                tok = int(arr[i, slot])
                self._outs[sr.rid].append(tok)
                self._last[slot] = tok
                self.metrics.on_token(sr.rid, t)
                self.queue.note_usage(sr.request.adapter_id, 1)
                yield ("token", sr.rid, tok, t)
                if self.slots.note_token(slot, tok):
                    yield self._finish(slot, t)

    # ---- speculative decode (DESIGN.md §Speculation) ----------------------
    def _spec_decode_once(self) -> Iterator[Event]:
        """One draft-then-verify step: the drafter proposes k tokens per
        slot, ONE `verify_step` forward scores the (n_slots, k+1) window,
        and each active slot accepts the longest prefix greedy decoding
        would have emitted — token j is kept iff draft j matched the
        model's own output after token j-1, with EOS and budget clamping
        anywhere inside the window. Accepted counts commit to the device
        `pos` via `advance_pos` (0 for FREE slots); rejected rows stay
        past kv_len as dirt the next window overwrites."""
        self._flush_stale()
        active = self.slots.active_slots()
        params, extra = self._batch_inputs()
        if self.pager is not None:
            extra["scratch_pages"] = self._scratch_pages
        k = self.drafter.k
        # drafters propose on device (SelfDrafter) or host (NGramDrafter);
        # the window assembles on device either way, and the host reads the
        # window AND the verify scores in ONE transfer after dispatch —
        # previously this synced twice per step (once on propose, once on
        # the scores)
        drafts = jnp.asarray(self.drafter.propose(), jnp.int32)
        last = jnp.asarray(np.asarray(self._last, np.int32))
        win_dev = jnp.concatenate([last[:, None], drafts], axis=1)
        out, self.cache = self._verify(params, self.cache,
                                       {"tokens": win_dev, **extra})
        self.t += 1
        self.metrics.on_step(len(active), self.n_slots)
        # the step's single intended sync point  # repro: allow(host-sync)
        wa = np.asarray(jnp.concatenate([win_dev, out], axis=1))
        win, arr = wa[:, :k + 1], wa[:, k + 1:]
        deltas = np.zeros((self.n_slots,), np.int32)
        for slot in active:
            sr = self._sr[slot]
            # greedy acceptance: token j is valid iff draft j matched the
            # model's own continuation after token j-1 (token 0 is the
            # mandatory next token — always valid)
            accepted = [int(arr[slot, 0])]
            for j in range(1, k + 1):
                if win[slot, j] != arr[slot, j - 1]:
                    break
                accepted.append(int(arr[slot, j]))
            n_emit, done = self.slots.note_window(slot, accepted)
            emitted = accepted[:n_emit]         # budget/EOS clamp
            for tok in emitted:
                self._outs[sr.rid].append(tok)
                self._last[slot] = tok
                self.metrics.on_token(sr.rid, self.t)
                self.queue.note_usage(sr.request.adapter_id, 1)
                yield ("token", sr.rid, tok, self.t)
            deltas[slot] = n_emit
            self.drafter.on_tokens(slot, emitted)
            self.metrics.on_spec(sr.rid, drafted=k, accepted=n_emit - 1,
                                 emitted=n_emit)
            if done:
                yield self._finish(slot)
        self.cache = self._advance(self.cache, jnp.asarray(deltas))

    # ---- static-analysis surface (repro.analysis, DESIGN.md §Analysis) ----
    def compiled_signatures(self) -> Dict[str, int]:
        """Compiled-signature count per jitted graph this scheduler
        dispatches (jit cache sizes — no tracing, safe anytime). Note the
        decode/prefill entries are the ENGINE's shared jits: a fresh Engine
        per scheduler keeps the counts attributable to this scheduler."""
        out = {"decode": int(self._decode._cache_size()),
               "reset": int(self._reset._cache_size()),
               "advance": int(self._advance._cache_size()),
               "write": int(self._write._cache_size())}
        if self.pager is not None:
            out["prefill_paged"] = int(self._prefill_paged._cache_size())
            out["copy_page"] = int(self._copy_page._cache_size())
        else:
            out["prefill"] = int(self._prefill._cache_size())
        if self.drafter is not None:
            out["verify"] = int(self._verify._cache_size())
        if self.eos_id is not None:
            out["or_eos"] = int(self._or_eos._cache_size())
        if self.tiering is not None and self.pager is not None:
            out["spill_pages"] = int(self._spill_pages._cache_size())
            out["fill_pages"] = int(self._fill_pages._cache_size())
            out["set_pos"] = int(self._set_pos._cache_size())
        return out

    def expected_compile_bounds(self) -> Dict[str, int]:
        """The compile-count CONTRACT the pow2 bucketing declares, keyed
        like `compiled_signatures()`. decode/verify run at one fixed
        (n_slots, ·) shape → exactly 1 graph regardless of churn; prime
        prefills compile per pow2 prompt bucket (× cold + pow2 prefix-
        window buckets when paged). With `bucket=False` prefill compiles
        per distinct prompt length — unbounded by design — so no prefill
        bound is declared and the analyzer skips it."""
        bounds = {"decode": 1, "reset": 1, "advance": 1}
        if self.drafter is not None:
            bounds["verify"] = 1
            # scalar rollback (drafter probe) + (B,) accept-commit deltas
            bounds["advance"] = 2
        if self.eos_id is not None:
            bounds["or_eos"] = 1
        if self.pager is not None:
            bounds["copy_page"] = 1
            bounds["write"] = 0            # dense-path graph, unused here
        if self.bucket:
            # pow2 buckets in [8, _bucket(max_len)]
            n_len = _bucket(self.max_len).bit_length() - 3
            if self.pager is not None:
                # pow2 warm prefix-window widths in [1, _bucket(pages)]
                wins = _bucket(self.pager.pages_per_seq, lo=1).bit_length()
                bounds["prefill_paged"] = n_len * (1 + wins)
            else:
                bounds["prefill"] = n_len
                bounds["write"] = n_len    # scratch k/v shape per bucket
        if self.tiering is not None and self.pager is not None:
            # spill/fill widths are pow2-bucketed in [1, _bucket(pages)]
            # regardless of the prompt-bucket flag (the widths come from
            # page counts, not prompt lengths)
            widths = _bucket(self.pager.pages_per_seq, lo=1).bit_length()
            bounds["spill_pages"] = widths
            bounds["fill_pages"] = widths
            bounds["set_pos"] = 1
        return bounds

    def resource_gauges(self) -> Dict[str, float]:
        """Occupancy gauges for the gateway's /metrics scrape (DESIGN.md
        §Tiering): bank residency, prefix-cache and page-pool fill, and
        host-tier occupancy when tiering is on."""
        out: Dict[str, float] = {}
        if self.bank is not None:
            out["bank_resident_adapters"] = float(len(self.bank.resident_ids))
        if self.pager is not None:
            out["prefix_cache_pages"] = float(len(self.pager.prefix_cache))
            out["kv_pages_free"] = float(self.pager.allocator.free_count())
        if self.host_kv is not None:
            out["host_kv_pages_used"] = float(self.host_kv.used_pages)
            out["host_kv_pages_capacity"] = float(self.host_kv.capacity_pages)
        if self.host_adapters is not None:
            out["host_adapter_rows"] = float(len(self.host_adapters))
            out["host_adapter_capacity"] = float(self.host_adapters.capacity)
        return out

    # ---- main loop --------------------------------------------------------
    def tick(self) -> List[Event]:
        """ONE scheduler round — admit every admissible arrived request,
        then (if anything is decoding) one decode/verify step — returning
        the round's events. Returns [] when there is nothing to do right
        now: the queue is empty or its head hasn't arrived yet (the round
        idle-skips the clock to the next arrival), or every arrived request
        is deferred on resources. Unlike `events()`, tick() never raises on
        an un-admittable backlog: under live traffic a later round can free
        what admission waits on (a disconnect cancels a slot, a drain
        unpins a tenant), so the async gateway pumps this from its own
        loop (serve/gateway/bridge.py) and decides idleness itself."""
        evs: List[Event] = list(self._admit_ready())
        if self.slots.any_active():
            if self.drafter is not None:
                evs.extend(self._spec_decode_once())
            else:
                evs.extend(self._decode_once())
        else:
            nxt = self.queue.next_arrival()
            if nxt is not None and nxt > self.t:
                self.t = nxt           # idle: skip to the next arrival
        if self.host_kv is not None:
            # materialize the round's in-flight spills now that the decode
            # work is dispatched (the async D2H copies overlapped it);
            # holding them longer would pin their HBM source buffers
            self.host_kv.settle()
        if self.host_adapters is not None:
            self.host_adapters.settle()
        self.metrics.queue_depth = len(self.queue)
        return evs

    def events(self) -> Iterator[Event]:
        """Drain the queue: admit -> decode -> recycle until no request is
        pending or in flight, yielding the event stream. Re-entrant across
        drains (the persistent cache and clock carry over), but only one
        events() iterator may be live at a time."""
        self.metrics.start()
        try:
            while len(self.queue) or self.slots.any_active():
                t_before = self.t
                evs = self.tick()
                yield from evs
                if not evs and not self.slots.any_active() \
                        and self.t == t_before and len(self.queue):
                    # no admission, no decode, no idle-skip progress, yet
                    # requests remain: a replay can never free what they
                    # wait on (live traffic can — see tick())
                    raise RuntimeError(
                        "scheduler stalled: arrived requests cannot be "
                        "admitted although every slot is free")
        finally:
            self.metrics.stop()

    def serve(self, requests: Sequence[Request],
              arrivals: Optional[Sequence[float]] = None) -> List[Request]:
        """Traffic replay: submit every request (arrivals on the decode-step
        clock, default all-at-0) and drain. Returns the requests with `.out`
        filled, in input order."""
        if arrivals is not None and len(arrivals) != len(requests):
            raise ValueError(f"{len(arrivals)} arrivals for "
                             f"{len(requests)} requests")
        for i, r in enumerate(requests):
            self.submit(r, arrivals[i] if arrivals is not None else 0.0)
        for _ in self.events():
            pass
        return list(requests)
