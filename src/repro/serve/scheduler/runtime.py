"""Continuous-batching runtime over the slot Engine (DESIGN.md §Scheduler).

One persistent fixed-shape KV cache: by default a PAGED cache (DESIGN.md
§Paging) — K/V in a global pool of fixed-size pages, each slot mapping its
logical positions onto pages through a block-table row, with page-aligned
prompt prefixes reused across requests (same tenant / bare base) so the
prime prefill computes only the unshared tail; `paged=False` keeps the
dense per-slot cache (`Model.init_cache(..., per_slot=True)`). Either way
every slot decodes at its own position/ragged kv_len, requests are
admitted into FREE slots the moment a slot, the tenant's bank row, AND (if
paged) the request's worst-case page count are available, and a slot is
recycled — its pages freed — the very step its request completes.
In-flight prefill primes a single slot while the other slots keep
decoding. All steady-state shapes are fixed: the decode graph NEVER
recompiles as requests come and go (the block table is a same-shape array
per call); prefill/splice compile once per pow2 prompt bucket.

Admission is adapter-bank-aware: a request's tenant is touched when
resident, loaded via `load_from_checkpoint` when not, with the tenants of
live slots pinned against LRU eviction (evicting one would zero the bank
row under a decoding batch). A request whose tenant cannot be made
resident right now waits, without head-of-line blocking the rest of the
queue.

Outputs are EXACT per request — bit-identical (fp32) to
`Engine.generate` run one request at a time: the prime prefill computes the
prompt at its true positions (`true_len` logits gather), pad-tail KV rows
are never readable (per-slot kv_len), and every decode einsum is
row-parallel.

Two throughput paths sit on top of the plain per-step decode loop:

- **Speculative decoding** (`drafter=`, DESIGN.md §Speculation): a
  `serve.spec.Drafter` proposes k tokens per slot; ONE `verify_step`
  forward (windowed paged_attention, q_len = k+1) scores all of them, the
  host accepts the longest greedy-consistent prefix per slot (EOS and
  budget clamp inside the window), and `advance_pos` commits per-slot
  deltas — rejection is position bookkeeping, never data movement, and the
  fixed (n_slots, k+1) verify shape never recompiles.
- **Buffered EOS detection**: the plain loop no longer syncs on every
  step's tokens. Decode feeds its own device output back as the next
  step's input; emitted tokens buffer on device and drain in one transfer
  when a budget completion is due (host-known, so budget-only traffic
  keeps its exact step timing), when the async per-slot EOS done-flag
  comes back set, or every `eos_sync_every` steps — so EOS-enabled decode
  no longer blocks on a host round-trip each step.
"""
from __future__ import annotations

import time
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.engine import BankFullError, Engine, Request
from repro.serve.paging import PagedKVCache, PrefixCache, PrimePlan
from repro.serve.scheduler.metrics import ServingMetrics
from repro.serve.scheduler.queue import RequestQueue, ScheduledRequest
from repro.serve.scheduler.slots import SlotManager

Event = Tuple  # ("admit", rid, slot, t) | ("token", rid, tok, t) | ("done", rid, toks, t)


def _bucket(n: int, lo: int = 8) -> int:
    """Next power of two >= n (floored at `lo`): bounds prime-prefill
    compilations at log2(max_len) graphs under arbitrary prompt lengths."""
    b = lo
    while b < n:
        b <<= 1
    return b


class ContinuousScheduler:
    """Continuous-batching front end over an Engine's model/params/bank.

    eos_id:  optional stop token — a slot completes on emitting it (the
             token is included in the output). Detected from the buffered
             device-side done-flag (no per-step host round-trip); at most
             `eos_sync_every` decode steps run past an EOS before the
             drain discards the overshoot.
    policy:  RequestQueue admission order ("fcfs" | "resident_first").
    bucket:  pad prime prefills to pow2 prompt buckets (bounded compile
             count); False compiles per distinct prompt length instead.
    paged:   block-table page-pool cache with shared-prefix reuse
             (DESIGN.md §Paging; the default) vs the dense per-slot cache.
             Outputs are bit-identical (fp32) either way.
    page_size / n_pages: paged-cache geometry (n_pages defaults to the
             zero-sharing worst case plus prefix-cache headroom, see
             serve/paging.PagedKVCache).
    drafter: optional `serve.spec.Drafter` — switches the decode loop to
             draft-then-verify speculative decoding (DESIGN.md
             §Speculation). Greedy outputs stay token-identical to the
             non-speculative path; `metrics` grows acceptance counters.
    eos_sync_every: max decode steps between token drains when eos_id is
             set and no completion is otherwise due (bounds both EOS
             detection latency and wasted overshoot steps).

    Streaming API: `events()` yields ("admit", rid, slot, t),
    ("token", rid, token, t) and ("done", rid, tokens, t) tuples as they
    happen; `serve(requests, arrivals)` replays a trace and returns the
    requests with `.out` filled. `metrics` accumulates TTFT / occupancy /
    tokens-per-s (ServingMetrics).
    """

    def __init__(self, engine: Engine, eos_id: Optional[int] = None,
                 policy: str = "fcfs", bucket: bool = True,
                 paged: bool = True, page_size: int = 16,
                 n_pages: Optional[int] = None, drafter=None,
                 eos_sync_every: int = 4):
        if not engine.model.supports_slot_cache:
            raise NotImplementedError(
                f"{engine.model.cfg.name}: continuous batching needs the "
                "per-slot cache path (token-input transformer families)")
        self.engine = engine
        self.model = engine.model
        self.bank = engine.bank
        self.n_slots = engine.batch
        self.max_len = engine.max_len
        self.eos_id = eos_id
        self.bucket = bucket
        self.queue = RequestQueue(policy)
        self.pager: Optional[PagedKVCache] = None
        if paged:
            self.pager = PagedKVCache(self.n_slots, self.max_len,
                                      page_size=page_size, n_pages=n_pages)
        self.slots = SlotManager(self.n_slots, eos_id=eos_id,
                                 on_release=self._release_pages)
        self.metrics = ServingMetrics()
        self.t = 0.0                           # decode-step clock
        self._decode = engine._decode          # shared jit: per-slot trace
        self._prefill = engine._prefill        # shared jit: (1, P) traces
        self._write = jax.jit(self.model.write_slot, donate_argnums=(0,))
        self._reset = jax.jit(self.model.reset_slots, donate_argnums=(0,))
        if paged:
            self.cache = engine._fresh_cache(
                paged=True, page_size=self.pager.page_size,
                n_pages=self.pager.n_pages)
            self._prefill_paged = jax.jit(self.model.prefill_paged,
                                          donate_argnums=(1,))
            self._copy_page = jax.jit(self.model.copy_page,
                                      donate_argnums=(0,))
        else:
            self.cache = engine._fresh_cache(per_slot=True)
        self._cache_dtype = jnp.dtype(self.model.cfg.dtype)
        self._sr: List[Optional[ScheduledRequest]] = [None] * self.n_slots
        self._plans: Dict[int, PrimePlan] = {}
        self._prefix_keys: Dict[int, list] = {}   # rid -> memoized hashes
        self._last = [0] * self.n_slots        # per-slot last token (host)
        self._outs: Dict[int, List[int]] = {}
        self._stale = set()                    # freed, not yet reset slots
        # buffered decode state (plain loop): device token feedback plus
        # not-yet-drained step outputs and the async EOS done-flag
        self.eos_sync_every = max(1, int(eos_sync_every))
        self._pending: List[Tuple] = []        # (t, nt_dev, [(slot, sr)..])
        self._toks_dev = None                  # (B, 1) next-step tokens
        self._flag_dev = None                  # (B,) device done-flags
        self._flag_prev = None                 # last flag snapshot in flight
        if eos_id is not None:
            eid = int(eos_id)
            self._or_eos = jax.jit(lambda f, nt: f | (nt == eid))
        # speculative decoding (DESIGN.md §Speculation)
        self.drafter = drafter
        if drafter is not None:
            self._verify = jax.jit(self.model.verify_step)
            drafter.bind(self)
        self._advance = jax.jit(self.model.advance_pos,
                                donate_argnums=(0,))
        if paged:
            # verify-window overflow writes route to the slot's reserved
            # scratch page (paging.py: scratch page of slot i is page i)
            self._scratch_pages = jnp.arange(self.n_slots, dtype=jnp.int32)

    # ---- submission -------------------------------------------------------
    def submit(self, request: Request, arrival: float = 0.0) -> int:
        """Queue a request; `arrival` is on the decode-step clock (traffic
        replay). Returns the request id used in events/metrics."""
        if request.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {request.max_new}")
        S = int(request.prompt.shape[0])
        if S < 1:
            raise ValueError("empty (length-0) prompt")
        # cache-position bound (slots.py invariant: the LAST generated token
        # is never written, so the final position used is S + max_new - 2
        # and the deepest read is kv_len = S + max_new - 1). The previous
        # `S + max_new > max_len` guard rejected feasible requests by one
        # token — a request may generate through exactly max_len positions.
        if S + request.max_new - 1 > self.max_len:
            raise ValueError(
                f"prompt ({S}) + max_new ({request.max_new}) needs "
                f"{S + request.max_new - 1} cache positions, exceeding the "
                f"persistent cache's max_len ({self.max_len})")
        if request.adapter_id is not None and self.bank is None:
            raise ValueError("request has an adapter_id but the engine "
                             "has no bank")
        rid = self.queue.push(request, arrival)
        self.metrics.on_arrival(rid, float(arrival))
        self.metrics.queue_depth = len(self.queue)
        return rid

    def reset_metrics(self) -> None:
        """Fresh per-run metrics AND a rewound decode-step clock for a new
        trace replay (compiled graphs stay warm). Only meaningful between
        drains — rewinding under live requests would corrupt their stamps.
        The monotonic cumulative counters (requests admitted/cancelled/…,
        ServingMetrics.COUNTERS) carry over: a /metrics scrape must never
        see them dip."""
        if self.slots.any_active() or len(self.queue):
            raise RuntimeError("reset_metrics with requests in flight")
        self.metrics = ServingMetrics(carry=self.metrics)
        self.t = 0.0

    # ---- admission --------------------------------------------------------
    def _ensure_resident(self, sr: ScheduledRequest) -> bool:
        """Make the request's tenant bank-resident (admission side effect).
        False = defer: the bank is full of pinned (live) tenants."""
        aid = sr.request.adapter_id
        if aid is None:
            return True
        if aid in self.bank.resident_ids:
            self.bank.touch(aid)
            return True
        pinned = [a for a in self.slots.adapter_ids() if a is not None]
        try:
            self.bank.load_from_checkpoint(aid, pinned=pinned)
        except BankFullError:
            return False
        return True

    def _try_admit(self, sr: ScheduledRequest) -> bool:
        """Admission callback for the queue: bank residency first, then (if
        paged) the page plan — matching the prefix cache and allocating the
        slot's worst-case pages up-front, so decode never allocates. False
        defers the request without head-of-line blocking the queue."""
        if not self._ensure_resident(sr):
            return False
        if self.pager is not None:
            memo = self._prefix_keys.get(sr.rid)
            if memo is None:                     # hash + host-copy once;
                prompt = np.asarray(sr.request.prompt)   # deferred requests
                memo = (prompt, PrefixCache.chain_keys(  # are re-offered
                    prompt, self.pager.page_size,        # every cycle
                    sr.request.adapter_id))
                self._prefix_keys[sr.rid] = memo
            prompt, keys = memo
            plan = self.pager.plan_admit(
                self.slots.free_slots()[0], prompt, sr.request.max_new,
                adapter_id=sr.request.adapter_id, keys=keys)
            if plan is None:
                return False
            self._plans[sr.rid] = plan
            self._prefix_keys.pop(sr.rid, None)
        return True

    def _release_pages(self, slot: int, snapshot) -> None:
        """SlotManager release hook: a recycled slot frees its pages the
        same scheduler step its request completes."""
        if self.pager is not None:
            self.pager.release(slot)

    def _bucketed_prompt(self, tokens, n: int) -> Tuple[int, Dict]:
        """(padded length P, {tokens, true_len?}) for a batch-1 prefill:
        pow2-bucketed, clamped to max_len (the bucket of a near-max prompt
        can overshoot a non-pow2 cache), `true_len` present iff padded —
        the ONE place both prime flavors get their prefill shapes from."""
        P = min(_bucket(n), self.max_len) if self.bucket else n
        batch: Dict = {"tokens":
                       jnp.zeros((1, P), jnp.int32).at[0, :n].set(tokens)}
        if P != n:
            batch["true_len"] = jnp.full((1,), n, jnp.int32)
        return P, batch

    def _prime(self, sr: ScheduledRequest, slot: int) -> int:
        """In-flight prefill: run the prompt through a batch-1 scratch
        prefill and splice its KV into `slot` of the live cache. Returns the
        first generated token. On the paged cache, only the UNSHARED TAIL of
        the prompt is computed (`Model.prefill_paged`): reused prefix pages
        enter the tail's attention through the block-table window, after the
        COW clone when the plan calls for one."""
        prompt = sr.request.prompt
        params = self.engine.params
        extra: Dict = {}
        if self.bank is not None:
            extra["adapter_slots"] = self.bank.slot_rows(
                [sr.request.adapter_id], 1)
            params = {**params, "bank": self.bank.params}
        t0 = time.perf_counter()
        if self.pager is not None:
            plan = self._plans.pop(sr.rid)
            if plan.cow is not None:
                self.cache = self._copy_page(self.cache, *plan.cow)
            _, batch = self._bucketed_prompt(jnp.asarray(plan.tail),
                                             int(plan.tail.shape[0]))
            batch.update(block_table=jnp.asarray(plan.block_row[None]),
                         slot=jnp.int32(slot),
                         scratch_page=jnp.int32(plan.scratch_page), **extra)
            if plan.prefix_len:
                # warm prime: the attention window gathers only the pow2
                # bucket of the PREFIX pages (compile count stays log-
                # bounded) — not the full pages_per_seq window, which would
                # cost O(tail * max_len) at long max_len. Cold primes omit
                # both keys and take the statically window-free graph.
                ps = self.pager.page_size
                wp = min(_bucket(-(-plan.prefix_len // ps), lo=1),
                         self.pager.pages_per_seq)
                batch["window_table"] = jnp.asarray(
                    plan.block_row[None, :wp])
                batch["prefix_len"] = jnp.int32(plan.prefix_len)
            nt, self.cache = self._prefill_paged(params, self.cache, batch)
        else:
            S = int(prompt.shape[0])
            P, batch = self._bucketed_prompt(prompt, S)
            batch.update(extra)
            scratch = self.model.init_cache(1, P, dtype=self._cache_dtype)
            nt, scratch = self._prefill(params, scratch, batch)
            self.cache = self._write(
                self.cache, {"k": scratch["k"], "v": scratch["v"]}, slot, S)
        tok = int(np.asarray(nt).reshape(-1)[0])
        if self.pager is not None:
            # publish the prompt's chunks for future sharing only past the
            # host sync above (async dispatch errors surface there) — a
            # failed prime must not leave prefix-cache entries pointing at
            # never-filled pages
            self.pager.register_prompt(plan)
        self.metrics.on_prime(sr.rid, time.perf_counter() - t0)
        return tok

    def _admit_ready(self) -> Iterator[Event]:
        while self.slots.free_slots() and len(self.queue):
            resident = self.bank.resident_ids if self.bank else ()
            sr = self.queue.pop_next(self.t, self._try_admit,
                                     resident=resident)
            if sr is None:
                return
            plan = self._plans.get(sr.rid)
            slot = self.slots.acquire(sr.rid, budget=sr.request.max_new,
                                      adapter_id=sr.request.adapter_id,
                                      prompt_len=int(sr.request.prompt.shape[0]),
                                      slot=plan.slot if plan else None)
            self._sr[slot] = sr
            self.metrics.on_admit(sr.rid, self.t)
            tok = self._prime(sr, slot)
            self._outs[sr.rid] = [tok]
            self._last[slot] = tok
            if self._toks_dev is not None:
                # mid-buffer admission: in-flight slots' next tokens live
                # only on device, so splice the new slot's first token in
                # instead of rebuilding from the (stale) host view
                self._toks_dev = self._toks_dev.at[slot, 0].set(tok)
            if self.drafter is not None:
                self.drafter.on_prime(slot, np.asarray(sr.request.prompt),
                                      tok)
            self.metrics.on_token(sr.rid, self.t)
            yield ("admit", sr.rid, slot, self.t)
            yield ("token", sr.rid, tok, self.t)
            if self.slots.note_token(slot, tok):
                yield self._finish(slot)

    def _finish(self, slot: int, t: Optional[float] = None) -> Event:
        t = self.t if t is None else t
        sr = self._sr[slot]
        self._sr[slot] = None
        self._last[slot] = 0
        self.slots.release(slot)
        self._stale.add(slot)          # reset is batched into the next step
        if self.drafter is not None:
            self.drafter.on_release(slot)
        toks = self._outs.pop(sr.rid)
        sr.request.out = toks
        self.metrics.on_finish(sr.rid, t)
        return ("done", sr.rid, toks, t)

    # ---- cancellation ------------------------------------------------------
    def cancel(self, rid: int) -> bool:
        """Abort request `rid` wherever it is — the client-disconnect path
        (DESIGN.md §Gateway). A queued request is withdrawn; an ACTIVE one
        releases its slot THIS step: `SlotManager.release` fires the
        on_release hook (freeing the slot's KV pages), the tenant's bank
        row is unpinned the moment the slot leaves `slots.adapter_ids()`,
        and any not-yet-drained buffered tokens for the slot are discarded
        by the drain's occupancy check (the same mechanism that drops
        post-EOS overshoot). Returns True iff the request was found live;
        its `.out` holds the tokens emitted before the abort."""
        sr = self.queue.remove(rid)
        if sr is not None:                     # still queued: never admitted
            self._prefix_keys.pop(rid, None)
            sr.request.out = []
            self.metrics.on_cancel(rid, self.t)
            self.metrics.queue_depth = len(self.queue)
            return True
        for slot in self.slots.active_slots():
            sr = self._sr[slot]
            if sr is None or sr.rid != rid:
                continue
            self._sr[slot] = None              # buffered overshoot for this
            self._last[slot] = 0               # slot now drains to nowhere
            self.slots.release(slot)           # frees pages via on_release
            self._stale.add(slot)
            if self.drafter is not None:
                self.drafter.on_release(slot)
            sr.request.out = self._outs.pop(rid, [])
            if not self.slots.any_active():
                # nothing left to drain for: drop the buffered-decode state
                # now instead of carrying dead device work into the next
                # admission cycle
                self._pending.clear()
                self._flag_dev = None
                self._flag_prev = None
            self.metrics.on_cancel(rid, self.t)
            return True
        return False

    # ---- decode -----------------------------------------------------------
    def _flush_stale(self) -> None:
        """One batched reset for slots freed since the last step; slots that
        were already re-primed (write_slot set their position) drop out."""
        stale = self._stale & set(self.slots.free_slots())
        self._stale.clear()
        if stale:
            mask = np.zeros((self.n_slots,), bool)
            mask[list(stale)] = True
            self.cache = self._reset(self.cache, mask)

    def _batch_inputs(self) -> Tuple[Dict, Dict]:
        """(params, extra) for a full-batch decode/verify dispatch."""
        params, extra = self.engine.params, {}
        if self.pager is not None:
            extra["block_table"] = self.pager.block_table_device()
        if self.bank is not None:
            extra["adapter_slots"] = self.bank.slot_rows(
                self.slots.adapter_ids(), self.n_slots)
            params = {**params, "bank": self.bank.params}
        return params, extra

    def _min_budget_left(self) -> int:
        """Tokens until the EARLIEST budget completion among active slots,
        counted from the last drain — once the buffer holds that many
        steps, a completion is inside it and must be processed (so
        budget-only traffic drains at exactly its completion steps and
        keeps the unbuffered loop's scheduling timing)."""
        budgets = [self.slots.state(s).budget
                   for s in self.slots.active_slots()]
        return min(budgets) if budgets else 0

    def _decode_once(self) -> Iterator[Event]:
        self._flush_stale()
        active = self.slots.active_slots()
        params, extra = self._batch_inputs()
        if self._toks_dev is None:
            self._toks_dev = self.engine.commit_tokens(
                np.asarray(self._last, np.int32)[:, None])
        nt, self.cache = self._decode(params, self.cache,
                                      {"tokens": self._toks_dev, **extra})
        # feed the device output straight back as the next step's input —
        # the host never sees tokens until a drain
        self._toks_dev = nt[:, None]
        self.t += 1
        self.metrics.on_step(len(active), self.n_slots)
        self._pending.append((self.t, nt, [(s, self._sr[s]) for s in active]))
        sync = self._min_budget_left() <= len(self._pending)
        if self.eos_id is not None:
            if self._flag_dev is None:
                self._flag_dev = jnp.zeros((self.n_slots,), jnp.bool_)
            self._flag_dev = self._or_eos(self._flag_dev, nt)
            # the PREVIOUS flag snapshot has had a full decode dispatch to
            # come back (copy_to_host_async below) — reading it now is
            # effectively free, and one step of detection latency only
            # delays the drain, never correctness
            if self._flag_prev is not None \
                    and bool(np.asarray(self._flag_prev).any()):
                sync = True
            self._flag_dev.copy_to_host_async()
            self._flag_prev = self._flag_dev
            if len(self._pending) >= self.eos_sync_every:
                sync = True
        if sync:
            yield from self._drain()

    def _drain(self) -> Iterator[Event]:
        """Fetch every buffered step's tokens in ONE device transfer and
        replay them through the per-token accounting, stamped with their
        original step times. Slots that complete mid-buffer stop
        contributing from that step on (their later buffered tokens — the
        decode overshoot — are discarded, exactly what the unbuffered loop
        never generated; the device rows were dirt past their kv_len)."""
        if not self._pending:
            return
        pending, self._pending = self._pending, []
        self._flag_dev = None
        self._flag_prev = None
        # THE drain: one transfer per buffer  # repro: allow(host-sync)
        arr = np.asarray(jnp.stack([nt for _, nt, _ in pending]))
        for i, (t, _, occupants) in enumerate(pending):
            for slot, sr in occupants:
                if self._sr[slot] is not sr:   # finished at an earlier step
                    continue
                tok = int(arr[i, slot])
                self._outs[sr.rid].append(tok)
                self._last[slot] = tok
                self.metrics.on_token(sr.rid, t)
                yield ("token", sr.rid, tok, t)
                if self.slots.note_token(slot, tok):
                    yield self._finish(slot, t)

    # ---- speculative decode (DESIGN.md §Speculation) ----------------------
    def _spec_decode_once(self) -> Iterator[Event]:
        """One draft-then-verify step: the drafter proposes k tokens per
        slot, ONE `verify_step` forward scores the (n_slots, k+1) window,
        and each active slot accepts the longest prefix greedy decoding
        would have emitted — token j is kept iff draft j matched the
        model's own output after token j-1, with EOS and budget clamping
        anywhere inside the window. Accepted counts commit to the device
        `pos` via `advance_pos` (0 for FREE slots); rejected rows stay
        past kv_len as dirt the next window overwrites."""
        self._flush_stale()
        active = self.slots.active_slots()
        params, extra = self._batch_inputs()
        if self.pager is not None:
            extra["scratch_pages"] = self._scratch_pages
        k = self.drafter.k
        # drafters propose on device (SelfDrafter) or host (NGramDrafter);
        # the window assembles on device either way, and the host reads the
        # window AND the verify scores in ONE transfer after dispatch —
        # previously this synced twice per step (once on propose, once on
        # the scores)
        drafts = jnp.asarray(self.drafter.propose(), jnp.int32)
        last = jnp.asarray(np.asarray(self._last, np.int32))
        win_dev = jnp.concatenate([last[:, None], drafts], axis=1)
        out, self.cache = self._verify(params, self.cache,
                                       {"tokens": win_dev, **extra})
        self.t += 1
        self.metrics.on_step(len(active), self.n_slots)
        # the step's single intended sync point  # repro: allow(host-sync)
        wa = np.asarray(jnp.concatenate([win_dev, out], axis=1))
        win, arr = wa[:, :k + 1], wa[:, k + 1:]
        deltas = np.zeros((self.n_slots,), np.int32)
        for slot in active:
            sr = self._sr[slot]
            # greedy acceptance: token j is valid iff draft j matched the
            # model's own continuation after token j-1 (token 0 is the
            # mandatory next token — always valid)
            accepted = [int(arr[slot, 0])]
            for j in range(1, k + 1):
                if win[slot, j] != arr[slot, j - 1]:
                    break
                accepted.append(int(arr[slot, j]))
            n_emit, done = self.slots.note_window(slot, accepted)
            emitted = accepted[:n_emit]         # budget/EOS clamp
            for tok in emitted:
                self._outs[sr.rid].append(tok)
                self._last[slot] = tok
                self.metrics.on_token(sr.rid, self.t)
                yield ("token", sr.rid, tok, self.t)
            deltas[slot] = n_emit
            self.drafter.on_tokens(slot, emitted)
            self.metrics.on_spec(sr.rid, drafted=k, accepted=n_emit - 1,
                                 emitted=n_emit)
            if done:
                yield self._finish(slot)
        self.cache = self._advance(self.cache, jnp.asarray(deltas))

    # ---- static-analysis surface (repro.analysis, DESIGN.md §Analysis) ----
    def compiled_signatures(self) -> Dict[str, int]:
        """Compiled-signature count per jitted graph this scheduler
        dispatches (jit cache sizes — no tracing, safe anytime). Note the
        decode/prefill entries are the ENGINE's shared jits: a fresh Engine
        per scheduler keeps the counts attributable to this scheduler."""
        out = {"decode": int(self._decode._cache_size()),
               "reset": int(self._reset._cache_size()),
               "advance": int(self._advance._cache_size()),
               "write": int(self._write._cache_size())}
        if self.pager is not None:
            out["prefill_paged"] = int(self._prefill_paged._cache_size())
            out["copy_page"] = int(self._copy_page._cache_size())
        else:
            out["prefill"] = int(self._prefill._cache_size())
        if self.drafter is not None:
            out["verify"] = int(self._verify._cache_size())
        if self.eos_id is not None:
            out["or_eos"] = int(self._or_eos._cache_size())
        return out

    def expected_compile_bounds(self) -> Dict[str, int]:
        """The compile-count CONTRACT the pow2 bucketing declares, keyed
        like `compiled_signatures()`. decode/verify run at one fixed
        (n_slots, ·) shape → exactly 1 graph regardless of churn; prime
        prefills compile per pow2 prompt bucket (× cold + pow2 prefix-
        window buckets when paged). With `bucket=False` prefill compiles
        per distinct prompt length — unbounded by design — so no prefill
        bound is declared and the analyzer skips it."""
        bounds = {"decode": 1, "reset": 1, "advance": 1}
        if self.drafter is not None:
            bounds["verify"] = 1
            # scalar rollback (drafter probe) + (B,) accept-commit deltas
            bounds["advance"] = 2
        if self.eos_id is not None:
            bounds["or_eos"] = 1
        if self.pager is not None:
            bounds["copy_page"] = 1
            bounds["write"] = 0            # dense-path graph, unused here
        if self.bucket:
            # pow2 buckets in [8, _bucket(max_len)]
            n_len = _bucket(self.max_len).bit_length() - 3
            if self.pager is not None:
                # pow2 warm prefix-window widths in [1, _bucket(pages)]
                wins = _bucket(self.pager.pages_per_seq, lo=1).bit_length()
                bounds["prefill_paged"] = n_len * (1 + wins)
            else:
                bounds["prefill"] = n_len
                bounds["write"] = n_len    # scratch k/v shape per bucket
        return bounds

    # ---- main loop --------------------------------------------------------
    def tick(self) -> List[Event]:
        """ONE scheduler round — admit every admissible arrived request,
        then (if anything is decoding) one decode/verify step — returning
        the round's events. Returns [] when there is nothing to do right
        now: the queue is empty or its head hasn't arrived yet (the round
        idle-skips the clock to the next arrival), or every arrived request
        is deferred on resources. Unlike `events()`, tick() never raises on
        an un-admittable backlog: under live traffic a later round can free
        what admission waits on (a disconnect cancels a slot, a drain
        unpins a tenant), so the async gateway pumps this from its own
        loop (serve/gateway/bridge.py) and decides idleness itself."""
        evs: List[Event] = list(self._admit_ready())
        if self.slots.any_active():
            if self.drafter is not None:
                evs.extend(self._spec_decode_once())
            else:
                evs.extend(self._decode_once())
        else:
            nxt = self.queue.next_arrival()
            if nxt is not None and nxt > self.t:
                self.t = nxt           # idle: skip to the next arrival
        self.metrics.queue_depth = len(self.queue)
        return evs

    def events(self) -> Iterator[Event]:
        """Drain the queue: admit -> decode -> recycle until no request is
        pending or in flight, yielding the event stream. Re-entrant across
        drains (the persistent cache and clock carry over), but only one
        events() iterator may be live at a time."""
        self.metrics.start()
        try:
            while len(self.queue) or self.slots.any_active():
                t_before = self.t
                evs = self.tick()
                yield from evs
                if not evs and not self.slots.any_active() \
                        and self.t == t_before and len(self.queue):
                    # no admission, no decode, no idle-skip progress, yet
                    # requests remain: a replay can never free what they
                    # wait on (live traffic can — see tick())
                    raise RuntimeError(
                        "scheduler stalled: arrived requests cannot be "
                        "admitted although every slot is free")
        finally:
            self.metrics.stop()

    def serve(self, requests: Sequence[Request],
              arrivals: Optional[Sequence[float]] = None) -> List[Request]:
        """Traffic replay: submit every request (arrivals on the decode-step
        clock, default all-at-0) and drain. Returns the requests with `.out`
        filled, in input order."""
        if arrivals is not None and len(arrivals) != len(requests):
            raise ValueError(f"{len(arrivals)} arrivals for "
                             f"{len(requests)} requests")
        for i, r in enumerate(requests):
            self.submit(r, arrivals[i] if arrivals is not None else 0.0)
        for _ in self.events():
            pass
        return list(requests)
