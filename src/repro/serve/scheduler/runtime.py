"""Continuous-batching runtime over the slot Engine (DESIGN.md §Scheduler).

One persistent fixed-shape KV cache: by default a PAGED cache (DESIGN.md
§Paging) — K/V in a global pool of fixed-size pages, each slot mapping its
logical positions onto pages through a block-table row, with page-aligned
prompt prefixes reused across requests (same tenant / bare base) so the
prime prefill computes only the unshared tail; `paged=False` keeps the
dense per-slot cache (`Model.init_cache(..., per_slot=True)`). Either way
every slot decodes at its own position/ragged kv_len, requests are
admitted into FREE slots the moment a slot, the tenant's bank row, AND (if
paged) the request's worst-case page count are available, and a slot is
recycled — its pages freed — the very step its request completes.
In-flight prefill primes a single slot while the other slots keep
decoding. All steady-state shapes are fixed: the decode graph NEVER
recompiles as requests come and go (the block table is a same-shape array
per call); prefill/splice compile once per pow2 prompt bucket.

Admission is adapter-bank-aware: a request's tenant is touched when
resident, loaded via `load_from_checkpoint` when not, with the tenants of
live slots pinned against LRU eviction (evicting one would zero the bank
row under a decoding batch). A request whose tenant cannot be made
resident right now waits, without head-of-line blocking the rest of the
queue.

Outputs are EXACT per request — bit-identical (fp32) to
`Engine.generate` run one request at a time: the prime prefill computes the
prompt at its true positions (`true_len` logits gather), pad-tail KV rows
are never readable (per-slot kv_len), and every decode einsum is
row-parallel.
"""
from __future__ import annotations

import time
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.engine import BankFullError, Engine, Request
from repro.serve.paging import PagedKVCache, PrefixCache, PrimePlan
from repro.serve.scheduler.metrics import ServingMetrics
from repro.serve.scheduler.queue import RequestQueue, ScheduledRequest
from repro.serve.scheduler.slots import SlotManager

Event = Tuple  # ("admit", rid, slot, t) | ("token", rid, tok, t) | ("done", rid, toks, t)


def _bucket(n: int, lo: int = 8) -> int:
    """Next power of two >= n (floored at `lo`): bounds prime-prefill
    compilations at log2(max_len) graphs under arbitrary prompt lengths."""
    b = lo
    while b < n:
        b <<= 1
    return b


class ContinuousScheduler:
    """Continuous-batching front end over an Engine's model/params/bank.

    eos_id:  optional stop token — a slot completes on emitting it (the
             token is included in the output). Forces one host sync per
             decode step; budget-only traffic stays async.
    policy:  RequestQueue admission order ("fcfs" | "resident_first").
    bucket:  pad prime prefills to pow2 prompt buckets (bounded compile
             count); False compiles per distinct prompt length instead.
    paged:   block-table page-pool cache with shared-prefix reuse
             (DESIGN.md §Paging; the default) vs the dense per-slot cache.
             Outputs are bit-identical (fp32) either way.
    page_size / n_pages: paged-cache geometry (n_pages defaults to the
             zero-sharing worst case plus prefix-cache headroom, see
             serve/paging.PagedKVCache).

    Streaming API: `events()` yields ("admit", rid, slot, t),
    ("token", rid, token, t) and ("done", rid, tokens, t) tuples as they
    happen; `serve(requests, arrivals)` replays a trace and returns the
    requests with `.out` filled. `metrics` accumulates TTFT / occupancy /
    tokens-per-s (ServingMetrics).
    """

    def __init__(self, engine: Engine, eos_id: Optional[int] = None,
                 policy: str = "fcfs", bucket: bool = True,
                 paged: bool = True, page_size: int = 16,
                 n_pages: Optional[int] = None):
        if not engine.model.supports_slot_cache:
            raise NotImplementedError(
                f"{engine.model.cfg.name}: continuous batching needs the "
                "per-slot cache path (token-input transformer families)")
        self.engine = engine
        self.model = engine.model
        self.bank = engine.bank
        self.n_slots = engine.batch
        self.max_len = engine.max_len
        self.eos_id = eos_id
        self.bucket = bucket
        self.queue = RequestQueue(policy)
        self.pager: Optional[PagedKVCache] = None
        if paged:
            self.pager = PagedKVCache(self.n_slots, self.max_len,
                                      page_size=page_size, n_pages=n_pages)
        self.slots = SlotManager(self.n_slots, eos_id=eos_id,
                                 on_release=self._release_pages)
        self.metrics = ServingMetrics()
        self.t = 0.0                           # decode-step clock
        self._decode = engine._decode          # shared jit: per-slot trace
        self._prefill = engine._prefill        # shared jit: (1, P) traces
        self._write = jax.jit(self.model.write_slot, donate_argnums=(0,))
        self._reset = jax.jit(self.model.reset_slots, donate_argnums=(0,))
        if paged:
            self.cache = engine._fresh_cache(
                paged=True, page_size=self.pager.page_size,
                n_pages=self.pager.n_pages)
            self._prefill_paged = jax.jit(self.model.prefill_paged,
                                          donate_argnums=(1,))
            self._copy_page = jax.jit(self.model.copy_page,
                                      donate_argnums=(0,))
        else:
            self.cache = engine._fresh_cache(per_slot=True)
        self._cache_dtype = jnp.dtype(self.model.cfg.dtype)
        self._sr: List[Optional[ScheduledRequest]] = [None] * self.n_slots
        self._plans: Dict[int, PrimePlan] = {}
        self._prefix_keys: Dict[int, list] = {}   # rid -> memoized hashes
        self._last = [0] * self.n_slots        # per-slot last token (host)
        self._outs: Dict[int, List[int]] = {}
        self._stale = set()                    # freed, not yet reset slots

    # ---- submission -------------------------------------------------------
    def submit(self, request: Request, arrival: float = 0.0) -> int:
        """Queue a request; `arrival` is on the decode-step clock (traffic
        replay). Returns the request id used in events/metrics."""
        if request.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {request.max_new}")
        S = int(request.prompt.shape[0])
        if S < 1:
            raise ValueError("empty (length-0) prompt")
        # cache-position bound (slots.py invariant: the LAST generated token
        # is never written, so the final position used is S + max_new - 2
        # and the deepest read is kv_len = S + max_new - 1). The previous
        # `S + max_new > max_len` guard rejected feasible requests by one
        # token — a request may generate through exactly max_len positions.
        if S + request.max_new - 1 > self.max_len:
            raise ValueError(
                f"prompt ({S}) + max_new ({request.max_new}) needs "
                f"{S + request.max_new - 1} cache positions, exceeding the "
                f"persistent cache's max_len ({self.max_len})")
        if request.adapter_id is not None and self.bank is None:
            raise ValueError("request has an adapter_id but the engine "
                             "has no bank")
        rid = self.queue.push(request, arrival)
        self.metrics.on_arrival(rid, float(arrival))
        return rid

    def reset_metrics(self) -> None:
        """Fresh metrics AND a rewound decode-step clock for a new trace
        replay (compiled graphs stay warm). Only meaningful between drains —
        rewinding under live requests would corrupt their stamps."""
        if self.slots.any_active() or len(self.queue):
            raise RuntimeError("reset_metrics with requests in flight")
        self.metrics = ServingMetrics()
        self.t = 0.0

    # ---- admission --------------------------------------------------------
    def _ensure_resident(self, sr: ScheduledRequest) -> bool:
        """Make the request's tenant bank-resident (admission side effect).
        False = defer: the bank is full of pinned (live) tenants."""
        aid = sr.request.adapter_id
        if aid is None:
            return True
        if aid in self.bank.resident_ids:
            self.bank.touch(aid)
            return True
        pinned = [a for a in self.slots.adapter_ids() if a is not None]
        try:
            self.bank.load_from_checkpoint(aid, pinned=pinned)
        except BankFullError:
            return False
        return True

    def _try_admit(self, sr: ScheduledRequest) -> bool:
        """Admission callback for the queue: bank residency first, then (if
        paged) the page plan — matching the prefix cache and allocating the
        slot's worst-case pages up-front, so decode never allocates. False
        defers the request without head-of-line blocking the queue."""
        if not self._ensure_resident(sr):
            return False
        if self.pager is not None:
            memo = self._prefix_keys.get(sr.rid)
            if memo is None:                     # hash + host-copy once;
                prompt = np.asarray(sr.request.prompt)   # deferred requests
                memo = (prompt, PrefixCache.chain_keys(  # are re-offered
                    prompt, self.pager.page_size,        # every cycle
                    sr.request.adapter_id))
                self._prefix_keys[sr.rid] = memo
            prompt, keys = memo
            plan = self.pager.plan_admit(
                self.slots.free_slots()[0], prompt, sr.request.max_new,
                adapter_id=sr.request.adapter_id, keys=keys)
            if plan is None:
                return False
            self._plans[sr.rid] = plan
            self._prefix_keys.pop(sr.rid, None)
        return True

    def _release_pages(self, slot: int, snapshot) -> None:
        """SlotManager release hook: a recycled slot frees its pages the
        same scheduler step its request completes."""
        if self.pager is not None:
            self.pager.release(slot)

    def _bucketed_prompt(self, tokens, n: int) -> Tuple[int, Dict]:
        """(padded length P, {tokens, true_len?}) for a batch-1 prefill:
        pow2-bucketed, clamped to max_len (the bucket of a near-max prompt
        can overshoot a non-pow2 cache), `true_len` present iff padded —
        the ONE place both prime flavors get their prefill shapes from."""
        P = min(_bucket(n), self.max_len) if self.bucket else n
        batch: Dict = {"tokens":
                       jnp.zeros((1, P), jnp.int32).at[0, :n].set(tokens)}
        if P != n:
            batch["true_len"] = jnp.full((1,), n, jnp.int32)
        return P, batch

    def _prime(self, sr: ScheduledRequest, slot: int) -> int:
        """In-flight prefill: run the prompt through a batch-1 scratch
        prefill and splice its KV into `slot` of the live cache. Returns the
        first generated token. On the paged cache, only the UNSHARED TAIL of
        the prompt is computed (`Model.prefill_paged`): reused prefix pages
        enter the tail's attention through the block-table window, after the
        COW clone when the plan calls for one."""
        prompt = sr.request.prompt
        params = self.engine.params
        extra: Dict = {}
        if self.bank is not None:
            extra["adapter_slots"] = self.bank.slot_rows(
                [sr.request.adapter_id], 1)
            params = {**params, "bank": self.bank.params}
        t0 = time.perf_counter()
        if self.pager is not None:
            plan = self._plans.pop(sr.rid)
            if plan.cow is not None:
                self.cache = self._copy_page(self.cache, *plan.cow)
            _, batch = self._bucketed_prompt(jnp.asarray(plan.tail),
                                             int(plan.tail.shape[0]))
            batch.update(block_table=jnp.asarray(plan.block_row[None]),
                         slot=jnp.int32(slot),
                         scratch_page=jnp.int32(plan.scratch_page), **extra)
            if plan.prefix_len:
                # warm prime: the attention window gathers only the pow2
                # bucket of the PREFIX pages (compile count stays log-
                # bounded) — not the full pages_per_seq window, which would
                # cost O(tail * max_len) at long max_len. Cold primes omit
                # both keys and take the statically window-free graph.
                ps = self.pager.page_size
                wp = min(_bucket(-(-plan.prefix_len // ps), lo=1),
                         self.pager.pages_per_seq)
                batch["window_table"] = jnp.asarray(
                    plan.block_row[None, :wp])
                batch["prefix_len"] = jnp.int32(plan.prefix_len)
            nt, self.cache = self._prefill_paged(params, self.cache, batch)
        else:
            S = int(prompt.shape[0])
            P, batch = self._bucketed_prompt(prompt, S)
            batch.update(extra)
            scratch = self.model.init_cache(1, P, dtype=self._cache_dtype)
            nt, scratch = self._prefill(params, scratch, batch)
            self.cache = self._write(
                self.cache, {"k": scratch["k"], "v": scratch["v"]}, slot, S)
        tok = int(np.asarray(nt).reshape(-1)[0])
        if self.pager is not None:
            # publish the prompt's chunks for future sharing only past the
            # host sync above (async dispatch errors surface there) — a
            # failed prime must not leave prefix-cache entries pointing at
            # never-filled pages
            self.pager.register_prompt(plan)
        self.metrics.on_prime(sr.rid, time.perf_counter() - t0)
        return tok

    def _admit_ready(self) -> Iterator[Event]:
        while self.slots.free_slots() and len(self.queue):
            resident = self.bank.resident_ids if self.bank else ()
            sr = self.queue.pop_next(self.t, self._try_admit,
                                     resident=resident)
            if sr is None:
                return
            plan = self._plans.get(sr.rid)
            slot = self.slots.acquire(sr.rid, budget=sr.request.max_new,
                                      adapter_id=sr.request.adapter_id,
                                      prompt_len=int(sr.request.prompt.shape[0]),
                                      slot=plan.slot if plan else None)
            self._sr[slot] = sr
            self.metrics.on_admit(sr.rid, self.t)
            tok = self._prime(sr, slot)
            self._outs[sr.rid] = [tok]
            self._last[slot] = tok
            self.metrics.on_token(sr.rid, self.t)
            yield ("admit", sr.rid, slot, self.t)
            yield ("token", sr.rid, tok, self.t)
            if self.slots.note_token(slot, tok):
                yield self._finish(slot)

    def _finish(self, slot: int) -> Event:
        sr = self._sr[slot]
        self._sr[slot] = None
        self._last[slot] = 0
        self.slots.release(slot)
        self._stale.add(slot)          # reset is batched into the next step
        toks = self._outs.pop(sr.rid)
        sr.request.out = toks
        self.metrics.on_finish(sr.rid, self.t)
        return ("done", sr.rid, toks, self.t)

    # ---- decode -----------------------------------------------------------
    def _flush_stale(self) -> None:
        """One batched reset for slots freed since the last step; slots that
        were already re-primed (write_slot set their position) drop out."""
        stale = self._stale & set(self.slots.free_slots())
        self._stale.clear()
        if stale:
            mask = np.zeros((self.n_slots,), bool)
            mask[list(stale)] = True
            self.cache = self._reset(self.cache, mask)

    def _decode_once(self) -> Iterator[Event]:
        self._flush_stale()
        active = self.slots.active_slots()
        params, extra = self.engine.params, {}
        if self.pager is not None:
            extra["block_table"] = self.pager.block_table_device()
        if self.bank is not None:
            extra["adapter_slots"] = self.bank.slot_rows(
                self.slots.adapter_ids(), self.n_slots)
            params = {**params, "bank": self.bank.params}
        toks = jnp.asarray(np.asarray(self._last, np.int32)[:, None])
        nt, self.cache = self._decode(params, self.cache,
                                      {"tokens": toks, **extra})
        self.t += 1
        self.metrics.on_step(len(active), self.n_slots)
        arr = np.asarray(nt)
        for slot in active:
            sr = self._sr[slot]
            tok = int(arr[slot])
            self._outs[sr.rid].append(tok)
            self._last[slot] = tok
            self.metrics.on_token(sr.rid, self.t)
            yield ("token", sr.rid, tok, self.t)
            if self.slots.note_token(slot, tok):
                yield self._finish(slot)

    # ---- main loop --------------------------------------------------------
    def events(self) -> Iterator[Event]:
        """Drain the queue: admit -> decode -> recycle until no request is
        pending or in flight, yielding the event stream. Re-entrant across
        drains (the persistent cache and clock carry over), but only one
        events() iterator may be live at a time."""
        self.metrics.start()
        try:
            while len(self.queue) or self.slots.any_active():
                yield from self._admit_ready()
                if not self.slots.any_active():
                    nxt = self.queue.next_arrival()
                    if nxt is None:
                        break
                    if nxt > self.t:       # idle: skip to the next arrival
                        self.t = nxt
                        continue
                    raise RuntimeError(
                        "scheduler stalled: arrived requests cannot be "
                        "admitted although every slot is free")
                yield from self._decode_once()
        finally:
            self.metrics.stop()

    def serve(self, requests: Sequence[Request],
              arrivals: Optional[Sequence[float]] = None) -> List[Request]:
        """Traffic replay: submit every request (arrivals on the decode-step
        clock, default all-at-0) and drain. Returns the requests with `.out`
        filled, in input order."""
        if arrivals is not None and len(arrivals) != len(requests):
            raise ValueError(f"{len(arrivals)} arrivals for "
                             f"{len(requests)} requests")
        for i, r in enumerate(requests):
            self.submit(r, arrivals[i] if arrivals is not None else 0.0)
        for _ in self.events():
            pass
        return list(requests)
