"""Serving metrics for the continuous runtime (DESIGN.md §Scheduler):
per-request TTFT, per-step batch occupancy, end-to-end tokens/s.

Step-denominated stamps (arrival/admit/first token/finish) use the
scheduler's decode-step clock — deterministic, replay-stable, and what the
admission policy actually trades off. Wall-clock covers the whole drain
(prefills, bank loads, dispatch overhead), so tokens_per_s is honest
end-to-end throughput, not a per-step extrapolation.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence


def nearest_rank(sorted_vals: Sequence[float], q: float) -> float:
    """Nearest-rank quantile: the ceil(q*N)-th smallest value (1-indexed).
    Unlike the floor-index `vals[int(q*(N-1))]`, this never under-reports
    the tail at small N — e.g. p90 of 10 samples is the 9th, not the 8th,
    and p99 of any N < 100 is the maximum."""
    if not sorted_vals:
        return 0.0
    i = max(0, min(len(sorted_vals) - 1,
                   math.ceil(q * len(sorted_vals)) - 1))
    return sorted_vals[i]


@dataclass
class RequestMetrics:
    arrival: float
    priority: str = "batch"            # serve/tiering class
    preempted: int = 0                 # times this request was evicted
    admitted: Optional[float] = None
    first_token: Optional[float] = None
    finished: Optional[float] = None
    n_tokens: int = 0
    prime_s: Optional[float] = None    # wall-clock prime-prefill latency —
                                       # the TTFT component arrival gaps
                                       # can't hide (shared-prefix reuse
                                       # shrinks exactly this)
    drafted: int = 0                   # speculative: draft tokens offered
    accepted: int = 0                  # speculative: draft tokens accepted
                                       # (the mandatory verify token is
                                       # free and not counted here)

    @property
    def ttft_steps(self) -> Optional[float]:
        """Decode steps between arrival and first emitted token (the prime
        prefill emits it, so admission == first token on this clock)."""
        if self.first_token is None:
            return None
        return self.first_token - self.arrival

    @property
    def accept_rate(self) -> Optional[float]:
        """Fraction of this request's draft tokens the verifier accepted
        (None when it never went through a speculative step)."""
        if self.drafted == 0:
            return None
        return self.accepted / self.drafted


class ServingMetrics:
    # monotonic cumulative counters: never reset within a serving process.
    # `ServingMetrics(carry=old)` copies them forward, and the runtime's
    # reset_metrics() uses exactly that — so a /metrics scrape (gateway)
    # never sees a counter dip even across per-run percentile resets.
    COUNTERS = ("requests_submitted_total", "requests_admitted_total",
                "requests_finished_total", "requests_cancelled_total",
                "requests_rejected_total", "tokens_emitted_total",
                # tiering (DESIGN.md §Tiering)
                "preemptions_total", "preempt_swap_total",
                "preempt_recompute_total", "resumed_total",
                "kv_pages_spilled_total", "kv_pages_filled_total",
                "kv_fills_degraded_total",
                "prefix_host_hits_total", "adapter_spills_total",
                "adapter_host_hits_total")

    def __init__(self, carry: Optional["ServingMetrics"] = None):
        self.requests: Dict[int, RequestMetrics] = {}
        self.occupancy: List[float] = []       # active/slots per decode step
        self.steps = 0
        self.wall_s = 0.0
        self._t0: Optional[float] = None
        # speculative counters (DESIGN.md §Speculation): one sample per
        # ACTIVE slot per verify step
        self.spec_slot_steps = 0
        self.accepted_hist: Dict[int, int] = {}  # emitted-per-step -> count
        for name in self.COUNTERS:
            setattr(self, name, getattr(carry, name, 0) if carry else 0)
        self.queue_depth = 0                   # gauge: pending admissions

    # ---- lifecycle hooks (called by the runtime) --------------------------
    def start(self) -> None:
        if self._t0 is None:
            self._t0 = time.perf_counter()

    def stop(self) -> None:
        if self._t0 is not None:
            self.wall_s += time.perf_counter() - self._t0
            self._t0 = None

    def on_arrival(self, rid: int, t: float,
                   priority: str = "batch") -> None:
        self.requests[rid] = RequestMetrics(arrival=t, priority=priority)
        self.requests_submitted_total += 1

    def on_admit(self, rid: int, t: float) -> None:
        self.requests[rid].admitted = t
        self.requests_admitted_total += 1

    def on_token(self, rid: int, t: float) -> None:
        r = self.requests[rid]
        r.n_tokens += 1
        self.tokens_emitted_total += 1
        if r.first_token is None:
            r.first_token = t

    def on_prime(self, rid: int, seconds: float) -> None:
        self.requests[rid].prime_s = seconds

    def on_finish(self, rid: int, t: float) -> None:
        self.requests[rid].finished = t
        self.requests_finished_total += 1

    def on_cancel(self, rid: int, t: float) -> None:
        """A queued or mid-stream request was aborted (client disconnect,
        timeout): stamp it finished so per-run aggregates stay consistent,
        and count it separately from natural completions."""
        r = self.requests.get(rid)
        if r is not None and r.finished is None:
            r.finished = t
        self.requests_cancelled_total += 1

    def on_reject(self) -> None:
        """An admission-side rejection (gateway backpressure 429) — counted
        without a request record: the request never entered the queue."""
        self.requests_rejected_total += 1

    # ---- tiering hooks (DESIGN.md §Tiering) -------------------------------
    def on_preempt(self, rid: int, t: float, mode: str) -> None:
        """A victim slot was evicted for a higher-class candidate; `mode`
        is how its KV leaves the device ("swap" or "recompute")."""
        r = self.requests.get(rid)
        if r is not None:
            r.preempted += 1
        self.preemptions_total += 1
        if mode == "swap":
            self.preempt_swap_total += 1
        else:
            self.preempt_recompute_total += 1

    def on_resume(self, rid: int, t: float) -> None:
        self.resumed_total += 1

    def on_kv_spill(self, n_pages: int) -> None:
        self.kv_pages_spilled_total += n_pages

    def on_kv_fill(self, n_pages: int) -> None:
        self.kv_pages_filled_total += n_pages

    def on_kv_fill_degraded(self, n_pages: int) -> None:
        """Planned host fills that aged out of the pool before the promote
        (displaced by the same plan's demotions) — recomputed on device
        instead; the stream stays exact, only the fill saving is lost."""
        self.kv_fills_degraded_total += n_pages

    def on_prefix_host_hit(self, n_pages: int) -> None:
        self.prefix_host_hits_total += n_pages

    def on_adapter_spill(self) -> None:
        self.adapter_spills_total += 1

    def on_adapter_host_hit(self) -> None:
        self.adapter_host_hits_total += 1

    def on_step(self, active: int, slots: int) -> None:
        self.steps += 1
        self.occupancy.append(active / slots)

    def on_spec(self, rid: int, drafted: int, accepted: int,
                emitted: int) -> None:
        """One slot's outcome of one verify step: `drafted` tokens offered,
        `accepted` of them kept, `emitted` tokens recorded (accepted + the
        mandatory verify token, clamped by budget/EOS)."""
        r = self.requests[rid]
        r.drafted += drafted
        r.accepted += accepted
        self.spec_slot_steps += 1
        self.accepted_hist[emitted] = self.accepted_hist.get(emitted, 0) + 1

    # ---- aggregates -------------------------------------------------------
    @property
    def total_tokens(self) -> int:
        return sum(r.n_tokens for r in self.requests.values())

    def summary(self) -> Dict[str, float]:
        ttfts = sorted(r.ttft_steps for r in self.requests.values()
                       if r.ttft_steps is not None)
        primes = sorted(r.prime_s for r in self.requests.values()
                        if r.prime_s is not None)
        occ = self.occupancy
        wall = self.wall_s if self._t0 is None \
            else self.wall_s + (time.perf_counter() - self._t0)
        out = {
            "n_requests": len(self.requests),
            "total_tokens": self.total_tokens,
            "steps": self.steps,
            "occupancy_mean": sum(occ) / len(occ) if occ else 0.0,
            "ttft_steps_mean": sum(ttfts) / len(ttfts) if ttfts else 0.0,
            "ttft_steps_p50": nearest_rank(ttfts, 0.50),
            "ttft_steps_p90": nearest_rank(ttfts, 0.90),
            "ttft_steps_p99": nearest_rank(ttfts, 0.99),
            "prime_s_mean": sum(primes) / len(primes) if primes else 0.0,
            "prime_s_p90": nearest_rank(primes, 0.90),
            "wall_s": wall,
            "tokens_per_s": self.total_tokens / wall if wall > 0 else 0.0,
            "queue_depth": float(self.queue_depth),
        }
        for name in self.COUNTERS:
            out[name] = float(getattr(self, name))
        # per-priority-class TTFT (only classes actually seen this run —
        # single-class traffic keeps the summary exactly as before)
        by_cls: Dict[str, List[float]] = {}
        for r in self.requests.values():
            if r.ttft_steps is not None:
                by_cls.setdefault(r.priority, []).append(r.ttft_steps)
        if len(by_cls) > 1:
            for cls, vals in by_cls.items():
                vals.sort()
                out[f"n_requests_{cls}"] = float(len(vals))
                out[f"ttft_steps_p50_{cls}"] = nearest_rank(vals, 0.50)
                out[f"ttft_steps_p90_{cls}"] = nearest_rank(vals, 0.90)
        if self.spec_slot_steps:
            drafted = sum(r.drafted for r in self.requests.values())
            accepted = sum(r.accepted for r in self.requests.values())
            emitted = sum(n * c for n, c in self.accepted_hist.items())
            out.update({
                "spec_slot_steps": float(self.spec_slot_steps),
                "spec_accept_rate": accepted / drafted if drafted else 0.0,
                "spec_tokens_per_step": emitted / self.spec_slot_steps,
                "spec_drafts_wasted": float(drafted - accepted),
            })
        return out
