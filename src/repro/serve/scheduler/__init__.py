"""Continuous-batching serving runtime (DESIGN.md §Scheduler): arrival
queue + admission policy, slot lifecycle with immediate recycling, and the
in-flight-prefill decode loop over one persistent per-slot KV cache."""
from repro.serve.scheduler.metrics import RequestMetrics, ServingMetrics
from repro.serve.scheduler.queue import RequestQueue, ScheduledRequest
from repro.serve.scheduler.runtime import ContinuousScheduler
from repro.serve.scheduler.slots import SlotManager, SlotState
