"""Arrival queue + admission policy for the continuous scheduler
(DESIGN.md §Scheduler).

Requests enter with an `arrival` stamp on the scheduler's decode-step clock
(a traffic replay: arrival 7.0 means the request becomes visible once 7
decode steps have run). Admission walks the ARRIVED requests in policy
order and offers each to an `admit` callback — the runtime's callback does
the bank work (touch resident / load_from_checkpoint with the live pin
set) and turns a request down only when its tenant cannot be made resident
right now (BankFullError), in which case the next arrived request gets the
free slot instead of head-of-line blocking it.
"""
from __future__ import annotations

import bisect
import itertools
from dataclasses import dataclass
from typing import Callable, Collection, List, Optional

from repro.serve.engine import Request


@dataclass
class ScheduledRequest:
    """A queued request plus its scheduling identity/stamps."""
    request: Request
    rid: int
    arrival: float = 0.0


class RequestQueue:
    """Arrival-ordered queue with a pluggable admission policy.

    policy:
      "fcfs"           arrived requests are offered strictly in arrival
                       order (ties by submission order);
      "resident_first" among arrived requests, those whose tenant is
                       already bank-resident go first (avoids checkpoint
                       loads and LRU churn under tenant-heavy traffic);
                       falls back to fcfs order within each class.
    """

    POLICIES = ("fcfs", "resident_first")

    def __init__(self, policy: str = "fcfs"):
        if policy not in self.POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"one of {self.POLICIES}")
        self.policy = policy
        self._pending: List[ScheduledRequest] = []   # arrival-sorted, stable
        self._rids = itertools.count()

    def __len__(self) -> int:
        return len(self._pending)

    @property
    def pending(self) -> List[ScheduledRequest]:
        return list(self._pending)

    def push(self, request: Request, arrival: float = 0.0) -> int:
        rid = next(self._rids)
        sr = ScheduledRequest(request, rid, float(arrival))
        # rids are monotone, so (arrival, rid) keeps insertion stable
        bisect.insort(self._pending, sr,
                      key=lambda s: (s.arrival, s.rid))
        return rid

    def arrived(self, now: float) -> List[ScheduledRequest]:
        """Arrived prefix of the pending list. `_pending` is sorted by
        (arrival, rid), so the arrived set is exactly the slice before the
        first `arrival > now` — found by bisection instead of the previous
        full linear scan per admission cycle."""
        cut = bisect.bisect_right(self._pending, now,
                                  key=lambda sr: sr.arrival)
        return self._pending[:cut]

    def next_arrival(self) -> Optional[float]:
        """Earliest pending arrival stamp (the idle-skip target), or None."""
        return self._pending[0].arrival if self._pending else None

    def remove(self, rid: int) -> Optional[ScheduledRequest]:
        """Withdraw a pending (not yet admitted) request by id — the
        cancellation path for queued requests (runtime.cancel). Returns the
        removed entry, or None when `rid` is not pending (already admitted,
        finished, or unknown)."""
        for i, sr in enumerate(self._pending):
            if sr.rid == rid:
                return self._pending.pop(i)
        return None

    def pop_next(self, now: float,
                 admit: Callable[[ScheduledRequest], bool],
                 resident: Collection[str] = ()) -> Optional[ScheduledRequest]:
        """Offer arrived requests to `admit` in policy order; remove and
        return the first accepted one (None when nothing arrived or every
        arrived request was turned down this cycle)."""
        order = self.arrived(now)
        if self.policy == "resident_first":
            resident = set(resident)
            # only the ARRIVED slice is (stably) re-ranked — the pending
            # tail keeps its arrival order untouched
            order = sorted(          # stable: fcfs within each class
                order, key=lambda sr: (sr.request.adapter_id is not None
                                       and sr.request.adapter_id
                                       not in resident))
        for sr in order:
            if admit(sr):
                self._pending.remove(sr)
                return sr
        return None
