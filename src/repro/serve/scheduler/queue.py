"""Arrival queue + admission policy for the continuous scheduler
(DESIGN.md §Scheduler, §Tiering).

Requests enter with an `arrival` stamp on the scheduler's decode-step clock
(a traffic replay: arrival 7.0 means the request becomes visible once 7
decode steps have run). Admission walks the ARRIVED requests in policy
order and offers each to an `admit` callback — the runtime's callback does
the bank work (touch resident / load_from_checkpoint with the live pin
set) and turns a request down only when its tenant cannot be made resident
right now (BankFullError), in which case the next arrived request gets the
free slot instead of head-of-line blocking it.

Priority classes (serve/tiering): every policy orders the arrived slice by
priority class FIRST (interactive before batch before best_effort), then
applies its own order within each class. Single-class traffic — including
everything submitted before tiering existed, which defaults to "batch" —
therefore sees exactly the pre-tiering order under every policy.
"""
from __future__ import annotations

import bisect
import itertools
from dataclasses import dataclass
from typing import Any, Callable, Collection, Dict, List, Optional

from repro.serve.engine import Request
from repro.serve.tiering.config import priority_rank


@dataclass(eq=False)               # identity equality: Request holds jnp
class ScheduledRequest:            # arrays, and list.remove must match the
    """A queued request plus its scheduling identity/stamps."""
    request: Request
    rid: int
    arrival: float = 0.0
    resume: Optional[Any] = None   # tiering: a preempted request carries
                                   # its ResumeState back through the queue


class RequestQueue:
    """Arrival-ordered queue with a pluggable admission policy.

    policy:
      "fcfs"           arrived requests are offered strictly in arrival
                       order (ties by submission order);
      "resident_first" among arrived requests, those whose tenant is
                       already bank-resident go first (avoids checkpoint
                       loads and LRU churn under tenant-heavy traffic);
                       falls back to fcfs order within each class.
      "fair"           per-tenant fair share: within a priority class, the
                       tenant that has consumed the fewest RECENT tokens
                       (fed by `note_usage` from the runtime's emission
                       path, decayed by periodic halving) goes first, so a
                       chatty tenant cannot starve quiet ones — but a
                       historically chatty tenant is not deprioritized
                       forever; falls back to fcfs within a tenant.
    """

    POLICIES = ("fcfs", "resident_first", "fair")

    def __init__(self, policy: str = "fcfs"):
        if policy not in self.POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"one of {self.POLICIES}")
        self.policy = policy
        self._pending: List[ScheduledRequest] = []   # arrival-sorted, stable
        self._rids = itertools.count()
        self._usage: Dict[Optional[str], int] = {}   # tenant -> tokens

    def __len__(self) -> int:
        return len(self._pending)

    @property
    def pending(self) -> List[ScheduledRequest]:
        return list(self._pending)

    def push(self, request: Request, arrival: float = 0.0) -> int:
        rid = next(self._rids)
        sr = ScheduledRequest(request, rid, float(arrival))
        # rids are monotone, so (arrival, rid) keeps insertion stable
        bisect.insort(self._pending, sr,
                      key=lambda s: (s.arrival, s.rid))
        return rid

    def requeue(self, sr: ScheduledRequest) -> None:
        """Put a preempted request back, KEEPING its rid and arrival — the
        rid is the stream identity the gateway holds a handle on, and the
        original arrival keeps the victim ahead of later same-class
        arrivals once pressure clears (preemption must not also push it to
        the back of the line)."""
        bisect.insort(self._pending, sr,
                      key=lambda s: (s.arrival, s.rid))

    # fair-policy decay: once any tenant's counter reaches this, every
    # counter halves and zeroed tenants drop out — fairness tracks recent
    # consumption (exponential decay) and the dict stays bounded by the
    # recently-active tenant set instead of growing per distinct tenant
    # for the queue's lifetime
    USAGE_HALF_AT = 1 << 14

    def note_usage(self, tenant: Optional[str], n_tokens: int) -> None:
        """Fair-share accounting: `tenant` consumed `n_tokens` more decode
        tokens (the runtime calls this on emission; None = base model).
        Tracked only under the "fair" policy — no other policy reads it."""
        if self.policy != "fair":
            return
        total = self._usage.get(tenant, 0) + n_tokens
        self._usage[tenant] = total
        if total >= self.USAGE_HALF_AT:
            self._usage = {t: n >> 1 for t, n in self._usage.items()
                           if n >> 1}

    def usage(self, tenant: Optional[str]) -> int:
        return self._usage.get(tenant, 0)

    def arrived(self, now: float) -> List[ScheduledRequest]:
        """Arrived prefix of the pending list. `_pending` is sorted by
        (arrival, rid), so the arrived set is exactly the slice before the
        first `arrival > now` — found by bisection instead of the previous
        full linear scan per admission cycle."""
        cut = bisect.bisect_right(self._pending, now,
                                  key=lambda sr: sr.arrival)
        return self._pending[:cut]

    def next_arrival(self) -> Optional[float]:
        """Earliest pending arrival stamp (the idle-skip target), or None."""
        return self._pending[0].arrival if self._pending else None

    def remove(self, rid: int) -> Optional[ScheduledRequest]:
        """Withdraw a pending (not yet admitted) request by id — the
        cancellation path for queued requests (runtime.cancel). Returns the
        removed entry, or None when `rid` is not pending (already admitted,
        finished, or unknown)."""
        for i, sr in enumerate(self._pending):
            if sr.rid == rid:
                return self._pending.pop(i)
        return None

    def _ordered(self, now: float,
                 resident: Collection[str]) -> List[ScheduledRequest]:
        """Arrived slice in policy order: priority class first, then the
        policy's tiebreak within each class. Only the ARRIVED slice is
        (stably) re-ranked — the pending tail keeps its arrival order."""
        order = self.arrived(now)
        if self.policy == "resident_first":
            resident = set(resident)
            key = lambda sr: (priority_rank(sr.request.priority),
                              sr.request.adapter_id is not None
                              and sr.request.adapter_id not in resident)
        elif self.policy == "fair":
            key = lambda sr: (priority_rank(sr.request.priority),
                              self._usage.get(sr.request.adapter_id, 0))
        else:
            key = lambda sr: priority_rank(sr.request.priority)
        return sorted(order, key=key)   # stable: fcfs within ties

    def peek_next(self, now: float,
                  resident: Collection[str] = ()
                  ) -> Optional[ScheduledRequest]:
        """First arrived request in policy order WITHOUT offering or
        removing it — the preemption path asks who is blocked before
        deciding whether (and whom) to evict for them."""
        order = self._ordered(now, resident)
        return order[0] if order else None

    def pop_next(self, now: float,
                 admit: Callable[[ScheduledRequest], bool],
                 resident: Collection[str] = ()) -> Optional[ScheduledRequest]:
        """Offer arrived requests to `admit` in policy order; remove and
        return the first accepted one (None when nothing arrived or every
        arrived request was turned down this cycle)."""
        for sr in self._ordered(now, resident):
            if admit(sr):
                self._pending.remove(sr)
                return sr
        return None
