"""Serving engine: merged-adapter deployment (the paper's zero-inference-
latency property), prefill + batched greedy decode over slotted requests.

`merge_for_serving` folds every mergeable ΔW into the base weights once —
after that the serving graph is byte-identical to the unadapted model's (the
zamba2 shared-block per-application adapters stay factored by construction;
see models/zamba2.py).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, PEFTConfig, ShapeConfig
from repro.core import peft as peft_mod
from repro.models.registry import Model, add_time_dim, build


def merge_for_serving(model: Model, params: Dict) -> Tuple[Model, Dict]:
    peft = model.peft
    if peft.method in ("none", "full") or not params.get("peft"):
        return model, params
    base = dict(params["base"])
    layers = dict(base["layers"])
    leftover = {}
    site_by_name = {s.name: s for s in model.sites}
    for name, ad in params["peft"].items():
        if not name.startswith("layers/"):
            leftover[name] = ad          # e.g. zamba2 shared per-app adapters
            continue
        key = name.split("/")[-1]
        if peft.method == "bitfit":
            bkey = key + "__b"
            layers[bkey] = (layers[bkey] + ad["delta_b"]) if bkey in layers \
                else ad["delta_b"]
            continue
        dw = peft_mod.site_delta(ad, site_by_name[name], peft,
                                 layers[key].dtype)
        layers[key] = layers[key] + dw
    base["layers"] = layers
    merged_model = build(model.cfg,
                         peft.replace(method="fourierft") if leftover
                         else peft.replace(method="none"),
                         remat=model.remat)
    return merged_model, {"base": base, "peft": leftover}


@dataclass
class Request:
    prompt: jax.Array            # (S,) int32
    max_new: int = 16
    out: Optional[List[int]] = None


class Engine:
    """Slot-based batched greedy decoding (tests/examples scale).

    `mesh`: optional jax Mesh — merged params are placed per the dist
    sharding rules (TP over `model`, replicated over batch axes) and the KV
    cache per `cache_specs`, so the jitted prefill/decode graphs compile
    SPMD-partitioned instead of replicated."""

    def __init__(self, model: Model, params: Dict, batch_slots: int,
                 max_len: int, merge: bool = True, mesh=None):
        if merge:
            model, params = merge_for_serving(model, params)
        self.mesh = mesh
        if mesh is not None:
            from repro.dist import sharding as shd
            specs = shd.state_specs(params, mesh, model.cfg, False)
            params = jax.device_put(params, shd.named(params, specs, mesh))
        self.model, self.params = model, params
        self.batch = batch_slots
        self.max_len = max_len
        self._decode = jax.jit(model.decode_step)
        # one compiled graph per prompt length (padded batches share it)
        self._prefill = jax.jit(model.prefill)

    def _fresh_cache(self):
        cache = self.model.init_cache(self.batch, self.max_len,
                                      dtype=jnp.dtype(self.model.cfg.dtype))
        if self.mesh is not None:
            from repro.dist import sharding as shd
            shape = ShapeConfig("serve", self.max_len, self.batch, "decode")
            specs = shd.cache_specs(cache, self.mesh, self.model.cfg, shape)
            cache = jax.device_put(cache, shd.named(cache, specs, self.mesh))
        return cache

    def generate(self, prompts: List[jax.Array], max_new: int = 16,
                 stepwise_prefill: bool = False):
        """Greedy-decode a batch of equal-priority prompts (padded to the
        longest; padded prefill keeps every slot's KV cache consistent).

        stepwise_prefill: legacy token-by-token teacher-forced prefill
        (reference path for the equivalence test; S decode dispatches)."""
        assert len(prompts) <= self.batch
        B = self.batch
        plen = max(int(p.shape[0]) for p in prompts)
        toks = jnp.zeros((B, plen) + prompts[0].shape[1:], jnp.int32)
        for i, p in enumerate(prompts):
            toks = toks.at[i, :p.shape[0]].set(p)
        cache = self._fresh_cache()
        if stepwise_prefill:
            last = None
            for t in range(plen):
                last, cache = self._decode(self.params, cache,
                                           {"tokens": toks[:, t:t + 1]})
        else:
            last, cache = self._prefill(self.params, cache, {"tokens": toks})
        outs = [last]
        cur = add_time_dim(last)
        for _ in range(max_new - 1):
            nxt, cache = self._decode(self.params, cache,
                                      {"tokens": cur})
            outs.append(nxt)
            cur = add_time_dim(nxt)
        gen = jnp.stack(outs, axis=1)                     # (B, max_new, ...)
        return [gen[i] for i in range(len(prompts))]
