"""Serving engine: merged-adapter deployment (the paper's zero-inference-
latency property), prefill + batched greedy decode over slotted requests,
and a multi-tenant **adapter bank** (DESIGN.md §Adapter API).

`merge_for_serving` folds every mergeable ΔW into the base weights once —
after that the serving graph is byte-identical to the unadapted model's.
Sites that cannot merge stay factored and KEEP THEIR TRUE METHOD (the zamba2
shared-block per-application adapters; any method whose `mergeable` flag is
off).

`AdapterBank` holds K resident factored adapters over one base: per method
group the trainable leaves live in (K+1, L, …) arrays whose last row is a
reserved all-zero row. `Request.adapter_id` selects a resident row; the
jitted prefill/decode graphs gather per-request rows once per call and apply
them with the method's `bank_apply` — no per-request merge, no recompile
when residents change (array values change, shapes don't). Heterogeneous
methods batch together because every request gathers a row from every
method's bank and the factored contribution is linear in the trainables
(zero row ⇒ exactly zero). LRU load/evict against adapter-only checkpoints
(checkpoint/adapters.py) gives thousands-of-tenants serving at n·(2+L)
numbers of storage per tenant — the paper's economics, end to end.

The Engine itself batches in lockstep (generate / generate_requests); the
continuous-batching runtime over the same model/params/bank — arrival
scheduling, per-slot budgets over one persistent cache, slot recycling,
in-flight prefill — lives in repro.serve.scheduler (DESIGN.md §Scheduler).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import PEFTConfig, ShapeConfig
from repro.core import adapter as adapter_api
from repro.models.registry import (
    Model, add_time_dim, build, resolve_default_targets,
)


def merge_for_serving(model: Model, params: Dict) -> Tuple[Model, Dict]:
    """Fold every mergeable layer-stack ΔW into the base. Leftover adapters
    (non-`layers/` sites such as the zamba2 shared block, or methods with
    `mergeable=False`) stay factored under their TRUE method — the rebuilt
    model keeps the original PEFTConfig whenever anything is left over.

    ΔW materialization runs through the method's `merge_site`, i.e. the
    kernel registry (DESIGN.md §Kernels): on TPU the compiled Pallas deltaw
    kernels do the folding; `model.explain_kernels()` reports the choice."""
    peft = model.peft
    method = model.method
    if not method.has_site_params or not params.get("peft"):
        return model, params
    base = dict(params["base"])
    layers = dict(base["layers"])
    leftover = {}
    site_by_name = {s.name: s for s in model.sites}
    for name, ad in params["peft"].items():
        if not name.startswith("layers/") or not method.mergeable:
            leftover[name] = ad      # e.g. zamba2 shared per-app adapters
            continue
        key = name.split("/")[-1]
        method.merge_site(layers, key, ad, site_by_name[name], peft)
    base["layers"] = layers
    merged_model = build(model.cfg,
                         peft if leftover else peft.replace(method="none"),
                         remat=model.remat)
    return merged_model, {"base": base, "peft": leftover}


@dataclass
class Request:
    prompt: jax.Array                  # (S,) int32
    max_new: int = 16
    adapter_id: Optional[str] = None   # resident AdapterBank tenant (or base)
    out: Optional[List[int]] = None
    priority: str = "batch"            # serve/tiering class: interactive |
                                       # batch | best_effort


class BankFullError(RuntimeError):
    """Raised by AdapterBank.load when the bank is at capacity and every
    resident tenant is pinned (in use by a live request) — the caller must
    defer the load until a pinned tenant's requests drain."""


class AdapterBank:
    """K resident factored adapters over one base model.

    `profiles` maps method name -> PEFTConfig: one bank group per method the
    deployment serves (all tenants of a group share frozen aux — entries /
    bases are keyed by method + entry seed, enforced at load). Rows:

        params[m]["sites"][site][leaf]  (K+1, L, ...)   trainable, zero-init
        params[m]["aux"][site][leaf]    shared frozen aux (entries, b1/b2)

    Row K is the reserved zero row: requests that don't use method m gather
    it and contribute exactly zero (linearity contract, core/adapter.py).
    Slots are global across groups — loading a tenant zeroes its slot row in
    every group, then writes its own method's leaves. Eviction is LRU.
    """

    def __init__(self, model: Model, profiles: Dict[str, PEFTConfig],
                 capacity: int, checkpoint_dir: Optional[str] = None):
        if capacity < 1:
            raise ValueError("AdapterBank needs capacity >= 1")
        self.capacity = capacity
        self.zero_row = capacity
        self.checkpoint_dir = checkpoint_dir
        self._cfg = model.cfg
        self.profiles: Dict[str, PEFTConfig] = {}
        self._bank_sites: Dict[str, List] = {}
        self.params: Dict[str, Dict] = {}
        for mname, prof in profiles.items():
            method = adapter_api.resolve(mname)
            if not method.has_site_params:
                raise ValueError(f"method {mname!r} has no adapter state")
            if prof.method != mname:
                prof = prof.replace(method=mname)
            prof = resolve_default_targets(prof, model.cfg)
            sites = [s for s in model.sites
                     if s.name.startswith("layers/")
                     and s.name.split("/")[-1] in prof.target_modules]
            if not sites:
                raise ValueError(f"profile {mname!r} targets no bank-eligible "
                                 f"(layers/*) site of {model.cfg.name}")
            self.profiles[mname] = prof
            self._bank_sites[mname] = sites
            group = {"sites": {}, "aux": {}}
            for site in sites:
                ad = method.init_site(jax.random.PRNGKey(0), site, prof)
                trainable = set(method.trainable_leaves(prof))
                group["sites"][site.name] = {
                    k: jnp.zeros((capacity + 1,) + v.shape, v.dtype)
                    for k, v in ad.items() if k in trainable}
                aux = {k: v for k, v in ad.items() if k not in trainable}
                if aux:
                    group["aux"][site.name] = aux
            self.params[mname] = group
        # adapter_id -> (method name, slot); insertion order = LRU order
        self._resident: "OrderedDict[str, Tuple[str, int]]" = OrderedDict()
        self._free = list(range(capacity))
        # optional HostAdapterTier (serve/tiering): when set, evicted rows
        # spill to pinned host arrays and reload without a checkpoint read
        self.host_tier = None

    # ---- residency --------------------------------------------------------
    @property
    def resident_ids(self) -> Tuple[str, ...]:
        return tuple(self._resident)

    # config fields with no effect on the served math — everything NOT listed
    # here must match the group profile (fail closed: a future method knob is
    # compared by default, not silently ignored). kernel_backend only selects
    # which registered implementation computes identical math (DESIGN.md
    # §Kernels); use_pallas is its deprecated alias (always None post-shim).
    _PROFILE_IRRELEVANT = ("strategy", "kernel_backend", "use_pallas",
                           "train_head", "param_dtype")

    def _profile_key(self, peft: PEFTConfig) -> tuple:
        d = dataclasses.asdict(peft)
        for k in self._PROFILE_IRRELEVANT:
            d.pop(k)
        return tuple(sorted(d.items()))

    def _snapshot_to_host(self, adapter_id: str, mname: str,
                          slot: int) -> None:
        """Spill one tenant's trainable rows to the host tier before the
        slot is cleared. The slices are handed over with the D2H copy
        dispatched asynchronously — the tier materializes them at its next
        settle(), overlapping the copy with whatever the device runs next.
        Must read the rows BEFORE `_clear_group_slot` zeroes them."""
        if self.host_tier is None:
            return
        group = self.params[mname]
        tree = {}
        for site, leaves in group["sites"].items():
            slices = {}
            for leaf, v in leaves.items():
                row = v[slot]
                row.copy_to_host_async()
                slices[leaf] = row
            tree[site] = slices
        self.host_tier.put(adapter_id, mname, tree)

    def _clear_group_slot(self, mname: str, slot: int) -> None:
        """Zero one slot row in one method group. Only the occupant's own
        group can hold non-zero rows (loads write exactly one group; freed
        slots are cleared on evict), so clearing stays O(one group), not
        O(whole bank), under LRU churn."""
        group = self.params[mname]
        for site, leaves in group["sites"].items():
            group["sites"][site] = {
                k: v.at[slot].set(jnp.zeros(v.shape[1:], v.dtype))
                for k, v in leaves.items()}

    def load(self, adapter_id: str, adapters: Dict, peft: PEFTConfig,
             pinned: Sequence[str] = ()) -> int:
        """Make `adapter_id` resident (LRU-evicting if full). `adapters` is a
        {site: {leaf: array}} tree — trainable leaves are written into the
        slot row; any frozen leaves present are validated against the group's
        shared aux (one bank group = one entry seed).

        pinned: tenant ids that must NOT be evicted (live requests are
        gathering their rows mid-stream — evicting one would zero the row
        under a decoding batch). The LRU victim is the least-recently-used
        UNPINNED resident; if every resident is pinned, BankFullError."""
        if peft.method not in self.profiles:
            raise KeyError(f"no bank group for method {peft.method!r}; "
                           f"groups: {sorted(self.profiles)}")
        prof = self.profiles[peft.method]
        peft = resolve_default_targets(peft, self._cfg)
        if self._profile_key(peft) != self._profile_key(prof):
            raise ValueError(
                f"adapter {adapter_id!r} config {self._profile_key(peft)} "
                f"does not match bank group {self._profile_key(prof)}")
        method = adapter_api.resolve(peft.method)
        group = self.params[peft.method]
        known = {s.name for s in self._bank_sites[peft.method]}
        stray = set(adapters) - known
        if stray:
            raise ValueError(
                f"adapter {adapter_id!r} carries sites {sorted(stray)} "
                f"outside the bank group's {sorted(known)} — serving it "
                "would silently drop them")
        # validate EVERYTHING before touching bank state: a failed load must
        # not leak a slot or wipe the tenant it would have evicted
        trainable = set(method.trainable_leaves(prof))
        writes = []
        for site in self._bank_sites[peft.method]:
            ad = adapters.get(site.name)
            if ad is None:
                continue                       # stays zero at this site
            missing = trainable - set(ad)
            if missing:                        # fail closed: a partial site
                raise ValueError(              # would silently serve wrong
                    f"{adapter_id!r} {site.name} is missing trainable "
                    f"leaves {sorted(missing)}")
            for leaf, v in ad.items():
                if leaf in trainable:
                    rows = group["sites"][site.name][leaf]
                    if v.shape != rows.shape[1:]:
                        raise ValueError(
                            f"{adapter_id!r} {site.name}/{leaf}: shape "
                            f"{v.shape} != bank row {rows.shape[1:]}")
                    writes.append((site.name, leaf, v))
                else:
                    shared = group["aux"].get(site.name, {}).get(leaf)
                    if shared is None or not np.array_equal(
                            np.asarray(v), np.asarray(shared)):
                        raise ValueError(
                            f"{adapter_id!r} frozen leaf {site.name}/{leaf} "
                            "differs from the bank group's shared aux "
                            "(adapters in one group must share entry seed)")
        if adapter_id in self._resident:
            prev_m, slot = self._resident.pop(adapter_id)
            self._clear_group_slot(prev_m, slot)
        elif self._free:
            slot = self._free.pop(0)           # zero by construction
        else:
            victim = next((a for a in self._resident if a not in pinned),
                          None)                # LRU order, skipping pinned
            if victim is None:
                raise BankFullError(
                    f"bank is full ({self.capacity} slots) and every "
                    f"resident tenant is pinned; cannot admit "
                    f"{adapter_id!r} until a pinned tenant drains")
            prev_m, slot = self._resident.pop(victim)
            self._snapshot_to_host(victim, prev_m, slot)
            self._clear_group_slot(prev_m, slot)
        for site_name, leaf, v in writes:
            rows = group["sites"][site_name][leaf]
            group["sites"][site_name][leaf] = \
                rows.at[slot].set(v.astype(rows.dtype))
        self._resident[adapter_id] = (peft.method, slot)
        if self.host_tier is not None:
            # any successful load supersedes a host copy (it would serve
            # stale rows if the tenant re-trained); eviction re-spills
            self.host_tier.drop(adapter_id)
        return slot

    def load_from_checkpoint(self, adapter_id: str,
                             directory: Optional[str] = None,
                             pinned: Sequence[str] = ()) -> int:
        """LRU reload path: import an adapter-only export (trainables + config
        manifest) and make it resident."""
        from repro.checkpoint import adapters as adapter_ckpt
        directory = directory or self.checkpoint_dir
        if directory is None:
            raise ValueError("no checkpoint directory configured")
        tree, peft = adapter_ckpt.import_adapter(directory, adapter_id)
        return self.load(adapter_id, tree, peft, pinned=pinned)

    def evict(self, adapter_id: str) -> None:
        mname, slot = self._resident.pop(adapter_id)
        self._snapshot_to_host(adapter_id, mname, slot)
        self._clear_group_slot(mname, slot)
        self._free.append(slot)

    def load_from_host(self, adapter_id: str,
                       pinned: Sequence[str] = ()) -> Optional[int]:
        """Make `adapter_id` resident from the host tier (serve/tiering),
        or return None on a host miss — the caller then falls back to
        `load_from_checkpoint`. Goes through `load()` so every validation
        (profile match, shapes, pinned-victim selection) applies to host
        reloads exactly as to checkpoint loads."""
        if self.host_tier is None:
            return None
        hit = self.host_tier.get(adapter_id)
        if hit is None:
            return None
        method, tree = hit
        return self.load(adapter_id, tree, self.profiles[method],
                         pinned=pinned)

    def touch(self, adapter_id: str) -> None:
        self._resident.move_to_end(adapter_id)

    def slot_rows(self, adapter_ids: Sequence[Optional[str]],
                  batch: int) -> Dict[str, jax.Array]:
        """Per-method gather rows for a batch: requests without an adapter —
        or using a different method — point at the reserved zero row."""
        if len(adapter_ids) > batch:
            raise ValueError(f"{len(adapter_ids)} adapter_ids for a "
                             f"{batch}-slot batch")
        missing = {a for a in adapter_ids
                   if a is not None and a not in self._resident}
        if missing:     # validate before touching: failed calls leave LRU as-is
            raise KeyError(f"adapters {sorted(missing)} are not resident; "
                           f"call load()/load_from_checkpoint() first")
        rows = {m: np.full((batch,), self.zero_row, np.int32)
                for m in self.profiles}
        for i, aid in enumerate(adapter_ids):
            if aid is None:
                continue
            mname, slot = self._resident[aid]
            rows[mname][i] = slot
            self.touch(aid)
        return {m: jnp.asarray(v) for m, v in rows.items()}


class Engine:
    """Slot-based batched greedy decoding (tests/examples scale).

    `mesh`: optional jax Mesh — merged params are placed per the dist
    sharding rules (TP over `model`, replicated over batch axes) and the KV
    cache per `cache_specs`, so the jitted prefill/decode graphs compile
    SPMD-partitioned instead of replicated.

    `bank`: optional AdapterBank — enables per-request `adapter_id`s; the
    bank's resident rows enter the jitted graphs as `params["bank"]` and the
    per-request gather indices as `batch["adapter_slots"]`, so residency
    changes never recompile."""

    def __init__(self, model: Model, params: Dict, batch_slots: int,
                 max_len: int, merge: bool = True, mesh=None,
                 bank: Optional[AdapterBank] = None, plan=None):
        if merge:
            model, params = merge_for_serving(model, params)
        self.bank = bank
        if bank is not None:
            # fresh Model facade: never mutate the caller's (merge may have
            # returned the input model unchanged, and it may be shared)
            model = dataclasses.replace(model,
                                        bank_profiles=dict(bank.profiles))
        self.mesh = mesh
        # plan: a dist.plan.PlanSource (or a --sharding-plan string); the
        # rules source reproduces the pre-PR-10 placements byte-identically
        from repro.dist import plan as plan_mod
        if plan is None or isinstance(plan, str):
            shape = ShapeConfig("serve", max_len, batch_slots, "decode")
            self.plan_source = plan_mod.resolve(plan, model=model, mesh=mesh,
                                                shape=shape,
                                                workload="decode")
        else:
            self.plan_source = plan
        if mesh is not None:
            from repro.dist import sharding as shd
            specs = self.plan_source.state_specs(params, mesh, model.cfg,
                                                 False)
            params = jax.device_put(params, shd.named(params, specs, mesh))
        self.model, self.params = model, params
        self.batch = batch_slots
        self.max_len = max_len
        self._decode = jax.jit(model.decode_step)
        # one compiled graph per prompt length (padded batches share it)
        self._prefill = jax.jit(model.prefill)

    def commit_tokens(self, arr) -> jax.Array:
        """Place a host-built token array the way the jitted graphs hand
        theirs back: committed replicated over the engine mesh. A host-
        seeded step otherwise arrives UNcommitted while every device-fed
        step arrives with a NamedSharding — two jit signatures for one
        shape, which the recompile audit (repro.analysis) rightly flags."""
        arr = jnp.asarray(arr, jnp.int32)
        if self.mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            arr = jax.device_put(arr, NamedSharding(self.mesh,
                                                    PartitionSpec()))
        return arr

    def _fresh_cache(self, per_slot: bool = False, paged: bool = False,
                     page_size: int = 16, n_pages: Optional[int] = None):
        cache = self.model.init_cache(self.batch, self.max_len,
                                      dtype=jnp.dtype(self.model.cfg.dtype),
                                      per_slot=per_slot, paged=paged,
                                      page_size=page_size, n_pages=n_pages)
        if self.mesh is not None:
            from repro.dist import sharding as shd
            shape = ShapeConfig("serve", self.max_len, self.batch, "decode")
            specs = self.plan_source.cache_specs(cache, self.mesh,
                                                 self.model.cfg, shape)
            cache = jax.device_put(cache, shd.named(cache, specs, self.mesh))
        return cache

    def _batch_extra(self, adapter_ids: Optional[Sequence[Optional[str]]]):
        """(params incl. bank rows, per-call batch extras) for one call's
        per-request adapter ids, None-padded to the engine's slot count.
        Shared by generate/generate_requests and the continuous scheduler
        so the three paths cannot diverge on bank wiring."""
        B = self.batch
        params = self.params
        extra: Dict = {}
        if self.bank is not None:
            ids = list(adapter_ids or [])
            ids += [None] * (B - len(ids))
            extra["adapter_slots"] = self.bank.slot_rows(ids, B)
            params = {**params, "bank": self.bank.params}
        elif adapter_ids is not None and any(a is not None for a in adapter_ids):
            raise ValueError("adapter_ids given but the engine has no bank")
        return params, extra

    def generate(self, prompts: List[jax.Array], max_new: int = 16,
                 stepwise_prefill: bool = False,
                 adapter_ids: Optional[Sequence[Optional[str]]] = None):
        """Greedy-decode a batch of equal-priority prompts (padded to the
        longest; padded prefill keeps every slot's KV cache consistent).

        adapter_ids: per-prompt AdapterBank tenant (None = bare base); the
        whole heterogeneous batch runs through ONE jitted graph.

        stepwise_prefill: legacy token-by-token teacher-forced prefill
        (reference path for the equivalence test; S decode dispatches)."""
        if not prompts:
            raise ValueError("generate() needs at least one prompt")
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        if any(int(p.shape[0]) < 1 for p in prompts):
            raise ValueError("generate() got an empty (length-0) prompt")
        assert len(prompts) <= self.batch
        if adapter_ids is not None and len(adapter_ids) != len(prompts):
            # fail closed: a silently None-padded tail would serve those
            # prompts unadapted under the caller's nose
            raise ValueError(f"{len(adapter_ids)} adapter_ids for "
                             f"{len(prompts)} prompts")
        B = self.batch
        params, extra = self._batch_extra(adapter_ids)
        plen = max(int(p.shape[0]) for p in prompts)
        # same bound as the continuous scheduler (slots.py invariant: the
        # last generated token is never written — the deepest cache read is
        # plen + max_new - 1); the lockstep batch pads to the longest prompt
        if plen + max_new - 1 > self.max_len:
            raise ValueError(
                f"prompt ({plen}) + max_new ({max_new}) needs "
                f"{plen + max_new - 1} cache positions, exceeding "
                f"max_len ({self.max_len})")
        toks = jnp.zeros((B, plen) + prompts[0].shape[1:], jnp.int32)
        for i, p in enumerate(prompts):
            toks = toks.at[i, :p.shape[0]].set(p)
        cache = self._fresh_cache()
        if stepwise_prefill:
            last = None
            for t in range(plen):
                last, cache = self._decode(params, cache,
                                           {"tokens": toks[:, t:t + 1],
                                            **extra})
        else:
            last, cache = self._prefill(params, cache,
                                        {"tokens": toks, **extra})
        outs = [last]
        cur = add_time_dim(last)
        for _ in range(max_new - 1):
            nxt, cache = self._decode(params, cache,
                                      {"tokens": cur, **extra})
            outs.append(nxt)
            cur = add_time_dim(nxt)
        gen = jnp.stack(outs, axis=1)                     # (B, max_new, ...)
        return [gen[i] for i in range(len(prompts))]

    def generate_requests(self, requests: List[Request],
                          eos_id: Optional[int] = None):
        """Request-object front end: FCFS lockstep chunks of `batch_slots`
        heterogeneous-adapter requests (any count — chunks run serially).

        Per-request completion (budget exhausted, or `eos_id` emitted) is
        tracked through the scheduler's SlotManager — the same shared logic
        the continuous runtime uses — so a finished request stops
        contributing tokens, and the chunk's decode loop exits as soon as
        EVERY slot is done instead of always paying max(r.max_new) steps.
        Lockstep chunks cannot recycle a freed slot mid-flight; for that
        (plus arrival scheduling and in-flight prefill) use
        repro.serve.scheduler.ContinuousScheduler."""
        if not requests:
            return requests
        for r in requests:
            if r.max_new < 1:
                raise ValueError(f"request max_new must be >= 1, "
                                 f"got {r.max_new}")
            if int(r.prompt.shape[0]) < 1:
                raise ValueError("request with an empty (length-0) prompt")
        # validate every chunk's capacity bound UP FRONT (chunking is a
        # deterministic slice): an infeasible late request must fail before
        # any earlier chunk runs and mutates its requests' .out
        for at in range(0, len(requests), self.batch):
            chunk = requests[at:at + self.batch]
            plen = max(int(r.prompt.shape[0]) for r in chunk)
            worst = max(r.max_new for r in chunk)
            # per-chunk feasibility: every slot pads to the chunk's longest
            # prompt and decodes until its longest budget — same
            # `plen + max_new - 1 <= max_len` bound as generate() and the
            # continuous scheduler (slots.py invariant)
            if plen + worst - 1 > self.max_len:
                raise ValueError(
                    f"lockstep chunk at {at}: prompt ({plen}) + max_new "
                    f"({worst}) needs {plen + worst - 1} cache positions, "
                    f"exceeding max_len ({self.max_len})")
        for at in range(0, len(requests), self.batch):
            self._lockstep_chunk(requests[at:at + self.batch], eos_id)
        return requests

    def _lockstep_chunk(self, chunk: List[Request],
                        eos_id: Optional[int]) -> None:
        # lazy: scheduler.queue imports Request from this module
        from repro.serve.scheduler.slots import SlotManager
        params, extra = self._batch_extra([r.adapter_id for r in chunk])
        B = self.batch
        plen = max(int(r.prompt.shape[0]) for r in chunk)
        toks = jnp.zeros((B, plen) + chunk[0].prompt.shape[1:], jnp.int32)
        for i, r in enumerate(chunk):
            toks = toks.at[i, :r.prompt.shape[0]].set(r.prompt)
        last, cache = self._prefill(params, self._fresh_cache(),
                                    {"tokens": toks, **extra})
        sm = SlotManager(len(chunk), eos_id=eos_id)
        for i, r in enumerate(chunk):
            sm.acquire(i, budget=r.max_new, adapter_id=r.adapter_id)
        taken = [0] * len(chunk)
        history = []

        def note(tokens):
            history.append(tokens)
            # EOS needs token VALUES on the host (one sync per step);
            # budget-only completion stays async — dispatches pipeline.
            arr = np.asarray(tokens) if eos_id is not None else None
            for i in list(sm.active_slots()):
                taken[i] += 1
                tok = int(np.asarray(arr[i]).reshape(-1)[0]) \
                    if arr is not None else None
                if sm.note_token(i, tok):
                    sm.release(i)

        note(last)
        cur = add_time_dim(last)
        while sm.any_active():
            nxt, cache = self._decode(params, cache,
                                      {"tokens": cur, **extra})
            note(nxt)
            cur = add_time_dim(nxt)
        # the chunk's single drain point  # repro: allow(host-sync)
        gen = np.asarray(jnp.stack(history, axis=1))    # (B, T, ...)
        for i, r in enumerate(chunk):
            r.out = [int(t) for t in gen[i, :taken[i]].reshape(-1)]
