"""OpenAI-compatible asyncio HTTP gateway over the continuous runtime
(DESIGN.md §Gateway).

Endpoints:

    POST /v1/chat/completions   chat messages -> completion (JSON or SSE)
    POST /v1/completions        text or token-id prompt -> completion
    GET  /v1/models             base + resident bank tenants
    GET  /metrics               Prometheus text: ServingMetrics counters/
                                percentiles + gateway response counters
    GET  /healthz               readiness probe

Built on `asyncio.start_server` with hand-rolled HTTP/1.1 — the repo's
serving path takes no dependency beyond the stdlib. One request per
connection (`Connection: close`); SSE streams are close-delimited, so a
client reads `data:` frames until `data: [DONE]` and EOF.

Admission control (per request, before the scheduler sees it):
  - validation (protocol.parse_request) -> 400/404;
  - backpressure: queued depth >= `max_queue` OR free-page fraction below
    `min_free_page_frac` with a non-empty queue -> 429 + Retry-After;
  - adapter routing: `adapter:<id>` must be bank-resident or present in
    the bank's checkpoint dir -> 404 otherwise (checked on the pump
    thread, racelessly against LRU churn).

Cancellation: a client disconnect (monitored at EOF mid-stream) or a
`request_timeout_s` overrun aborts the request through
`ContinuousScheduler.cancel` — the slot recycles, its pages free, and the
tenant's bank row unpins the same scheduler round.
"""
from __future__ import annotations

import asyncio
import json
import time
from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro.serve.engine import Request
from repro.serve.gateway import protocol
from repro.serve.gateway.bridge import RequestHandle, SchedulerBridge
from repro.serve.gateway.protocol import ApiError

_MAX_BODY = 4 << 20                     # 4 MiB request-body cap
_MAX_HEADER = 64 << 10


class GatewayServer:
    """The asyncio front end over one ContinuousScheduler.

    max_queue:            queued (not yet admitted) request watermark —
                          at/above it new work gets 429.
    min_free_page_frac:   page-pool watermark — with a non-empty queue and
                          less than this fraction of allocatable pages
                          free, new work gets 429 (0 disables).
    retry_after_s:        Retry-After header value on 429.
    request_timeout_s:    end-to-end deadline per request (None = off);
                          overruns cancel the request mid-stream.
    default_max_new:      `max_tokens` default when the client omits it.
    """

    def __init__(self, sched, eos_id: Optional[int] = None,
                 max_queue: int = 32, min_free_page_frac: float = 0.0,
                 retry_after_s: float = 1.0,
                 request_timeout_s: Optional[float] = None,
                 default_max_new: int = 16):
        self.sched = sched
        self.eos_id = eos_id
        self.max_queue = max_queue
        self.min_free_page_frac = min_free_page_frac
        self.retry_after_s = retry_after_s
        self.request_timeout_s = request_timeout_s
        self.default_max_new = default_max_new
        self.vocab = int(sched.model.cfg.vocab)
        self.max_len = int(sched.max_len)
        self.base_aliases = (sched.model.cfg.name,)
        self.bridge = SchedulerBridge(sched)
        self.responses: Dict[int, int] = {}    # HTTP status -> count
        self._ids = 0
        self._server: Optional[asyncio.base_events.Server] = None
        self.host: Optional[str] = None
        self.port: Optional[int] = None

    # ---- lifecycle ---------------------------------------------------------
    async def start(self, host: str = "127.0.0.1", port: int = 0) -> None:
        self.bridge.start(asyncio.get_running_loop())
        self._server = await asyncio.start_server(self._serve_conn,
                                                  host, port)
        sock = self._server.sockets[0].getsockname()
        self.host, self.port = sock[0], sock[1]

    async def close(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        self.bridge.stop()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    # ---- connection handling ----------------------------------------------
    async def _serve_conn(self, reader: asyncio.StreamReader,
                          writer: asyncio.StreamWriter) -> None:
        try:
            try:
                method, path, headers, body = await self._read_request(reader)
            except ApiError as e:
                await self._respond_json(writer, e.status, e.body())
                return
            except (asyncio.IncompleteReadError, ConnectionError):
                return                         # client went away mid-request
            await self._route(method, path, headers, body, reader, writer)
        except (ConnectionError, asyncio.IncompleteReadError):
            pass                               # disconnects are normal
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _read_request(self, reader) -> Tuple[str, str, Dict, bytes]:
        line = await reader.readuntil(b"\r\n")
        parts = line.decode("latin-1").strip().split()
        if len(parts) != 3:
            raise ApiError(400, "malformed request line")
        method, path = parts[0].upper(), parts[1]
        headers: Dict[str, str] = {}
        size = len(line)
        while True:
            line = await reader.readuntil(b"\r\n")
            size += len(line)
            if size > _MAX_HEADER:
                raise ApiError(431, "headers too large")
            if line == b"\r\n":
                break
            name, _, value = line.decode("latin-1").partition(":")
            headers[name.strip().lower()] = value.strip()
        if "chunked" in headers.get("transfer-encoding", "").lower():
            raise ApiError(400, "chunked request bodies are not supported")
        length = headers.get("content-length", "0")
        try:
            n = int(length)
        except ValueError:
            raise ApiError(400, f"bad Content-Length {length!r}") from None
        if n < 0 or n > _MAX_BODY:
            raise ApiError(413, f"request body of {n} bytes exceeds the "
                                f"{_MAX_BODY}-byte cap")
        body = await reader.readexactly(n) if n else b""
        return method, path, headers, body

    async def _route(self, method, path, headers, body, reader, writer):
        path = path.split("?", 1)[0]
        if method == "POST" and path == "/v1/chat/completions":
            await self._handle_generate("chat", body, reader, writer)
        elif method == "POST" and path == "/v1/completions":
            await self._handle_generate("completion", body, reader, writer)
        elif method == "GET" and path == "/v1/models":
            await self._handle_models(writer)
        elif method == "GET" and path == "/metrics":
            await self._handle_metrics(writer)
        elif method == "GET" and path == "/healthz":
            await self._respond_json(writer, 200, {"status": "ok"})
        else:
            await self._respond_json(
                writer, 404,
                ApiError(404, f"no route for {method} {path}",
                         err_type="not_found_error").body())

    # ---- plain responses ---------------------------------------------------
    _REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
                408: "Request Timeout", 413: "Payload Too Large",
                429: "Too Many Requests", 431: "Header Too Large",
                500: "Internal Server Error", 504: "Gateway Timeout"}

    def _head(self, status: int, content_type: str,
              extra: Dict[str, str] = (), length: Optional[int] = None) \
            -> bytes:
        self.responses[status] = self.responses.get(status, 0) + 1
        lines = [f"HTTP/1.1 {status} {self._REASONS.get(status, 'OK')}",
                 f"Content-Type: {content_type}", "Connection: close"]
        if length is not None:
            lines.append(f"Content-Length: {length}")
        for k, v in dict(extra or {}).items():
            lines.append(f"{k}: {v}")
        return ("\r\n".join(lines) + "\r\n\r\n").encode("latin-1")

    async def _respond(self, writer, status: int, payload: bytes,
                       content_type: str, extra=()) -> None:
        writer.write(self._head(status, content_type, extra, len(payload))
                     + payload)
        await writer.drain()

    async def _respond_json(self, writer, status: int, obj,
                            extra=()) -> None:
        await self._respond(writer, status,
                            json.dumps(obj).encode("utf-8"),
                            "application/json", extra)

    # ---- info endpoints ----------------------------------------------------
    async def _handle_models(self, writer) -> None:
        def _list():
            resident = self.sched.bank.resident_ids \
                if self.sched.bank is not None else ()
            return list(resident)
        resident = await self.bridge.call(_list)
        created = int(time.time())
        data = [{"id": protocol.MODEL_BASE, "object": "model",
                 "created": created, "owned_by": "repro"}]
        data += [{"id": f"{protocol.ADAPTER_PREFIX}{aid}",
                  "object": "model", "created": created,
                  "owned_by": "repro", "resident": True}
                 for aid in resident]
        await self._respond_json(writer, 200,
                                 {"object": "list", "data": data})

    async def _handle_metrics(self, writer) -> None:
        def _scrape():
            out = self.sched.metrics.summary()
            gauges = getattr(self.sched, "resource_gauges", None)
            if gauges is not None:
                # tiering gauges (DESIGN.md §Tiering): bank residency,
                # prefix-cache pages, host-tier occupancy
                out.update(gauges())
            return out
        summary = await self.bridge.call(_scrape)
        summary["gateway_page_free_frac"] = self.bridge.free_page_frac()
        labeled = {"gateway_responses_total":
                   {f'code="{code}"': n
                    for code, n in sorted(self.responses.items())}}
        text = protocol.prometheus_text(summary, labeled=labeled)
        await self._respond(writer, 200, text.encode("utf-8"),
                            "text/plain; version=0.0.4")

    # ---- generation --------------------------------------------------------
    def _overloaded(self, priority: str = "batch") -> bool:
        """Class-aware backpressure (DESIGN.md §Tiering): interactive work
        skips the page-frac gate ONLY when the scheduler can actually
        preempt for it (otherwise it would just queue behind pressure with
        overload protection disabled — and `priority` is client-supplied,
        so the bypass must not outrun what the backend enforces);
        best_effort work is shed at half the queue watermark so it never
        crowds out the classes above it."""
        queued = self.bridge.queued()
        watermark = self.max_queue
        if priority == "best_effort":
            watermark = max(1, self.max_queue // 2)
        if queued >= watermark:
            return True
        if priority == "interactive" and self.bridge.preempting():
            return False
        return (self.min_free_page_frac > 0 and queued > 0
                and self.bridge.free_page_frac() < self.min_free_page_frac)

    def _adapter_gate(self, adapter_id: Optional[str]):
        """Pump-thread validation closure: resolve the routed tenant
        against bank residency / on-disk checkpoints; a veto string maps
        to 404 model_not_found."""
        sched = self.sched
        if adapter_id is None:
            return None

        def _check() -> Optional[str]:
            bank = sched.bank
            if bank is None:
                return "this deployment serves no adapters (no bank)"
            if adapter_id in bank.resident_ids:
                return None
            if bank.checkpoint_dir is not None:
                from repro.checkpoint import adapters as adapter_ckpt
                if adapter_id in adapter_ckpt.list_adapters(
                        bank.checkpoint_dir):
                    return None
            return (f"model '{protocol.ADAPTER_PREFIX}{adapter_id}' is "
                    "neither resident nor checkpointed")
        return _check

    async def _handle_generate(self, kind, body, reader, writer) -> None:
        try:
            payload = json.loads(body.decode("utf-8"))
        except (UnicodeDecodeError, json.JSONDecodeError):
            await self._respond_json(
                writer, 400, ApiError(400, "body is not valid JSON").body())
            return
        try:
            preq = protocol.parse_request(
                kind, payload, vocab=self.vocab, max_len=self.max_len,
                default_max_new=self.default_max_new,
                base_aliases=self.base_aliases)
        except ApiError as e:
            await self._respond_json(writer, e.status, e.body())
            return
        if self._overloaded(preq.priority):
            self.sched.metrics.on_reject()
            await self._respond_json(
                writer, 429,
                ApiError(429, "server is saturated; retry later",
                         err_type="rate_limit_error",
                         code="server_overloaded").body(),
                extra={"Retry-After": f"{self.retry_after_s:g}"})
            return
        request = Request(prompt=jnp.asarray(preq.prompt, jnp.int32),
                          max_new=preq.max_new, adapter_id=preq.adapter_id,
                          priority=preq.priority)
        try:
            handle = await self.bridge.submit(
                request, validate=self._adapter_gate(preq.adapter_id))
        except RuntimeError as e:
            await self._respond_json(
                writer, 404,
                ApiError(404, str(e), err_type="not_found_error",
                         code="model_not_found").body())
            return
        self._ids += 1
        rid = f"{'chatcmpl' if kind == 'chat' else 'cmpl'}-{self._ids}"
        created = int(time.time())
        if preq.stream:
            await self._stream_response(preq, rid, created, handle,
                                        reader, writer)
        else:
            await self._block_response(preq, rid, created, handle,
                                       reader, writer)

    async def _next_item(self, handle: RequestHandle, monitor,
                         deadline: Optional[float]):
        """Next stream item, or ("disconnect",)/("timeout",) sentinels."""
        get = asyncio.ensure_future(handle.queue.get())
        waits = {get, monitor}
        timeout = None
        if deadline is not None:
            timeout = max(deadline - time.monotonic(), 0.0)
        done, _ = await asyncio.wait(waits, timeout=timeout,
                                     return_when=asyncio.FIRST_COMPLETED)
        if get in done:
            return get.result()
        get.cancel()
        return ("disconnect",) if monitor in done else ("timeout",)

    def _deadline(self) -> Optional[float]:
        if self.request_timeout_s is None:
            return None
        return time.monotonic() + self.request_timeout_s

    async def _block_response(self, preq, rid, created, handle,
                              reader, writer) -> None:
        monitor = asyncio.ensure_future(reader.read())
        deadline = self._deadline()
        tokens, status, reason = [], 200, None
        try:
            while True:
                item = await self._next_item(handle, monitor, deadline)
                kind = item[0]
                if kind == "token":
                    tokens.append(item[1])
                elif kind == "done":
                    tokens = item[1]
                    reason = protocol.finish_reason(tokens, self.eos_id)
                    break
                elif kind == "cancelled":
                    reason, status = "cancelled", 500
                    break
                elif kind == "error":
                    await self._respond_json(
                        writer, 500,
                        ApiError(500, item[1], "server_error").body())
                    return
                elif kind == "disconnect":
                    self.bridge.cancel(handle)
                    return                     # nobody to answer
                elif kind == "timeout":
                    self.bridge.cancel(handle)
                    await self._respond_json(
                        writer, 504,
                        ApiError(504, "generation exceeded "
                                 f"{self.request_timeout_s:g}s",
                                 "timeout_error").body())
                    return
        finally:
            monitor.cancel()
        body = protocol.completion_body(preq, rid, created, tokens,
                                        reason or "length")
        await self._respond_json(writer, status, body)

    async def _stream_response(self, preq, rid, created, handle,
                               reader, writer) -> None:
        monitor = asyncio.ensure_future(reader.read())
        deadline = self._deadline()
        writer.write(self._head(200, "text/event-stream",
                                {"Cache-Control": "no-cache"}))
        first = True
        try:
            while True:
                item = await self._next_item(handle, monitor, deadline)
                kind = item[0]
                if kind == "token":
                    chunk = protocol.stream_chunk(preq, rid, created,
                                                  item[1], first)
                    first = False
                    writer.write(protocol.sse_event(chunk))
                    await writer.drain()
                elif kind == "done":
                    reason = protocol.finish_reason(item[1], self.eos_id)
                    writer.write(protocol.sse_event(protocol.stream_chunk(
                        preq, rid, created, None, first, reason)))
                    writer.write(protocol.sse_event("[DONE]"))
                    await writer.drain()
                    return
                elif kind in ("cancelled", "error"):
                    writer.write(protocol.sse_event(protocol.stream_chunk(
                        preq, rid, created, None, first,
                        "cancelled" if kind == "cancelled" else "error")))
                    writer.write(protocol.sse_event("[DONE]"))
                    await writer.drain()
                    return
                elif kind == "disconnect":
                    self.bridge.cancel(handle)
                    return
                elif kind == "timeout":
                    self.bridge.cancel(handle)
                    writer.write(protocol.sse_event(protocol.stream_chunk(
                        preq, rid, created, None, first, "timeout")))
                    writer.write(protocol.sse_event("[DONE]"))
                    await writer.drain()
                    return
        except (ConnectionError, OSError):
            self.bridge.cancel(handle)         # write failed: client gone
        finally:
            monitor.cancel()
