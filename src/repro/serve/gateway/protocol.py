"""OpenAI-compatible wire protocol for the serving gateway (DESIGN.md
§Gateway): request validation, model-name -> adapter routing, token <->
text mapping, response/SSE framing, and the Prometheus text exposition.

Everything here is pure host-side data plumbing — no jax, no I/O — so the
HTTP server (server.py), the load generator (benchmarks/loadgen.py) and
the tests all speak exactly the same dialect.

Model-name routing convention: the `model` field selects the tenant.
`"base"` (or the engine's architecture name) runs the bare merged base;
`"adapter:<id>"` routes through the AdapterBank row of tenant `<id>`,
loaded from its adapter-only checkpoint at admission when not resident.
FourierFT's ~0.064M-parameter tenants are why per-request routing by name
is viable at scale — a tenant is one tiny bank row, not a model copy.

Tokens vs text: the repo has no external tokenizer (and must not grow the
dependency), so text prompts go through a deterministic byte-level
encoding (`encode_text`: UTF-8 byte folded into the model vocab) and
`/v1/completions` additionally accepts the prompt as a raw token-id array
— the exactness-friendly path the load harness and CI replay check use.
Every emitted chunk carries its `token_id` and non-streaming responses a
`token_ids` list (extension fields), so clients can compare streams
bit-for-bit without depending on the text mapping.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional

# jax-free by design (serve/tiering/config.py): the priority classes the
# `priority` request extension accepts
from repro.serve.tiering.config import DEFAULT_PRIORITY, PRIORITIES

MODEL_BASE = "base"
ADAPTER_PREFIX = "adapter:"
CHAT_ROLES = ("system", "user", "assistant", "tool")


class ApiError(Exception):
    """An HTTP-mappable request failure, serialized OpenAI-style:
    {"error": {"message", "type", "code"}}."""

    def __init__(self, status: int, message: str,
                 err_type: str = "invalid_request_error",
                 code: Optional[str] = None):
        super().__init__(message)
        self.status = status
        self.err_type = err_type
        self.code = code

    def body(self) -> Dict:
        return {"error": {"message": str(self), "type": self.err_type,
                          "code": self.code}}


def resolve_model(name, base_aliases=()) -> Optional[str]:
    """Model name -> adapter id (None = bare base). 404 on anything that is
    neither the base nor an `adapter:<id>` name — existence/residency of
    the id itself is the gateway's (bank-side) check, not ours."""
    if not isinstance(name, str) or not name:
        raise ApiError(400, "'model' must be a non-empty string")
    if name == MODEL_BASE or name in base_aliases:
        return None
    if name.startswith(ADAPTER_PREFIX):
        aid = name[len(ADAPTER_PREFIX):]
        if not aid:
            raise ApiError(400, "empty adapter id in 'model'")
        return aid
    raise ApiError(404, f"model {name!r} does not exist; use "
                        f"{MODEL_BASE!r} or '{ADAPTER_PREFIX}<id>'",
                   err_type="not_found_error", code="model_not_found")


# ---- token <-> text ---------------------------------------------------------
def encode_text(text: str, vocab: int) -> List[int]:
    """Deterministic byte-level encoding: UTF-8 byte folded into the model
    vocab. Not a linguistic tokenizer — a stable, dependency-free mapping
    every component (gateway, loadgen, replay check) shares."""
    return [b % vocab for b in text.encode("utf-8")]


def encode_chat(messages: List[Dict], vocab: int) -> List[int]:
    """ChatML-ish serialization of a message list, ending with the
    assistant header the completion notionally continues."""
    parts = [f"<{m['role']}>{m['content']}" for m in messages]
    parts.append("<assistant>")
    return encode_text("\n".join(parts), vocab)


def decode_token(tok: int) -> str:
    """Printable-ASCII bytes round-trip; everything else renders as a
    <id> placeholder (the byte-level mapping is not invertible once vocab
    folding or non-ASCII input is involved — `token_id` is the ground
    truth, text is a human courtesy)."""
    return chr(tok) if 32 <= tok < 127 else f"<{tok}>"


# ---- request parsing --------------------------------------------------------
@dataclass
class ParsedRequest:
    kind: str                      # "chat" | "completion"
    model: str                     # verbatim model name (echoed back)
    adapter_id: Optional[str]      # routed tenant (None = base)
    prompt: List[int]              # token ids
    max_new: int
    stream: bool
    priority: str = DEFAULT_PRIORITY   # extension: tiering class
                                       # (interactive|batch|best_effort)


def _require(cond: bool, message: str, status: int = 400) -> None:
    if not cond:
        raise ApiError(status, message)


def _parse_prompt_tokens(prompt, vocab: int) -> List[int]:
    if isinstance(prompt, str):
        return encode_text(prompt, vocab)
    _require(isinstance(prompt, list) and len(prompt) > 0,
             "'prompt' must be a non-empty string or token-id array")
    _require(all(isinstance(t, int) and not isinstance(t, bool)
                 for t in prompt),
             "'prompt' array must contain integer token ids")
    bad = [t for t in prompt if not 0 <= t < vocab]
    _require(not bad, f"prompt token ids {bad[:3]} outside the model "
                      f"vocab [0, {vocab})")
    return list(prompt)


def parse_request(kind: str, payload, *, vocab: int, max_len: int,
                  default_max_new: int = 16,
                  base_aliases=()) -> ParsedRequest:
    """Validate one /v1/chat/completions ("chat") or /v1/completions
    ("completion") body into a ParsedRequest; raises ApiError (400/404)
    on anything malformed. Decoding is greedy-only: sampling knobs are
    accepted and ignored (OpenAI-client pragmatism), but parameters that
    change the response SHAPE (n, best_of) must be absent or 1."""
    _require(isinstance(payload, dict), "request body must be a JSON object")
    adapter_id = resolve_model(payload.get("model"), base_aliases)
    if kind == "chat":
        messages = payload.get("messages")
        _require(isinstance(messages, list) and len(messages) > 0,
                 "'messages' must be a non-empty array")
        for m in messages:
            _require(isinstance(m, dict)
                     and isinstance(m.get("role"), str)
                     and isinstance(m.get("content"), str),
                     "each message needs string 'role' and 'content'")
            _require(m["role"] in CHAT_ROLES,
                     f"unknown message role {m['role']!r}; "
                     f"one of {CHAT_ROLES}")
        prompt = encode_chat(messages, vocab)
        max_new = payload.get("max_completion_tokens",
                              payload.get("max_tokens", default_max_new))
    else:
        _require("prompt" in payload, "'prompt' is required")
        prompt = _parse_prompt_tokens(payload["prompt"], vocab)
        max_new = payload.get("max_tokens", default_max_new)
    _require(len(prompt) >= 1, "prompt encodes to zero tokens")
    _require(isinstance(max_new, int) and not isinstance(max_new, bool)
             and max_new >= 1, "'max_tokens' must be an integer >= 1")
    stream = payload.get("stream", False)
    _require(isinstance(stream, bool), "'stream' must be a boolean")
    priority = payload.get("priority", DEFAULT_PRIORITY)
    _require(isinstance(priority, str) and priority in PRIORITIES,
             f"'priority' must be one of {list(PRIORITIES)}")
    for knob in ("n", "best_of"):
        _require(payload.get(knob, 1) == 1,
                 f"'{knob}' != 1 is not supported (greedy decoding "
                 "emits exactly one choice)")
    # same capacity invariant as the scheduler (slots.py): the last
    # generated token is never written, so the deepest cache position is
    # len(prompt) + max_new - 1
    need = len(prompt) + max_new - 1
    _require(need <= max_len,
             f"prompt ({len(prompt)} tokens) + max_tokens ({max_new}) "
             f"needs {need} cache positions, exceeding the server's "
             f"context window ({max_len})", status=400)
    return ParsedRequest(kind=kind, model=payload["model"],
                         adapter_id=adapter_id, prompt=prompt,
                         max_new=max_new, stream=stream,
                         priority=priority)


# ---- response framing -------------------------------------------------------
def finish_reason(tokens: List[int], eos_id: Optional[int],
                  cancelled: bool = False) -> str:
    if cancelled:
        return "cancelled"
    if eos_id is not None and tokens and tokens[-1] == eos_id:
        return "stop"
    return "length"


def completion_body(req: ParsedRequest, rid: str, created: int,
                    tokens: List[int], reason: str) -> Dict:
    """Non-streaming response JSON for either endpoint."""
    text = "".join(decode_token(t) for t in tokens)
    usage = {"prompt_tokens": len(req.prompt),
             "completion_tokens": len(tokens),
             "total_tokens": len(req.prompt) + len(tokens)}
    if req.kind == "chat":
        choice = {"index": 0, "finish_reason": reason,
                  "message": {"role": "assistant", "content": text}}
        obj = "chat.completion"
    else:
        choice = {"index": 0, "finish_reason": reason, "text": text}
        obj = "text_completion"
    choice["token_ids"] = list(tokens)         # extension: exactness checks
    return {"id": rid, "object": obj, "created": created,
            "model": req.model, "choices": [choice], "usage": usage}


def stream_chunk(req: ParsedRequest, rid: str, created: int,
                 token_id: Optional[int], first: bool,
                 reason: Optional[str] = None) -> Dict:
    """One SSE chunk: a token delta (token_id set) or the final
    finish_reason-only chunk (token_id None)."""
    if req.kind == "chat":
        delta: Dict = {}
        if token_id is not None:
            if first:
                delta["role"] = "assistant"
            delta["content"] = decode_token(token_id)
        choice = {"index": 0, "delta": delta, "finish_reason": reason}
        obj = "chat.completion.chunk"
    else:
        choice = {"index": 0, "finish_reason": reason,
                  "text": decode_token(token_id)
                  if token_id is not None else ""}
        obj = "text_completion"
    if token_id is not None:
        choice["token_id"] = int(token_id)     # extension: exactness checks
    return {"id": rid, "object": obj, "created": created,
            "model": req.model, "choices": [choice]}


def sse_event(payload) -> bytes:
    """`data: <json>\\n\\n` framing; pass the string "[DONE]" verbatim for
    the terminal sentinel."""
    data = payload if isinstance(payload, str) \
        else json.dumps(payload, separators=(",", ":"))
    return b"data: " + data.encode("utf-8") + b"\n\n"


# ---- metrics exposition -----------------------------------------------------
def prometheus_text(values: Dict[str, float], prefix: str = "repro",
                    labeled: Optional[Dict[str, Dict[str, float]]] = None) \
        -> str:
    """Prometheus text exposition of a flat summary dict: keys ending
    `_total` are counters, everything else gauges. `labeled` adds families
    with one label, e.g. {"gateway_responses_total": {'code="200"': 3}}."""
    lines = []
    for key in sorted(values):
        val = values[key]
        if not isinstance(val, (int, float)):
            continue
        name = f"{prefix}_{key}"
        kind = "counter" if key.endswith("_total") else "gauge"
        lines.append(f"# TYPE {name} {kind}")
        lines.append(f"{name} {float(val):.10g}")
    for key in sorted(labeled or ()):
        name = f"{prefix}_{key}"
        kind = "counter" if key.endswith("_total") else "gauge"
        lines.append(f"# TYPE {name} {kind}")
        for label, val in sorted(labeled[key].items()):
            lines.append(f"{name}{{{label}}} {float(val):.10g}")
    return "\n".join(lines) + "\n"
