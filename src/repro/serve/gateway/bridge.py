"""Async bridge between the asyncio gateway and the synchronous
`ContinuousScheduler` (DESIGN.md §Gateway).

The scheduler's decode loop is blocking host code (jit dispatches plus the
buffered drains' device syncs), so it cannot run on the event loop without
stalling every connection. `SchedulerBridge` runs it on ONE daemon thread
— the scheduler stays single-threaded, exactly as the replay path uses it
— and pumps `ContinuousScheduler.tick()` forever:

    event loop ──commands──▶ pump thread ──call_soon_threadsafe──▶ loop
      submit(req)              sched.submit / tick / cancel        handle
      cancel(handle)                                               queues

All scheduler access happens on the pump thread: submissions, bank
residency checks, cancellation, and arbitrary reads via `call()` (used by
/metrics and /v1/models so a scrape never iterates dicts the pump is
mutating). Commands are processed between ticks, so each one observes a
consistent scheduler. The only event-loop-side reads are the watermark
integers (`depth()`, `free_page_frac()`) — approximate by design.

Per request the bridge hands back a `RequestHandle` whose asyncio queue
receives ("token", id), ("done", tokens), ("cancelled", tokens) or
("error", message) items; a client disconnect calls `cancel(handle)`,
which aborts the request mid-stream through the scheduler's cancel path —
freeing its slot and pages and unpinning its tenant's bank row.
"""
from __future__ import annotations

import asyncio
import queue as _queue
import threading
from typing import Callable, Dict, List, Optional

from repro.serve.engine import Request


class RequestHandle:
    """Event-loop-side view of one in-flight request."""

    def __init__(self) -> None:
        self.rid: Optional[int] = None
        self.queue: "asyncio.Queue" = asyncio.Queue()
        self.tokens: List[int] = []            # tokens streamed so far
        self.closed = False                    # terminal item delivered


class SchedulerBridge:
    """Pumps a ContinuousScheduler from a daemon thread; see module doc."""

    def __init__(self, sched, idle_wait_s: float = 0.005):
        self.sched = sched
        self.idle_wait_s = idle_wait_s
        self._cmds: "_queue.SimpleQueue" = _queue.SimpleQueue()
        self._handles: Dict[int, RequestHandle] = {}
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ---- lifecycle (event loop side) --------------------------------------
    def start(self, loop: Optional[asyncio.AbstractEventLoop] = None) -> None:
        if self._thread is not None:
            raise RuntimeError("bridge already started")
        self._loop = loop or asyncio.get_event_loop()
        self.sched.metrics.start()             # wall clock = server uptime
        self._thread = threading.Thread(target=self._pump, daemon=True,
                                        name="gateway-scheduler-pump")
        self._thread.start()

    def stop(self) -> None:
        """Stop the pump (blocking join; the thread exits after at most one
        tick + idle_wait_s)."""
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None
        self.sched.metrics.stop()

    # ---- request API (event loop side) ------------------------------------
    def submit(self, request: Request,
               validate: Optional[Callable[[], Optional[str]]] = None) \
            -> "asyncio.Future":
        """Enqueue a submission; the returned future resolves to the
        request's RequestHandle once the pump has admitted it to the
        scheduler queue — or raises RuntimeError(message) when `validate`
        (run on the pump thread, e.g. bank-residency lookup) vetoes it."""
        fut = self._loop.create_future()
        self._cmds.put(("submit", request, validate, fut))
        return fut

    def cancel(self, handle: RequestHandle) -> None:
        """Abort `handle`'s request (queued or mid-stream). Safe to call
        redundantly or after completion — cancelling a finished request is
        a no-op."""
        self._cmds.put(("cancel", handle))

    def call(self, fn: Callable):
        """Run `fn()` on the pump thread between ticks and resolve the
        returned future with its result — THE way to read scheduler/bank
        state that the pump mutates (metrics summaries, residency lists)."""
        fut = self._loop.create_future()
        self._cmds.put(("call", fn, fut))
        return fut

    # ---- watermark reads (racy by design: single ints under the GIL) ------
    def depth(self) -> int:
        """Pending + in-flight request count (the 429 queue watermark)."""
        return len(self.sched.queue) + len(self.sched.slots.active_slots())

    def queued(self) -> int:
        return len(self.sched.queue)

    def free_page_frac(self) -> float:
        """Free fraction of the allocatable page pool (1.0 when dense)."""
        pager = self.sched.pager
        if pager is None:
            return 1.0
        total = pager.n_pages - pager.n_slots
        return pager.allocator.free_count() / max(total, 1)

    def preempting(self) -> bool:
        """Whether the scheduler can actually evict a victim for blocked
        high-class work (mirrors the runtime's admission-path gate) — the
        gateway's interactive backpressure bypass is only sound then."""
        tiering = getattr(self.sched, "tiering", None)
        return (tiering is not None and tiering.preempt
                and getattr(self.sched, "pager", None) is not None)

    # ---- pump thread -------------------------------------------------------
    def _post(self, handle: RequestHandle, item) -> None:
        try:
            self._loop.call_soon_threadsafe(handle.queue.put_nowait, item)
        except RuntimeError:
            pass                               # loop already closed

    def _resolve(self, fut: "asyncio.Future", value=None,
                 error: Optional[BaseException] = None) -> None:
        def _set() -> None:
            if fut.cancelled():
                return
            if error is not None:
                fut.set_exception(error)
            else:
                fut.set_result(value)
        try:
            self._loop.call_soon_threadsafe(_set)
        except RuntimeError:
            pass

    def _exec(self, cmd) -> None:
        kind = cmd[0]
        if kind == "submit":
            _, request, validate, fut = cmd
            try:
                if validate is not None:
                    veto = validate()
                    if veto:
                        raise RuntimeError(veto)
                handle = RequestHandle()
                # live traffic arrives NOW on the decode-step clock
                handle.rid = self.sched.submit(request, arrival=self.sched.t)
            except Exception as e:              # noqa: BLE001 — to caller
                self._resolve(fut, error=e)
                return
            self._handles[handle.rid] = handle
            self._resolve(fut, value=handle)
        elif kind == "cancel":
            _, handle = cmd
            rid = handle.rid
            if rid is None or rid not in self._handles:
                return                          # already finished / unknown
            del self._handles[rid]
            self.sched.cancel(rid)
            self._post(handle, ("cancelled", []))
        elif kind == "call":
            _, fn, fut = cmd
            try:
                self._resolve(fut, value=fn())
            except Exception as e:              # noqa: BLE001 — to caller
                self._resolve(fut, error=e)

    def _dispatch(self, ev) -> None:
        kind, rid = ev[0], ev[1]
        handle = self._handles.get(rid)
        if handle is None:
            return                             # cancelled or non-gateway rid
        if kind == "token":
            self._post(handle, ("token", int(ev[2])))
        elif kind == "done":
            del self._handles[rid]
            self._post(handle, ("done", [int(t) for t in ev[2]]))

    def _pump(self) -> None:
        sched = self.sched
        while not self._stop.is_set():
            while True:                        # drain commands between ticks
                try:
                    self._exec(self._cmds.get_nowait())
                except _queue.Empty:
                    break
            try:
                events = sched.tick()
            except Exception as e:             # noqa: BLE001 — fail streams
                # a poisoned admission (e.g. corrupt checkpoint at load)
                # surfaces here; every live stream gets the error rather
                # than hanging, and the pump keeps serving
                for rid, handle in list(self._handles.items()):
                    self._post(handle, ("error", f"scheduler error: {e}"))
                    try:
                        self.sched.cancel(rid)  # release slots/pages held
                    except Exception:           # noqa: BLE001 — best effort
                        pass
                self._handles.clear()
                events = []
            for ev in events:
                self._dispatch(ev)
            if not events and not sched.slots.any_active():
                # idle: block briefly for the next command so a quiet
                # server doesn't spin (bounded so stop() stays responsive)
                try:
                    self._exec(self._cmds.get(timeout=self.idle_wait_s))
                except _queue.Empty:
                    pass
