"""OpenAI-compatible async serving gateway over the continuous runtime
(DESIGN.md §Gateway): wire protocol, scheduler bridge, HTTP server."""
from repro.serve.gateway.bridge import RequestHandle, SchedulerBridge
from repro.serve.gateway.protocol import (
    ADAPTER_PREFIX, MODEL_BASE, ApiError, encode_chat, encode_text,
    parse_request, prometheus_text,
)
from repro.serve.gateway.server import GatewayServer
