from repro.serve.engine import Engine, merge_for_serving
