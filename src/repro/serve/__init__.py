from repro.serve.engine import AdapterBank, Engine, Request, merge_for_serving
