from repro.serve.engine import (
    AdapterBank, BankFullError, Engine, Request, merge_for_serving,
)
from repro.serve.paging import (
    OutOfPagesError, PageAllocator, PagedKVCache, PageError, PrefixCache,
)
from repro.serve.scheduler import (
    ContinuousScheduler, RequestQueue, ServingMetrics, SlotManager,
)
from repro.serve.spec import Drafter, NGramDrafter, SelfDrafter
from repro.serve.tiering import (
    DEFAULT_PRIORITY, PRIORITIES, HostAdapterTier, HostPagePool,
    TieringConfig, priority_rank,
)
