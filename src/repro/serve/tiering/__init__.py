"""Tiered-memory serving (DESIGN.md §Tiering): priority classes,
preempt-and-resume, and host-RAM tiers for KV pages and adapter-bank rows.
"""
from repro.serve.tiering.config import (
    DEFAULT_PRIORITY, PRIORITIES, TieringConfig, priority_rank,
)
from repro.serve.tiering.host_pool import HostAdapterTier, HostPagePool
from repro.serve.tiering.preempt import VictimInfo, choose_mode, choose_victim

__all__ = [
    "DEFAULT_PRIORITY", "PRIORITIES", "TieringConfig", "priority_rank",
    "HostAdapterTier", "HostPagePool",
    "VictimInfo", "choose_mode", "choose_victim",
]
