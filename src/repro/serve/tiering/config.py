"""Tiered-memory serving configuration (DESIGN.md §Tiering).

Jax-free on purpose: the gateway protocol layer (serve/gateway/protocol.py)
imports `PRIORITIES` to validate the `priority` request extension without
pulling the model stack into pure wire-format code.
"""
from __future__ import annotations

from dataclasses import dataclass

# priority classes, best first. Rank order is the scheduling order AND the
# preemption order: a candidate may only preempt victims of STRICTLY worse
# class (equal-class preemption would thrash two peers against each other).
PRIORITIES = ("interactive", "batch", "best_effort")
DEFAULT_PRIORITY = "batch"

_RANK = {p: i for i, p in enumerate(PRIORITIES)}


def priority_rank(priority: str) -> int:
    """Smaller is better; unknown classes sort worst (defensive — the
    queue/protocol validate on entry, so this is belt and braces)."""
    return _RANK.get(priority, len(PRIORITIES))


@dataclass(frozen=True)
class TieringConfig:
    """Knobs for preemption + host-RAM tiers (DESIGN.md §Tiering).

    host_kv_pages:      host KV tier capacity in pages — holds demoted
                        cold prefix pages (LRU, evictable) and preemption
                        snapshots (pinned until resumed). 0 disables the
                        KV host tier: evicted prefix pages are dropped and
                        preemption always recomputes.
    host_adapter_slots: host adapter tier capacity in bank rows — evicted
                        AdapterBank tenants spill here and reload without
                        a checkpoint read. 0 disables it.
    preempt:            allow the scheduler to evict a strictly-lower-class
                        victim slot under page/bank pressure instead of
                        deferring the admission (False = deferral only,
                        the pre-tiering behavior).
    mode:               victim eviction policy: "swap" snapshots the
                        victim's used KV pages to host and restores them
                        on resume; "recompute" drops them and re-prefills
                        prompt+emitted at resume; "auto" picks per victim
                        by cost estimate (see `preempt.choose_mode`).
    swap_cost_per_token: relative cost of moving one token's KV host<->
                        device (in recomputed-token units) — "auto"
                        swaps when 2 * moved_tokens * this < recomputed
                        tokens. The default says a D2H+H2D round trip is
                        ~4x cheaper per token than recomputing it.
    """
    host_kv_pages: int = 0
    host_adapter_slots: int = 0
    preempt: bool = True
    mode: str = "auto"
    swap_cost_per_token: float = 0.125

    def __post_init__(self):
        if self.mode not in ("auto", "swap", "recompute"):
            raise ValueError(f"unknown preempt mode {self.mode!r}; "
                             "one of auto|swap|recompute")
        if self.host_kv_pages < 0 or self.host_adapter_slots < 0:
            raise ValueError("host tier capacities must be >= 0")
