"""Host-RAM tiers for the serving runtime (DESIGN.md §Tiering).

Two pools, both plain host-side bookkeeping over arrays that START as
in-flight device values: the spill paths hand us jax arrays right after a
`copy_to_host_async()` dispatch, we hold them un-materialized, and
`settle()` — called by the runtime once per scheduler round, after the
round's device work is dispatched — converts them to numpy. That keeps the
D2H copies overlapped with decode instead of blocking the scheduler at the
spill site, while still releasing the device buffers promptly (an
unmaterialized spill pins its HBM copy until settled).

`HostPagePool` — KV pages. Two populations share one page-count budget:
  - prefix entries (one page, keyed by the prefix cache's chain hash):
    demoted cold prefix pages, LRU-evictable, promoted back on a match;
  - snapshots (keyed by request id): a preempted victim's used pages,
    PINNED until the request resumes or is cancelled — losing one would
    break the resume-exactness contract, so snapshots never evict and a
    put that cannot fit even after draining every prefix entry fails
    (the scheduler then falls back to recompute-from-prefix).

`HostAdapterTier` — evicted AdapterBank rows ({site: {leaf: array}} trees
keyed by tenant), LRU over `capacity` tenants. A host hit at admission
skips the checkpoint read entirely; a miss falls back to
`load_from_checkpoint` exactly as before.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


def _materialize(arrays: List) -> None:
    """In-place device -> numpy conversion of a spill entry's arrays.
    By the time this runs the async D2H copy has usually landed, so the
    sync is cheap; either way it is the tier's single intended sync."""
    for i, a in enumerate(arrays):
        if not isinstance(a, np.ndarray):
            # settle point of the async spill  # repro: allow(host-sync)
            arrays[i] = np.asarray(a)


class HostPagePool:
    """Host tier for KV pages: LRU prefix entries + pinned snapshots."""

    def __init__(self, capacity_pages: int):
        if capacity_pages < 1:
            raise ValueError("HostPagePool needs capacity_pages >= 1")
        self.capacity_pages = capacity_pages
        # key -> [k, v] with k/v (L, 1, ps, n_kv, hd); LRU order
        self._prefix: "OrderedDict[bytes, List]" = OrderedDict()
        # rid -> ([k, v], n_pages) with k/v (L, P, ps, n_kv, hd)
        self._snapshots: Dict[int, Tuple[List, int]] = {}
        self._snap_pages = 0

    # ---- accounting --------------------------------------------------------
    @property
    def used_pages(self) -> int:
        return len(self._prefix) + self._snap_pages

    @property
    def free_pages(self) -> int:
        return self.capacity_pages - self.used_pages

    def __len__(self) -> int:
        return len(self._prefix) + len(self._snapshots)

    def _make_room(self, need: int) -> bool:
        """Evict LRU prefix entries until `need` pages fit; False when even
        an empty prefix side cannot cover it (snapshots never evict)."""
        if need > self.capacity_pages - self._snap_pages:
            return False
        while self.free_pages < need:
            self._prefix.popitem(last=False)
        return True

    # ---- prefix tier -------------------------------------------------------
    def has_prefix(self, key: bytes) -> bool:
        return key in self._prefix

    def touch_prefix(self, key: bytes) -> bool:
        """has_prefix + LRU refresh: the admission planner probes fill
        candidates through this, so the keys a plan is about to promote
        become MRU and `_make_room` (fed by the SAME plan's device-side
        demotions) displaces older entries first. Not a pin — a plan
        whose demotions exceed the pool can still age its own fills out,
        which the runtime degrades to recompute."""
        if key not in self._prefix:
            return False
        self._prefix.move_to_end(key)
        return True

    def put_prefix(self, key: bytes, k, v) -> bool:
        """Admit one demoted prefix page (k/v may be in-flight device
        arrays). False when the pool cannot fit it — the page is simply
        dropped, the pre-tiering behavior."""
        if key in self._prefix:
            self._prefix.move_to_end(key)
            return True
        if not self._make_room(1):
            return False
        self._prefix[key] = [k, v]
        return True

    def get_prefix(self, key: bytes) -> Optional[Tuple[np.ndarray,
                                                       np.ndarray]]:
        """Materialized (k, v) for one host-resident chunk (LRU-touched),
        or None. The entry STAYS host-resident — a promotion copies it
        back to device pages; the host copy ages out via LRU."""
        entry = self._prefix.get(key)
        if entry is None:
            return None
        self._prefix.move_to_end(key)
        _materialize(entry)
        return entry[0], entry[1]

    # ---- snapshot tier -----------------------------------------------------
    def put_snapshot(self, rid: int, k, v, n_pages: int) -> bool:
        """Pin a preemption snapshot (page dim of k/v may be padded past
        `n_pages` by the spill gather's pow2 bucketing — the budget charges
        the stored width, which is what host RAM actually holds)."""
        if rid in self._snapshots:
            raise KeyError(f"request {rid} already holds a snapshot")
        width = int(k.shape[1])
        if not self._make_room(width):
            return False
        self._snapshots[rid] = ([k, v], n_pages)
        self._snap_pages += width
        return True

    def pop_snapshot(self, rid: int) -> Tuple[np.ndarray, np.ndarray, int]:
        """Consume (materialized k, v, n_pages) at resume; frees budget."""
        entry, n_pages = self._snapshots.pop(rid)
        self._snap_pages -= int(entry[0].shape[1])
        _materialize(entry)
        return entry[0], entry[1], n_pages

    def drop_snapshot(self, rid: int) -> bool:
        """Discard a snapshot without resuming (cancelled request)."""
        entry = self._snapshots.pop(rid, None)
        if entry is None:
            return False
        self._snap_pages -= int(entry[0][0].shape[1])
        return True

    def has_snapshot(self, rid: int) -> bool:
        return rid in self._snapshots

    # ---- lifecycle ---------------------------------------------------------
    def settle(self) -> None:
        """Materialize every in-flight spill (runtime calls this once per
        scheduler round, after dispatching the round's device work)."""
        for entry in self._prefix.values():
            _materialize(entry)
        for entry, _ in self._snapshots.values():
            _materialize(entry)


class HostAdapterTier:
    """LRU host tier for evicted AdapterBank rows."""

    def __init__(self, capacity: int,
                 on_spill: Optional[Callable[[], None]] = None):
        if capacity < 1:
            raise ValueError("HostAdapterTier needs capacity >= 1")
        self.capacity = capacity
        self.on_spill = on_spill
        # aid -> (method, {site: [leaf names]}, [arrays in site/leaf order])
        self._entries: "OrderedDict[str, Tuple[str, Dict, List]]" = \
            OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, adapter_id: str) -> bool:
        return adapter_id in self._entries

    def put(self, adapter_id: str, method: str,
            tree: Dict[str, Dict]) -> None:
        """Admit one evicted tenant's trainable rows ({site: {leaf: arr}};
        arrays may be in-flight device slices). Evicts the LRU tenant past
        capacity."""
        names = {site: sorted(leaves) for site, leaves in tree.items()}
        arrays = [tree[site][leaf] for site in sorted(names)
                  for leaf in names[site]]
        self._entries.pop(adapter_id, None)
        self._entries[adapter_id] = (method, names, arrays)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
        if self.on_spill is not None:
            self.on_spill()

    def get(self, adapter_id: str) -> Optional[Tuple[str, Dict]]:
        """(method, materialized {site: {leaf: np.ndarray}}) or None."""
        entry = self._entries.get(adapter_id)
        if entry is None:
            return None
        self._entries.move_to_end(adapter_id)
        method, names, arrays = entry
        _materialize(arrays)
        it = iter(arrays)
        tree = {site: {leaf: next(it) for leaf in names[site]}
                for site in sorted(names)}
        return method, tree

    def drop(self, adapter_id: str) -> bool:
        """Discard a spilled row (a fresh device load supersedes it).
        Returns whether anything was held."""
        return self._entries.pop(adapter_id, None) is not None

    def settle(self) -> None:
        for _, _, arrays in self._entries.values():
            _materialize(arrays)
