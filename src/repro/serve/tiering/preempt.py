"""Victim selection + eviction-mode cost model for preempt-and-resume
(DESIGN.md §Tiering).

Pure host-side policy — no jax, no scheduler state. The runtime hands in
plain numbers and applies the verdicts; keeping the policy here makes it
unit-testable without a model and swappable without touching the decode
loop.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from repro.serve.tiering.config import TieringConfig


@dataclass(frozen=True)
class VictimInfo:
    """One ACTIVE slot as the victim picker sees it."""
    slot: int
    rank: int                  # priority_rank of the occupant's class
    prompt_len: int            # original prompt tokens
    emitted: int               # tokens generated so far
    used_pages: int            # pages holding written KV rows


def choose_victim(candidate_rank: int,
                  occupants: List[VictimInfo]) -> Optional[VictimInfo]:
    """The slot to evict for a blocked candidate of `candidate_rank`, or
    None when no slot is eligible. Only STRICTLY worse classes are
    eligible (equal-class preemption would let two peers thrash); among
    them, take the worst class first, then the least progress (cheapest
    stream to redo/move), then the highest slot index (deterministic)."""
    eligible = [o for o in occupants if o.rank > candidate_rank]
    if not eligible:
        return None
    return max(eligible, key=lambda o: (o.rank, -o.emitted, o.slot))


def choose_mode(cfg: TieringConfig, victim: VictimInfo, page_size: int,
                host_can_swap: bool) -> str:
    """"swap" or "recompute" for one eviction.

    The estimate compares token-equivalent work: recompute re-prefills
    prompt + emitted tokens at resume, swap moves used_pages * page_size
    token rows across PCIe twice (spill + fill). `swap_cost_per_token`
    converts moved tokens into recomputed-token units. A forced "swap"
    still degrades to recompute when the host pool cannot take the
    snapshot — correctness never depends on host capacity."""
    if not host_can_swap:
        return "recompute"
    if cfg.mode != "auto":
        return cfg.mode
    cost_swap = 2.0 * victim.used_pages * page_size * cfg.swap_cost_per_token
    cost_recompute = float(victim.prompt_len + victim.emitted)
    return "swap" if cost_swap < cost_recompute else "recompute"
