"""Paged KV cache with shared-prefix reuse (DESIGN.md §Paging).

Host-side bookkeeping for the continuous-batching runtime's paged cache:

- `PageAllocator` — refcounted free-list over a fixed pool of fixed-size
  pages. Pages [0, n_reserved) are per-slot scratch (one per decode slot,
  never allocated or freed): every slot's unallocated block-table entries
  point at its own scratch page, so decode's unconditional scatter write
  always has a unique, harmless target.
- `PrefixCache` — chained hash of page-aligned prompt-prefix chunks ->
  immutable page. The chain key is seeded with the request's adapter id:
  factored adapters transform the backbone projections, so a prefix's KV is
  TENANT-DEPENDENT — sharing it across tenants would serve wrong math
  (bit-exactness would break). Same-tenant (and bare-base) traffic with a
  common system prompt is exactly the workload that shares. Each entry
  holds one allocator reference; entries whose page no live block table
  shares (refcount == 1) are LRU-evicted when the pool runs dry.
- `PagedKVCache` — the per-slot block-table manager gluing both to the
  scheduler's admit/decode/release lifecycle: `plan_admit` matches the
  prompt against the prefix cache, allocates the slot's owned pages
  up-front (every position the request can ever write, so decode NEVER
  allocates — admission is the only point that can defer on capacity), and
  returns the `PrimePlan` the runtime's tail prefill consumes; `release`
  frees/derefs every page the slot holds the same step its request
  completes.

COW rule: shared pages are immutable. Tail prefill and decode only ever
write positions >= prefix_len, which lie past every shared page — except
when a prompt is EXACTLY a cached page-aligned prefix: its last token must
still run through the model for the next-token logits, and that token's KV
row lives inside the final shared page. `plan_admit` then returns a
`cow=(src, dst)` pair — the runtime clones src into a freshly-owned dst
(`Model.copy_page`) and the 1-token tail write lands in the clone, leaving
the shared original byte-identical for its other holders.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple

import numpy as np

_CHAIN_SEED = b"repro-paging-v1/"


class PageError(RuntimeError):
    """Refcount misuse: double free / ref of a free page / reserved-page
    free — always a bug in the caller's lifecycle, never load-dependent."""


class OutOfPagesError(PageError):
    """The pool has no free page (after prefix-cache eviction)."""


class PageAllocator:
    """Refcounted free-list over `n_pages` fixed-size pages; pages
    [0, n_reserved) are reserved per-slot scratch, outside alloc/free."""

    def __init__(self, n_pages: int, n_reserved: int = 0):
        if n_pages <= n_reserved:
            raise ValueError(f"pool of {n_pages} pages can't reserve "
                             f"{n_reserved}")
        self.n_pages = n_pages
        self.n_reserved = n_reserved
        self._free = list(range(n_reserved, n_pages))
        self._refs = [0] * n_pages

    def free_count(self) -> int:
        return len(self._free)

    def refcount(self, page: int) -> int:
        return self._refs[page]

    def alloc(self) -> int:
        if not self._free:
            raise OutOfPagesError(
                f"page pool exhausted ({self.n_pages} pages, "
                f"{self.n_reserved} reserved)")
        page = self._free.pop()
        self._refs[page] = 1
        return page

    def ref(self, page: int) -> None:
        if page < self.n_reserved or self._refs[page] < 1:
            raise PageError(f"ref of unallocated page {page}")
        self._refs[page] += 1

    def free(self, page: int) -> None:
        if page < self.n_reserved:
            raise PageError(f"free of reserved scratch page {page}")
        if self._refs[page] < 1:
            raise PageError(f"double free of page {page}")
        self._refs[page] -= 1
        if self._refs[page] == 0:
            self._free.append(page)


class PrefixCache:
    """Chained-hash prefix chunks -> immutable pages, LRU-evictable.

    Entries form chains (chunk c's key hashes the whole prefix through it),
    so evicting an interior chunk while a descendant stays cached would
    strand the descendant: `match` walks front-to-back and stops at the
    first miss, making the still-referenced descendant pages unreachable
    dead weight. Eviction is therefore LEAF-FIRST — an entry is evictable
    only while no cached entry names it as parent — which also means chains
    shrink from the tail, exactly the cold end of a shared prefix.

    `on_evict(key, page)` fires right before each page is freed; the
    tiering runtime uses it to demote the page's KV to the host pool
    (DESIGN.md §Tiering). The hook must not touch the cache."""

    def __init__(self, allocator: PageAllocator,
                 on_evict: Optional[Callable[[bytes, int], None]] = None):
        self._alloc = allocator
        self.on_evict = on_evict
        self._entries: "OrderedDict[bytes, int]" = OrderedDict()
        self._parent: Dict[bytes, Optional[bytes]] = {}
        self._nkids: Dict[bytes, int] = {}

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def pages(self) -> Tuple[int, ...]:
        return tuple(self._entries.values())

    @staticmethod
    def chain_keys(prompt: np.ndarray, page_size: int,
                   adapter_id: Optional[str]) -> List[bytes]:
        """One key per full page-aligned chunk of `prompt`, each hashing
        the ENTIRE prefix through it (chained), seeded by the tenant."""
        keys = []
        h = _CHAIN_SEED + (adapter_id or "").encode()
        for c in range(len(prompt) // page_size):
            chunk = np.ascontiguousarray(
                prompt[c * page_size:(c + 1) * page_size], dtype=np.int32)
            h = hashlib.blake2b(h + chunk.tobytes(),
                                digest_size=16).digest()
            keys.append(h)
        return keys

    def match(self, keys: List[bytes]) -> List[int]:
        """Pages of the longest cached chain prefix (LRU-touched)."""
        pages = []
        for key in keys:
            page = self._entries.get(key)
            if page is None:
                break
            self._entries.move_to_end(key)
            pages.append(page)
        return pages

    def insert(self, key: bytes, page: int,
               parent: Optional[bytes] = None) -> None:
        """Register `page` as the immutable holder of chunk `key` (takes
        one allocator reference); `parent` is the previous chunk's key in
        the chain (None for the first chunk). No-op when the chunk is
        already cached — the existing page stays canonical.

        The parent link is recorded even when the ancestor is currently
        absent: chain keys are pure functions of the prefix, so if the
        ancestor's key is ever (re-)inserted it must immediately count
        this child — otherwise leaf-first eviction could evict the
        interior chunk first, stranding the descendant (unreachable —
        `match` stops at the first miss — yet still holding its page)."""
        if key in self._entries:
            self._entries.move_to_end(key)
            return
        self._alloc.ref(page)
        self._entries[key] = page
        self._parent[key] = parent
        if parent is not None:
            self._nkids[parent] = self._nkids.get(parent, 0) + 1

    def _drop(self, key: bytes, page: int) -> None:
        if self.on_evict is not None:
            self.on_evict(key, page)
        del self._entries[key]
        parent = self._parent.pop(key, None)
        if parent is not None:
            self._nkids[parent] -= 1
            if not self._nkids[parent]:
                del self._nkids[parent]
        self._alloc.free(page)

    def evict_until_free(self, need: int) -> Tuple[int, int]:
        """Drop entries until `need` pages are free, leaf-first in LRU
        order, touching only pages no block table shares (refcount 1).
        Stops the moment the free list covers `need` — never overshoots —
        and reports (evicted, shortfall) where shortfall is how many pages
        the caller still lacks because every remaining entry is pinned (by
        a live block table or a cached descendant)."""
        evicted = 0
        progress = True
        while progress and self._alloc.free_count() < need:
            progress = False
            for key in list(self._entries):
                if self._alloc.free_count() >= need:
                    break
                if self._nkids.get(key):
                    continue        # interior chunk: descendants first
                page = self._entries[key]
                if self._alloc.refcount(page) == 1:
                    self._drop(key, page)
                    evicted += 1
                    progress = True
        return evicted, max(0, need - self._alloc.free_count())


@dataclass
class PrimePlan:
    """Everything the runtime's paged prime needs for one admission."""
    slot: int
    prefix_len: int            # reused tokens already resident in pages
    tail: np.ndarray           # prompt[prefix_len:] — what prefill computes
    block_row: np.ndarray      # (pages_per_seq,) int32
    cow: Optional[Tuple[int, int]]   # (src, dst) page clone, or None
    scratch_page: int
    chunk_keys: List[bytes]    # chain keys of the prompt's full chunks —
                               # published via register_prompt AFTER the
                               # prime fills the pages
    fills: List[Tuple[int, bytes]] = field(default_factory=list)
                               # host-resident chunks to copy into owned
                               # pages before the prime: (chunk index c,
                               # chain key) — the target page is
                               # block_row[c] (DESIGN.md §Tiering)


class PagedKVCache:
    """Block-table + page-lifecycle manager for one paged decode pool.

    `host_has` (optional, set by the tiering runtime) answers whether a
    chain key is resident in the host KV tier; when set, `plan_admit`
    extends a device prefix match with host-resident chunks and returns
    them as `PrimePlan.fills` for the runtime to copy back (promote)
    before the prime."""

    def __init__(self, n_slots: int, max_len: int, page_size: int = 16,
                 n_pages: Optional[int] = None):
        self.host_has: Optional[Callable[[bytes], bool]] = None
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.n_slots = n_slots
        self.max_len = max_len
        self.page_size = page_size
        self.pages_per_seq = pps = -(-max_len // page_size)
        if n_pages is None:
            # worst case (zero sharing): every slot owns its full window;
            # headroom lets the prefix cache retain pages across requests
            n_pages = n_slots + n_slots * pps + 2 * pps
        if n_pages < n_slots + pps:
            raise ValueError(
                f"{n_pages} pages cannot hold {n_slots} scratch pages plus "
                f"one full {pps}-page window")
        self.n_pages = n_pages
        self.allocator = PageAllocator(n_pages, n_reserved=n_slots)
        self.prefix_cache = PrefixCache(self.allocator)
        # scratch page of slot i is page i: unallocated entries default there
        self.block_tables = np.tile(
            np.arange(n_slots, dtype=np.int32)[:, None], (1, pps))
        self._slot_pages: List[List[int]] = [[] for _ in range(n_slots)]
        self._device_bt = None

    # ---- admission --------------------------------------------------------
    def plan_admit(self, slot: int, prompt: np.ndarray, max_new: int,
                   adapter_id: Optional[str] = None,
                   keys: Optional[List[bytes]] = None) -> Optional[PrimePlan]:
        """Build the slot's block-table row for one request: match the
        prompt's page-aligned prefix against the prefix cache, allocate
        every owned page the request can ever write (positions
        0..S+max_new-2 — the last generated token is never written), and
        register the prompt's own full chunks for future sharing. Returns
        None when the pool (after eviction) cannot cover the owned pages —
        the scheduler defers the request, exactly like a pinned-full bank.

        keys: precomputed `PrefixCache.chain_keys(prompt, page_size,
        adapter_id)` — a deferred request is re-offered every admission
        cycle, and the chain hash is a pure function of the prompt, so the
        scheduler memoizes it instead of re-hashing per offer."""
        if self._slot_pages[slot]:
            raise PageError(f"slot {slot} still holds pages")
        prompt = np.asarray(prompt)
        S = int(prompt.shape[0])
        ps = self.page_size
        total_pages = -(-(S + max_new - 1) // ps)
        if total_pages > self.pages_per_seq:
            raise ValueError(
                f"prompt ({S}) + max_new ({max_new}) needs {total_pages} "
                f"pages > pages_per_seq ({self.pages_per_seq})")
        if keys is None:
            keys = PrefixCache.chain_keys(prompt, ps, adapter_id)
        shared = self.prefix_cache.match(keys)
        cow_src = None
        if shared and len(shared) * ps >= S:
            # the prompt IS a cached page-aligned prefix: its last token
            # must still be recomputed for the next-token logits, and its
            # KV row lives inside the final shared page -> COW that page
            cow_src = shared.pop()
        fills: List[Tuple[int, bytes]] = []
        if cow_src is None and self.host_has is not None:
            # extend the device match with host-resident chunks. The fill
            # target is an OWNED page (no pinning, no COW interplay), and
            # we stop one token short of full coverage so the last prompt
            # token always prefills on device for its logits — the host
            # tier never recreates the COW corner.
            c = len(shared)
            while ((c + 1) * ps <= S - 1 and c < len(keys)
                   and self.host_has(keys[c])):
                fills.append((c, keys[c]))
                c += 1
        # pin the matched pages (and the COW source) BEFORE any eviction:
        # once their original slots drained they sit at refcount 1 (cache-
        # only), exactly what the LRU pass below frees — matching without
        # pinning would let eviction pull the pages out from under us
        for page in shared:
            self.allocator.ref(page)
        if cow_src is not None:
            self.allocator.ref(cow_src)
        n_owned = total_pages - len(shared)
        if self.allocator.free_count() < n_owned:
            self.prefix_cache.evict_until_free(n_owned)
        if self.allocator.free_count() < n_owned:
            # give the match back before deferring: the entries WE pinned
            # may be the only evictable pages (e.g. a fully-cached prompt
            # at the capacity bound on a minimal pool, where the COW clone
            # needs one page more than a full window) — a cold prime needs
            # more owned pages but zero pins, and always fits a pool that
            # holds one full window once the cache is drained
            for page in shared:
                self.allocator.free(page)
            if cow_src is not None:
                self.allocator.free(cow_src)
            shared, cow_src, fills = [], None, []
            n_owned = total_pages
            if self.allocator.free_count() < n_owned:
                self.prefix_cache.evict_until_free(n_owned)
                if self.allocator.free_count() < n_owned:
                    return None
        row = np.full((self.pages_per_seq,), slot, np.int32)
        held: List[int] = list(shared)         # pinned above
        for i, page in enumerate(shared):
            row[i] = page
        owned = [self.allocator.alloc() for _ in range(n_owned)]
        for i, page in enumerate(owned):
            row[len(shared) + i] = page
            held.append(page)
        if cow_src is not None:
            prefix_len = S - 1
            cow = (cow_src, owned[0])
            held.append(cow_src)   # the pin guards src until the runtime's
        else:                      # copy_page; held through the request —
            # filled chunks count as resident prefix: the runtime copies
            # them into their owned pages before the prime runs
            prefix_len = (len(shared) + len(fills)) * ps
            cow = None
        self._slot_pages[slot] = held
        self.block_tables[slot] = row
        self._device_bt = None
        return PrimePlan(slot=slot, prefix_len=prefix_len,
                         tail=prompt[prefix_len:], block_row=row,
                         cow=cow, scratch_page=slot, chunk_keys=keys,
                         fills=fills)

    def register_prompt(self, plan: PrimePlan) -> None:
        """Publish the plan's full page-aligned chunks into the prefix
        cache. Called by the runtime AFTER the prime prefill has filled the
        pages — registering inside plan_admit would poison the cache with
        never-filled pages if the prime raised (the pages are immutable
        from here on: tail writes stop at position S-1, decode writes start
        at S, both past every full chunk)."""
        for c, key in enumerate(plan.chunk_keys):
            self.prefix_cache.insert(key, int(plan.block_row[c]),
                                     parent=plan.chunk_keys[c - 1] if c
                                     else None)

    def plan_resume(self, slot: int, total_pages: int) -> Optional[PrimePlan]:
        """Block-table row for a swap-resumed request (DESIGN.md §Tiering):
        all `total_pages` pages are freshly owned — the snapshot holds the
        victim's exact KV including any formerly-shared prefix pages, so
        nothing is matched or pinned and the restored pages stay private
        (re-publishing them could collide with keys the cache still holds
        canonical pages for; resume keeps it simple and private). Returns
        None when the pool cannot cover it — the scheduler keeps the
        request queued and re-offers next cycle."""
        if self._slot_pages[slot]:
            raise PageError(f"slot {slot} still holds pages")
        if total_pages > self.pages_per_seq:
            raise ValueError(
                f"resume needs {total_pages} pages > pages_per_seq "
                f"({self.pages_per_seq})")
        if self.allocator.free_count() < total_pages:
            self.prefix_cache.evict_until_free(total_pages)
            if self.allocator.free_count() < total_pages:
                return None
        row = np.full((self.pages_per_seq,), slot, np.int32)
        owned = [self.allocator.alloc() for _ in range(total_pages)]
        for i, page in enumerate(owned):
            row[i] = page
        self._slot_pages[slot] = owned
        self.block_tables[slot] = row
        self._device_bt = None
        return PrimePlan(slot=slot, prefix_len=0,
                         tail=np.empty((0,), np.int32), block_row=row,
                         cow=None, scratch_page=slot, chunk_keys=[])

    # ---- lifecycle --------------------------------------------------------
    def release(self, slot: int) -> None:
        """Free every page reference the slot holds (owned pages return to
        the free list unless the prefix cache retains them) and point the
        slot's block-table row back at its scratch page."""
        for page in self._slot_pages[slot]:
            self.allocator.free(page)
        self._slot_pages[slot] = []
        self.block_tables[slot, :] = slot
        self._device_bt = None

    def block_table_device(self):
        """(n_slots, pages_per_seq) int32 on device, cached until the host
        tables change — one small transfer per admission/release, not per
        decode step."""
        if self._device_bt is None:
            import jax.numpy as jnp
            self._device_bt = jnp.asarray(self.block_tables)
        return self._device_bt

    # ---- invariants (tests) -----------------------------------------------
    def holders(self) -> Dict[int, int]:
        """page -> number of holders (slots + prefix cache), non-reserved."""
        refs: Dict[int, int] = {}
        for pages in self._slot_pages:
            for page in pages:
                refs[page] = refs.get(page, 0) + 1
        for page in self.prefix_cache.pages:
            refs[page] = refs.get(page, 0) + 1
        return refs

    def assert_no_leaks(self) -> None:
        """Every non-reserved page's refcount equals its holder count, and
        unheld pages are exactly the free list."""
        refs = self.holders()
        free = 0
        for page in range(self.n_slots, self.n_pages):
            expect = refs.get(page, 0)
            got = self.allocator.refcount(page)
            if got != expect:
                raise AssertionError(
                    f"page {page}: refcount {got} != {expect} holders")
            free += expect == 0
        if free != self.allocator.free_count():
            raise AssertionError(
                f"{free} unheld pages but free list has "
                f"{self.allocator.free_count()}")
