"""FourierFT core (the paper's contribution, TPU-adapted).

ΔW = α · Re(IFFT2(ToDense(E, c)))  (paper Eq. 2–4, Algorithm 1 normalization)

On TPU we never run an FFT. The closed form

    ΔW[j,k] = α/(d1·d2) · Σ_l c_l · cos(2π(j·u_l/d1 + k·v_l/d2))
            = [cosθ ⊙ c] @ cosφᵀ − [sinθ ⊙ c] @ sinφᵀ

expresses FourierFT as a rank-2n adapter with frozen Fourier factors and a
trainable diagonal — two MXU matmuls (see DESIGN.md §2). The FFT form survives
as the reference oracle in `repro.kernels.ref`.

Entry sampling supports the paper's Eq. 5 Gaussian band-pass frequency bias.
Entries are shared across all layers (paper: one seed for every layer; we use
one seed per adapted weight *shape*, since distinct (d1,d2) grids cannot share
integer entries — GQA value projections are rectangular).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

TWO_PI = 2.0 * np.pi


# ---------------------------------------------------------------------------
# Entry sampling (host-side, deterministic; runs once at adapter init)
# ---------------------------------------------------------------------------

def _bandpass_prob(d1: int, d2: int, fc: float, bandwidth: float,
                   centered: bool = True) -> np.ndarray:
    """Paper Eq. 5: p(u,v) = exp(-((D² - fc²) / (D·W))²), D = distance to the
    matrix center (paper-literal; note that in unshifted DFT indexing the
    center is the Nyquist frequency — pass centered=False for a physical
    wraparound distance-to-DC, i.e. a true low/band-pass over |frequency|).
    D=0 is a removable singularity: p→1 iff fc==0 else p→0."""
    if centered:
        u = np.arange(d1, dtype=np.float64)[:, None] - d1 / 2.0
        v = np.arange(d2, dtype=np.float64)[None, :] - d2 / 2.0
    else:
        uu = np.arange(d1, dtype=np.float64)
        vv = np.arange(d2, dtype=np.float64)
        u = np.minimum(uu, d1 - uu)[:, None]
        v = np.minimum(vv, d2 - vv)[None, :]
    D = np.sqrt(u * u + v * v)
    with np.errstate(divide="ignore", invalid="ignore"):
        z = (D * D - fc * fc) / (D * bandwidth)
    p = np.exp(-np.square(z))
    p[D == 0] = 1.0 if fc == 0 else 0.0
    return p


def sample_entries(d1: int, d2: int, n: int, seed: int = 2024, *,
                   freq_bias: bool = False, fc: float = 0.0,
                   bandwidth: float = 200.0,
                   centered: bool = True) -> jnp.ndarray:
    """Sample n distinct spectral entries of a d1×d2 grid. Returns int32 (2, n).

    No-bias default matches Algorithm 1 (`randperm(d1*d2)[:n]`), decoded
    row-major (`divmod(idx, d2)` — Algorithm 1's `// d1` assumes square W).
    With freq_bias, Gumbel-top-k over Eq. 5 log-probabilities gives an exact
    without-replacement draw from the band-pass distribution.
    """
    if n > d1 * d2:
        raise ValueError(f"n={n} exceeds grid size {d1}x{d2}")
    rng = np.random.default_rng(seed)
    if freq_bias:
        logp = np.log(_bandpass_prob(d1, d2, fc, bandwidth, centered)
                      + 1e-30).ravel()
        gumbel = rng.gumbel(size=logp.shape)
        flat = np.argpartition(-(logp + gumbel), n - 1)[:n]
    elif d1 * d2 <= (1 << 24):
        flat = rng.permutation(d1 * d2)[:n]
    else:
        # huge grids (e.g. embedding-sized): draw-and-dedup, O(n) memory
        flat = np.unique(rng.integers(0, d1 * d2, size=2 * n))
        while flat.size < n:
            flat = np.unique(np.concatenate(
                [flat, rng.integers(0, d1 * d2, size=2 * n)]))
        flat = rng.permutation(flat)[:n]
    uv = np.stack(np.divmod(flat.astype(np.int64), d2))
    return jnp.asarray(uv, dtype=jnp.int32)


# ---------------------------------------------------------------------------
# Fourier bases (traced; generated on the fly, never checkpointed)
# ---------------------------------------------------------------------------

def fourier_angles(entries: jax.Array, d1: int, d2: int):
    """Phase grids for the selected entries: θ[j,l] = 2π·j·u_l/d1 (d1, n)
    and φ[k,l] = 2π·k·v_l/d2 (d2, n)."""
    u = entries[0].astype(jnp.float32)   # (n,)
    v = entries[1].astype(jnp.float32)
    j = jnp.arange(d1, dtype=jnp.float32)[:, None]
    k = jnp.arange(d2, dtype=jnp.float32)[:, None]
    theta = (TWO_PI / d1) * (j * u[None, :])
    phi = (TWO_PI / d2) * (k * v[None, :])
    return theta, phi


def fourier_bases(entries: jax.Array, d1: int, d2: int):
    theta, phi = fourier_angles(entries, d1, d2)
    return jnp.cos(theta), jnp.sin(theta), jnp.cos(phi), jnp.sin(phi)


# ---------------------------------------------------------------------------
# ΔW materialization (einsum path; the Pallas path lives in repro.kernels)
# ---------------------------------------------------------------------------

def materialize_delta(c: jax.Array, entries: jax.Array, d1: int, d2: int,
                      alpha: float, *, out_dtype=None) -> jax.Array:
    """ΔW for one layer (c: (n,)) or a stack (c: (L, n) -> (L, d1, d2)).

    scale = α/(d1·d2) matches `torch.fft.ifft2` backward normalization used by
    the paper's Algorithm 1.
    """
    cos_t, sin_t, cos_p, sin_p = fourier_bases(entries, d1, d2)
    scale = alpha / (d1 * d2)
    c = c.astype(jnp.float32)
    if c.ndim == 1:
        dw = (cos_t * c) @ cos_p.T - (sin_t * c) @ sin_p.T
    else:
        # stacked layers: contract n against shared bases
        dw = (jnp.einsum("ln,dn,en->lde", c, cos_t, cos_p)
              - jnp.einsum("ln,dn,en->lde", c, sin_t, sin_p))
    dw = dw * scale
    return dw.astype(out_dtype) if out_dtype is not None else dw


def factored_apply(x: jax.Array, c: jax.Array, entries: jax.Array,
                   d1: int, d2: int, alpha: float) -> jax.Array:
    """y += x @ ΔW without materializing ΔW (rank-2n bypass).

    x: (..., d1) -> (..., d2). Exactly equals x @ materialize_delta(...).
    """
    cos_t, sin_t, cos_p, sin_p = fourier_bases(entries, d1, d2)
    scale = alpha / (d1 * d2)
    xf = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    pc = (xf @ cos_t) * c                      # (..., n)
    ps = (xf @ sin_t) * c
    y = pc @ cos_p.T - ps @ sin_p.T
    return (y * scale).astype(x.dtype)


def delta_norm(c: jax.Array, entries: jax.Array, d1: int, d2: int,
               alpha: float) -> jax.Array:
    """||ΔW||_F via Parseval, without materialization (logging/guards).

    ⟨cos ψ_l, cos ψ_m⟩ over the grid is (d1·d2/2)·(eq[l,m] + conj[l,m]) where
    conj matches entry m against (-u_l, -v_l) mod (d1, d2) — conjugate-pair
    entries share one real basis function, so the Gram matrix is not diagonal;
    the exact O(n²) form is cheap at adapter sizes."""
    u, v = entries[0], entries[1]
    cf = c.astype(jnp.float32)
    conj = ((u[:, None] == (d1 - u[None, :]) % d1)
            & (v[:, None] == (d2 - v[None, :]) % d2))
    s = jnp.sum(jnp.square(cf)) + jnp.einsum(
        "l,m,lm->", cf, cf, conj.astype(jnp.float32))
    scale = alpha / (d1 * d2)
    return scale * jnp.sqrt(jnp.maximum(s, 0.0) * d1 * d2 / 2.0)
