"""PEFT attachment layer: adapter initialization, parameter accounting
(paper Table 1), and the per-site trees shared by every registered
`AdapterMethod` (core/adapter.py).

A model advertises its adaptable 2-D weight matrices as `AdapterSite`s
(name, d_in, d_out, stack). Adapter params live in a tree parallel to the base
params: params = {"base": ..., "peft": {site.name: {...}}}. Only "peft" (plus
optionally the head) receives gradients — XLA then dead-code-eliminates every
frozen-weight gradient GEMM.

Method-specific math lives behind the `AdapterMethod` protocol; the functions
here are site-tree plumbing (target filtering, per-site RNG folding,
accounting sums) and stay method-agnostic.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax

from repro.configs.base import ModelConfig, PEFTConfig
from repro.core import adapter as adapter_api
from repro.core.adapter import AdapterSite, entry_seed_for  # noqa: F401 (re-export)


def init_site(rng: jax.Array, site: AdapterSite, peft: PEFTConfig) -> Dict:
    return adapter_api.resolve(peft.method).init_site(rng, site, peft)


def init_adapters(rng: jax.Array, sites: Sequence[AdapterSite],
                  peft: PEFTConfig) -> Dict:
    method = adapter_api.resolve(peft.method)
    if not method.has_site_params:
        return {}
    out = {}
    for i, site in enumerate(sites):
        if site.name.split("/")[-1] not in peft.target_modules:
            continue
        out[site.name] = method.init_site(jax.random.fold_in(rng, i), site,
                                          peft)
    return out


def site_delta(adapter: Dict, site: AdapterSite, peft: PEFTConfig,
               out_dtype) -> jax.Array:
    """Materialize the stacked ΔW (stack, d_in, d_out) for one site."""
    return adapter_api.resolve(peft.method).site_delta(adapter, site, peft,
                                                       out_dtype)


def trainable_adapter_tree(adapters: Dict, peft: PEFTConfig) -> Dict:
    trainable = set(adapter_api.resolve(peft.method).trainable_leaves(peft))
    return {
        site: {k: v for k, v in d.items() if k in trainable}
        for site, d in adapters.items()
    }


# ---------------------------------------------------------------------------
# Parameter accounting (paper Table 1 / §3.2)
# ---------------------------------------------------------------------------

def _targeted(sites: Sequence[AdapterSite],
              peft: PEFTConfig) -> List[AdapterSite]:
    return [s for s in sites
            if s.name.split("/")[-1] in peft.target_modules]


def count_trainable(sites: Sequence[AdapterSite], peft: PEFTConfig) -> int:
    """|Θ| per paper §3.2 — coefficient parameters only (entries are stored,
    not trained; the paper counts n·L_t for FourierFT, 2·d·L_t·r for LoRA)."""
    method = adapter_api.resolve(peft.method)
    return sum(method.count_trainable(s, peft) for s in _targeted(sites, peft))


def storage_bytes(sites: Sequence[AdapterSite], peft: PEFTConfig,
                  bytes_per_param: int = 4) -> int:
    """Checkpoint bytes: trainables plus whatever frozen numbers the method
    must carry (FourierFT: the 2n integer entries once per shape group —
    paper: n·(2+L) numbers total)."""
    method = adapter_api.resolve(peft.method)
    extra = method.shared_storage_numbers(_targeted(sites, peft), peft)
    return (count_trainable(sites, peft) + extra) * bytes_per_param


def qv_sites_for(cfg: ModelConfig) -> List[AdapterSite]:
    """The paper's default adaptation set: per-block query and value
    projections (L_t = 2·num_layers square-ish matrices)."""
    return [
        AdapterSite("layers/wq", cfg.d_model, cfg.attn_dim, cfg.num_layers),
        AdapterSite("layers/wv", cfg.d_model, cfg.kv_dim, cfg.num_layers),
    ]
