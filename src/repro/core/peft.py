"""PEFT attachment layer: adapter initialization, parameter accounting
(paper Table 1), merging, and the per-site machinery shared by FourierFT,
LoRA, and the basis ablations.

A model advertises its adaptable 2-D weight matrices as `AdapterSite`s
(name, d_in, d_out, stack). Adapter params live in a tree parallel to the base
params: params = {"base": ..., "peft": {site.name: {...}}}. Only "peft" (plus
optionally the head) receives gradients — XLA then dead-code-eliminates every
frozen-weight gradient GEMM.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, PEFTConfig
from repro.core import fourierft, lora, basis as basis_mod


@dataclass(frozen=True)
class AdapterSite:
    name: str          # matches the stacked weight key in base params
    d_in: int
    d_out: int
    stack: int         # number of layers stacked on axis 0 (scan-over-layers)


def entry_seed_for(peft: PEFTConfig, site: AdapterSite) -> int:
    """Paper: one shared seed (2024) for all layers. Distinct (d1, d2) grids
    cannot share integer entries, so the seed is offset per site shape only
    when shapes differ; equal-shaped sites share entries exactly as the paper
    prescribes."""
    return peft.entry_seed + hash((site.d_in, site.d_out)) % 1000


def init_site(rng: jax.Array, site: AdapterSite, peft: PEFTConfig) -> Dict:
    dtype = jnp.dtype(peft.param_dtype)
    if peft.method == "fourierft":
        if peft.basis == "fourier":
            entries = fourierft.sample_entries(
                site.d_in, site.d_out, peft.n, entry_seed_for(peft, site),
                freq_bias=peft.freq_bias, fc=peft.fc, bandwidth=peft.bandwidth)
            aux = {"entries": entries}
        else:
            b1, b2 = basis_mod.make_basis(
                jax.random.fold_in(jax.random.PRNGKey(peft.entry_seed),
                                   site.d_in * 131071 + site.d_out),
                peft.basis, site.d_in, site.d_out, peft.n)
            aux = {"b1": b1, "b2": b2}
        c = jax.random.normal(rng, (site.stack, peft.n), dtype)
        return {"c": c, **aux}
    if peft.method == "lora":
        return lora.init_lora(rng, site.d_in, site.d_out, peft.lora_r,
                              stack=site.stack, dtype=dtype)
    if peft.method == "bitfit":
        return {"delta_b": jnp.zeros((site.stack, site.d_out), dtype)}
    raise ValueError(f"no per-site params for method {peft.method!r}")


def init_adapters(rng: jax.Array, sites: Sequence[AdapterSite],
                  peft: PEFTConfig) -> Dict:
    if peft.method in ("none", "full"):
        return {}
    out = {}
    for i, site in enumerate(sites):
        if site.name.split("/")[-1] not in peft.target_modules:
            continue
        out[site.name] = init_site(jax.random.fold_in(rng, i), site, peft)
    return out


def site_delta(adapter: Dict, site: AdapterSite, peft: PEFTConfig,
               out_dtype) -> jax.Array:
    """Materialize the stacked ΔW (stack, d_in, d_out) for one site."""
    if peft.method == "fourierft":
        if peft.basis == "fourier":
            return fourierft.materialize_delta(
                adapter["c"], adapter["entries"], site.d_in, site.d_out,
                peft.alpha, out_dtype=out_dtype)
        return basis_mod.materialize_delta_basis(
            adapter["c"], adapter["b1"], adapter["b2"], peft.basis,
            peft.alpha, out_dtype=out_dtype)
    if peft.method == "lora":
        return lora.lora_delta(adapter["lora_a"], adapter["lora_b"],
                               peft.lora_alpha, peft.lora_r,
                               out_dtype=out_dtype)
    raise ValueError(peft.method)


def adapter_frozen_leaves(peft: PEFTConfig) -> tuple:
    """Leaf names inside adapter dicts that are frozen (not trained)."""
    return ("entries", "b1", "b2")


def trainable_adapter_tree(adapters: Dict, peft: PEFTConfig) -> Dict:
    frozen = adapter_frozen_leaves(peft)
    return {
        site: {k: v for k, v in d.items() if k not in frozen}
        for site, d in adapters.items()
    }


# ---------------------------------------------------------------------------
# Parameter accounting (paper Table 1 / §3.2)
# ---------------------------------------------------------------------------

def count_trainable(sites: Sequence[AdapterSite], peft: PEFTConfig) -> int:
    """|Θ| per paper §3.2 — coefficient parameters only (entries are stored,
    not trained; the paper counts n·L_t for FourierFT, 2·d·L_t·r for LoRA)."""
    total = 0
    for site in sites:
        if site.name.split("/")[-1] not in peft.target_modules:
            continue
        if peft.method == "fourierft":
            total += peft.n * site.stack
        elif peft.method == "lora":
            total += peft.lora_r * (site.d_in + site.d_out) * site.stack
        elif peft.method == "bitfit":
            total += site.d_out * site.stack
    return total


def storage_bytes(sites: Sequence[AdapterSite], peft: PEFTConfig,
                  bytes_per_param: int = 4) -> int:
    """Checkpoint bytes: FourierFT additionally stores the 2n integer entries
    once per shape group (paper: n·(2+L) numbers total)."""
    n_params = count_trainable(sites, peft)
    extra = 0
    if peft.method == "fourierft" and peft.basis == "fourier":
        shapes = {(s.d_in, s.d_out) for s in sites
                  if s.name.split("/")[-1] in peft.target_modules}
        extra = 2 * peft.n * len(shapes)
    return (n_params + extra) * bytes_per_param


def qv_sites_for(cfg: ModelConfig) -> List[AdapterSite]:
    """The paper's default adaptation set: per-block query and value
    projections (L_t = 2·num_layers square-ish matrices)."""
    return [
        AdapterSite("layers/wq", cfg.d_model, cfg.attn_dim, cfg.num_layers),
        AdapterSite("layers/wv", cfg.d_model, cfg.kv_dim, cfg.num_layers),
    ]
