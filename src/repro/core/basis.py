"""Basis-expressiveness ablation (paper Table 6): replace the Fourier basis with
random Gaussian or orthogonal bases.

Paper formulation: S = B¹ F B², F sparse at entries (u_l, v_l). Only the
selected columns/rows of B¹/B² ever touch F, so we generate exactly those:
ΔW = scale · (B1 ⊙ c) @ B2ᵀ with B1 (d1, n), B2 (d2, n).

Scale convention: Fourier basis vectors have entries of magnitude O(1) and the
paper divides by d1·d2 (ifft2 normalization). Orthogonal bases have unit-norm
columns (entries O(1/√d)); random Gaussian have unit-variance entries. We match
the expected ΔW Frobenius magnitude of the Fourier path so that a single α
sweep is comparable across bases:
    fourier:    α/(d1·d2)          (||basis col||² ≈ d/2)
    random:     α/(d1·d2)          (||col||² ≈ d)
    orthogonal: α/(2·√(d1·d2))     (||col||² = 1)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_basis(rng: jax.Array, kind: str, d1: int, d2: int, n: int):
    k1, k2 = jax.random.split(rng)
    b1 = jax.random.normal(k1, (d1, n), jnp.float32)
    b2 = jax.random.normal(k2, (d2, n), jnp.float32)
    if kind == "orthogonal":
        if n > min(d1, d2):
            raise ValueError(f"orthogonal basis needs n <= min(d1,d2), got "
                             f"n={n}, dims=({d1},{d2})")
        b1, _ = jnp.linalg.qr(b1)   # (d1, n) orthonormal columns
        b2, _ = jnp.linalg.qr(b2)
    elif kind != "random":
        raise ValueError(f"unknown basis kind {kind!r}")
    return b1, b2


def basis_scale(kind: str, d1: int, d2: int, alpha: float) -> float:
    if kind in ("random", "fourier"):
        return alpha / (d1 * d2)
    return alpha / (2.0 * (d1 * d2) ** 0.5)


def materialize_delta_basis(c: jax.Array, b1: jax.Array, b2: jax.Array,
                            kind: str, alpha: float, out_dtype=None):
    d1, d2 = b1.shape[0], b2.shape[0]
    scale = basis_scale(kind, d1, d2, alpha)
    if c.ndim == 1:
        dw = (b1 * c.astype(jnp.float32)) @ b2.T
    else:
        dw = jnp.einsum("ln,dn,en->lde", c.astype(jnp.float32), b1, b2)
    dw = dw * scale
    return dw.astype(out_dtype) if out_dtype is not None else dw
