from repro.core import adapter, basis, fourierft, lora, peft
from repro.core.adapter import AdapterMethod, register, registered_methods, resolve
from repro.core.fourierft import (
    factored_apply, fourier_bases, materialize_delta, sample_entries,
)
from repro.core.peft import (
    AdapterSite, count_trainable, init_adapters, site_delta, storage_bytes,
)
