"""Pluggable adapter-method API (DESIGN.md §Adapter API).

Every PEFT method is an `AdapterMethod` subclass registered under its config
string (`PEFTConfig.method`). The protocol is the *only* place the codebase
knows what a method stores or computes — core, models, train, serve, and
launch dispatch through `resolve(name)` instead of string-matching, so adding
a spectral variant is one registration here, zero edits elsewhere.

Protocol (per adapted 2-D weight site, stacked over layers on axis 0):

    init_site(rng, site, peft)          -> adapter dict (trainable + frozen)
    trainable_leaves(peft)              -> names of the trainable leaves
    kernel_ops()                        -> KernelOp implementations, keyed
                                           (op, method, backend) — see below
    site_delta(adapter, site, peft)     -> dense ΔW (stack, d1, d2)
    factored_apply(x, tr, aux, d1, d2)  -> y-contribution without ΔW
    bank_apply(x, tr, aux, d1, d2)      -> row-batched factored_apply (serving
                                           adapter bank; tr leaves carry a
                                           leading per-request dim)
    merge_site(eff, key, adapter, ...)  -> fold the site into eff layer tree
    count_trainable(site, peft)         -> |Θ| contribution (paper Table 1)
    shared_storage_numbers(sites, peft) -> frozen numbers a checkpoint must
                                           carry beyond Θ (e.g. 2n entries)

Kernel dispatch (DESIGN.md §Kernels): `site_delta`, `factored_apply`, and
`bank_apply` are implemented ONCE on the base class as registry lookups —
a method contributes math by returning `KernelOp`s from `kernel_ops()`
(an `einsum` reference per op it supports, plus optional `pallas` /
`interpret` accelerated backends with capability constraints). The backend
is chosen per call site by `peft.kernel_backend` + the op's `supports()`
(platform, int32 phase bound, config predicates); `Model` snapshots the
choices once at build as its `kernel_policy`. This is how the FourierFT/DCT
Pallas ΔW kernels and the circulant FFT apply reach the train/serve/merge
hot paths without any method-specific branching outside this file.

Flags: `mergeable` (ΔW folds into W — the zamba2 shared block additionally
keeps any method factored for structural reasons), `linear_delta` (the
contribution is x @ ΔW; BitFit's bias shift is not), `has_site_params`
("none"/"full" own no adapter state), `trains_base` ("full").

Contract required by the serving adapter bank: the factored contribution is
*linear in the trainable leaves* — an all-zero row contributes exactly zero,
which is how heterogeneous-method batches share one jitted graph (every
request gathers a row from every method's bank; non-participating requests
gather the reserved zero row).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import PEFTConfig
from repro.core import basis as basis_mod
from repro.core import fourierft, lora
from repro.kernels import api as kernel_api
from repro.kernels.api import KernelOp


@dataclass(frozen=True)
class AdapterSite:
    name: str          # matches the stacked weight key in base params
    d_in: int
    d_out: int
    stack: int         # number of layers stacked on axis 0 (scan-over-layers)


def _per_row(v: jax.Array, x_ndim: int) -> jax.Array:
    """Align a per-request leaf (B, k...) against x (B, ..., d): insert
    broadcast axes so row b of the leaf meets row b of x (activations inside
    the layer may be (B, d) or (B, T, d) depending on the family)."""
    return v.reshape(v.shape[:1] + (1,) * (x_ndim - v.ndim) + v.shape[1:])


def entry_seed_for(peft: PEFTConfig, site: AdapterSite) -> int:
    """Paper: one shared seed (2024) for all layers. Distinct (d1, d2) grids
    cannot share integer entries, so the seed is offset per site shape only
    when shapes differ; equal-shaped sites share entries exactly as the paper
    prescribes."""
    return peft.entry_seed + hash((site.d_in, site.d_out)) % 1000


# ---------------------------------------------------------------------------
# Protocol
# ---------------------------------------------------------------------------

class AdapterMethod:
    """Base class: one instance per method, registered by `name`."""

    name: str = ""
    mergeable: bool = True        # ΔW can be folded into the base weight
    linear_delta: bool = True     # contribution is x @ ΔW (BitFit: bias)
    has_site_params: bool = True  # owns per-site adapter state
    trains_base: bool = False     # "full": the base weights are the trainables

    # ---- state ------------------------------------------------------------
    def init_site(self, rng: jax.Array, site: AdapterSite,
                  peft: PEFTConfig) -> Dict:
        raise ValueError(f"no per-site params for method {self.name!r}")

    def trainable_leaves(self, peft: PEFTConfig) -> Tuple[str, ...]:
        return ()

    def split_adapter(self, adapter: Dict,
                      peft: PEFTConfig) -> Tuple[Dict, Dict]:
        """-> (trainable, aux) views of one site's adapter dict."""
        names = set(self.trainable_leaves(peft))
        tr = {k: v for k, v in adapter.items() if k in names}
        aux = {k: v for k, v in adapter.items() if k not in names}
        return tr, aux

    # ---- kernels ----------------------------------------------------------
    def kernel_ops(self) -> Tuple[KernelOp, ...]:
        """KernelOp implementations this method provides, collected lazily
        into the kernel registry on first dispatch (kernels/api.py). Every op
        the method serves needs at least an `einsum` reference; accelerated
        backends (`pallas`/`interpret`) are optional and constraint-gated.
        Implementations must be linear in the trainable leaves (bank
        contract) and return float32."""
        return ()

    def _kernel(self, op: str, peft: PEFTConfig, d1: int,
                d2: int) -> Optional[KernelOp]:
        return kernel_api.resolve_op(op, self, peft, d1, d2, missing_ok=True)

    # ---- math (registry-dispatched; see module docstring) ------------------
    def site_delta(self, adapter: Dict, site: AdapterSite, peft: PEFTConfig,
                   out_dtype=None) -> jax.Array:
        op = self._kernel("deltaw", peft, site.d_in, site.d_out)
        if op is None:
            raise NotImplementedError(f"{self.name} has no dense ΔW form")
        tr, aux = self.split_adapter(adapter, peft)
        dw = op.fn(tr, aux, site.d_in, site.d_out, peft)
        return dw.astype(out_dtype) if out_dtype is not None else dw

    def factored_apply(self, x: jax.Array, trainable: Dict, aux: Dict,
                       d1: int, d2: int, peft: PEFTConfig) -> jax.Array:
        """Additive output contribution for one layer slice, x (..., d1) ->
        (..., d2), in float32. Must equal x @ site_delta(...) exactly (up to
        float error) whenever `linear_delta`."""
        op = self._kernel("factored_apply", peft, d1, d2)
        if op is None:
            raise NotImplementedError(self.name)
        return op.fn(x, trainable, aux, d1, d2, peft)

    def bank_apply(self, x: jax.Array, trainable: Dict, aux: Dict,
                   d1: int, d2: int, peft: PEFTConfig) -> jax.Array:
        """Row-batched factored apply: x (B, ..., d1); every trainable leaf
        carries a leading (B,) per-request dim. Falls back to vmapping the
        per-row path for methods that register no bank op."""
        op = self._kernel("bank_apply", peft, d1, d2)
        if op is not None:
            return op.fn(x, trainable, aux, d1, d2, peft)
        return jax.vmap(
            lambda xr, tr: self.factored_apply(xr, tr, aux, d1, d2, peft)
        )(x, trainable)

    def merge_site(self, eff: Dict, key: str, adapter: Dict,
                   site: AdapterSite, peft: PEFTConfig, constrain=None,
                   path: Optional[str] = None) -> None:
        """Fold one site into the (stacked) layer tree `eff` in place."""
        dw = self.site_delta(adapter, site, peft, eff[key].dtype)
        if constrain is not None:
            dw = constrain(path or key, dw)
        eff[key] = eff[key] + dw

    # ---- accounting (paper Table 1 / §3.2) --------------------------------
    def count_trainable(self, site: AdapterSite, peft: PEFTConfig) -> int:
        return 0

    def shared_storage_numbers(self, sites: Sequence[AdapterSite],
                               peft: PEFTConfig) -> int:
        """Frozen numbers stored once per checkpoint beyond the trainables
        (regenerable-from-seed state counts 0)."""
        return 0


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, AdapterMethod] = {}


def register(method: AdapterMethod) -> AdapterMethod:
    if not method.name:
        raise ValueError("AdapterMethod.name must be set before registration")
    if method.name in _REGISTRY:
        raise ValueError(f"adapter method {method.name!r} already registered")
    _REGISTRY[method.name] = method
    return method


def resolve(name: str) -> AdapterMethod:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown adapter method {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def registered_methods(site_params_only: bool = False) -> Tuple[str, ...]:
    names = sorted(_REGISTRY)
    if site_params_only:
        names = [n for n in names if _REGISTRY[n].has_site_params]
    return tuple(names)


# ---------------------------------------------------------------------------
# FourierFT (the paper) — spectral coefficients on frozen Fourier entries,
# with the Table-6 random/orthogonal basis ablation folded in via peft.basis.
# ---------------------------------------------------------------------------

def _fourier_basis_only(peft: PEFTConfig) -> bool:
    return getattr(peft, "basis", "fourier") == "fourier"


def _fourier_deltaw_einsum(tr, aux, d1, d2, peft):
    if "entries" in aux:
        return fourierft.materialize_delta(tr["c"], aux["entries"], d1, d2,
                                           peft.alpha)
    return basis_mod.materialize_delta_basis(tr["c"], aux["b1"], aux["b2"],
                                             peft.basis, peft.alpha)


def _fourier_deltaw_pallas(tr, aux, d1, d2, peft, *, interpret):
    from repro.kernels import ops as kops
    return kops.fourier_deltaw_harness(tr["c"], aux["entries"], d1, d2,
                                       peft.alpha, interpret=interpret)


def _fourier_factored_einsum(x, tr, aux, d1, d2, peft):
    if "entries" in aux:
        return fourierft.factored_apply(
            x.astype(jnp.float32), tr["c"], aux["entries"], d1, d2,
            peft.alpha)
    scale = basis_mod.basis_scale(peft.basis, d1, d2, peft.alpha)
    proj = (x.astype(jnp.float32) @ aux["b1"]) \
        * tr["c"].astype(jnp.float32)
    return proj @ aux["b2"].T * scale


def _fourier_bank_einsum(x, tr, aux, d1, d2, peft):
    xf = x.astype(jnp.float32)
    c = _per_row(tr["c"].astype(jnp.float32), x.ndim)
    if "entries" in aux:
        cos_t, sin_t, cos_p, sin_p = fourierft.fourier_bases(
            aux["entries"], d1, d2)
        pc = (xf @ cos_t) * c
        ps = (xf @ sin_t) * c
        return (pc @ cos_p.T - ps @ sin_p.T) * (peft.alpha / (d1 * d2))
    scale = basis_mod.basis_scale(peft.basis, d1, d2, peft.alpha)
    return ((xf @ aux["b1"]) * c) @ aux["b2"].T * scale


class FourierFT(AdapterMethod):
    name = "fourierft"

    def init_site(self, rng, site, peft):
        dtype = jnp.dtype(peft.param_dtype)
        if peft.basis == "fourier":
            entries = fourierft.sample_entries(
                site.d_in, site.d_out, peft.n, entry_seed_for(peft, site),
                freq_bias=peft.freq_bias, fc=peft.fc, bandwidth=peft.bandwidth)
            aux = {"entries": entries}
        else:
            b1, b2 = basis_mod.make_basis(
                jax.random.fold_in(jax.random.PRNGKey(peft.entry_seed),
                                   site.d_in * 131071 + site.d_out),
                peft.basis, site.d_in, site.d_out, peft.n)
            aux = {"b1": b1, "b2": b2}
        c = jax.random.normal(rng, (site.stack, peft.n), dtype)
        return {"c": c, **aux}

    def trainable_leaves(self, peft):
        return ("c",)

    def kernel_ops(self):
        from repro.kernels import fourier_deltaw as fdk
        from repro.kernels import ops as kops
        return (
            KernelOp("deltaw", self.name, "einsum", _fourier_deltaw_einsum),
            KernelOp("deltaw", self.name, "pallas",
                     functools.partial(_fourier_deltaw_pallas,
                                       interpret=False),
                     platforms=("tpu",),
                     max_dim=kops.FOURIER_INT32_SAFE_DIM,
                     requires=_fourier_basis_only,
                     note="integer-phase MXU tiles (fourier_deltaw.py)",
                     caps=fdk.CAPS),
            KernelOp("deltaw", self.name, "interpret",
                     functools.partial(_fourier_deltaw_pallas,
                                       interpret=True),
                     max_dim=kops.FOURIER_INT32_SAFE_DIM,
                     requires=_fourier_basis_only,
                     caps=fdk.CAPS),
            KernelOp("factored_apply", self.name, "einsum",
                     _fourier_factored_einsum),
            KernelOp("bank_apply", self.name, "einsum",
                     _fourier_bank_einsum),
        )

    def count_trainable(self, site, peft):
        return peft.n * site.stack

    def shared_storage_numbers(self, sites, peft):
        if peft.basis != "fourier":
            return 0        # b1/b2 regenerate from entry_seed
        shapes = {(s.d_in, s.d_out) for s in sites}
        return 2 * peft.n * len(shapes)


# ---------------------------------------------------------------------------
# DCT (LoCA-style, arXiv:2502.06820): real cosine basis on frozen entries —
# ΔW[j,k] = α/(d1·d2) Σ_l c_l cos(π(2j+1)u_l/2d1) cos(π(2k+1)v_l/2d2).
# Rank-n factored: ΔW = (C1 ⊙ c) @ C2ᵀ, same wire format as FourierFT
# (one coefficient vector + 2n integer entries per shape group).
# ---------------------------------------------------------------------------

def _dct_bases(entries: jax.Array, d1: int, d2: int):
    u = entries[0].astype(jnp.float32)
    v = entries[1].astype(jnp.float32)
    j = jnp.arange(d1, dtype=jnp.float32)[:, None]
    k = jnp.arange(d2, dtype=jnp.float32)[:, None]
    c1 = jnp.cos((np.pi / (2.0 * d1)) * (2.0 * j + 1.0) * u[None, :])
    c2 = jnp.cos((np.pi / (2.0 * d2)) * (2.0 * k + 1.0) * v[None, :])
    return c1, c2                                              # (d1,n) (d2,n)


def _dct_deltaw_einsum(tr, aux, d1, d2, peft):
    c1, c2 = _dct_bases(aux["entries"], d1, d2)
    c = tr["c"].astype(jnp.float32)
    if c.ndim == 1:
        dw = (c1 * c) @ c2.T
    else:
        dw = jnp.einsum("ln,dn,en->lde", c, c1, c2)
    return dw * (peft.alpha / (d1 * d2))


def _dct_deltaw_pallas(tr, aux, d1, d2, peft, *, interpret):
    from repro.kernels import ops as kops
    return kops.dct_deltaw_harness(tr["c"], aux["entries"], d1, d2,
                                   peft.alpha, interpret=interpret)


def _dct_factored_einsum(x, tr, aux, d1, d2, peft):
    c1, c2 = _dct_bases(aux["entries"], d1, d2)
    proj = (x.astype(jnp.float32) @ c1) * tr["c"].astype(jnp.float32)
    return proj @ c2.T * (peft.alpha / (d1 * d2))


def _dct_bank_einsum(x, tr, aux, d1, d2, peft):
    c1, c2 = _dct_bases(aux["entries"], d1, d2)
    c = _per_row(tr["c"].astype(jnp.float32), x.ndim)
    return ((x.astype(jnp.float32) @ c1) * c) @ c2.T * (peft.alpha / (d1 * d2))


class DCTAdapter(AdapterMethod):
    name = "dct"

    def init_site(self, rng, site, peft):
        entries = fourierft.sample_entries(
            site.d_in, site.d_out, peft.n, entry_seed_for(peft, site),
            freq_bias=peft.freq_bias, fc=peft.fc, bandwidth=peft.bandwidth)
        c = jax.random.normal(rng, (site.stack, peft.n),
                              jnp.dtype(peft.param_dtype))
        return {"c": c, "entries": entries}

    def trainable_leaves(self, peft):
        return ("c",)

    def kernel_ops(self):
        from repro.kernels import dct_deltaw as ddk
        from repro.kernels import ops as kops
        return (
            KernelOp("deltaw", self.name, "einsum", _dct_deltaw_einsum),
            KernelOp("deltaw", self.name, "pallas",
                     functools.partial(_dct_deltaw_pallas, interpret=False),
                     platforms=("tpu",), max_dim=kops.DCT_INT32_SAFE_DIM,
                     note="cosine-only integer-phase tiles (dct_deltaw.py)",
                     caps=ddk.CAPS),
            KernelOp("deltaw", self.name, "interpret",
                     functools.partial(_dct_deltaw_pallas, interpret=True),
                     max_dim=kops.DCT_INT32_SAFE_DIM,
                     caps=ddk.CAPS),
            KernelOp("factored_apply", self.name, "einsum",
                     _dct_factored_einsum),
            KernelOp("bank_apply", self.name, "einsum", _dct_bank_einsum),
        )

    def count_trainable(self, site, peft):
        return peft.n * site.stack

    def shared_storage_numbers(self, sites, peft):
        shapes = {(s.d_in, s.d_out) for s in sites}
        return 2 * peft.n * len(shapes)


# ---------------------------------------------------------------------------
# Circulant (arXiv:2505.00580 family): one kernel g per layer, ΔW[j,k] =
# α/(d1·d2) · g[(k−j) mod M], M = max(d1,d2). max(d1,d2) trainables per site
# per layer. The accelerated apply path is an FFT circular convolution
# (kernels/ops.py circulant_apply_fft, O(M log M) per token) — an XLA FFT
# rather than a hand-written Pallas kernel, registered under the accelerated
# backends; the einsum reference materializes the (d1,d2) gather.
# ---------------------------------------------------------------------------

def _circulant_idx(d1: int, d2: int) -> jnp.ndarray:
    m = max(d1, d2)
    idx = (np.arange(d2)[None, :] - np.arange(d1)[:, None]) % m
    return jnp.asarray(idx, jnp.int32)


def _circ_deltaw_einsum(tr, aux, d1, d2, peft):
    g = tr["kernel"].astype(jnp.float32)
    return jnp.take(g, _circulant_idx(d1, d2), axis=-1) \
        * (peft.alpha / (d1 * d2))


def _circ_factored_einsum(x, tr, aux, d1, d2, peft):
    g = tr["kernel"].astype(jnp.float32)
    dw = jnp.take(g, _circulant_idx(d1, d2), axis=-1) \
        * (peft.alpha / (d1 * d2))
    return x.astype(jnp.float32) @ dw


def _circ_bank_einsum(x, tr, aux, d1, d2, peft):
    g = tr["kernel"].astype(jnp.float32)                 # (B, M)
    dw = jnp.take(g, _circulant_idx(d1, d2), axis=-1) \
        * (peft.alpha / (d1 * d2))
    return jnp.einsum("b...d,bdf->b...f", x.astype(jnp.float32), dw)


def _circ_factored_fft(x, tr, aux, d1, d2, peft):
    from repro.kernels import ops as kops
    return kops.circulant_apply_fft(x, tr["kernel"], d1, d2, peft.alpha)


def _circ_bank_fft(x, tr, aux, d1, d2, peft):
    from repro.kernels import ops as kops
    return kops.circulant_apply_fft(x, _per_row(tr["kernel"], x.ndim),
                                    d1, d2, peft.alpha)


class CirculantAdapter(AdapterMethod):
    name = "circulant"

    def init_site(self, rng, site, peft):
        del rng  # zero-init: fine-tuning starts at the base model (cf. LoRA B)
        m = max(site.d_in, site.d_out)
        return {"kernel": jnp.zeros((site.stack, m),
                                    jnp.dtype(peft.param_dtype))}

    def trainable_leaves(self, peft):
        return ("kernel",)

    def kernel_ops(self):
        # the FFT apply is plain XLA and runs anywhere, but at adapter dims
        # its CPU win over the einsum gather is inside measurement noise —
        # keep the default `auto` chain on the documented semantics
        # (accelerated on TPU, reference elsewhere) by TPU-gating the pallas
        # key; the interpret key stays platform-free so CI cross-checks the
        # FFT math everywhere and CPU users can opt in explicitly.
        fft_note = "XLA rfft circular convolution (not a Pallas kernel)"
        return (
            KernelOp("deltaw", self.name, "einsum", _circ_deltaw_einsum),
            KernelOp("factored_apply", self.name, "einsum",
                     _circ_factored_einsum),
            KernelOp("factored_apply", self.name, "pallas",
                     _circ_factored_fft, platforms=("tpu",), note=fft_note),
            KernelOp("factored_apply", self.name, "interpret",
                     _circ_factored_fft, note=fft_note),
            KernelOp("bank_apply", self.name, "einsum", _circ_bank_einsum),
            KernelOp("bank_apply", self.name, "pallas", _circ_bank_fft,
                     platforms=("tpu",), note=fft_note),
            KernelOp("bank_apply", self.name, "interpret", _circ_bank_fft,
                     note=fft_note),
        )

    def count_trainable(self, site, peft):
        return max(site.d_in, site.d_out) * site.stack


# ---------------------------------------------------------------------------
# LoRA baseline
# ---------------------------------------------------------------------------

def _lora_deltaw_einsum(tr, aux, d1, d2, peft):
    return lora.lora_delta(tr["lora_a"], tr["lora_b"], peft.lora_alpha,
                           peft.lora_r)


def _lora_factored_einsum(x, tr, aux, d1, d2, peft):
    xf = x.astype(jnp.float32)
    y = (xf @ tr["lora_a"].astype(jnp.float32)) \
        @ tr["lora_b"].astype(jnp.float32)
    return y * (peft.lora_alpha / peft.lora_r)


def _lora_bank_einsum(x, tr, aux, d1, d2, peft):
    xf = x.astype(jnp.float32)
    p = jnp.einsum("b...d,bdr->b...r", xf,
                   tr["lora_a"].astype(jnp.float32))
    y = jnp.einsum("b...r,brf->b...f", p,
                   tr["lora_b"].astype(jnp.float32))
    return y * (peft.lora_alpha / peft.lora_r)


class LoRA(AdapterMethod):
    name = "lora"

    def init_site(self, rng, site, peft):
        return lora.init_lora(rng, site.d_in, site.d_out, peft.lora_r,
                              stack=site.stack,
                              dtype=jnp.dtype(peft.param_dtype))

    def trainable_leaves(self, peft):
        return ("lora_a", "lora_b")

    def kernel_ops(self):
        return (
            KernelOp("deltaw", self.name, "einsum", _lora_deltaw_einsum),
            KernelOp("factored_apply", self.name, "einsum",
                     _lora_factored_einsum),
            KernelOp("bank_apply", self.name, "einsum", _lora_bank_einsum),
        )

    def count_trainable(self, site, peft):
        return peft.lora_r * (site.d_in + site.d_out) * site.stack


# ---------------------------------------------------------------------------
# BitFit baseline — a bias shift, not a weight delta (linear_delta=False);
# merging adds to (or creates) the site's `__b` bias leaf. No deltaw op, so
# site_delta raises through the base class's registry miss.
# ---------------------------------------------------------------------------

def _bitfit_factored_einsum(x, tr, aux, d1, d2, peft):
    b = tr["delta_b"].astype(jnp.float32)
    return jnp.broadcast_to(b, x.shape[:-1] + (d2,))


def _bitfit_bank_einsum(x, tr, aux, d1, d2, peft):
    b = tr["delta_b"].astype(jnp.float32)                # (B, d2)
    return jnp.broadcast_to(_per_row(b, x.ndim), x.shape[:-1] + (d2,))


class BitFit(AdapterMethod):
    name = "bitfit"
    linear_delta = False

    def init_site(self, rng, site, peft):
        del rng
        return {"delta_b": jnp.zeros((site.stack, site.d_out),
                                     jnp.dtype(peft.param_dtype))}

    def trainable_leaves(self, peft):
        return ("delta_b",)

    def kernel_ops(self):
        return (
            KernelOp("factored_apply", self.name, "einsum",
                     _bitfit_factored_einsum),
            KernelOp("bank_apply", self.name, "einsum", _bitfit_bank_einsum),
        )

    def merge_site(self, eff, key, adapter, site, peft, constrain=None,
                   path=None):
        bkey = key + "__b"
        db = adapter["delta_b"]
        eff[bkey] = (eff[bkey] + db) if bkey in eff else db

    def count_trainable(self, site, peft):
        return site.d_out * site.stack


# ---------------------------------------------------------------------------
# Degenerate methods: no adapter state
# ---------------------------------------------------------------------------

class NoAdapter(AdapterMethod):
    name = "none"
    has_site_params = False


class FullFinetune(AdapterMethod):
    name = "full"
    has_site_params = False
    trains_base = True


register(FourierFT())
register(DCTAdapter())
register(CirculantAdapter())
register(LoRA())
register(BitFit())
register(NoAdapter())
register(FullFinetune())
