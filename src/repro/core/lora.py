"""LoRA baseline (Hu et al. 2021) — the paper's primary comparator.

Convention: weights are (d_in, d_out), y = x @ W. ΔW = A @ B with
A (d_in, r) ~ N(0, 1/r) and B (r, d_out) = 0, scaled by lora_alpha / r.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_lora(rng: jax.Array, d_in: int, d_out: int, r: int,
              stack: int | None = None, dtype=jnp.float32):
    shape_a = (d_in, r) if stack is None else (stack, d_in, r)
    shape_b = (r, d_out) if stack is None else (stack, r, d_out)
    a = jax.random.normal(rng, shape_a, dtype) * (1.0 / jnp.sqrt(r))
    b = jnp.zeros(shape_b, dtype)
    return {"lora_a": a, "lora_b": b}


def lora_delta(a: jax.Array, b: jax.Array, lora_alpha: float, r: int,
               out_dtype=None) -> jax.Array:
    dw = jnp.einsum("...dr,...rf->...df", a.astype(jnp.float32),
                    b.astype(jnp.float32)) * (lora_alpha / r)
    return dw.astype(out_dtype) if out_dtype is not None else dw


def lora_apply(x: jax.Array, a: jax.Array, b: jax.Array, lora_alpha: float,
               r: int) -> jax.Array:
    y = ((x.astype(jnp.float32) @ a.astype(jnp.float32))
         @ b.astype(jnp.float32)) * (lora_alpha / r)
    return y.astype(x.dtype)
