"""repro — FourierFT (ICML 2024) as a production multi-pod JAX framework."""
__version__ = "1.0.0"
