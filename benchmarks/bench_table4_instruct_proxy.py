"""Paper Table 4 proxy (instruction tuning, LLaMA-family): llama-shaped
reduced decoder, LoRA r=64-equivalent vs FourierFT n=1000-equivalent budget
ratio (paper: 0.064M vs 33.5M = 0.2%)."""
from repro.configs.base import PEFTConfig
import repro.configs as C
from benchmarks.common import emit, finetune


def main():
    cfg = C.reduced(C.PAPER_MODELS["llama2-7b"]).replace(vocab=64)
    rows = {}
    for name, peft, lr in [
        ("lora_r16", PEFTConfig(method="lora", lora_r=16), 1e-2),
        ("fourier_n64", PEFTConfig(method="fourierft", n=64, alpha=16.0), 3e-2),
    ]:
        r = finetune(cfg, peft, steps=60, lr=lr, pretrain_steps=30,
                     task_seed=13)
        rows[name] = r
        emit(f"table4/{name}", r["us_per_step"],
             f"loss={r['final_loss']:.4f};trainable={r['trainable']}")
    ratio = rows["fourier_n64"]["trainable"] / rows["lora_r16"]["trainable"]
    emit("table4/param_ratio", 0.0, f"ratio={ratio:.4f}")


if __name__ == "__main__":
    main()
