"""Paper Table 3 proxy (E2E NLG, GPT-2 M/L): generation fine-tune measured by
final LM loss on a GPT-2-shaped reduced config; FourierFT at ~10-14% of LoRA's
parameter count."""
from repro.configs.base import PEFTConfig
import repro.configs as C
from benchmarks.common import emit, finetune


def main():
    # gpt2-medium-shaped reduced config (non-gated GELU mlp, MHA)
    cfg = C.reduced(C.PAPER_MODELS["gpt2-medium"]).replace(vocab=64)
    for name, peft, lr in [
        ("lora_r4", PEFTConfig(method="lora", lora_r=4, train_head=True), 2e-2),
        ("fourier_n128", PEFTConfig(method="fourierft", n=128, alpha=10.0,
                                    train_head=True), 3e-2),
    ]:
        r = finetune(cfg, peft, steps=50, lr=lr, pretrain_steps=30,
                     task_seed=9)
        emit(f"table3/{name}", r["us_per_step"],
             f"loss={r['final_loss']:.4f};trainable={r['trainable']}")


if __name__ == "__main__":
    main()
