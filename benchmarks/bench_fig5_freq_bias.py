"""Paper Fig. 5 / Eq. 5: frequency-bias ablation — fine-tune with entries
sampled at different favored central frequencies vs no bias."""
from repro.configs.base import PEFTConfig
from benchmarks.common import emit, finetune, tiny


def main():
    cfg = tiny("yi-6b")
    rows = {}
    for name, kw in [
        ("no_bias", dict(freq_bias=False)),
        ("fc_low", dict(freq_bias=True, fc=0.0, bandwidth=12.0)),
        ("fc_mid", dict(freq_bias=True, fc=20.0, bandwidth=12.0)),
        ("fc_high", dict(freq_bias=True, fc=40.0, bandwidth=12.0)),
    ]:
        r = finetune(cfg, PEFTConfig(method="fourierft", n=64, alpha=10.0,
                                     train_head=True, **kw),
                     steps=40, lr=3e-2, pretrain_steps=20)
        rows[name] = r["final_loss"]
        emit(f"fig5/{name}", r["us_per_step"], f"loss={r['final_loss']:.4f}")
    emit("fig5/no_bias_competitive", 0.0,
         f"no_bias={rows['no_bias']:.4f};best_biased="
         f"{min(v for k, v in rows.items() if k != 'no_bias'):.4f}")


if __name__ == "__main__":
    main()
