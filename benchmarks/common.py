"""Shared benchmark harness: tiny-model fine-tuning runner used by the
paper-table proxies. Prints `name,us_per_call,derived` CSV rows via emit()."""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.configs.base import ModelConfig, PEFTConfig, TrainConfig
from repro.data import SyntheticLM
from repro.models import build
from repro.train import step as ts

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def finetune(cfg: ModelConfig, peft: PEFTConfig, *, steps: int = 60,
             lr: float = 2e-2, batch: int = 8, seq: int = 32,
             pretrain_steps: int = 0, seed: int = 0,
             task_seed: int = 7) -> Dict:
    """Pre-train (optionally) on task A with full FT, then fine-tune with
    `peft` on task B. Returns losses + eval perplexity + wall time."""
    model = build(cfg, peft)
    tcfg = TrainConfig(learning_rate=lr, total_steps=steps,
                       warmup_steps=max(2, steps // 10), seed=seed)
    state, frozen = ts.init_state(model, tcfg, jax.random.PRNGKey(seed))
    if pretrain_steps:
        base_model = build(cfg, PEFTConfig(method="full"))
        btcfg = TrainConfig(learning_rate=3e-3, total_steps=pretrain_steps,
                            warmup_steps=5)
        bstate, bfrozen = ts.init_state(base_model, btcfg,
                                        jax.random.PRNGKey(seed))
        bstep = jax.jit(ts.make_train_step(base_model, btcfg))
        pre_data = SyntheticLM(vocab=cfg.vocab, batch=batch, seq=seq,
                               seed=seed, task_seed=1)
        for i in range(pretrain_steps):
            bstate, _ = bstep(bstate, bfrozen, pre_data.batch_at(i))
        frozen = {"base": bstate["trainable"]["base"], "peft": frozen["peft"]}

    step_fn = jax.jit(ts.make_train_step(model, tcfg))
    data = SyntheticLM(vocab=cfg.vocab, batch=batch, seq=seq, seed=seed + 1,
                       task_seed=task_seed)
    b0 = data.batch_at(0)
    state, _ = step_fn(state, frozen, b0)  # compile
    losses = []
    t0 = time.perf_counter()
    for i in range(1, steps):
        state, m = step_fn(state, frozen, data.batch_at(i))
        losses.append(float(m["loss"]))
    wall = time.perf_counter() - t0
    eval_loss = float(np.mean(losses[-5:]))
    return {
        "losses": losses,
        "final_loss": eval_loss,
        "us_per_step": wall / max(len(losses), 1) * 1e6,
        "trainable": model.trainable_params(),
    }


def tiny(arch: str = "yi-6b", vocab: int = 64, **kw) -> ModelConfig:
    return C.reduced(C.get(arch)).replace(vocab=vocab, **kw)
