"""Tiered-memory serving under overload (DESIGN.md §Tiering).

One overload cell, run twice over the SAME constrained page pool: two
long batch requests are sized to own every allocatable page, then short
interactive requests arrive while they decode.

  (a) deferral-only: the interactives wait in the queue until a long
      request finishes and frees its pages;
  (b) tiered: the scheduler preempts a batch victim (spilling its KV
      pages to the host tier), serves the interactives, and later resumes
      the victim — whose stream must stay bit-identical to an
      unpreempted serial run.

Emits admitted-requests-within-horizon for both (the acceptance cell:
tiered must admit STRICTLY more), interactive TTFT for both, the
preempt/spill/fill counters, and the exactness cross-check of every
stream — including the preempted-and-resumed ones — against the serial
one-request-at-a-time engine. Leak-checks the page pool and asserts the
host tier holds no orphaned snapshots after the drain."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.configs.base import PEFTConfig
from repro.models import build
from repro.serve import ContinuousScheduler, Engine, Request, TieringConfig
from benchmarks.common import emit

SLOTS = 4
MAX_LEN = 64
PAGE = 8
# allocatable pages = N_PAGES - SLOTS scratch = 16: exactly two worst-case
# long requests (8 pages each) — the third admission MUST wait or preempt
N_PAGES = 20
HORIZON = 40.0                 # admission-count window, decode steps

LONG = dict(prompt_len=8, max_new=50)     # 57 positions -> 8 pages
SHORT = dict(prompt_len=4, max_new=4)     # 7 positions  -> 1 page


def _requests():
    reqs, arrivals = [], []
    for i in range(2):
        reqs.append(Request(
            prompt=(jnp.arange(LONG["prompt_len"], dtype=jnp.int32)
                    + 3 * i) % 256,
            max_new=LONG["max_new"], priority="batch"))
        arrivals.append(0.0)
    for i in range(6):
        reqs.append(Request(
            prompt=(jnp.arange(SHORT["prompt_len"], dtype=jnp.int32)
                    + 7 * i + 2) % 256,
            max_new=SHORT["max_new"], priority="interactive"))
        arrivals.append(4.0 * (i + 1))
    return reqs, arrivals


def _run(eng, tiering):
    sched = ContinuousScheduler(eng, page_size=PAGE, n_pages=N_PAGES,
                                tiering=tiering)
    reqs, arrivals = _requests()
    for r, at in zip(reqs, arrivals):
        sched.submit(r, arrival=at)
    admits_in_h = 0
    ttft = {}
    for ev in sched.events():
        if ev[0] == "admit" and ev[-1] <= HORIZON:
            admits_in_h += 1
        if ev[0] == "token" and ev[1] not in ttft:
            ttft[ev[1]] = ev[-1]
    s = sched.metrics.summary()
    sched.pager.assert_no_leaks()
    if sched.host_kv is not None:
        assert not sched.host_kv._snapshots, \
            "host tier holds snapshots after a full drain"
    # interactive TTFT on the decode-step clock (rids 2.. are interactive)
    int_ttft = [ttft[rid] - arrivals[rid] for rid in range(2, len(reqs))
                if rid in ttft]
    return reqs, s, admits_in_h, (sum(int_ttft) / len(int_ttft)
                                  if int_ttft else float("nan"))


def main():
    cfg = C.reduced(C.get("yi-6b")).replace(vocab=256)
    model = build(cfg, PEFTConfig(method="none"))
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, batch_slots=SLOTS, max_len=MAX_LEN)

    tiered_cfg = TieringConfig(host_kv_pages=64, preempt=True)
    _run(eng, None)                        # warm-up (compile)
    _, _, _, _ = _run(eng, tiered_cfg)     # warm-up the tiering graphs too
    reqs_d, s_d, admits_d, ttft_d = _run(eng, None)
    reqs_t, s_t, admits_t, ttft_t = _run(eng, tiered_cfg)

    emit("serve_tiering/deferral", ttft_d,
         f"admits_in_h={admits_d};steps={s_d['steps']:.0f};"
         f"int_ttft_steps={ttft_d:.1f}")
    emit("serve_tiering/tiered", ttft_t,
         f"admits_in_h={admits_t};steps={s_t['steps']:.0f};"
         f"int_ttft_steps={ttft_t:.1f};"
         f"preempts={s_t['preemptions_total']:.0f};"
         f"spilled={s_t['kv_pages_spilled_total']:.0f};"
         f"filled={s_t['kv_pages_filled_total']:.0f}")
    assert admits_t > admits_d, (
        f"tiered admitted {admits_t} within {HORIZON:g} steps, deferral "
        f"{admits_d}: preemption bought no admission throughput")
    assert s_t["preemptions_total"] >= 1, "overload cell never preempted"

    # exactness: every stream (preempted+resumed included) vs the serial
    # engine
    bad = 0
    for r in reqs_d + reqs_t:
        ref = eng.generate([r.prompt], max_new=r.max_new)[0]
        if r.out != [int(t) for t in np.asarray(ref).reshape(-1)]:
            bad += 1
    emit("serve_tiering/exact_vs_serial", 0.0,
         f"mismatches={bad}/{len(reqs_d) + len(reqs_t)}")
    assert bad == 0, "tiered outputs diverged from serial"


if __name__ == "__main__":
    main()
