"""Kernel benchmarks: einsum vs FFT materialization paths (CPU wall time) +
interpret-mode Pallas correctness cross-check, the merged-vs-factored
strategy flop model from DESIGN §2, and the kernel-registry backend
comparison (DESIGN §Kernels) — per spectral method, which backend the auto
policy selects on this host (compiled Pallas on TPU) and how the accelerated
path times against the einsum reference."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import PEFTConfig
from repro.core import adapter as adapter_api
from repro.core.adapter import AdapterSite
from repro.core.fourierft import factored_apply, materialize_delta, sample_entries
from repro.kernels import api, ops, ref
from benchmarks.common import emit


def timeit(fn, *args, iters=10):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def bench_backends(d1=768, d2=768, n=1000, tokens=512):
    """Registry comparison: per spectral method × op, report the backend the
    auto policy resolves on this host, time einsum vs the accelerated path
    where it is compiled (TPU pallas / any-platform FFT), and cross-check
    interpret-mode outputs against einsum at fp32 tolerance."""
    site = AdapterSite("layers/wq", d1, d2, 1)
    x = jax.random.normal(jax.random.PRNGKey(1), (tokens, d1))
    for mname in ("fourierft", "dct", "circulant"):
        m = adapter_api.resolve(mname)
        peft = PEFTConfig(method=mname, n=n, alpha=300.0,
                          param_dtype="float32")
        ad = m.init_site(jax.random.PRNGKey(0), site, peft)
        ad = {k: (v + 0.1 if jnp.issubdtype(v.dtype, jnp.floating) else v)
              for k, v in ad.items()}
        tr = {k: ad[k][0] for k in m.trainable_leaves(peft)}
        aux = {k: v for k, v in ad.items()
               if k not in m.trainable_leaves(peft)}
        for op in api.ops_for(m):
            resolved = api.resolve_op(op, m, peft, d1, d2)
            emit(f"kernels/policy_{mname}_{op}", 0.0,
                 f"auto->{resolved.backend}")

        def run(op, backend):
            """jitted hot-path fn of the trainables (traced, NOT closed-over
            constants — a captured kernel would let XLA constant-fold the
            whole materialization out of the timing)."""
            p = peft.replace(kernel_backend=backend)
            if op == "deltaw":
                return jax.jit(lambda t: m.site_delta({**ad, **t}, site, p))
            return jax.jit(
                lambda t, xx: m.factored_apply(xx, t, aux, d1, d2, p))

        # time the op that carries each method's hot path: deltaw for the
        # spectral-coefficient methods (merged train/serve), the factored
        # apply for circulant (its acceleration is the FFT bypass)
        hot = "deltaw" if "deltaw" in api.ops_for(m) \
            and mname != "circulant" else "factored_apply"
        tr_stack = {k: ad[k] for k in m.trainable_leaves(peft)}
        args = (tr_stack,) if hot == "deltaw" else (tr, x)
        ref_fn = run(hot, "einsum")
        us_ref = timeit(ref_fn, *args, iters=5)
        emit(f"kernels/{hot}_{mname}_einsum_{d1}", us_ref, "reference")
        auto = api.resolve_op(hot, m, peft, d1, d2)
        if auto.backend != "einsum":        # compiled pallas (TPU) or FFT
            us_acc = timeit(run(hot, "auto"), *args, iters=5)
            emit(f"kernels/{hot}_{mname}_{auto.backend}_{d1}", us_acc,
                 f"speedup={us_ref / max(us_acc, 1e-9):.2f}x")
        # interpret-mode fp32 cross-check (the CI conformance gate's numbers)
        itp = api.resolve_op(hot, m, peft.replace(kernel_backend="interpret"),
                             d1, d2)
        if itp.backend == "interpret":
            err = float(jnp.abs(jnp.asarray(run(hot, "interpret")(*args))
                                - jnp.asarray(ref_fn(*args))).max())
            emit(f"kernels/{hot}_{mname}_interpret_allclose", 0.0,
                 f"err={err:.2e}")


def main():
    d1 = d2 = 768
    n = 1000
    E = sample_entries(d1, d2, n, seed=2024)
    c = jax.random.normal(jax.random.PRNGKey(0), (n,))

    einsum_fn = jax.jit(lambda c: materialize_delta(c, E, d1, d2, 300.0))
    fft_fn = jax.jit(lambda c: ref.deltaw_ref(c, E, d1, d2, 300.0))
    us_e = timeit(einsum_fn, c)
    us_f = timeit(fft_fn, c)
    err = float(jnp.abs(einsum_fn(c) - fft_fn(c)).max())
    emit("kernels/materialize_einsum_768", us_e, f"err_vs_fft={err:.2e}")
    emit("kernels/materialize_fft_768", us_f, "paper_literal_path")

    k = ops.fourier_deltaw(c, E, d1, d2, 300.0, backend="interpret")
    kerr = float(jnp.abs(k - fft_fn(c)).max())
    emit("kernels/pallas_interpret_allclose", 0.0, f"err={kerr:.2e}")

    # strategy crossover (DESIGN §2): factored vs merged extra flops
    tokens = 512
    x = jax.random.normal(jax.random.PRNGKey(1), (tokens, d1))
    fact = jax.jit(lambda x, c: factored_apply(x, c, E, d1, d2, 300.0))
    merg = jax.jit(lambda x, c: x @ materialize_delta(c, E, d1, d2, 300.0,
                                                      out_dtype=jnp.float32))
    us_fact = timeit(fact, x, c)
    us_merg = timeit(merg, x, c)
    emit("kernels/factored_apply_768_t512", us_fact,
         f"flops_model={4*n*(d1+d2)*tokens:.2e}")
    emit("kernels/merged_apply_768_t512", us_merg,
         f"flops_model={4*n*d1*d2 + 2*d1*d2*tokens:.2e}")

    bench_backends(d1, d2, n, tokens)


if __name__ == "__main__":
    main()
