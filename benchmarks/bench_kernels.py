"""Kernel benchmarks: einsum vs FFT materialization paths (CPU wall time) +
interpret-mode Pallas correctness cross-check, plus the merged-vs-factored
strategy flop model from DESIGN §2."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fourierft import factored_apply, materialize_delta, sample_entries
from repro.kernels import ops, ref
from benchmarks.common import emit


def timeit(fn, *args, iters=10):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def main():
    d1 = d2 = 768
    n = 1000
    E = sample_entries(d1, d2, n, seed=2024)
    c = jax.random.normal(jax.random.PRNGKey(0), (n,))

    einsum_fn = jax.jit(lambda c: materialize_delta(c, E, d1, d2, 300.0))
    fft_fn = jax.jit(lambda c: ref.deltaw_ref(c, E, d1, d2, 300.0))
    us_e = timeit(einsum_fn, c)
    us_f = timeit(fft_fn, c)
    err = float(jnp.abs(einsum_fn(c) - fft_fn(c)).max())
    emit("kernels/materialize_einsum_768", us_e, f"err_vs_fft={err:.2e}")
    emit("kernels/materialize_fft_768", us_f, "paper_literal_path")

    k = ops.fourier_deltaw(c, E, d1, d2, 300.0, use_pallas="interpret")
    kerr = float(jnp.abs(k - fft_fn(c)).max())
    emit("kernels/pallas_interpret_allclose", 0.0, f"err={kerr:.2e}")

    # strategy crossover (DESIGN §2): factored vs merged extra flops
    tokens = 512
    x = jax.random.normal(jax.random.PRNGKey(1), (tokens, d1))
    fact = jax.jit(lambda x, c: factored_apply(x, c, E, d1, d2, 300.0))
    merg = jax.jit(lambda x, c: x @ materialize_delta(c, E, d1, d2, 300.0,
                                                      out_dtype=jnp.float32))
    us_fact = timeit(fact, x, c)
    us_merg = timeit(merg, x, c)
    emit("kernels/factored_apply_768_t512", us_fact,
         f"flops_model={4*n*(d1+d2)*tokens:.2e}")
    emit("kernels/merged_apply_768_t512", us_merg,
         f"flops_model={4*n*d1*d2 + 2*d1*d2*tokens:.2e}")


if __name__ == "__main__":
    main()
