"""Static-analyzer bench: wall time + finding counts per pass.

Times the three cheap analyzer passes (AST source lint over src/repro,
kernel-capability verifier, sharding-coverage audit) and emits one row per
pass plus a rollup, so analyzer latency and the finding trajectory are
machine-diffable across PRs (BENCH_analysis.json next to BENCH_serve.json).
The graph pass (trace + compile of the train/serve graphs) is exercised by
the blocking `repro.analysis --all` CI gate instead — benching a full XLA
compile here would dwarf every other row.
"""
from __future__ import annotations

import os
import time

from benchmarks.common import emit


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


def main():
    import repro
    from repro.analysis import ast_lint, kernel_audit, sharding_audit

    pkg = os.path.dirname(os.path.abspath(repro.__file__))
    ast_f, ast_us = _timed(
        lambda: ast_lint.lint_paths([pkg], root=os.path.dirname(pkg)))
    ker_f, ker_us = _timed(kernel_audit.run)
    shd_f, shd_us = _timed(sharding_audit.run)

    emit("analysis_ast", ast_us, f"findings={len(ast_f)}")
    emit("analysis_kernels", ker_us, f"findings={len(ker_f)}")
    emit("analysis_sharding", shd_us, f"findings={len(shd_f)}")
    total = len(ast_f) + len(ker_f) + len(shd_f)
    emit("analysis_static", ast_us + ker_us + shd_us,
         f"findings={total};passes=3")


if __name__ == "__main__":
    main()
