"""Sharding-planner fleet validation over the checked-in dry-run baselines.

For every `results/dryrun_baseline_v0` cell (the 64-cell arch x shape x mesh
sweep) this reconstructs the model abstractly, prices the rules placement
with the alpha-beta cost model (`dist/planner.score_source`), and compares
the prediction against the analyzer-measured terms stored in the cell JSON —
the calibration check for the planner: costs don't need to be exact, they
need to RANK cells the way the HLO analyzer does. The Spearman rank
correlations (total + collective) land as `sharding_plan_*` rows in
``BENCH_analysis.json`` so calibration drift is machine-diffable across PRs.

Each cell also gets a searched plan (`dist/planner.plan_model`) written to
``results/sharding_plans_v0/<cell>.plan.json`` with the rules-vs-search
ranking, the spec diff against the rules, and the measured terms inlined —
the promotion artifact DESIGN.md §Sharding describes. Nothing compiles:
everything here runs on eval_shape trees, so the whole fleet sweep is
seconds, not hours.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from benchmarks.common import emit

ROOT = pathlib.Path(__file__).resolve().parent.parent
BASELINES = ROOT / "results" / "dryrun_baseline_v0"
PLANS_OUT = ROOT / "results" / "sharding_plans_v0"


def _rank(v: np.ndarray) -> np.ndarray:
    """Average ranks (ties share the mean rank) — no scipy in the image."""
    v = np.asarray(v, dtype=float)
    order = np.argsort(v, kind="mergesort")
    ranks = np.empty(len(v), dtype=float)
    sv = v[order]
    i, n = 0, len(v)
    while i < n:
        j = i
        while j + 1 < n and sv[j + 1] == sv[i]:
            j += 1
        ranks[order[i:j + 1]] = (i + j) / 2.0
        i = j + 1
    return ranks


def spearman(x, y) -> float:
    rx, ry = _rank(x), _rank(y)
    rx -= rx.mean()
    ry -= ry.mean()
    denom = float(np.sqrt((rx * rx).sum() * (ry * ry).sum()))
    return float((rx * ry).sum() / denom) if denom else 0.0


def _measured_total(rec: dict) -> float:
    t = rec["terms"]
    # same convention as PlanCost.total_s: overlap-free compute/memory max
    # plus serial collectives
    return max(t["compute_s"], t["memory_s"]) + t["collective_s"]


def main():
    import repro.configs as configs
    from repro.dist import plan as plan_mod
    from repro.dist import planner
    from repro.dist.cost_model import MeshSpec
    from repro.launch.dryrun_lib import peft_for
    from repro.models import build

    cells = sorted(BASELINES.glob("*.json"))
    if not cells:
        emit("sharding_plan_fleet", 0.0, "cells=0;skipped=no-baselines")
        return
    PLANS_OUT.mkdir(parents=True, exist_ok=True)

    pred_total, meas_total = [], []
    pred_coll, meas_coll = [], []
    search_beats = search_ties = 0
    diff_cells = 0
    t0 = time.perf_counter()
    built = {}
    for path in cells:
        rec = json.loads(path.read_text())
        arch, kind = rec["arch"], rec["kind"]
        shape = configs.shape_for(rec["shape"])
        mesh = MeshSpec.from_string(rec["mesh"])
        key = (arch, "train" if kind == "train" else "serve")
        if key not in built:
            cfg = configs.get(arch)
            built[key] = build(cfg, peft_for(cfg, key[1]), remat="none")
        model = built[key]

        rules = plan_mod.RulesSource()
        rules_cost = planner.score_source(model, mesh, shape, rules,
                                          workload=kind)
        pred_total.append(rules_cost.total_s)
        meas_total.append(_measured_total(rec))
        pred_coll.append(rules_cost.collective_bytes)
        meas_coll.append(rec["collective_bytes_per_device"])

        plan = planner.plan_model(model, mesh, shape=shape, workload=kind)
        ranked = plan.meta.get("ranked", [])
        rules_obj = next((r["objective_s"] for r in ranked
                          if r["strategy"] == "rules"), None)
        best_obj = ranked[0]["objective_s"] if ranked else None
        if rules_obj is not None and best_obj is not None:
            if best_obj < rules_obj * (1 - 1e-9):
                search_beats += 1
            else:
                search_ties += 1
        diffs = planner.spec_diff(rules, plan_mod.PlanTableSource(plan),
                                  model, mesh, model.cfg, shape, kind)
        if diffs:
            diff_cells += 1
        plan.meta["validation"] = {
            "cell": path.stem,
            "measured_terms": rec["terms"],
            "measured_collective_bytes": rec["collective_bytes_per_device"],
            "rules_predicted": rules_cost.to_json(),
            "spec_diffs_vs_rules": len(diffs),
        }
        plan.save(str(PLANS_OUT / f"{path.stem}.plan.json"))
    wall_us = (time.perf_counter() - t0) * 1e6

    rho_total = spearman(pred_total, meas_total)
    rho_coll = spearman(pred_coll, meas_coll)
    n = len(cells)
    emit("sharding_plan_fleet", wall_us / n,
         f"cells={n};spearman_total={rho_total:.4f};"
         f"spearman_collective={rho_coll:.4f}")
    emit("sharding_plan_search", wall_us / n,
         f"cells={n};search_beats_rules={search_beats};"
         f"search_ties_rules={search_ties};spec_diff_cells={diff_cells}")


if __name__ == "__main__":
    main()
