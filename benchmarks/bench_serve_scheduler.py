"""Continuous batching vs lockstep serving (DESIGN.md §Scheduler).

Replays a staggered-arrival, mixed-`max_new` trace (short-heavy with a
long tail — the shape that hurts lockstep most) through

  (a) the lockstep `Engine.generate_requests`: FCFS chunks of
      `batch_slots`, padded full-batch prefill per chunk, and — even with
      the per-slot completion fix — every chunk decodes until its LONGEST
      request finishes, so short requests ride along as dead slots; and
  (b) the `ContinuousScheduler`: per-slot budgets over one persistent
      cache, slot recycling the step a request completes, in-flight
      batch-1 prefill at admission.

Emits tokens/s for both, the speedup, the occupancy ratio (continuous
per-step mean vs lockstep useful-token share), and continuous TTFT at
several arrival rates. Also cross-checks the continuous outputs against
the serial one-request-at-a-time engine (exact per-request semantics —
the lockstep path is only the throughput baseline: its padded prefill
intentionally keeps the legacy equal-padding semantics)."""
import time

import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.configs.base import PEFTConfig
from repro.models import build
from repro.serve import ContinuousScheduler, Engine, Request
from benchmarks.common import emit

import jax

SLOTS = 8
MAX_LEN = 64
N_REQ = 24
# short-heavy budget mix with a long tail (deterministic): every lockstep
# chunk of 8 carries one 48-token straggler that holds its 7 peers' slots
BUDGETS = [2, 3, 2, 4, 2, 3, 2, 48] * 3
PROMPT_LENS = [3, 5, 8, 4, 6, 10, 5, 7] * 3


def _requests():
    return [Request(prompt=(jnp.arange(PROMPT_LENS[i], dtype=jnp.int32)
                            + 3 * i) % 256,
                    max_new=BUDGETS[i])
            for i in range(N_REQ)]


def _lockstep_run(eng):
    reqs = _requests()
    t0 = time.perf_counter()
    eng.generate_requests(reqs)
    wall = time.perf_counter() - t0
    toks = sum(len(r.out) for r in reqs)
    # lockstep decode steps: each chunk pays max(max_new) for every slot
    steps = sum(max(r.max_new for r in reqs[at:at + SLOTS])
                for at in range(0, N_REQ, SLOTS))
    occ = toks / (SLOTS * steps)
    return reqs, toks / wall, occ


def _continuous_run(eng, gap):
    sched = ContinuousScheduler(eng)
    reqs = _requests()
    arrivals = [i * gap for i in range(N_REQ)]
    sched.serve(reqs, arrivals)          # warm-up: compiles all graphs
    sched.reset_metrics()                # fresh metrics + rewound clock
    reqs = _requests()
    sched.serve(reqs, arrivals)
    s = sched.metrics.summary()
    return reqs, s


def main():
    cfg = C.reduced(C.get("yi-6b")).replace(vocab=256)
    model = build(cfg, PEFTConfig(method="none"))
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, batch_slots=SLOTS, max_len=MAX_LEN)

    _lockstep_run(eng)                   # warm-up (compile)
    _, lockstep_tok_s, lockstep_occ = _lockstep_run(eng)
    emit("serve_scheduler/lockstep", 1e6 / lockstep_tok_s,
         f"tok_s={lockstep_tok_s:.0f};occupancy={lockstep_occ:.2f}")

    # gap = arrival spacing in decode steps. 0.25 saturates the slots
    # (the acceptance cell: staggered, short-heavy + tail, continuous must
    # win at >=2x occupancy); 1.0 is near the service rate; 4.0 is
    # arrival-limited — there even an idle-free oracle only ties lockstep,
    # which unrealistically receives the whole trace at t=0.
    for gap in (0.25, 1.0, 4.0):
        reqs, s = _continuous_run(eng, gap)
        emit(f"serve_scheduler/continuous_gap{gap:g}",
             1e6 / s["tokens_per_s"],
             f"tok_s={s['tokens_per_s']:.0f};"
             f"occupancy={s['occupancy_mean']:.2f};"
             f"ttft_steps={s['ttft_steps_mean']:.1f};"
             f"speedup={s['tokens_per_s'] / lockstep_tok_s:.2f};"
             f"occ_x={s['occupancy_mean'] / lockstep_occ:.2f}")
        if gap == 0.25:
            # acceptance cross-check: exact vs the serial engine
            bad = 0
            for r in reqs:
                ref = eng.generate([r.prompt], max_new=r.max_new)[0]
                if r.out != [int(t) for t in np.asarray(ref).reshape(-1)]:
                    bad += 1
            emit("serve_scheduler/exact_vs_serial", 0.0,
                 f"mismatches={bad}/{len(reqs)}")
            assert bad == 0, "continuous outputs diverged from serial"


if __name__ == "__main__":
    main()
