"""Speculative decoding on the continuous runtime (DESIGN.md §Speculation).

Replays a shared-prefix decode-heavy trace (224-token common system prompt,
short unique tails, 20-token budgets) through the paged continuous
scheduler four ways: non-speculative baseline, base-row self-drafter,
n-gram prompt-lookup drafter, and the self-drafter under a FourierFT
tenant (drafts from the bank's reserved zero row, verify through the
tenant's spectral delta — the paper-relevant cell: acceptance stays high
because the delta is small). Reports, per cell:

  - mean accepted tokens per slot per verify step (`tok_step`) and the
    draft acceptance rate — the headline gate is tok_step > 1.0 for the
    self-drafter (its drafts ARE the target argmax on base traffic, so
    only budget clamping rejects);
  - end-to-end tokens/s and the uplift ratio vs the non-speculative
    baseline (whole-drain wall clock, prefills + draft probes included);
  - a token-exactness cross-check: every speculative cell must reproduce
    its non-speculative counterpart's outputs exactly.

Uses the 4-layer d_model=256 config (as bench_serve_paging) so decode
compute is non-trivial; at the tests' tiny scale every step is
dispatch-bound and the verify batching effect would drown."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.checkpoint import adapters as adapter_ckpt
from repro.configs.base import PEFTConfig
from repro.core import adapter as adapter_api
from repro.core import peft as peft_mod
from repro.models import build
from repro.serve import (
    AdapterBank, ContinuousScheduler, Engine, NGramDrafter, Request,
    SelfDrafter,
)
from benchmarks.common import emit

SLOTS = 4
MAX_LEN = 288
PAGE = 16
N_REQ = 8
PREFIX_LEN = 224                   # 14 shared pages
MAX_NEW = 20                       # decode-heavy: budget >> tail
K = 4
PREFIX = (np.arange(PREFIX_LEN) * 5 + 3) % 256


def _requests(salt: int, adapter_id=None):
    rng = np.random.default_rng(900 + salt)
    reqs = []
    for i in range(N_REQ):
        tail = rng.integers(0, 256, size=4 + i % 5)
        reqs.append(Request(prompt=jnp.asarray(
            np.concatenate([PREFIX, tail]), jnp.int32),
            max_new=MAX_NEW, adapter_id=adapter_id))
    return reqs


def _run(engine, drafter, salt: int, adapter_id=None):
    sched = ContinuousScheduler(engine, page_size=PAGE, drafter=drafter)
    arrivals = [float(i) for i in range(N_REQ)]
    sched.serve(_requests(salt, adapter_id), arrivals)     # warm-up
    sched.reset_metrics()
    reqs = sched.serve(_requests(salt + 1, adapter_id), arrivals)
    return [r.out for r in reqs], sched.metrics.summary()


def _export_tenant(model, directory):
    prof = PEFTConfig(method="fourierft", n=64, alpha=1.0,
                      param_dtype="float32")
    tree = peft_mod.init_adapters(jax.random.PRNGKey(11), model.sites, prof)
    trainable = set(
        adapter_api.resolve("fourierft").trainable_leaves(prof))
    tree = {s: {k: v for k, v in d.items() if k in trainable}
            for s, d in tree.items()}
    adapter_ckpt.export_adapter(directory, "tenant-fft", tree, prof)
    return {"fourierft": prof}


def _row(tag, s, base_tok_s):
    emit(f"serve_spec/{tag}", s["wall_s"] * 1e6,
         f"tok_step={s.get('spec_tokens_per_step', 1.0):.2f};"
         f"accept_rate={s.get('spec_accept_rate', 0.0):.2f};"
         f"tok_s={s['tokens_per_s']:.0f};"
         f"tok_s_ratio={s['tokens_per_s'] / max(base_tok_s, 1e-9):.2f};"
         f"ttft_p50={s['ttft_steps_p50']:.1f};"
         f"ttft_p90={s['ttft_steps_p90']:.1f}")


def main():
    cfg = C.reduced(C.get("yi-6b")).replace(
        vocab=256, d_model=256, num_layers=4, d_ff=768,
        n_heads=8, n_kv=4, head_dim=32)
    model = build(cfg, PEFTConfig(method="none"))
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, batch_slots=SLOTS, max_len=MAX_LEN)

    base_out, base = _run(eng, None, salt=1)
    self_out, self_s = _run(eng, SelfDrafter(k=K), salt=1)
    ngram_out, ngram_s = _run(eng, NGramDrafter(k=K), salt=1)
    assert self_out == base_out, "self-drafter outputs diverged"
    assert ngram_out == base_out, "ngram-drafter outputs diverged"
    assert self_s["spec_tokens_per_step"] > 1.0, \
        "acceptance gate: self-drafter must accept > 1 token/step/slot"

    _row("baseline", base, base["tokens_per_s"])
    _row(f"self_k{K}", self_s, base["tokens_per_s"])
    _row(f"ngram_k{K}", ngram_s, base["tokens_per_s"])

    # FourierFT tenant: drafts from the zero row, verify through the delta
    with tempfile.TemporaryDirectory() as tmp:
        profiles = _export_tenant(model, tmp)
        bank = AdapterBank(model, profiles, capacity=2, checkpoint_dir=tmp)
        beng = Engine(model, params, batch_slots=SLOTS, max_len=MAX_LEN,
                      bank=bank)
        tb_out, tb = _run(beng, None, salt=3, adapter_id="tenant-fft")
        ts_out, ts_s = _run(beng, SelfDrafter(k=K), salt=3,
                            adapter_id="tenant-fft")
        assert ts_out == tb_out, "tenant spec outputs diverged"
        _row(f"tenant_fft_self_k{K}", ts_s, tb["tokens_per_s"])


if __name__ == "__main__":
    main()
