"""Paper Table 6: basis expressiveness — Fourier vs random vs orthogonal
bases at equal parameter count (matrix-recovery + fine-tune ordering)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import PEFTConfig
from benchmarks.common import emit, finetune, tiny


def matrix_recovery(basis: str, d1=64, d2=64, n=48, steps=400):
    """Recover a structured target ΔW* (smooth low-frequency field + low-rank
    bump — the kind of spectral concentration real weight deltas show) from n
    coefficients by GD. A rank-k random target is information-theoretically
    unrecoverable from n ≪ d² random basis functions (any basis captures
    ≈ √(n/d²) of its energy), so structure is what separates the bases —
    the paper's premise (§1, compression literature)."""
    from repro.core import basis as basis_mod
    from repro.core import fourierft
    key = jax.random.PRNGKey(0)
    # smooth field: superposition of low-frequency cosines
    jj = jnp.arange(d1)[:, None]
    kk = jnp.arange(d2)[None, :]
    freqs = [(1, 2, 1.0), (3, 1, 0.7), (2, 5, 0.5), (0, 3, 0.6), (4, 4, 0.4)]
    target = sum(a * jnp.cos(2 * jnp.pi * (fu * jj / d1 + fv * kk / d2))
                 for fu, fv, a in freqs)
    u = jax.random.normal(key, (d1, 2))
    v = jax.random.normal(jax.random.fold_in(key, 1), (2, d2))
    target = target + 0.15 * (u @ v)
    if basis == "fourier":
        # low-frequency entry bias (paper Eq. 5): the spectral parameterization
        # can be TARGETED at the structure, which no random/orthogonal basis
        # supports — this is the expressiveness asymmetry Table 6 reports.
        E = fourierft.sample_entries(d1, d2, n, seed=2024, freq_bias=True,
                                     fc=0.0, bandwidth=8.0, centered=False)
        mat = lambda c: fourierft.materialize_delta(c, E, d1, d2, float(d1 * d2))
    else:
        b1, b2 = basis_mod.make_basis(jax.random.fold_in(key, 2), basis,
                                      d1, d2, n)
        mat = lambda c: basis_mod.materialize_delta_basis(
            c, b1, b2, basis, float(d1 * d2) if basis == "random"
            else 2.0 * (d1 * d2) ** 0.5)
    c = jnp.zeros(n)
    lossf = jax.jit(lambda c: jnp.mean((mat(c) - target) ** 2))
    g = jax.jit(jax.grad(lossf))
    lr = 0.5
    for _ in range(steps):
        c = c - lr * g(c)
    rel = float(jnp.linalg.norm(mat(c) - target) / jnp.linalg.norm(target))
    return rel


def main():
    recs = {}
    for basis in ["fourier", "orthogonal", "random"]:
        rel = matrix_recovery(basis)
        recs[basis] = rel
        emit(f"table6/recovery_{basis}", 0.0, f"rel_err={rel:.4f}")
    # fine-tune ordering at equal params
    cfg = tiny("yi-6b")
    for basis in ["fourier", "orthogonal", "random"]:
        # square wq site only: the orthogonal ablation needs n <= min(d1,d2)
        r = finetune(cfg, PEFTConfig(method="fourierft", n=48, alpha=10.0,
                                     basis=basis, strategy="merged",
                                     target_modules=("wq",), train_head=True),
                     steps=40, lr=3e-2, pretrain_steps=20)
        emit(f"table6/finetune_{basis}", r["us_per_step"],
             f"loss={r['final_loss']:.4f}")


if __name__ == "__main__":
    main()
