"""Benchmark driver — one module per paper table/figure (+ systems benches).
Prints ``name,us_per_call,derived`` CSV. `python -m benchmarks.run [--only X]`.
"""
import argparse
import importlib
import sys
import time
import traceback

MODULES = [
    "benchmarks.bench_table1_params",
    "benchmarks.bench_table2_glue_proxy",
    "benchmarks.bench_table3_e2e_proxy",
    "benchmarks.bench_table4_instruct_proxy",
    "benchmarks.bench_table5_vision_proxy",
    "benchmarks.bench_table6_basis",
    "benchmarks.bench_fig4_scalability",
    "benchmarks.bench_fig5_freq_bias",
    "benchmarks.bench_fig6_curve",
    "benchmarks.bench_kernels",
    "benchmarks.bench_grad_comm",
    "benchmarks.bench_adapter_bank",
    "benchmarks.bench_serve_scheduler",
    "benchmarks.bench_serve_paging",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = []
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        t0 = time.perf_counter()
        try:
            importlib.import_module(mod_name).main()
            print(f"# {mod_name} done in {time.perf_counter()-t0:.1f}s",
                  flush=True)
        except Exception:
            traceback.print_exc()
            failed.append(mod_name)
    if failed:
        print(f"# FAILED: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
