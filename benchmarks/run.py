"""Benchmark driver — one module per paper table/figure (+ systems benches).
Prints ``name,us_per_call,derived`` CSV. `python -m benchmarks.run [--only X]`.

Serving rows (`serve_*`) are additionally written to ``BENCH_serve.json``
at the repo root — tok/s, TTFT quantiles, speculative acceptance — so the
serving perf trajectory is machine-diffable across PRs instead of living
only in stdout. Analyzer rows (`analysis_*`: pass latency + finding
counts) land in ``BENCH_analysis.json`` the same way.
"""
import argparse
import importlib
import json
import pathlib
import sys
import time
import traceback

from benchmarks.common import ROWS

MODULES = [
    "benchmarks.bench_table1_params",
    "benchmarks.bench_table2_glue_proxy",
    "benchmarks.bench_table3_e2e_proxy",
    "benchmarks.bench_table4_instruct_proxy",
    "benchmarks.bench_table5_vision_proxy",
    "benchmarks.bench_table6_basis",
    "benchmarks.bench_fig4_scalability",
    "benchmarks.bench_fig5_freq_bias",
    "benchmarks.bench_fig6_curve",
    "benchmarks.bench_kernels",
    "benchmarks.bench_grad_comm",
    "benchmarks.bench_adapter_bank",
    "benchmarks.bench_serve_scheduler",
    "benchmarks.bench_serve_paging",
    "benchmarks.bench_serve_spec",
    "benchmarks.bench_serve_gateway",
    "benchmarks.bench_serve_tiering",
    "benchmarks.bench_analysis",
    "benchmarks.bench_sharding_plan",
]

SERVE_JSON = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_serve.json"
ANALYSIS_JSON = SERVE_JSON.with_name("BENCH_analysis.json")


def parse_row(row: str) -> tuple:
    """`name,us_per_call,k=v;k=v` -> (name, {us_per_call, k: v, ...}) with
    numeric values parsed (the emit() contract keeps values float-able;
    anything else stays a string rather than failing the dump)."""
    name, us, derived = row.split(",", 2)
    rec = {"us_per_call": float(us)}
    for kv in derived.split(";"):
        if "=" not in kv:
            continue
        k, v = kv.split("=", 1)
        try:
            rec[k] = float(v)
        except ValueError:
            rec[k] = v
    return name, rec


def dump_prefix_json(rows, prefix, path) -> dict:
    """Merge every `<prefix>*` row into the JSON object keyed by row name:
    re-run rows replace their previous values, rows a partial run (e.g.
    `--only serve_gateway`) did not produce keep theirs, and empty runs
    leave the file alone."""
    picked = dict(parse_row(r) for r in rows if r.startswith(prefix))
    if picked:
        merged = {}
        if path.exists():
            try:
                merged = json.loads(path.read_text())
            except json.JSONDecodeError:
                merged = {}                    # corrupt file: rebuild
        merged.update(picked)
        path.write_text(json.dumps(merged, indent=2, sort_keys=True) + "\n")
    return picked


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = []
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        t0 = time.perf_counter()
        try:
            importlib.import_module(mod_name).main()
            print(f"# {mod_name} done in {time.perf_counter()-t0:.1f}s",
                  flush=True)
        except Exception:
            traceback.print_exc()
            failed.append(mod_name)
    if dump_prefix_json(ROWS, "serve", SERVE_JSON):
        print(f"# serving rows -> {SERVE_JSON}", flush=True)
    if dump_prefix_json(ROWS, "analysis", ANALYSIS_JSON):
        print(f"# analysis rows -> {ANALYSIS_JSON}", flush=True)
    if dump_prefix_json(ROWS, "sharding_plan", ANALYSIS_JSON):
        print(f"# sharding-plan rows -> {ANALYSIS_JSON}", flush=True)
    if failed:
        print(f"# FAILED: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
