"""Paged KV cache vs dense per-slot cache under shared-prefix traffic
(DESIGN.md §Paging).

Replays staggered traces through the continuous scheduler with the dense
per-slot cache and with the paged cache, at 0% / 50% / 90% shared-prefix
traffic: a 224-token page-aligned system prompt + short unique tails for
the shared fraction, never-repeating full-length prompts for the rest
(fresh rng per run, so the 0% cell stays truly 0% across the warm-up and
the measured run — repeating "unique" traffic would silently become 100%
shared on the second pass). Reports, per cell:

  - TTFT as wall-clock prime-prefill latency (`prime_s_mean/p90`): the
    decode-step-clock TTFT is identical by construction (admission emits
    the first token), so what moves is the prefill compute the prefix
    cache removes — the paged prime runs only the unshared tail (a
    16-token bucket instead of the 256-token full prompt);
  - end-to-end tokens/s (whole-drain wall clock, prefills included);
  - a bit-exactness cross-check of paged vs dense outputs.

Acceptance: paged TTFT < dense TTFT at >= 50% shared traffic (at 0% the
two sit near parity — the block-table gather is the only overhead). Uses a
4-layer d_model=256 config so prefill compute dominates dispatch; at the
tests' tiny reduced scale every prime is dispatch-bound and the effect
would drown."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.configs.base import PEFTConfig
from repro.models import build
from repro.serve import ContinuousScheduler, Engine, Request
from benchmarks.common import emit

SLOTS = 4
MAX_LEN = 288
PAGE = 16
N_REQ = 12
PREFIX_LEN = 224                   # 14 shared pages
GAP = 1.0                          # arrival spacing (decode steps)
BUDGETS = [3, 5, 2, 6, 4, 3] * 2
PREFIX = (np.arange(PREFIX_LEN) * 5 + 3) % 256


def _requests(share: float, salt: int):
    """share = fraction opening with the common system prompt; the rest are
    fully unique prompts of the same total length, never repeated across
    runs (salt)."""
    rng = np.random.default_rng(1000 * salt + int(share * 100))
    n_shared = round(share * N_REQ)
    reqs = []
    for i in range(N_REQ):
        tail = rng.integers(0, 256, size=4 + i % 5)
        if i < n_shared:
            toks = np.concatenate([PREFIX, tail])
        else:
            toks = rng.integers(0, 256, size=PREFIX_LEN + len(tail))
        reqs.append(Request(prompt=jnp.asarray(toks, jnp.int32),
                            max_new=BUDGETS[i]))
    return reqs


def _run(sched, share: float):
    arrivals = [i * GAP for i in range(N_REQ)]
    sched.serve(_requests(share, salt=1), arrivals)    # warm-up: compile
    sched.reset_metrics()                              # + seed the prefix
    reqs = sched.serve(_requests(share, salt=2), arrivals)
    return reqs, sched.metrics.summary()


def main():
    cfg = C.reduced(C.get("yi-6b")).replace(
        vocab=256, d_model=256, num_layers=4, d_ff=768,
        n_heads=8, n_kv=4, head_dim=32)
    model = build(cfg, PEFTConfig(method="none"))
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, batch_slots=SLOTS, max_len=MAX_LEN)
    dense = ContinuousScheduler(eng, paged=False)
    paged = ContinuousScheduler(eng, page_size=PAGE)

    for share in (0.0, 0.5, 0.9):
        d_reqs, d = _run(dense, share)
        p_reqs, p = _run(paged, share)
        mismatch = sum(a.out != b.out for a, b in zip(p_reqs, d_reqs))
        assert mismatch == 0, "paged outputs diverged from dense"
        for tag, s in (("dense", d), ("paged", p)):
            emit(f"serve_paging/{tag}_share{int(share * 100)}",
                 s["prime_s_mean"] * 1e6,
                 f"ttft_prime_ms={s['prime_s_mean'] * 1e3:.1f};"
                 f"ttft_prime_p90_ms={s['prime_s_p90'] * 1e3:.1f};"
                 f"tok_s={s['tokens_per_s']:.0f};"
                 f"occupancy={s['occupancy_mean']:.2f}")
        emit(f"serve_paging/speedup_share{int(share * 100)}", 0.0,
             f"ttft_ratio={d['prime_s_mean'] / max(p['prime_s_mean'], 1e-9):.2f};"
             f"tok_s_ratio={p['tokens_per_s'] / max(d['tokens_per_s'], 1e-9):.2f};"
             f"mismatches={mismatch}/{N_REQ}")


if __name__ == "__main__":
    main()
