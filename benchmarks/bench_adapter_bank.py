"""Adapter-bank serving cost: decode throughput with 1/8/64 resident
factored adapters vs the single-merged baseline (the paper's zero-latency
deployment). The bank's per-step overhead is the row gather plus a few
rank-2n einsums per adapted site — flat in the number of residents K
(the gather indexes rows; K only grows HBM residency), which is the whole
point: one graph serves a heterogeneous fleet of tenants."""
import time

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.configs.base import PEFTConfig
from repro.core import peft as peft_mod
from repro.models import build
from repro.serve import AdapterBank, Engine
from benchmarks.common import emit

BATCH = 8
MAX_LEN = 64
STEPS = 30


def _decode_us(engine, params, extra):
    cache = engine._fresh_cache()
    toks = jnp.ones((BATCH, 1), jnp.int32)
    nt, cache = engine._decode(params, cache, {"tokens": toks, **extra})
    jax.block_until_ready(nt)                                  # compile
    t0 = time.perf_counter()
    cur = toks
    for _ in range(STEPS):
        nt, cache = engine._decode(params, cache, {"tokens": cur, **extra})
        cur = nt[:, None]
    jax.block_until_ready(nt)
    return (time.perf_counter() - t0) * 1e6 / STEPS


def main():
    cfg = C.reduced(C.get("yi-6b")).replace(vocab=256)
    prof = PEFTConfig(method="fourierft", n=64, alpha=25.0,
                      param_dtype="float32")
    model = build(cfg, prof)
    params = model.init(jax.random.PRNGKey(0))

    # baseline: one tenant merged into the base (zero added latency)
    merged = Engine(model, params, batch_slots=BATCH, max_len=MAX_LEN)
    base_us = _decode_us(merged, merged.params, {})
    emit("adapter_bank/merged_baseline", base_us,
         f"batch={BATCH};tok_s={BATCH * 1e6 / base_us:.0f}")

    base_model = build(cfg, PEFTConfig(method="none"))
    base_params = base_model.init(jax.random.PRNGKey(0))
    for k in (1, 8, 64):
        bank = AdapterBank(base_model, {"fourierft": prof}, capacity=k)
        for i in range(k):
            tree = peft_mod.init_adapters(jax.random.PRNGKey(i),
                                          base_model.sites, prof)
            bank.load(f"tenant-{i}", tree, prof)
        eng = Engine(base_model, base_params, batch_slots=BATCH,
                     max_len=MAX_LEN, bank=bank)
        ids = [f"tenant-{i % k}" for i in range(BATCH)]
        extra = {"adapter_slots": bank.slot_rows(ids, BATCH)}
        bank_params = {**eng.params, "bank": bank.params}
        us = _decode_us(eng, bank_params, extra)
        emit(f"adapter_bank/resident_{k}", us,
             f"batch={BATCH};tok_s={BATCH * 1e6 / us:.0f};"
             f"vs_merged={us / base_us:.3f}")


if __name__ == "__main__":
    main()
