"""Systems table (DESIGN §4): cross-pod DP gradient payload per step —
full FT vs LoRA vs FourierFT — and int8 error-feedback compression on top
(now measured with repro.dist.compression, not just counted).
This is the paper's storage claim re-cast as a distributed-training claim:
the FourierFT all-reduce payload for LLaMA2-7B-sized q/v adaptation is 524x
smaller than LoRA r=64's and 450,000x smaller than full FT's."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import PEFTConfig
from repro.configs.paper_models import PAPER_MODELS
from repro.core import peft as peft_mod
from repro.dist import compression
from benchmarks.common import emit


def _compression_fidelity():
    """Run the real int8-EF path on a synthetic FourierFT gradient tree:
    per-step relative error and the EF property (mean of sent -> truth)."""
    rng = np.random.default_rng(0)
    grads = {
        "c": jnp.asarray(rng.normal(size=(32, 1000)).astype(np.float32)),
        "head": jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32)
                            * 1e-2),
    }
    residual = compression.init_residual(grads)
    acc = jax.tree.map(jnp.zeros_like, grads)
    steps = 32
    # time the jitted path (what the train step runs); eager per-leaf
    # dispatch would overstate the cost ~1000x
    compress = jax.jit(compression.compress_with_feedback)
    jax.block_until_ready(compress(grads, residual))   # compile
    t0 = time.perf_counter()
    for _ in range(steps):
        sent, residual = compress(grads, residual)
        acc = jax.tree.map(jnp.add, acc, sent)
    jax.block_until_ready((acc, residual))
    dt = (time.perf_counter() - t0) / steps
    one, _ = compression.compress_with_feedback(
        grads, compression.init_residual(grads))
    step_err = max(
        float(jnp.abs(s - g).max() / jnp.abs(g).max())
        for s, g in zip(jax.tree.leaves(one), jax.tree.leaves(grads)))
    ef_err = max(
        float(jnp.abs(a / steps - g).max() / jnp.abs(g).max())
        for a, g in zip(jax.tree.leaves(acc), jax.tree.leaves(grads)))
    f32_b, int8_b = compression.payload_bytes(grads)
    emit("grad_comm/int8_ef_step_relerr_ppm", step_err * 1e6,
         f"us_per_step={dt*1e6:.0f}")
    emit("grad_comm/int8_ef_accum_relerr_ppm", ef_err * 1e6,
         f"steps={steps};payload_f32={f32_b};payload_int8={int8_b}")


def main():
    cfg = PAPER_MODELS["llama2-7b"]
    sites = peft_mod.qv_sites_for(cfg)
    full_params = 6_738_000_000
    rows = [
        ("full_ft", full_params),
        ("lora_r64", peft_mod.count_trainable(sites, PEFTConfig(method="lora", lora_r=64))),
        ("lora_r16", peft_mod.count_trainable(sites, PEFTConfig(method="lora", lora_r=16))),
        ("fourier_n1000", peft_mod.count_trainable(sites, PEFTConfig(method="fourierft", n=1000))),
        ("fourier_n2000", peft_mod.count_trainable(sites, PEFTConfig(method="fourierft", n=2000))),
    ]
    base = rows[0][1] * 4
    for name, params in rows:
        f32 = params * 4
        int8 = params  # int8 error-feedback compression payload
        emit(f"grad_comm/{name}", 0.0,
             f"bytes_f32={f32};bytes_int8={int8};vs_full={f32/base:.2e}")
    # at 50GB/s ICI, per-step cross-pod all-reduce time (2x payload, ring)
    for name, params in rows:
        t_us = 2 * params * 4 / 50e9 * 1e6
        emit(f"grad_comm/{name}_xpod_time", t_us, "ring_allreduce_2x@50GBps")
    _compression_fidelity()


if __name__ == "__main__":
    main()
