"""Systems table (DESIGN §4): cross-pod DP gradient payload per step —
full FT vs LoRA vs FourierFT — and int8 error-feedback compression on top.
This is the paper's storage claim re-cast as a distributed-training claim:
the FourierFT all-reduce payload for LLaMA2-7B-sized q/v adaptation is 524x
smaller than LoRA r=64's and 450,000x smaller than full FT's."""
import numpy as np

from repro.configs.base import PEFTConfig
from repro.configs.paper_models import PAPER_MODELS
from repro.core import peft as peft_mod
from benchmarks.common import emit


def main():
    cfg = PAPER_MODELS["llama2-7b"]
    sites = peft_mod.qv_sites_for(cfg)
    full_params = 6_738_000_000
    rows = [
        ("full_ft", full_params),
        ("lora_r64", peft_mod.count_trainable(sites, PEFTConfig(method="lora", lora_r=64))),
        ("lora_r16", peft_mod.count_trainable(sites, PEFTConfig(method="lora", lora_r=16))),
        ("fourier_n1000", peft_mod.count_trainable(sites, PEFTConfig(method="fourierft", n=1000))),
        ("fourier_n2000", peft_mod.count_trainable(sites, PEFTConfig(method="fourierft", n=2000))),
    ]
    base = rows[0][1] * 4
    for name, params in rows:
        f32 = params * 4
        int8 = params  # int8 error-feedback compression payload
        emit(f"grad_comm/{name}", 0.0,
             f"bytes_f32={f32};bytes_int8={int8};vs_full={f32/base:.2e}")
    # at 50GB/s ICI, per-step cross-pod all-reduce time (2x payload, ring)
    for name, params in rows:
        t_us = 2 * params * 4 / 50e9 * 1e6
        emit(f"grad_comm/{name}_xpod_time", t_us, "ring_allreduce_2x@50GBps")


if __name__ == "__main__":
    main()
