"""Paper Table 2 proxy (GLUE): FF vs BitFit vs LoRA vs FourierFT on a
synthetic NLU suite (Markov-LM fine-tune after a task shift). GLUE itself is
unavailable offline; the claim being reproduced is the ORDERING — FourierFT
matches/beats LoRA with ~8% of its trainable parameters."""
from repro.configs.base import PEFTConfig
from benchmarks.common import emit, finetune, tiny


def main():
    cfg = tiny("yi-6b")
    methods = [
        ("ff", PEFTConfig(method="full"), 3e-3),
        ("bitfit", PEFTConfig(method="bitfit", train_head=True), 2e-2),
        ("lora_r8", PEFTConfig(method="lora", lora_r=8, train_head=True), 2e-2),
        ("fourier_n100", PEFTConfig(method="fourierft", n=100, alpha=10.0,
                                    train_head=True), 3e-2),
    ]
    results = {}
    for name, peft, lr in methods:
        r = finetune(cfg, peft, steps=50, lr=lr, pretrain_steps=30)
        results[name] = r
        emit(f"table2/{name}", r["us_per_step"],
             f"loss={r['final_loss']:.4f};trainable={r['trainable']}")
    # ordering claim: fourier within 5% of lora's loss at ~6-8% of params
    four, lora = results["fourier_n100"], results["lora_r8"]
    ok = four["final_loss"] <= lora["final_loss"] * 1.05
    ratio = four["trainable"] / max(lora["trainable"], 1)
    emit("table2/claim_fourier_matches_lora", 0.0,
         f"holds={ok};param_ratio={ratio:.3f}")


if __name__ == "__main__":
    main()
