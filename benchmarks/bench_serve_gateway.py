"""Gateway end-to-end serving benchmark (DESIGN.md §Gateway).

Boots the asyncio HTTP gateway over a reduced-config continuous runtime
IN-PROCESS (server and loadgen client share one event loop — the rows
measure the full wire path: HTTP parse, scheduler bridge, SSE framing,
plus decode itself) and drives it with `benchmarks.loadgen`'s open-loop
Poisson traffic at three arrival rates, each at 0% and 90% shared-prefix
mix. Per cell:

  - `us_per_call` = p50 end-to-end request latency;
  - TTFT p50/p99 (ms), ITL p50 (ms), delivered tok/s, and the
    ok/retry counts (429 backpressure shows up as retries, not failures).

The 90% shared-prefix cells exercise the paged prefix cache through the
gateway: TTFT should drop vs the 0% cells at equal rate since admitted
prompts prefill only their tails. Uses a 4-layer d_model=256 config (as
bench_serve_paging) so decode compute is non-trivial at bench scale.
"""
import asyncio

import jax

import repro.configs as C
from repro.configs.base import PEFTConfig
from repro.models import build
from repro.serve import ContinuousScheduler, Engine
from repro.serve.gateway import GatewayServer
from benchmarks import loadgen
from benchmarks.common import emit

RATES = (4.0, 16.0, 64.0)              # req/s offered (open loop)
N_REQ = 24
MAX_NEW = 12
PREFIX_LEN = 64                        # page-aligned shared system prompt
TAIL_LEN = 4


def _scheduler():
    cfg = C.reduced(C.get("yi-6b"), layers=4, width=256).replace(
        vocab=512, param_dtype="float32", dtype="float32")
    model = build(cfg, PEFTConfig(method="none"))
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, batch_slots=4, max_len=128)
    return ContinuousScheduler(eng, page_size=16)


async def _cell(server: GatewayServer, rate: float, shared_frac: float,
                seed: int):
    payloads = loadgen.make_traffic(
        n=N_REQ, vocab=512, models=["base"], zipf_a=0.0,
        shared_frac=shared_frac, prefix_len=PREFIX_LEN, tail_len=TAIL_LEN,
        max_new=MAX_NEW, stream=True, seed=seed)
    results, wall_s = await loadgen.run_open_loop(
        server.host, server.port, payloads, rate=rate, seed=seed,
        retries=16, timeout_s=300.0)
    return loadgen.summarize(results, wall_s)


async def _run() -> None:
    server = GatewayServer(_scheduler(), max_queue=2 * N_REQ,
                           default_max_new=MAX_NEW)
    await server.start()
    try:
        # one warmup pass populates the jit caches so the first cell is
        # not charged the prefill/decode compile time
        await _cell(server, rate=0.0, shared_frac=0.5, seed=99)
        for rate in RATES:
            for shared in (0.0, 0.9):
                s = await _cell(server, rate, shared, seed=int(rate))
                emit(f"serve_gateway_rate{rate:g}_shared{int(shared * 100)}",
                     s["latency_p50_ms"] * 1e3,
                     f"ttft_p50_ms={s['ttft_p50_ms']:.2f};"
                     f"ttft_p99_ms={s['ttft_p99_ms']:.2f};"
                     f"itl_p50_ms={s['itl_p50_ms']:.3f};"
                     f"tok_s={s['tok_s']:.1f};"
                     f"ok={s['ok']};retries={s['retries']}")
    finally:
        await server.close()


def main() -> None:
    asyncio.run(_run())


if __name__ == "__main__":
    main()
