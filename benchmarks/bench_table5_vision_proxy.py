"""Paper Table 5 proxy (ViT image classification): LP vs LoRA vs FourierFT on
the synthetic blob-classification task through a ViT-shaped trunk operating on
patch-like random-projection embeddings."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fourierft, lora
from repro.data import SyntheticClassification
from benchmarks.common import emit


def _run(method: str, steps: int = 300, n: int = 64, r: int = 2):
    data = SyntheticClassification(num_classes=8, dim=16, noise=0.35)
    x, y = data.dataset(48)
    key = jax.random.PRNGKey(0)
    d = 64
    ks = jax.random.split(key, 8)
    layers = [(jax.random.normal(ks[i], (16 if i == 0 else d, d)) * 0.3,
               jnp.zeros(d)) for i in range(2)]
    head_w0 = jax.random.normal(ks[6], (d, 8)) * 0.1
    entries = [fourierft.sample_entries(w.shape[0], w.shape[1], n, seed=2024)
               for w, _ in layers]
    loras = [lora.init_lora(jax.random.fold_in(key, i), w.shape[0],
                            w.shape[1], r) for i, (w, _) in enumerate(layers)]

    def forward(train):
        h = x
        for i, (w, b) in enumerate(layers):
            yy = h @ w + b
            if method == "fourierft":
                yy = yy + fourierft.factored_apply(
                    h, train["cs"][i], entries[i], w.shape[0], w.shape[1],
                    float(w.shape[0] * w.shape[1]))
            elif method == "lora":
                ad = train["loras"][i]
                yy = yy + lora.lora_apply(h, ad["lora_a"], ad["lora_b"],
                                          2.0 * r, r)
            h = jax.nn.gelu(yy)
        return h @ train["hw"] + train["hb"]

    def loss_fn(train):
        logits = forward(train)
        onehot = jax.nn.one_hot(y, 8)
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))

    train = {"hw": head_w0, "hb": jnp.zeros(8)}
    if method == "fourierft":
        train["cs"] = [jnp.zeros(n) for _ in layers]
    elif method == "lora":
        train["loras"] = loras
    lr = 0.05

    @jax.jit
    def step(train):
        l, g = jax.value_and_grad(loss_fn)(train)
        return l, jax.tree.map(lambda p, gg: p - lr * gg, train, g)

    t0 = time.perf_counter()
    for _ in range(steps):
        l, train = step(train)
    wall = (time.perf_counter() - t0) / steps * 1e6
    acc = float((jnp.argmax(forward(train), -1) == y).mean())
    n_adapter = sum(int(np.prod(v.shape)) for k, v in train.items()
                    if k in ("cs", "loras")
                    for v in jax.tree.leaves(train[k]))
    return acc, wall, n_adapter


def main():
    for method in ["none", "lora", "fourierft"]:
        acc, us, n_train = _run(method)
        emit(f"table5/{'lp' if method == 'none' else method}", us,
             f"acc={acc:.3f};adapter_params={n_train}")


if __name__ == "__main__":
    main()
