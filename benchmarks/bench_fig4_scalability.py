"""Paper Fig. 4: parameter scalability — n sweep (FourierFT) vs r sweep
(LoRA) at matched budgets; FourierFT should improve monotonically with n."""
import numpy as np

from repro.configs.base import PEFTConfig
from benchmarks.common import emit, finetune, tiny


def main():
    cfg = tiny("yi-6b")
    four_losses = []
    for n in [16, 64, 256]:
        r = finetune(cfg, PEFTConfig(method="fourierft", n=n, alpha=10.0,
                                     train_head=True),
                     steps=40, lr=3e-2, pretrain_steps=20)
        four_losses.append(r["final_loss"])
        emit(f"fig4/fourier_n{n}", r["us_per_step"],
             f"loss={r['final_loss']:.4f};params={r['trainable']}")
    for rr in [1, 4, 8]:
        r = finetune(cfg, PEFTConfig(method="lora", lora_r=rr,
                                     train_head=True),
                     steps=40, lr=2e-2, pretrain_steps=20)
        emit(f"fig4/lora_r{rr}", r["us_per_step"],
             f"loss={r['final_loss']:.4f};params={r['trainable']}")
    trend = "improving" if four_losses[-1] <= four_losses[0] else "flat"
    emit("fig4/fourier_n_trend", 0.0, f"{trend};losses=" +
         "|".join(f"{l:.3f}" for l in four_losses))


if __name__ == "__main__":
    main()
