"""Open-loop asyncio load generator for the serving gateway
(DESIGN.md §Gateway).

Fires `--n` token-id completion requests at a running gateway with
Poisson arrivals at `--rate` req/s (open loop: the arrival process never
waits for responses, so queueing delay shows up in the latency tail
instead of throttling the offered load). Traffic mixes:

  - tenant skew: requests route to `--models` (comma list, e.g.
    ``base,adapter:t0,adapter:t1``) under a Zipf law over list order —
    `--zipf-a 0` is uniform, larger is more skewed;
  - shared prefixes: with probability `--shared-frac` a request reuses
    its model's deterministic common prefix (per-tenant, so prefix-cache
    hits stay tenant-isolated) followed by a short random tail; the rest
    are fully random prompts of the same total length.

Per request it records latency, TTFT and inter-token gaps from the SSE
stream (or the blocking JSON response with `--no-stream`), honours 429
Retry-After backpressure with bounded retries, and prints nearest-rank
percentiles. `--out` dumps per-request results as JSON.

`--verify` is the gateway's exactness check: it rebuilds the identical
engine in-process from the same model flags (`repro.launch.api
.build_scheduler` — pass the server's --arch/--reduced/--seed/... here
too), replays the collected traffic through `ContinuousScheduler.serve`,
and exits 1 unless every gateway stream is bit-identical to the replay.

Typical run against a laptop-scale server:

    PYTHONPATH=src python -m repro.launch.api --arch yi-6b --reduced \\
        --bank-dir /tmp/bank --port 8080 &
    PYTHONPATH=src python -m benchmarks.loadgen --port 8080 --n 64 \\
        --rate 16 --models base,adapter:t0 --shared-frac 0.9 --verify \\
        --arch yi-6b --reduced --bank-dir /tmp/bank
"""
from __future__ import annotations

import argparse
import asyncio
import json
import sys
import time
import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

_RETRYABLE = (ConnectionError, asyncio.IncompleteReadError, OSError)


@dataclass
class ReqResult:
    """One request's outcome (tokens are the bit-exactness payload)."""
    payload: Dict
    ok: bool = False
    status: int = 0
    tokens: List[int] = field(default_factory=list)
    finish: Optional[str] = None
    ttft_s: float = float("nan")
    latency_s: float = float("nan")
    itl_s: List[float] = field(default_factory=list)
    retries: int = 0                   # 429/connection retries consumed
    error: Optional[str] = None


# ---- traffic ---------------------------------------------------------------
def shared_prefix(model: str, prefix_len: int, vocab: int,
                  seed: int) -> List[int]:
    """The model's deterministic common prefix — same flags, same prefix,
    on both the loadgen and any verifier that wants to precompute it."""
    rng = np.random.default_rng([seed, zlib.crc32(model.encode())])
    return [int(t) for t in rng.integers(1, vocab, size=prefix_len)]


def parse_priority_mix(spec: str) -> (List[str], List[float]):
    """`"interactive:1,batch:2"` -> (classes, normalized weights).
    A bare class name means weight 1."""
    classes, weights = [], []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        name, _, w = part.partition(":")
        classes.append(name)
        weights.append(float(w) if w else 1.0)
    total = sum(weights)
    return classes, [w / total for w in weights]


def make_traffic(*, n: int, vocab: int, models: List[str], zipf_a: float,
                 shared_frac: float, prefix_len: int, tail_len: int,
                 max_new: int, stream: bool, seed: int,
                 priorities: Optional[str] = None) -> List[Dict]:
    """`n` /v1/completions payloads; deterministic in the arguments.
    `priorities` ("cls:weight,..." — see parse_priority_mix) samples a
    tiering class per request and sets the `priority` payload extension."""
    rng = np.random.default_rng(seed)
    w = 1.0 / (np.arange(1, len(models) + 1) ** max(zipf_a, 0.0))
    w /= w.sum()
    prefixes = {m: shared_prefix(m, prefix_len, vocab, seed) for m in models}
    cls_names, cls_w = (parse_priority_mix(priorities)
                        if priorities else ([], []))
    payloads = []
    for _ in range(n):
        model = models[int(rng.choice(len(models), p=w))]
        tail = [int(t) for t in rng.integers(1, vocab, size=tail_len)]
        if rng.random() < shared_frac:
            prompt = prefixes[model] + tail
        else:
            prompt = [int(t) for t in
                      rng.integers(1, vocab, size=prefix_len)] + tail
        payload = {"model": model, "prompt": prompt,
                   "max_tokens": max_new, "stream": stream}
        if cls_names:
            payload["priority"] = \
                cls_names[int(rng.choice(len(cls_names), p=cls_w))]
        payloads.append(payload)
    return payloads


# ---- stdlib HTTP client ----------------------------------------------------
async def _once(host: str, port: int, payload: Dict,
                res: ReqResult) -> Optional[float]:
    """One HTTP attempt. Fills `res`; returns a Retry-After delay when the
    server answered 429 (the caller backs off and retries)."""
    reader, writer = await asyncio.open_connection(host, port)
    try:
        body = json.dumps(payload).encode("utf-8")
        writer.write((f"POST /v1/completions HTTP/1.1\r\nHost: {host}\r\n"
                      f"Content-Type: application/json\r\n"
                      f"Content-Length: {len(body)}\r\n"
                      f"Connection: close\r\n\r\n").encode("latin-1") + body)
        t_send = time.perf_counter()
        await writer.drain()
        head = await reader.readuntil(b"\r\n\r\n")
        head_lines = head.decode("latin-1").split("\r\n")
        res.status = int(head_lines[0].split()[1])
        headers = {}
        for ln in head_lines[1:]:
            k, _, v = ln.partition(":")
            headers[k.strip().lower()] = v.strip()
        if res.status == 429:
            await reader.read()                # drain the error body
            return float(headers.get("retry-after", "0.1"))
        if res.status != 200:
            res.error = (await reader.read()).decode("utf-8",
                                                     "replace")[:200]
            return None
        if payload.get("stream"):
            await _read_sse(reader, t_send, res)
        else:
            obj = json.loads(await reader.read())
            choice = obj["choices"][0]
            res.tokens = [int(t) for t in choice["token_ids"]]
            res.finish = choice.get("finish_reason")
            res.latency_s = time.perf_counter() - t_send
            res.ttft_s = res.latency_s         # no stream: first=last byte
        res.ok = res.finish in ("stop", "length")
        if not res.ok and res.error is None:
            res.error = f"finish_reason={res.finish!r}"
        return None
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, OSError):
            pass


async def _read_sse(reader: asyncio.StreamReader, t_send: float,
                    res: ReqResult) -> None:
    """Consume `data:` frames until [DONE], timestamping token chunks."""
    t_prev = None
    while True:
        line = await reader.readline()
        if not line:
            res.error = "stream closed before [DONE]"
            return
        line = line.strip()
        if not line.startswith(b"data: "):
            continue
        data = line[len(b"data: "):]
        if data == b"[DONE]":
            res.latency_s = time.perf_counter() - t_send
            return
        choice = json.loads(data)["choices"][0]
        if "token_id" in choice:
            now = time.perf_counter()
            if t_prev is None:
                res.ttft_s = now - t_send
            else:
                res.itl_s.append(now - t_prev)
            t_prev = now
            res.tokens.append(int(choice["token_id"]))
        if choice.get("finish_reason") is not None:
            res.finish = choice["finish_reason"]


async def send_request(host: str, port: int, payload: Dict, *,
                       retries: int = 8, retry_cap_s: float = 2.0,
                       timeout_s: float = 120.0) -> ReqResult:
    """POST with bounded 429/connection retries (honours Retry-After)."""
    res = ReqResult(payload=payload)
    for _ in range(retries + 1):
        try:
            backoff = await asyncio.wait_for(_once(host, port, payload, res),
                                             timeout_s)
        except asyncio.TimeoutError:
            res.error = f"client timeout after {timeout_s:g}s"
            return res
        except _RETRYABLE as e:
            res.retries += 1
            res.error = f"{type(e).__name__}: {e}"
            await asyncio.sleep(0.2)
            continue
        if backoff is None:
            return res
        res.retries += 1
        res.error = "429 retries exhausted"
        await asyncio.sleep(min(backoff, retry_cap_s))
    return res


async def run_open_loop(host: str, port: int, payloads: List[Dict], *,
                        rate: float, seed: int, retries: int,
                        timeout_s: float) -> (List[ReqResult], float):
    """Poisson open loop: arrival times are drawn up front and every
    request fires at its slot regardless of how the server is doing."""
    rng = np.random.default_rng(seed + 0x9E3779B9)
    if rate > 0:
        arrivals = np.cumsum(rng.exponential(1.0 / rate,
                                             size=len(payloads)))
    else:
        arrivals = np.zeros(len(payloads))     # burst: all at once
    t0 = time.perf_counter()

    async def fire(i: int, payload: Dict) -> ReqResult:
        delay = arrivals[i] - (time.perf_counter() - t0)
        if delay > 0:
            await asyncio.sleep(float(delay))
        return await send_request(host, port, payload, retries=retries,
                                  timeout_s=timeout_s)

    results = list(await asyncio.gather(
        *(fire(i, p) for i, p in enumerate(payloads))))
    return results, time.perf_counter() - t0


async def wait_ready(host: str, port: int, wait_s: float) -> bool:
    """Poll /healthz until the gateway answers (server boot races)."""
    deadline = time.monotonic() + wait_s
    while True:
        try:
            reader, writer = await asyncio.open_connection(host, port)
            writer.write((f"GET /healthz HTTP/1.1\r\nHost: {host}\r\n"
                          "Connection: close\r\n\r\n").encode("latin-1"))
            await writer.drain()
            head = await reader.readuntil(b"\r\n\r\n")
            await reader.read()
            writer.close()
            if b" 200 " in head.split(b"\r\n", 1)[0]:
                return True
        except _RETRYABLE:
            pass
        if time.monotonic() >= deadline:
            return False
        await asyncio.sleep(0.25)


# ---- reporting -------------------------------------------------------------
def slo_attainment(results: List[ReqResult], slo_ttft_ms: Optional[float],
                   slo_itl_ms: Optional[float]) -> Dict[str, Dict]:
    """Per-priority-class SLO attainment: the fraction of each class's
    requests whose TTFT (and p99 inter-token gap) landed inside the SLO.
    A failed request counts as missed — dropping traffic never helps the
    attainment number."""
    import math

    from repro.serve.scheduler.metrics import nearest_rank

    by_cls: Dict[str, List[ReqResult]] = {}
    for r in results:
        by_cls.setdefault(r.payload.get("priority", "batch"), []).append(r)
    out: Dict[str, Dict] = {}
    for cls, rs in sorted(by_cls.items()):
        met = 0
        for r in rs:
            good = r.ok
            if good and slo_ttft_ms is not None:
                good = (not math.isnan(r.ttft_s)
                        and r.ttft_s * 1e3 <= slo_ttft_ms)
            if good and slo_itl_ms is not None and r.itl_s:
                good = nearest_rank(sorted(r.itl_s), 0.99) * 1e3 \
                    <= slo_itl_ms
            met += bool(good)
        out[cls] = {"n": len(rs), "attained": met / len(rs)}
    return out


def summarize(results: List[ReqResult], wall_s: float,
              slo_ttft_ms: Optional[float] = None,
              slo_itl_ms: Optional[float] = None) -> Dict:
    from repro.serve.scheduler.metrics import nearest_rank

    ok = [r for r in results if r.ok]
    lat = sorted(r.latency_s for r in ok)
    ttft = sorted(r.ttft_s for r in ok)
    itl = sorted(g for r in ok for g in r.itl_s)
    toks = sum(len(r.tokens) for r in ok)
    by_model: Dict[str, int] = {}
    for r in results:
        m = r.payload["model"]
        by_model[m] = by_model.get(m, 0) + 1
    out = {
        "n": len(results), "ok": len(ok), "failed": len(results) - len(ok),
        "retries": sum(r.retries for r in results),
        "wall_s": wall_s, "tok_s": toks / max(wall_s, 1e-9),
        "latency_p50_ms": nearest_rank(lat, 0.50) * 1e3,
        "latency_p99_ms": nearest_rank(lat, 0.99) * 1e3,
        "ttft_p50_ms": nearest_rank(ttft, 0.50) * 1e3,
        "ttft_p99_ms": nearest_rank(ttft, 0.99) * 1e3,
        "itl_p50_ms": nearest_rank(itl, 0.50) * 1e3,
        "itl_p99_ms": nearest_rank(itl, 0.99) * 1e3,
        "by_model": by_model,
    }
    if slo_ttft_ms is not None or slo_itl_ms is not None:
        out["slo"] = {"ttft_ms": slo_ttft_ms, "itl_ms": slo_itl_ms,
                      "by_class": slo_attainment(results, slo_ttft_ms,
                                                 slo_itl_ms)}
    return out


# ---- verification ----------------------------------------------------------
def verify_replay(results: List[ReqResult], args) -> int:
    """Rebuild the engine from the model flags and replay every completed
    request in-process; returns the stream-mismatch count."""
    import jax.numpy as jnp

    from repro.launch.api import build_scheduler
    from repro.serve.engine import Request
    from repro.serve.gateway.protocol import resolve_model

    ok = [r for r in results if r.ok]
    if not ok:
        print("verify: no completed requests to replay")
        return 0
    sched, _ = build_scheduler(args)
    reqs = [Request(prompt=jnp.asarray(r.payload["prompt"], jnp.int32),
                    max_new=int(r.payload["max_tokens"]),
                    adapter_id=resolve_model(r.payload["model"]),
                    priority=r.payload.get("priority", "batch"))
            for r in ok]
    sched.serve(reqs)
    mismatches = 0
    for r, req in zip(ok, reqs):
        expect = [int(t) for t in req.out]
        if expect != r.tokens:
            mismatches += 1
            if mismatches <= 5:
                print(f"verify MISMATCH model={r.payload['model']} "
                      f"gateway={r.tokens} replay={expect}")
    print(f"verify: {len(ok)} streams replayed, {mismatches} mismatches")
    return mismatches


# ---- CLI -------------------------------------------------------------------
def main(argv=None) -> None:
    from repro.launch.api import add_model_args

    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="mean Poisson arrival rate, req/s (0 = one burst)")
    ap.add_argument("--models", default="base",
                    help="comma list routed under a Zipf law over order, "
                         "e.g. base,adapter:t0,adapter:t1")
    ap.add_argument("--zipf-a", type=float, default=1.2,
                    help="tenant-skew exponent (0 = uniform)")
    ap.add_argument("--shared-frac", type=float, default=0.0,
                    help="fraction of requests reusing the per-model "
                         "shared prefix")
    ap.add_argument("--prefix-len", type=int, default=32)
    ap.add_argument("--tail-len", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--vocab", type=int, default=512,
                    help="token-id space for synthetic prompts; must not "
                         "exceed the server's vocab")
    ap.add_argument("--priorities", default=None,
                    help="tiering-class mix 'cls[:weight],...', e.g. "
                         "interactive:1,batch:2,best_effort:1 — sets the "
                         "'priority' payload extension per request")
    ap.add_argument("--slo-ttft-ms", type=float, default=None,
                    help="TTFT SLO: report per-class attainment against it")
    ap.add_argument("--slo-itl-ms", type=float, default=None,
                    help="p99 inter-token-gap SLO, per request")
    ap.add_argument("--no-stream", action="store_true",
                    help="blocking JSON instead of SSE (no TTFT/ITL split)")
    ap.add_argument("--traffic-seed", type=int, default=0)
    ap.add_argument("--retries", type=int, default=8,
                    help="max 429/connection retries per request")
    ap.add_argument("--client-timeout", type=float, default=120.0)
    ap.add_argument("--wait-s", type=float, default=0.0,
                    help="poll /healthz up to this long before starting")
    ap.add_argument("--out", default=None,
                    help="write per-request results JSON here")
    ap.add_argument("--verify", action="store_true",
                    help="replay traffic in-process (build_scheduler on "
                         "the model flags below) and require bit-identical "
                         "streams")
    add_model_args(ap)                 # --arch/--reduced/... for --verify
    args = ap.parse_args(argv)

    models = [m.strip() for m in args.models.split(",") if m.strip()]
    payloads = make_traffic(
        n=args.n, vocab=args.vocab, models=models, zipf_a=args.zipf_a,
        shared_frac=args.shared_frac, prefix_len=args.prefix_len,
        tail_len=args.tail_len, max_new=args.max_new,
        stream=not args.no_stream, seed=args.traffic_seed,
        priorities=args.priorities)

    async def _go():
        if args.wait_s and not await wait_ready(args.host, args.port,
                                                args.wait_s):
            raise SystemExit(f"gateway at {args.host}:{args.port} not "
                             f"ready after {args.wait_s:g}s")
        return await run_open_loop(
            args.host, args.port, payloads, rate=args.rate,
            seed=args.traffic_seed, retries=args.retries,
            timeout_s=args.client_timeout)

    results, wall_s = asyncio.run(_go())
    summary = summarize(results, wall_s, slo_ttft_ms=args.slo_ttft_ms,
                        slo_itl_ms=args.slo_itl_ms)
    print(json.dumps(summary, indent=2, sort_keys=True))
    for r in results:
        if not r.ok:
            print(f"FAILED status={r.status} error={r.error}",
                  file=sys.stderr)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"summary": summary,
                       "results": [vars(r) for r in results]}, f, indent=2)
    bad = summary["failed"]
    if args.verify:
        bad += verify_replay(results, args)
    if bad:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
