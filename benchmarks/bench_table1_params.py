"""Paper Table 1: trainable parameters + storage bytes, LoRA vs FourierFT,
for every base model row. Asserts exact agreement with the paper's counts."""
import time

from repro.configs.base import PEFTConfig
from repro.configs.paper_models import PAPER_MODELS
from repro.core import peft as peft_mod
from benchmarks.common import emit

# (model, lora_r, fourier_n, paper lora count, paper fourier count)
TABLE1 = [
    ("roberta-base", 4, 200, 147_456, 4_800),
    ("roberta-base", 8, 200, 294_912, 4_800),
    ("roberta-large", 4, 200, 393_216, 9_600),
    ("roberta-large", 8, 1000, 786_432, 48_000),
    ("gpt2-medium", 4, 500, 393_216, 24_000),
    ("gpt2-medium", 8, 1000, 786_432, 48_000),
    ("gpt2-large", 4, 500, 737_280, 36_000),
    ("gpt2-large", 8, 1000, 1_474_560, 72_000),
    ("llama2-7b", 16, 1000, 8_388_608, 64_000),
    ("llama2-7b", 64, 2000, 33_554_432, 128_000),
    ("llama2-13b", 16, 1000, 13_107_200, 80_000),
    ("llama2-13b", 64, 2000, 52_428_800, 160_000),
    ("vit-base", 8, 3000, 294_912, 72_000),
    ("vit-base", 16, 10000, 589_824, 240_000),
    ("vit-large", 8, 3000, 786_432, 144_000),
    ("vit-large", 16, 10000, 1_572_864, 480_000),
]


def main():
    t0 = time.perf_counter()
    worst_ratio = 0.0
    for model, r, n, lora_expect, four_expect in TABLE1:
        cfg = PAPER_MODELS[model]
        sites = peft_mod.qv_sites_for(cfg)
        lora = peft_mod.count_trainable(sites, PEFTConfig(method="lora", lora_r=r))
        four = peft_mod.count_trainable(sites, PEFTConfig(method="fourierft", n=n))
        lora_b = peft_mod.storage_bytes(sites, PEFTConfig(method="lora", lora_r=r))
        four_b = peft_mod.storage_bytes(sites, PEFTConfig(method="fourierft", n=n))
        assert lora == lora_expect, (model, r, lora, lora_expect)
        assert four == four_expect, (model, n, four, four_expect)
        worst_ratio = max(worst_ratio, four / lora)
        emit(f"table1/{model}/lora_r{r}", 0.0,
             f"params={lora};bytes={lora_b}")
        emit(f"table1/{model}/fourier_n{n}", 0.0,
             f"params={four};bytes={four_b};vs_lora={four/lora:.4f}")
    us = (time.perf_counter() - t0) * 1e6 / len(TABLE1)
    emit("table1/all_rows_exact", us, f"rows={len(TABLE1)};max_ratio={worst_ratio:.3f}")


if __name__ == "__main__":
    main()
