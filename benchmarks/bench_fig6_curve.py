"""Paper Fig. 6 / Appendix C.1: training curves at EQUAL parameter count —
LoRA r=1 vs FourierFT n = r·(d1+d2)/L-matched. FourierFT should dominate the
curve (paper: consistently better loss through training)."""
import numpy as np

from repro.configs.base import PEFTConfig
from benchmarks.common import emit, finetune, tiny


def main():
    cfg = tiny("yi-6b")
    # equal params: lora r=1 totals r·(d_in+d_out) over both q/v sites;
    # fourier n matches exactly at n = lora_total / (sites · L)
    lora = finetune(cfg, PEFTConfig(method="lora", lora_r=1, train_head=True),
                    steps=60, lr=2e-2, pretrain_steps=20, task_seed=21)
    n = lora["trainable"] // (2 * cfg.num_layers)
    four = finetune(cfg, PEFTConfig(method="fourierft", n=n, alpha=10.0,
                                    train_head=True),
                    steps=60, lr=3e-2, pretrain_steps=20, task_seed=21)
    assert four["trainable"] == lora["trainable"], (
        four["trainable"], lora["trainable"])
    mid = len(lora["losses"]) // 2
    emit("fig6/lora_r1", lora["us_per_step"],
         f"loss={lora['final_loss']:.4f};mid={np.mean(lora['losses'][mid:mid+5]):.4f}")
    emit("fig6/fourier_equal_params", four["us_per_step"],
         f"loss={four['final_loss']:.4f};mid={np.mean(four['losses'][mid:mid+5]):.4f}")
    emit("fig6/fourier_beats_lora_at_equal_params", 0.0,
         f"{four['final_loss'] <= lora['final_loss'] * 1.02}")


if __name__ == "__main__":
    main()
