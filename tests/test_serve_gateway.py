"""OpenAI-compatible gateway over the continuous runtime (DESIGN.md
§Gateway): SSE streams bit-identical to the in-process replay (including
a heterogeneous fourierft+lora+base tenant mix), 429 backpressure under
saturation with a successful retry, mid-stream client disconnect leaving
zero leaked slots/pages/bank-pins, request validation 400s/404s, the
/v1/models and /metrics endpoints, and the scheduler-side cancel path +
monotonic cumulative counters the gateway leans on."""
import asyncio
import json

import jax
import jax.numpy as jnp
import pytest

import repro.configs as C
from repro.checkpoint import adapters as adapter_ckpt
from repro.configs.base import PEFTConfig
from repro.core import adapter as adapter_api
from repro.core import peft as peft_mod
from repro.models import build
from repro.serve import AdapterBank, ContinuousScheduler, Engine, Request
from repro.serve.gateway import GatewayServer
from repro.serve.gateway.protocol import (
    ApiError, parse_request, prometheus_text, resolve_model,
)


def _cfg():
    return C.reduced(C.get("yi-6b")).replace(vocab=64, param_dtype="float32",
                                             dtype="float32")


def _base_model():
    model = build(_cfg(), PEFTConfig(method="none"))
    return model, model.init(jax.random.PRNGKey(0))


def _export_tenants(model, directory):
    profiles = {
        "fourierft": PEFTConfig(method="fourierft", n=16, alpha=25.0,
                                param_dtype="float32"),
        "lora": PEFTConfig(method="lora", lora_r=2, param_dtype="float32"),
    }
    for i, (tid, m) in enumerate(zip(("t-fft", "t-lora"),
                                     ("fourierft", "lora"))):
        prof = profiles[m]
        tree = peft_mod.init_adapters(jax.random.PRNGKey(10 + i),
                                      model.sites, prof)
        tree = jax.tree.map(
            lambda x: x + 0.05 if jnp.issubdtype(x.dtype, jnp.floating)
            else x, tree)
        trainable = set(adapter_api.resolve(m).trainable_leaves(prof))
        tree = {s: {k: v for k, v in d.items() if k in trainable}
                for s, d in tree.items()}
        adapter_ckpt.export_adapter(str(directory), tid, tree, prof)
    return profiles


def _server(model, params, *, slots=2, max_len=48, bank=None, **kw):
    eng = Engine(model, params, batch_slots=slots, max_len=max_len,
                 bank=bank)
    return GatewayServer(ContinuousScheduler(eng, page_size=8), **kw)


# ---- stdlib test client ----------------------------------------------------
async def _raw(host, port, data: bytes):
    reader, writer = await asyncio.open_connection(host, port)
    writer.write(data)
    await writer.drain()
    raw = await reader.read()
    writer.close()
    head, _, body = raw.partition(b"\r\n\r\n")
    lines = head.decode("latin-1").split("\r\n")
    status = int(lines[0].split()[1])
    headers = {}
    for ln in lines[1:]:
        k, _, v = ln.partition(":")
        headers[k.strip().lower()] = v.strip()
    return status, headers, body


async def _post(host, port, path, payload):
    body = payload if isinstance(payload, bytes) \
        else json.dumps(payload).encode()
    return await _raw(host, port,
                      (f"POST {path} HTTP/1.1\r\nHost: t\r\n"
                       f"Content-Length: {len(body)}\r\n"
                       f"Connection: close\r\n\r\n").encode() + body)


async def _get(host, port, path):
    return await _raw(host, port, (f"GET {path} HTTP/1.1\r\nHost: t\r\n"
                                   "Connection: close\r\n\r\n").encode())


def _sse_parse(body: bytes):
    """SSE body -> (token ids, finish_reason, saw [DONE])."""
    tokens, finish, done = [], None, False
    for line in body.split(b"\n"):
        line = line.strip()
        if not line.startswith(b"data: "):
            continue
        data = line[len(b"data: "):]
        if data == b"[DONE]":
            done = True
            continue
        choice = json.loads(data)["choices"][0]
        if "token_id" in choice:
            tokens.append(int(choice["token_id"]))
        if choice.get("finish_reason") is not None:
            finish = choice["finish_reason"]
    return tokens, finish, done


def _completion(model, prompt, max_new, stream=True):
    return {"model": model, "prompt": prompt, "max_tokens": max_new,
            "stream": stream}


async def _drain_idle(server, timeout=10.0):
    """Wait until the scheduler has no active slots (pump-thread read)."""
    deadline = asyncio.get_event_loop().time() + timeout
    sched = server.sched
    while await server.bridge.call(lambda: sched.slots.any_active()):
        assert asyncio.get_event_loop().time() < deadline, "never drained"
        await asyncio.sleep(0.01)


# ---------------------------------------------------------------------------
# protocol units (no server)
# ---------------------------------------------------------------------------
class TestProtocol:
    def _parse(self, payload, kind="completion", **kw):
        kw.setdefault("vocab", 64)
        kw.setdefault("max_len", 48)
        kw.setdefault("default_max_new", 8)
        kw.setdefault("base_aliases", ())
        return parse_request(kind, payload, **kw)

    def test_validation_rejections(self):
        cases = [
            ({"model": "base"}, 400),                       # no prompt
            ({"model": "base", "prompt": []}, 400),         # empty
            ({"model": "base", "prompt": [1, 2], "n": 2}, 400),
            ({"model": "base", "prompt": [1, 999]}, 400),   # id >= vocab
            ({"model": "base", "prompt": [1, -2]}, 400),    # negative id
            ({"model": "base", "prompt": [1.5]}, 400),      # non-int id
            ({"model": "base", "prompt": [1],
              "max_tokens": 0}, 400),
            ({"model": "base", "prompt": [1],
              "stream": "yes"}, 400),
            ({"model": "base", "prompt": list(range(1, 47)),
              "max_tokens": 30}, 400),                      # cache overflow
            ({"model": 7, "prompt": [1]}, 400),
            ({"model": "oops", "prompt": [1]}, 404),
        ]
        for payload, status in cases:
            with pytest.raises(ApiError) as ei:
                self._parse(payload)
            assert ei.value.status == status, payload

    def test_chat_needs_messages(self):
        with pytest.raises(ApiError):
            self._parse({"model": "base"}, kind="chat")
        preq = self._parse({"model": "base",
                            "messages": [{"role": "user", "content": "hi"}]},
                           kind="chat")
        assert preq.prompt and all(0 <= t < 64 for t in preq.prompt)

    def test_resolve_model(self):
        assert resolve_model("base") is None
        assert resolve_model("yi-6b-smoke", ("yi-6b-smoke",)) is None
        assert resolve_model("adapter:t0") == "t0"
        with pytest.raises(ApiError) as ei:
            resolve_model("gpt-4")
        assert ei.value.status == 404
        with pytest.raises(ApiError):
            resolve_model("adapter:")

    def test_prometheus_text(self):
        text = prometheus_text(
            {"requests_admitted_total": 3, "queue_depth": 1.0},
            labeled={"gateway_responses_total": {'code="200"': 4}})
        assert "# TYPE repro_requests_admitted_total counter" in text
        assert "# TYPE repro_queue_depth gauge" in text
        assert "repro_requests_admitted_total 3" in text
        assert 'repro_gateway_responses_total{code="200"} 4' in text


# ---------------------------------------------------------------------------
# scheduler cancel path + cumulative counters (no HTTP)
# ---------------------------------------------------------------------------
class TestSchedulerCancel:
    def test_cancel_queued_request(self):
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=1, max_len=48)
        sched = ContinuousScheduler(eng, page_size=8)
        r = Request(prompt=jnp.array([1, 2, 3], jnp.int32), max_new=4)
        rid = sched.submit(r)
        assert sched.cancel(rid) is True
        assert sched.cancel(rid) is False      # already gone
        assert len(sched.queue) == 0
        assert r.out == []
        s = sched.metrics.summary()
        assert s["requests_cancelled_total"] == 1.0
        assert s["queue_depth"] == 0.0

    def test_cancel_active_frees_slot_and_pages(self):
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=2, max_len=48)
        sched = ContinuousScheduler(eng, page_size=8)
        long = Request(prompt=jnp.array([1, 2, 3, 4], jnp.int32), max_new=24)
        rid = sched.submit(long)
        for _ in range(6):                     # admit + buffer some decode
            sched.tick()
        assert sched.slots.any_active()
        assert sched.cancel(rid) is True       # abort with work in flight
        assert not sched.slots.any_active()
        sched.pager.assert_no_leaks()
        # the drained partial (here: the prime token) lands on the request
        assert 0 < len(long.out) < 24
        # the runtime stays healthy: a follow-up request is exact
        follow = Request(prompt=jnp.array([7, 8, 9], jnp.int32), max_new=5)
        sched.serve([follow])
        ref = eng.generate([follow.prompt], max_new=5)[0]
        assert follow.out == [int(t) for t in jnp.asarray(ref).reshape(-1)]
        sched.pager.assert_no_leaks()

    def test_counters_survive_reset(self):
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=2, max_len=48)
        sched = ContinuousScheduler(eng, page_size=8)
        sched.serve([Request(prompt=jnp.array([1, 2], jnp.int32), max_new=3)
                     for _ in range(2)])
        before = sched.metrics.summary()
        assert before["requests_finished_total"] == 2.0
        sched.reset_metrics()                  # scrape-window reset
        after = sched.metrics.summary()
        for k in ("requests_submitted_total", "requests_admitted_total",
                  "requests_finished_total", "tokens_emitted_total"):
            assert after[k] == before[k], k    # counters are cumulative
        sched.serve([Request(prompt=jnp.array([5], jnp.int32), max_new=2)])
        assert sched.metrics.summary()["requests_finished_total"] == 3.0


# ---------------------------------------------------------------------------
# HTTP end-to-end
# ---------------------------------------------------------------------------
class TestGatewayHTTP:
    def test_streams_bit_identical_heterogeneous(self, tmp_path):
        """Concurrent SSE streams over a fourierft+lora+base mix equal the
        in-process scheduler replay token for token."""
        model, params = _base_model()
        profiles = _export_tenants(model, tmp_path)

        def bank():
            return AdapterBank(model, profiles, capacity=4,
                               checkpoint_dir=str(tmp_path))

        prompts = [[1, 2, 3, 4, 5], [7, 8, 9], [11, 12],
                   [3, 1, 4, 1, 5], [2, 7, 1, 8], [6, 6, 6]]
        models = ["adapter:t-fft", "adapter:t-lora", "base",
                  "adapter:t-fft", "adapter:t-lora", "base"]

        async def drive():
            server = _server(model, params, slots=3, bank=bank())
            await server.start()
            try:
                return await asyncio.gather(*(
                    _post(server.host, server.port, "/v1/completions",
                          _completion(m, p, 6))
                    for m, p in zip(models, prompts)))
            finally:
                await server.close()

        responses = asyncio.run(drive())
        got = []
        for status, _, body in responses:
            assert status == 200
            tokens, finish, done = _sse_parse(body)
            assert done and finish == "length"
            got.append(tokens)
        # replay the same traffic through a fresh scheduler, no HTTP
        replay_eng = Engine(model, params, batch_slots=3, max_len=48,
                            bank=bank())
        reqs = [Request(prompt=jnp.array(p, jnp.int32), max_new=6,
                        adapter_id=resolve_model(m))
                for m, p in zip(models, prompts)]
        ContinuousScheduler(replay_eng, page_size=8).serve(reqs)
        assert got == [r.out for r in reqs]

    def test_blocking_json_matches_stream(self):
        model, params = _base_model()

        async def drive():
            server = _server(model, params)
            await server.start()
            try:
                s1, _, b1 = await _post(server.host, server.port,
                                        "/v1/completions",
                                        _completion("base", [1, 2, 3], 5))
                s2, _, b2 = await _post(
                    server.host, server.port, "/v1/completions",
                    _completion("base", [1, 2, 3], 5, stream=False))
                return s1, b1, s2, b2
            finally:
                await server.close()

        s1, b1, s2, b2 = asyncio.run(drive())
        assert s1 == 200 and s2 == 200
        stream_tokens, _, _ = _sse_parse(b1)
        obj = json.loads(b2)
        choice = obj["choices"][0]
        assert choice["token_ids"] == stream_tokens
        assert choice["finish_reason"] == "length"
        assert obj["usage"]["completion_tokens"] == len(stream_tokens)

    def test_429_under_saturation_then_retry(self):
        """One slot + max_queue=1: a third request bounces with 429 and
        Retry-After while the runtime is saturated, then succeeds once the
        backlog drains."""
        model, params = _base_model()

        async def drive():
            server = _server(model, params, slots=1, max_queue=1,
                             retry_after_s=0.25)
            await server.start()
            host, port = server.host, server.port
            try:
                a = asyncio.ensure_future(_post(
                    host, port, "/v1/completions",
                    _completion("base", [1, 2, 3], 24)))
                b = asyncio.ensure_future(_post(
                    host, port, "/v1/completions",
                    _completion("base", [4, 5], 24, stream=False)))
                saw_429, retry_after = False, None
                for _ in range(100):           # while a+b occupy slot+queue
                    status, headers, _ = await _post(
                        host, port, "/v1/completions",
                        _completion("base", [6], 2, stream=False))
                    if status == 429:
                        saw_429 = True
                        retry_after = headers.get("retry-after")
                        break
                    await asyncio.sleep(0.005)
                (sa, _, _), (sb, _, _) = await asyncio.gather(a, b)
                await _drain_idle(server)
                sc, _, body = await _post(     # the retry goes through
                    host, port, "/v1/completions",
                    _completion("base", [6], 2, stream=False))
                metrics = await server.bridge.call(
                    lambda: server.sched.metrics.summary())
                return saw_429, retry_after, sa, sb, sc, body, metrics
            finally:
                await server.close()

        saw_429, retry_after, sa, sb, sc, body, metrics = asyncio.run(drive())
        assert saw_429 and retry_after is not None
        assert float(retry_after) == 0.25
        assert (sa, sb, sc) == (200, 200, 200)
        assert len(json.loads(body)["choices"][0]["token_ids"]) == 2
        assert metrics["requests_rejected_total"] >= 1.0

    def test_disconnect_mid_stream_leaks_nothing(self, tmp_path):
        """Abruptly closing the socket mid-stream cancels the request:
        every slot returns to FREE, the page pool balances, the tenant's
        bank row unpins, and the next request is exact."""
        model, params = _base_model()
        profiles = _export_tenants(model, tmp_path)

        async def drive():
            bank = AdapterBank(model, profiles, capacity=4,
                               checkpoint_dir=str(tmp_path))
            server = _server(model, params, slots=2, bank=bank)
            await server.start()
            host, port = server.host, server.port
            try:
                reader, writer = await asyncio.open_connection(host, port)
                body = json.dumps(_completion(
                    "adapter:t-fft", [1, 2, 3, 4], 32)).encode()
                writer.write((f"POST /v1/completions HTTP/1.1\r\n"
                              f"Host: t\r\nContent-Length: {len(body)}\r\n"
                              f"Connection: close\r\n\r\n").encode() + body)
                await writer.drain()
                await reader.readuntil(b"\r\n\r\n")      # response head
                await reader.readuntil(b"\n\n")          # >= 1 SSE frame
                writer.close()                           # walk away
                await _drain_idle(server)
                sched = server.sched
                state = await server.bridge.call(lambda: (
                    sched.slots.active_slots(),
                    sched.slots.adapter_ids(),
                    sched.metrics.summary()["requests_cancelled_total"]))
                await server.bridge.call(sched.pager.assert_no_leaks)
                # runtime still serves exactly after the abort
                status, _, resp = await _post(
                    host, port, "/v1/completions",
                    _completion("adapter:t-lora", [7, 8, 9], 4,
                                stream=False))
                await server.bridge.call(sched.pager.assert_no_leaks)
                return state, status, json.loads(resp)
            finally:
                await server.close()

        (active, pins, cancelled), status, resp = asyncio.run(drive())
        assert active == [] and pins == [None, None]
        assert cancelled >= 1.0
        assert status == 200
        ref_eng = Engine(model, params, batch_slots=2, max_len=48,
                         bank=AdapterBank(model, profiles, capacity=4,
                                          checkpoint_dir=str(tmp_path)))
        ref_eng.bank.load_from_checkpoint("t-lora")
        ref = ref_eng.generate([jnp.array([7, 8, 9], jnp.int32)],
                               max_new=4, adapter_ids=["t-lora"])[0]
        assert resp["choices"][0]["token_ids"] \
            == [int(t) for t in jnp.asarray(ref).reshape(-1)]

    def test_request_timeout_504(self):
        model, params = _base_model()

        async def drive():
            server = _server(model, params, request_timeout_s=1e-4)
            await server.start()
            try:
                status, _, body = await _post(
                    server.host, server.port, "/v1/completions",
                    _completion("base", [1, 2, 3], 16, stream=False))
                await _drain_idle(server)
                await server.bridge.call(server.sched.pager.assert_no_leaks)
                return status, body
            finally:
                await server.close()

        status, body = asyncio.run(drive())
        assert status == 504
        assert json.loads(body)["error"]["type"] == "timeout_error"

    def test_malformed_requests(self):
        model, params = _base_model()

        async def drive():
            server = _server(model, params)
            await server.start()
            host, port = server.host, server.port
            try:
                return [
                    await _post(host, port, "/v1/completions",
                                b"{not json"),
                    await _post(host, port, "/v1/completions",
                                {"model": "base"}),
                    await _post(host, port, "/v1/completions",
                                {"model": "base", "prompt": [1], "n": 2}),
                    await _post(host, port, "/v1/completions",
                                {"model": "base", "prompt": [999]}),
                    await _post(host, port, "/v1/completions",
                                {"model": "base",
                                 "prompt": list(range(1, 50)),
                                 "max_tokens": 16}),
                    await _post(host, port, "/v1/completions",
                                {"model": "gpt-4", "prompt": [1]}),
                    await _post(host, port, "/v1/completions",
                                {"model": "adapter:ghost", "prompt": [1]}),
                    await _get(host, port, "/nope"),
                ]
            finally:
                await server.close()

        results = asyncio.run(drive())
        statuses = [r[0] for r in results]
        assert statuses == [400, 400, 400, 400, 400, 404, 404, 404]
        for status, _, body in results[:5]:
            assert json.loads(body)["error"]["type"] \
                == "invalid_request_error"

    def test_models_and_metrics_endpoints(self, tmp_path):
        model, params = _base_model()
        profiles = _export_tenants(model, tmp_path)

        async def drive():
            bank = AdapterBank(model, profiles, capacity=4,
                               checkpoint_dir=str(tmp_path))
            bank.load_from_checkpoint("t-fft")
            server = _server(model, params, bank=bank)
            await server.start()
            host, port = server.host, server.port
            try:
                await _post(host, port, "/v1/chat/completions",
                            {"model": "base", "stream": False,
                             "messages": [{"role": "user",
                                           "content": "hi"}],
                             "max_tokens": 3})
                ms, _, mbody = await _get(host, port, "/v1/models")
                ps, _, pbody = await _get(host, port, "/metrics")
                hs, _, _ = await _get(host, port, "/healthz")
                return ms, mbody, ps, pbody, hs
            finally:
                await server.close()

        ms, mbody, ps, pbody, hs = asyncio.run(drive())
        assert (ms, ps, hs) == (200, 200, 200)
        ids = [m["id"] for m in json.loads(mbody)["data"]]
        assert "base" in ids and "adapter:t-fft" in ids
        text = pbody.decode()
        assert "# TYPE repro_requests_admitted_total counter" in text
        assert "repro_requests_admitted_total 1" in text
        assert "repro_requests_finished_total 1" in text
        assert "repro_gateway_page_free_frac" in text
        assert 'repro_gateway_responses_total{code="200"}' in text
