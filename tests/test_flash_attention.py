"""Flash attention (triangular custom-VJP) — the §Perf A1/A3 layer.

Forward and all three gradients must match direct-attention autodiff exactly;
the triangular pair enumeration must cover every causal block once."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    _tri_pairs, direct_attention, flash_attention,
)


def _qkv(B=2, S=512, H=4, K=2, dh=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (B, S, H, dh)),
            jax.random.normal(ks[1], (B, S, K, dh)),
            jax.random.normal(ks[2], (B, S, K, dh)))


class TestTriangularPairs:
    @pytest.mark.parametrize("nq", [1, 2, 5, 8])
    def test_covers_causal_blocks_exactly_once(self, nq):
        iqs, jks = _tri_pairs(nq)
        pairs = set(zip(iqs.tolist(), jks.tolist()))
        assert len(pairs) == nq * (nq + 1) // 2 == len(iqs)
        assert all(j <= i for i, j in pairs)
        # row-major order so the online-softmax state resets align
        order = list(zip(iqs.tolist(), jks.tolist()))
        assert order == sorted(order)


class TestFlashForward:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("chunk", [64, 128, 256])
    def test_matches_direct(self, causal, chunk):
        q, k, v = _qkv()
        out = flash_attention(q, k, v, causal, chunk)
        ref = direct_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=3e-6)

    def test_mha_and_gqa_shapes(self):
        for K in (1, 2, 4):
            q, k, v = _qkv(H=4, K=K)
            out = flash_attention(q, k, v, True, 128)
            assert out.shape == q.shape

    def test_bf16_inputs(self):
        q, k, v = (x.astype(jnp.bfloat16) for x in _qkv())
        out = flash_attention(q, k, v, True, 128)
        ref = direct_attention(q, k, v)
        assert out.dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=3e-2)


class TestFlashVJP:
    @pytest.mark.parametrize("causal", [True, False])
    def test_grads_match_direct_autodiff(self, causal):
        q, k, v = _qkv()
        tgt = jax.random.normal(jax.random.PRNGKey(9), q.shape)

        def loss(fn):
            return lambda q, k, v: jnp.sum((fn(q, k, v) - tgt) ** 2)

        g_flash = jax.grad(loss(lambda q, k, v: flash_attention(
            q, k, v, causal, 128)), argnums=(0, 1, 2))(q, k, v)
        g_ref = jax.grad(loss(lambda q, k, v: direct_attention(
            q, k, v, causal=causal)), argnums=(0, 1, 2))(q, k, v)
        for a, b, name in zip(g_flash, g_ref, "qkv"):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-5, err_msg=f"d{name}")

    def test_grad_through_jit_and_scan(self):
        """flash inside a scanned layer body (the real usage)."""
        q, k, v = _qkv(S=256)

        @jax.jit
        def loss(k):
            def body(c, _):
                return c + flash_attention(q, k, v, True, 128).sum(), None
            out, _ = jax.lax.scan(body, 0.0, None, length=3)
            return out

        g = jax.grad(loss)(k)
        assert np.isfinite(np.asarray(g)).all()

    def test_causality_of_gradients(self):
        """dk/dv at future positions get no contribution from earlier q."""
        q, k, v = _qkv(B=1, S=256, H=2, K=2)

        def loss(k, v):
            out = flash_attention(q, k, v, True, 64)
            return jnp.sum(out[:, :64] ** 2)   # only first q block

        dk, dv = jax.grad(loss, argnums=(0, 1))(k, v)
        assert float(jnp.abs(dk[:, 64:]).max()) == 0.0
        assert float(jnp.abs(dv[:, 64:]).max()) == 0.0
