"""Multi-tenant adapter-bank serving: heterogeneous batches reproduce each
tenant's single-tenant outputs bit-for-bit at fp32, LRU eviction + adapter-
only-checkpoint reload round-trips, and the bank composes with meshes and
recurrent families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.checkpoint import adapters as adapter_ckpt
from repro.configs.base import PEFTConfig
from repro.core import peft as peft_mod
from repro.models import build
from repro.serve import AdapterBank, Engine, Request

TENANTS = ("tenant-fft", "tenant-lora", "tenant-circ")
METHODS = ("fourierft", "lora", "circulant")


def _cfg(arch="yi-6b"):
    return C.reduced(C.get(arch)).replace(vocab=64, param_dtype="float32",
                                          dtype="float32")


def _profiles():
    return {
        "fourierft": PEFTConfig(method="fourierft", n=16, alpha=25.0,
                                param_dtype="float32"),
        "lora": PEFTConfig(method="lora", lora_r=2, param_dtype="float32"),
        "circulant": PEFTConfig(method="circulant", alpha=25.0,
                                param_dtype="float32"),
    }


def _tenant_adapters(model, profiles):
    """Three nontrivially-valued adapters, one per method."""
    out = {}
    for i, (tid, m) in enumerate(zip(TENANTS, METHODS)):
        prof = profiles[m]
        tree = peft_mod.init_adapters(jax.random.PRNGKey(10 + i),
                                      model.sites, prof)
        tree = jax.tree.map(
            lambda x: x + 0.05 if jnp.issubdtype(x.dtype, jnp.floating) else x,
            tree)
        out[tid] = (tree, prof)
    return out


def _setup(arch="yi-6b", capacity=4):
    cfg = _cfg(arch)
    model = build(cfg, PEFTConfig(method="none"))
    params = model.init(jax.random.PRNGKey(0))
    profiles = _profiles()
    tenants = _tenant_adapters(model, profiles)
    bank = AdapterBank(model, profiles, capacity=capacity)
    for tid, (tree, prof) in tenants.items():
        bank.load(tid, tree, prof)
    return model, params, profiles, tenants, bank


PROMPTS = [jnp.array([1, 2, 3, 4], jnp.int32),
           jnp.array([5, 6, 7], jnp.int32),
           jnp.array([9, 8], jnp.int32)]


class TestHeterogeneousBatch:
    def test_three_tenant_batch_matches_single_tenant_bitwise(self):
        """Acceptance: a 3-adapter heterogeneous batch reproduces each
        adapter's single-tenant outputs bit-for-bit at fp32."""
        model, params, profiles, tenants, bank = _setup()
        eng = Engine(model, params, batch_slots=3, max_len=32, bank=bank)
        het = eng.generate(PROMPTS, max_new=6, adapter_ids=list(TENANTS))
        for i, tid in enumerate(TENANTS):
            b1 = AdapterBank(model, profiles, capacity=4)
            b1.load(tid, *tenants[tid])
            e1 = Engine(model, params, batch_slots=3, max_len=32, bank=b1)
            single = e1.generate(PROMPTS, max_new=6, adapter_ids=[tid] * 3)
            np.testing.assert_array_equal(np.asarray(het[i]),
                                          np.asarray(single[i]))

    def test_heterogeneous_logits_bitwise_fp32(self):
        """Same property at the logits level, through the full forward."""
        model, params, profiles, tenants, bank = _setup()
        model.bank_profiles = dict(bank.profiles)
        toks = jax.random.randint(jax.random.PRNGKey(2), (3, 8), 0, 64)
        p = {**params, "bank": bank.params}
        het, _ = model.forward(
            p, {"tokens": toks,
                "adapter_slots": bank.slot_rows(list(TENANTS), 3)})
        for i, tid in enumerate(TENANTS):
            single, _ = model.forward(
                p, {"tokens": toks,
                    "adapter_slots": bank.slot_rows([tid] * 3, 3)})
            np.testing.assert_array_equal(np.asarray(het[i]),
                                          np.asarray(single[i]))

    def test_none_adapter_id_equals_bare_base(self):
        """The reserved zero row contributes exactly zero: a request with no
        adapter_id through the bank engine == the bare-base engine."""
        model, params, _, _, bank = _setup()
        eng = Engine(model, params, batch_slots=3, max_len=32, bank=bank)
        mixed = eng.generate(PROMPTS, max_new=6,
                             adapter_ids=["tenant-fft", None, None])
        bare = Engine(model, params, batch_slots=3,
                      max_len=32).generate(PROMPTS, max_new=6)
        np.testing.assert_array_equal(np.asarray(mixed[1]),
                                      np.asarray(bare[1]))
        np.testing.assert_array_equal(np.asarray(mixed[2]),
                                      np.asarray(bare[2]))
        assert not np.array_equal(np.asarray(mixed[0]), np.asarray(bare[0]))

    def test_request_front_end(self):
        model, params, _, _, bank = _setup()
        eng = Engine(model, params, batch_slots=3, max_len=32, bank=bank)
        reqs = [Request(PROMPTS[i], max_new=4, adapter_id=tid)
                for i, tid in enumerate(TENANTS)]
        eng.generate_requests(reqs)
        ref = eng.generate(PROMPTS, max_new=4, adapter_ids=list(TENANTS))
        for r, o in zip(reqs, ref):
            assert r.out == [int(t) for t in np.asarray(o)]

    def test_ssm_family_bank(self):
        """The gather-then-apply path also rides the recurrent scan (mamba2
        adapts wx/wo_ssm; profile targets auto-resolve)."""
        cfg = _cfg("mamba2-2.7b")
        model = build(cfg, PEFTConfig(method="none"))
        params = model.init(jax.random.PRNGKey(0))
        prof = {"fourierft": PEFTConfig(method="fourierft", n=8, alpha=25.0,
                                        param_dtype="float32")}
        bank = AdapterBank(model, prof, capacity=2)
        tree = peft_mod.init_adapters(jax.random.PRNGKey(3), model.sites,
                                      bank.profiles["fourierft"])
        bank.load("ssm-tenant", tree, bank.profiles["fourierft"])
        eng = Engine(model, params, batch_slots=2, max_len=24, bank=bank)
        outs = eng.generate(PROMPTS[:2], max_new=4,
                            adapter_ids=["ssm-tenant", None])
        bare = Engine(model, params, batch_slots=2, max_len=24).generate(
            PROMPTS[:2], max_new=4)
        np.testing.assert_array_equal(np.asarray(outs[1]),
                                      np.asarray(bare[1]))

    def test_request_front_end_without_bank(self):
        """A bank-less engine serves Requests with no adapter_id (and still
        rejects real adapter ids)."""
        model, params, _, _, _ = _setup()
        eng = Engine(model, params, batch_slots=2, max_len=24)
        reqs = [Request(PROMPTS[0], max_new=4), Request(PROMPTS[1], max_new=4)]
        eng.generate_requests(reqs)
        ref = eng.generate(PROMPTS[:2], max_new=4)
        for r, o in zip(reqs, ref):
            assert r.out == [int(t) for t in np.asarray(o)]
        with pytest.raises(ValueError, match="no bank"):
            eng.generate(PROMPTS[:2], max_new=2, adapter_ids=["tenant-fft",
                                                              None])

    def test_engine_does_not_mutate_caller_model(self):
        """Two engines over one Model object must not cross-contaminate
        bank profiles (Engine now builds its own facade)."""
        model, params, profiles, tenants, bank = _setup()
        assert model.bank_profiles is None
        eng = Engine(model, params, batch_slots=2, max_len=24, bank=bank)
        assert model.bank_profiles is None
        assert eng.model is not model
        plain = Engine(model, params, batch_slots=2, max_len=24)
        a = plain.generate(PROMPTS[:2], max_new=4)
        b = Engine(model, params, batch_slots=2,
                   max_len=24).generate(PROMPTS[:2], max_new=4)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_hybrid_family_bank(self):
        """zamba2: the bank rides the mamba layer sites (the shared block's
        per-application adapters are orthogonal to tenancy)."""
        cfg = _cfg("zamba2-7b")
        model = build(cfg, PEFTConfig(method="none"))
        params = model.init(jax.random.PRNGKey(0))
        prof = {"fourierft": PEFTConfig(method="fourierft", n=8, alpha=25.0,
                                        param_dtype="float32",
                                        target_modules=("wx", "wo_ssm"))}
        bank = AdapterBank(model, prof, capacity=2)
        tree = peft_mod.init_adapters(jax.random.PRNGKey(3), model.sites,
                                      bank.profiles["fourierft"])
        bank.load("hy-tenant", tree, bank.profiles["fourierft"])
        eng = Engine(model, params, batch_slots=2, max_len=24, bank=bank)
        outs = eng.generate(PROMPTS[:2], max_new=4,
                            adapter_ids=["hy-tenant", None])
        bare = Engine(model, params, batch_slots=2, max_len=24).generate(
            PROMPTS[:2], max_new=4)
        np.testing.assert_array_equal(np.asarray(outs[1]),
                                      np.asarray(bare[1]))

    def test_mesh_sharded_bank_engine_matches(self):
        """Bank engine under a host mesh == unsharded bank engine (the CI
        smoke runs this file on 8 fake devices)."""
        from repro.launch.mesh import make_host_mesh
        model, params, _, _, bank = _setup()
        plain = Engine(model, params, batch_slots=3, max_len=32, bank=bank)
        sharded = Engine(model, params, batch_slots=3, max_len=32,
                         mesh=make_host_mesh(), bank=bank)
        a = plain.generate(PROMPTS, max_new=4, adapter_ids=list(TENANTS))
        b = sharded.generate(PROMPTS, max_new=4, adapter_ids=list(TENANTS))
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestResidency:
    def test_lru_eviction_and_checkpoint_reload_roundtrip(self, tmp_path):
        """Evict under capacity pressure, reload from an adapter-only export,
        and reproduce the pre-eviction outputs bit-for-bit."""
        model, params, profiles, tenants, _ = _setup()
        bank = AdapterBank(model, profiles, capacity=2,
                           checkpoint_dir=str(tmp_path))
        for tid in TENANTS:
            adapter_ckpt.export_adapter(str(tmp_path), tid, *tenants[tid])
        bank.load_from_checkpoint("tenant-fft")
        bank.load_from_checkpoint("tenant-lora")
        eng = Engine(model, params, batch_slots=3, max_len=32, bank=bank)
        before = eng.generate(PROMPTS, max_new=5,
                              adapter_ids=["tenant-fft"] * 3)
        # third tenant forces LRU eviction of tenant-lora (fft was touched)
        eng.generate(PROMPTS, max_new=2, adapter_ids=["tenant-fft"] * 3)
        bank.load_from_checkpoint("tenant-circ")
        assert set(bank.resident_ids) == {"tenant-fft", "tenant-circ"}
        with pytest.raises(KeyError, match="not resident"):
            eng.generate(PROMPTS, max_new=2, adapter_ids=["tenant-lora"] * 3)
        # reload the evicted tenant; fft gets evicted, then reload fft and
        # check outputs are unchanged across the whole evict/reload cycle
        bank.load_from_checkpoint("tenant-lora")
        bank.load_from_checkpoint("tenant-fft")
        after = eng.generate(PROMPTS, max_new=5,
                             adapter_ids=["tenant-fft"] * 3)
        for x, y in zip(before, after):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_export_import_roundtrip_regenerates_frozen_aux(self, tmp_path):
        """Adapter-only exports store trainables only; import rebuilds the
        spectral entries from method + entry seed."""
        model, _, profiles, tenants, _ = _setup()
        tree, prof = tenants["tenant-fft"]
        path = adapter_ckpt.export_adapter(str(tmp_path), "t", tree, prof)
        import numpy as onp
        z = onp.load(f"{path}/adapter.npz")
        assert all(k.endswith("::c") for k in z.files)   # no entries stored
        got, got_peft = adapter_ckpt.import_adapter(str(tmp_path), "t",
                                                    sites=model.sites)
        assert got_peft == prof
        for site, d in tree.items():
            np.testing.assert_array_equal(np.asarray(got[site]["c"]),
                                          np.asarray(d["c"]))
            np.testing.assert_array_equal(np.asarray(got[site]["entries"]),
                                          np.asarray(d["entries"]))

    def test_profile_mismatch_rejected(self):
        model, _, profiles, tenants, bank = _setup()
        tree, prof = tenants["tenant-fft"]
        with pytest.raises(ValueError, match="does not match bank group"):
            bank.load("bad", tree, prof.replace(entry_seed=999))
        with pytest.raises(KeyError, match="no bank group"):
            bank.load("bad", {}, PEFTConfig(method="bitfit"))

    def test_failed_load_leaks_no_slot(self):
        """A load that fails validation must leave residency, capacity, and
        the would-be-evicted tenant's rows untouched."""
        model, params, profiles, tenants, _ = _setup()
        bank = AdapterBank(model, profiles, capacity=1)
        bank.load("tenant-fft", *tenants["tenant-fft"])
        eng = Engine(model, params, batch_slots=2, max_len=24, bank=bank)
        before = eng.generate(PROMPTS[:2], max_new=4,
                              adapter_ids=["tenant-fft", None])
        tree, prof = tenants["tenant-lora"]
        bad = {site: {k: v[..., :1] for k, v in d.items()}
               for site, d in tree.items()}
        for _ in range(3):                    # repeated failures don't drain
            with pytest.raises(ValueError, match="bank row"):
                bank.load("bad-tenant", bad, prof)
        assert bank.resident_ids == ("tenant-fft",)
        after = eng.generate(PROMPTS[:2], max_new=4,
                             adapter_ids=["tenant-fft", None])
        for x, y in zip(before, after):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        # capacity is intact: a good load still succeeds (evicting fft)
        bank.load("tenant-lora", tree, prof)
        assert bank.resident_ids == ("tenant-lora",)

    def test_partial_site_export_rejected(self):
        """An export missing one trainable leaf at a site must be rejected —
        loading it would silently serve a zeroed (bare-base-ish) tenant."""
        model, _, profiles, tenants, bank = _setup()
        tree, prof = tenants["tenant-lora"]
        partial = {site: {k: v for k, v in d.items() if k != "lora_b"}
                   for site, d in tree.items()}
        with pytest.raises(ValueError, match="missing trainable leaves"):
            bank.load("partial", partial, prof)

    def test_oversized_adapter_ids_rejected(self):
        model, params, _, _, bank = _setup()
        eng = Engine(model, params, batch_slots=3, max_len=24, bank=bank)
        with pytest.raises(ValueError, match="adapter_ids"):
            eng.generate(PROMPTS, max_new=2,
                         adapter_ids=list(TENANTS) + ["tenant-fft"])

    def test_slot_reuse_clears_stale_rows(self):
        """A reused slot must not leak the previous tenant's rows — the new
        tenant's unused method groups read as zero."""
        model, params, profiles, tenants, _ = _setup()
        bank = AdapterBank(model, profiles, capacity=1)
        bank.load("tenant-fft", *tenants["tenant-fft"])
        bank.load("tenant-lora", *tenants["tenant-lora"])    # evicts fft
        eng = Engine(model, params, batch_slots=2, max_len=24, bank=bank)
        outs = eng.generate(PROMPTS[:2], max_new=4,
                            adapter_ids=["tenant-lora", None])
        b1 = AdapterBank(model, profiles, capacity=1)
        b1.load("tenant-lora", *tenants["tenant-lora"])
        ref = Engine(model, params, batch_slots=2, max_len=24,
                     bank=b1).generate(PROMPTS[:2], max_new=4,
                                       adapter_ids=["tenant-lora", None])
        for x, y in zip(outs, ref):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
