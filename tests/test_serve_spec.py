"""Speculative decoding on the continuous runtime (DESIGN.md §Speculation):
greedy draft-then-verify outputs token-identical to the non-speculative
scheduler AND the serial engine — across paged/dense caches, heterogeneous
adapters, EOS traffic, and both drafters — plus drafter/accounting unit
tests and the acceptance-rate counters. The self-drafter must clear the
headline gate: > 1 accepted token per slot per verify step on base-model
traffic (its drafts ARE the target model's argmax)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.checkpoint import adapters as adapter_ckpt
from repro.configs.base import PEFTConfig
from repro.core import adapter as adapter_api
from repro.core import peft as peft_mod
from repro.models import build
from repro.serve import (
    AdapterBank, ContinuousScheduler, Engine, NGramDrafter, Request,
    SelfDrafter,
)
from repro.serve.scheduler.slots import SlotManager


def _cfg(arch="yi-6b"):
    return C.reduced(C.get(arch)).replace(vocab=64, param_dtype="float32",
                                          dtype="float32")


def _base_model():
    model = build(_cfg(), PEFTConfig(method="none"))
    return model, model.init(jax.random.PRNGKey(0))


def _serial(engine, req):
    if req.adapter_id is not None and \
            req.adapter_id not in engine.bank.resident_ids:
        engine.bank.load_from_checkpoint(req.adapter_id)
    out = engine.generate([req.prompt], max_new=req.max_new,
                          adapter_ids=[req.adapter_id]
                          if engine.bank is not None else None)[0]
    return [int(t) for t in np.asarray(out).reshape(-1)]


PROMPTS = [[1, 2, 3, 4, 5], [7, 8, 9], [11, 12], [3, 1, 4, 1, 5, 9],
           [2, 7, 1, 8], [6, 6, 6], [9, 8, 7, 6, 5, 4, 3], [5, 5]]


def _trace(max_news, adapter_ids=None):
    return [Request(prompt=jnp.array(PROMPTS[i % len(PROMPTS)], jnp.int32),
                    max_new=mn,
                    adapter_id=adapter_ids[i] if adapter_ids else None)
            for i, mn in enumerate(max_news)]


# ---------------------------------------------------------------------------
# unit: window accounting + drafters
# ---------------------------------------------------------------------------

class TestNoteWindow:
    def test_budget_clamps_inside_window(self):
        slots = SlotManager(2)
        slots.acquire(0, budget=3)
        assert slots.note_window(0, [5, 6, 7, 8, 9]) == (3, True)

    def test_eos_clamps_inside_window(self):
        slots = SlotManager(2, eos_id=7)
        slots.acquire(0, budget=10)
        assert slots.note_window(0, [5, 7, 6, 6]) == (2, True)
        slots.release(0)

    def test_full_window_not_done(self):
        slots = SlotManager(1, eos_id=7)
        slots.acquire(0, budget=10)
        assert slots.note_window(0, [1, 2, 3]) == (3, False)
        assert slots.state(0).budget == 7
        assert slots.state(0).taken == 3

    def test_window_is_n_sequential_note_tokens(self):
        a, b = SlotManager(1, eos_id=9), SlotManager(1, eos_id=9)
        a.acquire(0, budget=5)
        b.acquire(0, budget=5)
        a.note_window(0, [1, 2, 3])
        for t in [1, 2, 3]:
            b.note_token(0, t)
        assert a.state(0) == b.state(0)

    def test_empty_window_rejected(self):
        slots = SlotManager(1)
        slots.acquire(0, budget=5)
        with pytest.raises(ValueError):
            slots.note_window(0, [])


class TestNGramDrafter:
    def _drafter(self, k=4, ngram=3):
        d = NGramDrafter(k=k, ngram=ngram)
        d.bind(None)
        return d

    def test_lookup_continues_most_recent_match(self):
        d = self._drafter()
        # trailing 3-gram [1,2,3] occurred before, continued by 9,8,7,6
        assert d._lookup([1, 2, 3, 9, 8, 7, 6, 1, 2, 3]) == [9, 8, 7, 6]

    def test_lookup_prefers_recent_occurrence(self):
        d = self._drafter(k=1)
        # [5] occurs twice before the suffix; the later one continues w/ 4
        assert d._lookup([5, 2, 0, 5, 4, 5]) == [4]

    def test_lookup_falls_back_to_shorter_ngram(self):
        d = self._drafter(k=2, ngram=3)
        # no prior [2,3,4]; prior [3,4]? no; prior [4] -> continues with 8
        assert d._lookup([4, 8, 1, 2, 3, 4]) == [8, 1]

    def test_lookup_pads_short_continuation(self):
        d = self._drafter(k=4, ngram=2)
        # prior [1,2] continuation is only [7] before history ends
        assert d._lookup([1, 2, 7, 1, 2]) == [7, 1, 2, 2]

    def test_no_match_repeats_last_token(self):
        d = self._drafter(k=3)
        assert d._lookup([1, 2, 3]) == [3, 3, 3]

    def test_history_lifecycle(self):
        d = self._drafter(k=2)
        d.on_prime(1, np.array([1, 2, 3]), 4)
        d.on_tokens(1, [5, 6])
        assert d._hist[1] == [1, 2, 3, 4, 5, 6]
        d.on_release(1)
        assert 1 not in d._hist

    def test_history_capped(self):
        d = NGramDrafter(k=2, max_history=8)
        d.bind(None)
        d.on_prime(0, np.arange(6), 6)
        d.on_tokens(0, list(range(7, 12)))
        assert len(d._hist[0]) == 8
        assert d._hist[0][-1] == 11

    def test_invalid_params(self):
        with pytest.raises(ValueError):
            NGramDrafter(k=0)
        with pytest.raises(ValueError):
            NGramDrafter(ngram=0)
        with pytest.raises(ValueError):
            SelfDrafter(k=0)


# ---------------------------------------------------------------------------
# exactness: speculative == non-speculative == serial
# ---------------------------------------------------------------------------

class TestSpecExactness:
    @pytest.mark.parametrize("paged", [True, False])
    def test_self_drafter_token_identical(self, paged):
        """Acceptance: greedy speculative output is token-identical to the
        non-speculative scheduler on the staggered trace, paged and dense."""
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=3, max_len=48)
        arrivals = [0, 0, 1, 2, 3, 5, 8, 9]
        budgets = [4, 7, 2, 5, 1, 6, 3, 8]
        base = _trace(budgets)
        ContinuousScheduler(eng, paged=paged, page_size=8).serve(
            base, arrivals)
        spec = _trace(budgets)
        ContinuousScheduler(eng, paged=paged, page_size=8,
                            drafter=SelfDrafter(k=3)).serve(spec, arrivals)
        assert [r.out for r in spec] == [r.out for r in base]
        for r in spec:
            assert r.out == _serial(eng, r)

    def test_ngram_drafter_token_identical(self):
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=3, max_len=48)
        budgets = [6, 4, 8, 3, 5]
        base = _trace(budgets)
        ContinuousScheduler(eng, page_size=8).serve(base)
        spec = _trace(budgets)
        ContinuousScheduler(eng, page_size=8,
                            drafter=NGramDrafter(k=4)).serve(spec)
        assert [r.out for r in spec] == [r.out for r in base]

    def test_spec_with_eos_token_identical(self):
        """EOS anywhere inside the verify window truncates exactly like the
        per-token loop: learn a token the trace emits, replay with it as
        eos_id on both paths."""
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=2, max_len=48)
        probe = _trace([8, 8])
        ContinuousScheduler(eng, page_size=8).serve(probe)
        eos = probe[0].out[3]
        base = _trace([8, 8, 8, 8])
        ContinuousScheduler(eng, page_size=8, eos_id=eos).serve(base)
        spec = _trace([8, 8, 8, 8])
        ContinuousScheduler(eng, page_size=8, eos_id=eos,
                            drafter=SelfDrafter(k=3)).serve(spec)
        assert [r.out for r in spec] == [r.out for r in base]
        assert any(len(r.out) < 8 for r in spec)   # EOS actually truncated

    def test_heterogeneous_adapters_spec_token_identical(self, tmp_path):
        """Mixed tenants (fourierft + lora + bare base) under the SELF
        drafter: drafts come from the zero bank row, verify gathers each
        slot's tenant row — outputs must still equal each request's serial
        reference exactly."""
        model, params = _base_model()
        profiles = {
            "fourierft": PEFTConfig(method="fourierft", n=16, alpha=25.0,
                                    param_dtype="float32"),
            "lora": PEFTConfig(method="lora", lora_r=2,
                               param_dtype="float32"),
        }
        for i, (tid, m) in enumerate(zip(("tenant-fft", "tenant-lora"),
                                         ("fourierft", "lora"))):
            prof = profiles[m]
            tree = peft_mod.init_adapters(jax.random.PRNGKey(10 + i),
                                          model.sites, prof)
            tree = jax.tree.map(
                lambda x: x + 0.05 if jnp.issubdtype(x.dtype, jnp.floating)
                else x, tree)
            trainable = set(adapter_api.resolve(m).trainable_leaves(prof))
            tree = {s: {k: v for k, v in d.items() if k in trainable}
                    for s, d in tree.items()}
            adapter_ckpt.export_adapter(str(tmp_path), tid, tree, prof)
        bank = AdapterBank(model, profiles, capacity=4,
                           checkpoint_dir=str(tmp_path))
        eng = Engine(model, params, batch_slots=3, max_len=48, bank=bank)
        ids = ["tenant-fft", "tenant-lora", None, "tenant-fft",
               "tenant-lora", None]
        reqs = _trace([5, 3, 6, 2, 4, 3], adapter_ids=ids)
        ContinuousScheduler(eng, page_size=8,
                            drafter=SelfDrafter(k=3)).serve(
            reqs, arrivals=[0, 0, 0, 1, 3, 4])
        for r in reqs:
            assert r.out == _serial(eng, r)


# ---------------------------------------------------------------------------
# throughput gate + metrics
# ---------------------------------------------------------------------------

class TestSpecMetrics:
    def test_self_drafter_accepts_more_than_one_per_step(self):
        """Headline gate: on base-model traffic the self-drafter's drafts
        ARE the target's argmax, so mean emitted tokens per slot-step must
        exceed 1.0 (only budget/EOS clamping can reject)."""
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=3, max_len=48)
        sched = ContinuousScheduler(eng, page_size=8,
                                    drafter=SelfDrafter(k=3))
        reqs = _trace([8, 8, 8, 8, 8, 8])
        sched.serve(reqs, arrivals=[0, 0, 0, 1, 2, 3])
        s = sched.metrics.summary()
        assert s["spec_tokens_per_step"] > 1.0
        assert s["spec_accept_rate"] > 0.5
        assert s["spec_slot_steps"] > 0
        # histogram totals the emitted tokens the requests actually got;
        # primes emit 1 token each outside the spec path
        emitted = sum(n * c for n, c in sched.metrics.accepted_hist.items())
        assert emitted + len(reqs) == s["total_tokens"]

    def test_per_request_accept_rate_recorded(self):
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=2, max_len=48)
        sched = ContinuousScheduler(eng, page_size=8,
                                    drafter=SelfDrafter(k=2))
        reqs = _trace([6, 5])
        sched.serve(reqs)
        for rm in sched.metrics.requests.values():
            assert rm.drafted > 0
            assert rm.accept_rate is not None
            assert 0.0 <= rm.accept_rate <= 1.0
        assert sched.metrics.summary()["spec_drafts_wasted"] >= 0

    def test_no_spec_counters_without_drafter(self):
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=2, max_len=48)
        sched = ContinuousScheduler(eng, page_size=8)
        sched.serve(_trace([3, 2]))
        assert "spec_accept_rate" not in sched.metrics.summary()


# ---------------------------------------------------------------------------
# buffered async-EOS decode loop (satellite)
# ---------------------------------------------------------------------------

class TestBufferedEOS:
    def test_eos_traffic_exact_vs_serial_reference(self):
        """The buffered loop (device-side done-flag, drains every
        eos_sync_every steps) truncates exactly where the serial
        generate_requests EOS path does."""
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=3, max_len=48)
        probe = _trace([10])
        ContinuousScheduler(eng, page_size=8).serve(probe)
        eos = probe[0].out[2]
        reqs = _trace([10, 10, 10, 10, 10, 10])
        sched = ContinuousScheduler(eng, page_size=8, eos_id=eos)
        sched.serve(reqs, arrivals=[0, 0, 0, 1, 2, 4])
        for r in reqs:
            ref = [Request(prompt=r.prompt, max_new=r.max_new)]
            eng.generate_requests(ref, eos_id=eos)
            assert r.out == ref[0].out

    def test_eos_sync_every_one_matches_default(self):
        """eos_sync_every=1 degenerates to per-step syncing; outputs (and
        token counts) must match the buffered default exactly."""
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=2, max_len=48)
        probe = _trace([10])
        ContinuousScheduler(eng, page_size=8).serve(probe)
        eos = probe[0].out[2]
        a = _trace([10, 10, 10])
        ContinuousScheduler(eng, page_size=8, eos_id=eos,
                            eos_sync_every=1).serve(a, arrivals=[0, 0, 3])
        b = _trace([10, 10, 3])
        ContinuousScheduler(eng, page_size=8, eos_id=eos,
                            eos_sync_every=4).serve(b, arrivals=[0, 0, 3])
        assert [r.out for r in a[:2]] == [r.out for r in b[:2]]

    def test_budget_only_traffic_unaffected_by_buffering(self):
        """No eos_id: the buffered loop drains exactly at budget
        completions, so admission/completion step stamps match the
        historical per-step loop's timing."""
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=2, max_len=48)
        reqs = _trace([4, 6, 3, 5])
        sched = ContinuousScheduler(eng, page_size=8)
        sched.serve(reqs, arrivals=[0, 0, 2, 3])
        done = {r.out is not None for r in reqs}
        assert done == {True}
        m = sched.metrics
        for rm in m.requests.values():
            assert rm.finished is not None
            # every token carries a step stamp inside the run
            assert rm.first_token is not None
        assert m.total_tokens == sum(len(r.out) for r in reqs)
