"""Roofline tooling: dryrun_lib accounting helpers + report renderer."""
import json

import jax
import pytest

import repro.configs as C
from repro.configs.base import PEFTConfig
from repro.launch import dryrun_lib as dl
from repro.launch import roofline as R
from repro.models import build


class TestAccounting:
    def test_backbone_param_counts_exclude_embeddings(self):
        model = build(C.get("yi-6b"), PEFTConfig(method="none"))
        total, active = dl.backbone_params(model)
        assert total == active            # dense: all params active
        # llama-arch analytic: L*(d*(attn+2kv+attn) + 3*d*ff) (+norms)
        d, L, ff = 4096, 32, 11008
        analytic = L * (d * (4096 + 512 + 512 + 4096) + 3 * d * ff)
        assert abs(total - analytic) / analytic < 0.01

    def test_moe_active_params(self):
        model = build(C.get("olmoe-1b-7b"), PEFTConfig(method="none"))
        total, active = dl.backbone_params(model)
        assert active < total             # top-8 of 64 experts
        # expert fraction = 8/64
        cfg = C.get("olmoe-1b-7b")
        expert = cfg.num_layers * cfg.moe.num_experts * 3 * 2048 * 1024
        assert abs((total - active) - expert * (1 - 8 / 64)) / total < 0.02

    def test_model_flops_conventions(self):
        model = build(C.get("yi-6b"), PEFTConfig(method="none"))
        _, n = dl.backbone_params(model)
        train = dl.model_flops(model, C.shape_for("train_4k"))
        prefill = dl.model_flops(model, C.shape_for("prefill_32k"))
        decode = dl.model_flops(model, C.shape_for("decode_32k"))
        assert train == pytest.approx(6.0 * n * 256 * 4096)
        assert prefill == pytest.approx(2.0 * n * 32 * 32768)
        assert decode == pytest.approx(2.0 * n * 128)

    def test_long_context_gate(self):
        assert dl.long_context_skip(C.get("yi-6b"), C.shape_for("long_500k"))
        assert not dl.long_context_skip(C.get("mamba2-2.7b"),
                                        C.shape_for("long_500k"))
        assert not dl.long_context_skip(C.get("yi-6b"),
                                        C.shape_for("train_4k"))


class TestRenderer:
    def _row(self, **kw):
        base = {
            "arch": "yi-6b", "shape": "train_4k", "kind": "train",
            "mesh": "16x16", "chips": 256, "variant": "baseline",
            "flops_per_device": 1e14, "bytes_per_device": 1e12,
            "collective_bytes_per_device": 1e11,
            "collectives": {"all-reduce": 1e11},
            "collective_counts": {"all-reduce": 10},
            "terms": {"compute_s": 0.5, "memory_s": 1.2,
                      "memory_s_upper": 3.0, "collective_s": 2.0},
            "dominant": "collective_s", "model_flops": 3e16,
            "useful_flops_ratio": 0.8, "roofline_fraction": 0.1,
            "memory": {"argument_bytes": 1, "output_bytes": 1,
                       "temp_bytes": 1, "alias_bytes": 0,
                       "peak_estimate_bytes": 3, "fits_hbm": True},
            "compile_seconds": 10.0,
        }
        base.update(kw)
        return base

    def test_render_includes_skips_and_sorts(self):
        rows = [self._row(), self._row(arch="mamba2-2.7b")]
        out = R.render(rows, "16x16", "baseline")
        assert "| yi-6b | train_4k |" in out
        assert "SKIP" in out                      # full-attn long_500k rows
        assert out.count("SKIP") == 8
        assert "0.1000" in out

    def test_fmt(self):
        assert R.fmt_s(2.0) == "2.00s"
        assert R.fmt_s(0.0021) == "2.1ms"
        assert R.fmt_s(5e-6) == "5us"

    def test_real_artifacts_parse(self):
        """The shipped dry-run JSONs load and render."""
        rows = R.load("results/dryrun_baseline_v0")
        assert len(rows) >= 60
        out = R.render(rows, "16x16", "baseline")
        assert len(out.splitlines()) >= 30
        multi = [r for r in rows if r["mesh"] == "2x16x16"]
        assert len(multi) == 32               # full multi-pod coverage
