"""Continuous-batching scheduler (DESIGN.md §Scheduler): per-request
outputs bit-identical (fp32) to the serial engine under staggered arrivals
and heterogeneous adapters, slot recycling under churn, bank-aware
admission (live-tenant pinning, LRU eviction mid-stream), slot-lifecycle
invariants, and the Engine.generate_requests per-slot completion fix."""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.configs as C
from repro.checkpoint import adapters as adapter_ckpt
from repro.configs.base import PEFTConfig
from repro.core import adapter as adapter_api
from repro.core import peft as peft_mod
from repro.models import build
from repro.serve import (
    AdapterBank, BankFullError, ContinuousScheduler, Engine, Request,
)
from repro.serve.scheduler.slots import ACTIVE, FREE, SlotManager

TENANTS = ("tenant-fft", "tenant-lora")
METHODS = ("fourierft", "lora")


def _cfg(arch="yi-6b"):
    return C.reduced(C.get(arch)).replace(vocab=64, param_dtype="float32",
                                          dtype="float32")


def _profiles():
    return {
        "fourierft": PEFTConfig(method="fourierft", n=16, alpha=25.0,
                                param_dtype="float32"),
        "lora": PEFTConfig(method="lora", lora_r=2, param_dtype="float32"),
    }


def _base_model():
    model = build(_cfg(), PEFTConfig(method="none"))
    return model, model.init(jax.random.PRNGKey(0))


def _export_tenants(model, directory, tenant_ids=TENANTS, methods=METHODS):
    profiles = _profiles()
    for i, (tid, m) in enumerate(zip(tenant_ids, methods)):
        prof = profiles[m]
        tree = peft_mod.init_adapters(jax.random.PRNGKey(10 + i),
                                      model.sites, prof)
        tree = jax.tree.map(
            lambda x: x + 0.05 if jnp.issubdtype(x.dtype, jnp.floating)
            else x, tree)
        trainable = set(adapter_api.resolve(m).trainable_leaves(prof))
        tree = {s: {k: v for k, v in d.items() if k in trainable}
                for s, d in tree.items()}
        adapter_ckpt.export_adapter(str(directory), tid, tree, prof)
    return profiles


def _serial(engine, req):
    """Reference: the request alone through Engine.generate (exact
    per-request semantics — no foreign padding, own decode length)."""
    if req.adapter_id is not None and \
            req.adapter_id not in engine.bank.resident_ids:
        engine.bank.load_from_checkpoint(req.adapter_id)
    out = engine.generate([req.prompt], max_new=req.max_new,
                          adapter_ids=[req.adapter_id]
                          if engine.bank is not None else None)[0]
    return [int(t) for t in np.asarray(out).reshape(-1)]


PROMPTS = [[1, 2, 3, 4, 5], [7, 8, 9], [11, 12], [3, 1, 4, 1, 5, 9],
           [2, 7, 1, 8], [6, 6, 6], [9, 8, 7, 6, 5, 4, 3], [5, 5]]


def _trace(max_news, adapter_ids=None):
    return [Request(prompt=jnp.array(PROMPTS[i % len(PROMPTS)], jnp.int32),
                    max_new=mn,
                    adapter_id=adapter_ids[i] if adapter_ids else None)
            for i, mn in enumerate(max_news)]


class TestExactness:
    def test_staggered_arrivals_bitwise_vs_serial(self):
        """Acceptance: continuous outputs == one-request-at-a-time engine,
        bit-identical at fp32, under staggered arrivals + mixed budgets."""
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=3, max_len=48)
        reqs = _trace([4, 7, 2, 5, 1, 6, 3, 8])
        sched = ContinuousScheduler(eng)
        sched.serve(reqs, arrivals=[0, 0, 1, 2, 3, 5, 8, 9])
        for r in reqs:
            assert r.out == _serial(eng, r)
        s = sched.metrics.summary()
        assert s["total_tokens"] == sum(len(r.out) for r in reqs)
        assert 0 < s["occupancy_mean"] <= 1

    def test_heterogeneous_adapters_bitwise(self, tmp_path):
        """Mixed tenants (two methods + bare base) in one continuous batch
        reproduce each request's serial outputs exactly."""
        model, params = _base_model()
        profiles = _export_tenants(model, tmp_path)
        bank = AdapterBank(model, profiles, capacity=4,
                           checkpoint_dir=str(tmp_path))
        eng = Engine(model, params, batch_slots=3, max_len=48, bank=bank)
        ids = ["tenant-fft", "tenant-lora", None, "tenant-fft",
               "tenant-lora", None]
        reqs = _trace([5, 3, 6, 2, 4, 3], adapter_ids=ids)
        ContinuousScheduler(eng).serve(reqs, arrivals=[0, 0, 0, 1, 3, 4])
        for r in reqs:
            assert r.out == _serial(eng, r)

    def test_exact_prime_matches_bucketed(self):
        """bucket=False (per-length prefill) and bucket=True (pow2 padded
        prefill + true_len gather) are the same math."""
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=2, max_len=48)
        a = _trace([4, 3, 5])
        ContinuousScheduler(eng, bucket=True).serve(a, [0, 1, 2])
        b = _trace([4, 3, 5])
        ContinuousScheduler(eng, bucket=False).serve(b, [0, 1, 2])
        assert [r.out for r in a] == [r.out for r in b]

    def test_bucket_clamped_to_non_pow2_max_len(self):
        """Regression: a near-max prompt whose pow2 bucket overshoots a
        non-pow2 max_len must clamp to max_len, not crash the splice."""
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=2, max_len=48)
        long_p = jnp.arange(40, dtype=jnp.int32) % 64
        reqs = [Request(prompt=long_p, max_new=5)]
        ContinuousScheduler(eng).serve(reqs)
        assert reqs[0].out == _serial(eng, reqs[0])

    def test_event_stream(self):
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=2, max_len=48)
        sched = ContinuousScheduler(eng)
        rids = [sched.submit(r, t) for r, t in zip(_trace([3, 2]), (0, 1))]
        events = list(sched.events())
        kinds = [e[0] for e in events]
        assert kinds.count("admit") == 2 and kinds.count("done") == 2
        for rid, n in zip(rids, (3, 2)):
            toks = [e[2] for e in events if e[0] == "token" and e[1] == rid]
            done = next(e for e in events if e[0] == "done" and e[1] == rid)
            assert toks == done[2] and len(toks) == n

    def test_unsupported_family_raises(self):
        cfg = C.reduced(C.get("mamba2-2.7b")).replace(
            vocab=64, param_dtype="float32", dtype="float32")
        model = build(cfg, PEFTConfig(method="none"))
        eng = Engine(model, model.init(jax.random.PRNGKey(0)),
                     batch_slots=2, max_len=32)
        with pytest.raises(NotImplementedError):
            ContinuousScheduler(eng)


class TestSlotLifecycle:
    def test_recycling_under_churn(self):
        """More requests than slots: freed slots are re-primed in flight and
        every request still matches the serial reference."""
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=2, max_len=48)
        reqs = _trace([3, 1, 4, 2, 5, 2, 3, 1, 2, 4])
        sched = ContinuousScheduler(eng)
        admits = []
        for r, t in zip(reqs, [0] * 10):
            sched.submit(r, t)
        for ev in sched.events():
            if ev[0] == "admit":
                admits.append(ev[2])
        assert all(r.out is not None for r in reqs)
        for r in reqs:
            assert r.out == _serial(eng, r)
        # both slots recycled repeatedly
        assert admits.count(0) >= 3 and admits.count(1) >= 3
        assert not sched.slots.any_active()

    def test_lru_eviction_mid_stream(self, tmp_path):
        """A non-resident tenant arriving against a full bank must wait for
        a pinned (live) tenant to drain, then evict it via LRU — and the
        still-running streams are unaffected."""
        model, params = _base_model()
        profiles = _export_tenants(
            model, tmp_path,
            tenant_ids=("t-a", "t-b", "t-c"),
            methods=("fourierft", "fourierft", "lora"))
        bank = AdapterBank(model, profiles, capacity=2,
                           checkpoint_dir=str(tmp_path))
        eng = Engine(model, params, batch_slots=3, max_len=48, bank=bank)
        reqs = _trace([8, 2, 3], adapter_ids=["t-a", "t-b", "t-c"])
        sched = ContinuousScheduler(eng)
        for r, t in zip(reqs, (0, 0, 1)):
            sched.submit(r, t)
        events = list(sched.events())
        admit_t = {e[1]: e[3] for e in events if e[0] == "admit"}
        done_t = {e[1]: e[3] for e in events if e[0] == "done"}
        # t-c could not be admitted at its arrival (bank full, both pinned):
        # it waited for t-b to finish
        assert admit_t[2] >= done_t[1]
        # t-b was evicted for t-c; the long-running t-a stayed resident
        assert "t-b" not in bank.resident_ids
        assert {"t-a", "t-c"} <= set(bank.resident_ids)
        for r in reqs:
            assert r.out == _serial(eng, r)

    def test_load_refuses_to_evict_pinned(self, tmp_path):
        model, _ = _base_model()
        profiles = _export_tenants(
            model, tmp_path, tenant_ids=("t-a", "t-b", "t-c"),
            methods=("fourierft", "fourierft", "fourierft"))
        bank = AdapterBank(model, profiles, capacity=2,
                           checkpoint_dir=str(tmp_path))
        bank.load_from_checkpoint("t-a")
        bank.load_from_checkpoint("t-b")
        with pytest.raises(BankFullError):
            bank.load_from_checkpoint("t-c", pinned=["t-a", "t-b"])
        assert set(bank.resident_ids) == {"t-a", "t-b"}  # load left no hole
        # unpinning one lets the LRU (t-a) go
        bank.load_from_checkpoint("t-c", pinned=["t-b"])
        assert set(bank.resident_ids) == {"t-b", "t-c"}


class TestSlotManagerInvariants:
    def _fuzz(self, ops):
        """Drive acquire/release/note against an external model of the
        assignment; any double assignment or phantom release must raise."""
        sm = SlotManager(4)
        assigned = {}                      # slot -> rid (external truth)
        next_rid = 0
        for op, slot in ops:
            if op == "acquire":
                if len(assigned) == len(sm):
                    with pytest.raises(RuntimeError):
                        sm.acquire(next_rid, budget=3)
                else:
                    got = sm.acquire(next_rid, budget=3)
                    assert got not in assigned          # never double-assign
                    assigned[got] = next_rid
                    next_rid += 1
            elif op == "release":
                if slot in assigned:
                    sm.release(slot)
                    del assigned[slot]
                else:
                    with pytest.raises(RuntimeError):
                        sm.release(slot)
            else:                          # note
                if slot in assigned:
                    if sm.note_token(slot):
                        sm.release(slot)
                        del assigned[slot]
                else:
                    with pytest.raises(RuntimeError):
                        sm.note_token(slot)
            assert set(sm.active_slots()) == set(assigned)
            assert set(sm.free_slots()) == \
                set(range(len(sm))) - set(assigned)

    @given(st.lists(st.tuples(st.sampled_from(["acquire", "release", "note"]),
                              st.integers(min_value=0, max_value=3)),
                    max_size=200))
    @settings(max_examples=50, deadline=None)
    def test_no_double_assignment_property(self, ops):
        self._fuzz(ops)

    def test_no_double_assignment_fuzz(self):
        """Deterministic mirror of the property test (runs when hypothesis
        is absent)."""
        rng = random.Random(0)
        for _ in range(20):
            ops = [(rng.choice(["acquire", "release", "note"]),
                    rng.randrange(4)) for _ in range(120)]
            self._fuzz(ops)

    def test_same_rid_twice_raises(self):
        sm = SlotManager(2)
        sm.acquire(7, budget=2)
        with pytest.raises(RuntimeError):
            sm.acquire(7, budget=2)

    def test_budget_and_eos_completion(self):
        sm = SlotManager(1, eos_id=42)
        sm.acquire(0, budget=3)
        assert not sm.note_token(0, token=5)
        assert sm.note_token(0, token=42)          # EOS before budget
        st_ = sm.release(0)
        assert st_.taken == 2 and st_.state == ACTIVE
        assert sm.state(0).state == FREE


class TestEngineGuards:
    def test_generate_rejects_bad_inputs(self):
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=2, max_len=32)
        p = jnp.array([1, 2, 3], jnp.int32)
        with pytest.raises(ValueError, match="at least one prompt"):
            eng.generate([], max_new=4)
        with pytest.raises(ValueError, match="max_new"):
            eng.generate([p], max_new=0)
        with pytest.raises(ValueError, match="empty"):
            eng.generate([jnp.zeros((0,), jnp.int32)], max_new=4)

    def test_generate_requests_rejects_bad_requests(self):
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=2, max_len=32)
        p = jnp.array([1, 2], jnp.int32)
        with pytest.raises(ValueError, match="max_new"):
            eng.generate_requests([Request(prompt=p, max_new=0)])
        with pytest.raises(ValueError, match="empty"):
            eng.generate_requests(
                [Request(prompt=jnp.zeros((0,), jnp.int32), max_new=2)])
        assert eng.generate_requests([]) == []

    def test_scheduler_submit_guards(self):
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=2, max_len=16)
        sched = ContinuousScheduler(eng)
        p = jnp.array([1, 2, 3], jnp.int32)
        with pytest.raises(ValueError, match="max_new"):
            sched.submit(Request(prompt=p, max_new=0))
        with pytest.raises(ValueError, match="empty"):
            sched.submit(Request(prompt=jnp.zeros((0,), jnp.int32)))
        # capacity bound (slots.py invariant): the last generated token is
        # never written, so prompt+max_new-1 positions must fit — max_new=14
        # (= 16 positions) is feasible, 15 is the first infeasible budget
        with pytest.raises(ValueError, match="max_len"):
            sched.submit(Request(prompt=p, max_new=15))
        sched.submit(Request(prompt=p, max_new=14))   # exactly max_len: ok
        with pytest.raises(ValueError, match="no bank"):
            sched.submit(Request(prompt=p, max_new=2, adapter_id="t"))


class TestMetricsQuantiles:
    """Satellite: nearest-rank (ceil) quantiles — the old floor index
    `vals[int(0.9*(N-1))]` under-reported the tail at small N."""

    def test_nearest_rank_known_distribution(self):
        from repro.serve.scheduler.metrics import nearest_rank
        vals = list(range(1, 11))                  # 1..10
        assert nearest_rank(vals, 0.50) == 5       # ceil(5) -> 5th
        assert nearest_rank(vals, 0.90) == 9       # ceil(9) -> 9th (old: 8)
        assert nearest_rank(vals, 0.99) == 10      # N < 100 -> the max
        assert nearest_rank([7.0], 0.90) == 7.0
        assert nearest_rank([], 0.90) == 0.0
        # quartile textbook case: 11 samples
        vals = [15, 20, 35, 40, 50] + [60, 70, 80, 90, 100, 110]
        assert nearest_rank(vals, 0.25) == 35      # ceil(2.75) -> 3rd

    def test_summary_percentiles(self):
        from repro.serve.scheduler.metrics import ServingMetrics
        m = ServingMetrics()
        for rid in range(10):
            m.on_arrival(rid, 0.0)
            m.on_token(rid, float(rid + 1))        # TTFTs 1..10
        s = m.summary()
        assert s["ttft_steps_p50"] == 5
        assert s["ttft_steps_p90"] == 9
        assert s["ttft_steps_p99"] == 10


class TestQueueBisect:
    """Satellite: `arrived` cuts at the first arrival > now via bisect —
    behavior must be unchanged vs the full linear scan."""

    def _naive_arrived(self, pending, now):
        return [sr for sr in pending if sr.arrival <= now]

    def test_randomized_trace_no_behavior_change(self):
        from repro.serve.scheduler.queue import RequestQueue
        rng = random.Random(7)
        p = jnp.array([1, 2], jnp.int32)
        for policy in ("fcfs", "resident_first"):
            q = RequestQueue(policy)
            for _ in range(60):
                q.push(Request(prompt=p, max_new=2,
                               adapter_id=rng.choice(
                                   [None, "t-a", "t-b", "t-c"])),
                       arrival=rng.choice([0.0, 1.0, 2.5, 2.5, 7.0, 11.0]))
            now = 0.0
            popped = []
            while len(q):
                assert q.arrived(now) == self._naive_arrived(q.pending, now)
                # admit every other offer: exercises the turned-down path
                flip = [True]
                sr = q.pop_next(now, lambda _: flip.__setitem__(0, not flip[0])
                                or not flip[0], resident=("t-a",))
                if sr is not None:
                    assert sr.arrival <= now
                    popped.append(sr.rid)
                else:
                    now += 0.5
            assert sorted(popped) == list(range(60))

    def test_arrived_is_sorted_prefix(self):
        from repro.serve.scheduler.queue import RequestQueue
        q = RequestQueue()
        p = jnp.array([1], jnp.int32)
        for arr in (5.0, 1.0, 3.0, 1.0, 9.0):
            q.push(Request(prompt=p, max_new=1), arrival=arr)
        assert [sr.arrival for sr in q.arrived(3.0)] == [1.0, 1.0, 3.0]
        assert q.arrived(0.5) == []
        assert len(q.arrived(100.0)) == 5


class TestLockstepCompletionFix:
    def test_budgets_and_chunking(self):
        """generate_requests handles more requests than slots and returns
        exactly max_new tokens each, matching generate() truncation."""
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=3, max_len=48)
        reqs = _trace([4, 7, 2, 5, 1, 6, 3, 8])
        eng.generate_requests(reqs)
        for at in range(0, len(reqs), 3):
            chunk = reqs[at:at + 3]
            outs = eng.generate([r.prompt for r in chunk],
                                max_new=max(r.max_new for r in chunk))
            for r, o in zip(chunk, outs):
                assert r.out == [int(t) for t in
                                 np.asarray(o[:r.max_new]).reshape(-1)]

    def test_eos_stops_contribution_and_decoding(self):
        """Once every slot hits EOS/budget the chunk's decode loop exits —
        no more max(max_new) over-decoding — and a finished slot records
        nothing past its EOS."""
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=2, max_len=48)
        probe = [Request(prompt=jnp.array(PROMPTS[0], jnp.int32), max_new=10)]
        eng.generate_requests(probe)
        eos = probe[0].out[2]
        calls = [0]
        real = eng._decode
        eng._decode = lambda *a, **k: (calls.__setitem__(0, calls[0] + 1)
                                       or real(*a, **k))
        reqs = [Request(prompt=jnp.array(PROMPTS[0], jnp.int32), max_new=10)]
        eng.generate_requests(reqs, eos_id=eos)
        eng._decode = real
        assert reqs[0].out == probe[0].out[:3]     # EOS token included
        assert calls[0] == 2                       # not 9: early exit
