"""Training-substrate tests: learning on synthetic tasks, microbatch
equivalence, anomaly guard, schedules, optimizer, PEFT gradient filtering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.configs.base import PEFTConfig, TrainConfig
from repro.data import SyntheticLM
from repro.models import build
from repro.optim import adamw, schedules
from repro.train import step as ts


def _tiny_model(peft=None, **kw):
    cfg = C.reduced(C.get("yi-6b")).replace(vocab=64, **kw)
    return build(cfg, peft or PEFTConfig(n=32, alpha=10.0, train_head=True))


class TestLearning:
    def test_fourierft_loss_decreases(self):
        model = _tiny_model()
        tcfg = TrainConfig(learning_rate=2e-2, total_steps=50, warmup_steps=5)
        state, frozen = ts.init_state(model, tcfg, jax.random.PRNGKey(0))
        step_fn = jax.jit(ts.make_train_step(model, tcfg))
        data = SyntheticLM(vocab=64, batch=8, seq=32, task_seed=3)
        losses = []
        for i in range(50):
            state, m = step_fn(state, frozen, data.batch_at(i))
            losses.append(float(m["loss"]))
        assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.1

    def test_only_adapters_receive_updates(self):
        model = _tiny_model(peft=PEFTConfig(n=32, alpha=10.0))
        tcfg = TrainConfig(total_steps=3)
        state, frozen = ts.init_state(model, tcfg, jax.random.PRNGKey(0))
        step_fn = jax.jit(ts.make_train_step(model, tcfg))
        data = SyntheticLM(vocab=64, batch=4, seq=16)
        base_before = jax.tree.map(lambda x: np.asarray(x).copy(),
                                   frozen["base"])
        c_before = {k: np.asarray(v["c"]).copy()
                    for k, v in state["trainable"]["peft"].items()}
        state, _ = step_fn(state, frozen, data.batch_at(0))
        # frozen base untouched (it is an input, never written)
        for (p1, l1), (p2, l2) in zip(
                jax.tree_util.tree_leaves_with_path(base_before),
                jax.tree_util.tree_leaves_with_path(frozen["base"])):
            np.testing.assert_array_equal(l1, np.asarray(l2))
        # adapter coefficients moved
        for k, v in state["trainable"]["peft"].items():
            assert not np.allclose(c_before[k], np.asarray(v["c"]))

    def test_microbatch_equals_full_batch_gradients(self):
        model = _tiny_model()
        data = SyntheticLM(vocab=64, batch=8, seq=16)
        batch = data.batch_at(0)
        grads = {}
        for k in (0, 4):
            tcfg = TrainConfig(microbatch=k, grad_clip=1e9)
            state, frozen = ts.init_state(model, tcfg, jax.random.PRNGKey(0))
            loss_f = ts._loss_for(model)
            if k:
                step = ts.make_train_step(model, tcfg)
                # reach inside: compare accumulated loss via metrics
                _, m = jax.jit(step)(state, frozen, batch)
                grads[k] = float(m["loss"])
            else:
                grads[k] = float(loss_f(state["trainable"], frozen, batch))
        assert abs(grads[0] - grads[4]) < 2e-3

    def test_anomaly_guard_skips_bad_step(self):
        model = _tiny_model()
        tcfg = TrainConfig(anomaly_threshold=1e4)
        state, frozen = ts.init_state(model, tcfg, jax.random.PRNGKey(0))
        step_fn = jax.jit(ts.make_train_step(model, tcfg))
        data = SyntheticLM(vocab=64, batch=4, seq=16)
        state, _ = step_fn(state, frozen, data.batch_at(0))
        snap = jax.tree.map(np.asarray, state["trainable"])
        # poison the batch -> non-finite loss
        bad = {"tokens": data.batch_at(1)["tokens"],
               "labels": data.batch_at(1)["labels"]}
        poisoned_frozen = jax.tree.map(
            lambda x: (x * np.nan if x.dtype in (jnp.bfloat16, jnp.float32)
                       and x.ndim >= 2 else x), frozen)
        state2, m = step_fn(state, poisoned_frozen, bad)
        assert int(m["skipped"]) == 1
        assert int(state2["anomalies"]) == 1
        for a, b in zip(jax.tree.leaves(snap),
                        jax.tree.leaves(state2["trainable"])):
            np.testing.assert_array_equal(a, np.asarray(b))


class TestOptim:
    def test_adamw_matches_reference_scalar(self):
        """One param, closed-form first step: update = -lr (bias-corrected)."""
        cfg = TrainConfig(learning_rate=0.1, weight_decay=0.0)
        p = {"w": jnp.array([2.0])}
        g = {"w": jnp.array([0.5])}
        opt = adamw.init(p)
        p2, opt2 = adamw.update(g, opt, p, 0.1, cfg)
        # m̂ = g, v̂ = g² -> step = g/|g| = 1 -> w' = 2 - 0.1
        np.testing.assert_allclose(p2["w"], jnp.array([1.9]), atol=1e-4)

    def test_weight_decay_decoupled(self):
        cfg = TrainConfig(learning_rate=0.1, weight_decay=0.1)
        p = {"w": jnp.array([1.0])}
        g = {"w": jnp.array([0.0])}
        opt = adamw.init(p)
        p2, _ = adamw.update(g, opt, p, 0.1, cfg)
        np.testing.assert_allclose(p2["w"], jnp.array([1.0 - 0.1 * 0.1 * 1.0]),
                                   atol=1e-6)

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
        clipped, norm = adamw.clip_by_global_norm(g, 1.0)
        np.testing.assert_allclose(norm, 10.0, atol=1e-5)
        np.testing.assert_allclose(adamw.global_norm(clipped), 1.0, atol=1e-5)

    def test_schedule_shapes(self):
        cfg = TrainConfig(learning_rate=1.0, warmup_steps=10, total_steps=110,
                          schedule="linear")
        np.testing.assert_allclose(float(schedules.lr_at(0, cfg)), 0.1)
        np.testing.assert_allclose(float(schedules.lr_at(9, cfg)), 1.0)
        assert float(schedules.lr_at(110, cfg)) < 1e-6
        cfg2 = cfg.replace(schedule="cosine")
        np.testing.assert_allclose(float(schedules.lr_at(60, cfg2)), 0.5,
                                   atol=1e-2)


class TestParamSplit:
    def test_full_ft_trains_base(self):
        model = _tiny_model(peft=PEFTConfig(method="full"))
        tcfg = TrainConfig(total_steps=1)
        state, frozen = ts.init_state(model, tcfg, jax.random.PRNGKey(0))
        assert "base" in state["trainable"]
        step_fn = jax.jit(ts.make_train_step(model, tcfg))
        data = SyntheticLM(vocab=64, batch=2, seq=16)
        state, m = step_fn(state, frozen, data.batch_at(0))
        assert np.isfinite(float(m["loss"]))

    def test_trainable_counts(self):
        for method, expect in [("fourierft", 32 * 2 * 2), ("lora", None)]:
            model = _tiny_model(peft=PEFTConfig(method=method, n=32, lora_r=2))
            tcfg = TrainConfig()
            state, _ = ts.init_state(model, tcfg, jax.random.PRNGKey(0))
            n = sum(int(np.prod(x.shape)) for x in
                    jax.tree.leaves(state["trainable"]["peft"]))
            if method == "fourierft":
                assert n == 32 * model.cfg.num_layers * 2  # q and v sites
