"""Sharding-plan tests (DESIGN.md §Sharding): PlanSource byte-identity with
the rule table, plan serialization round-trips, planner search never losing
to the rules under its own cost model, the analyzer's per-kind collective
buckets, and a compiled 8-fake-device smoke showing a searched plan beating
the rules on analyzer-measured collective bytes while staying fp32-equivalent
for train and serve."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as C
from repro.configs.base import PEFTConfig, ShapeConfig
from repro.dist import hlo
from repro.dist import plan as plan_mod
from repro.dist import planner
from repro.dist import sharding as shd
from repro.dist.cost_model import ClusterEnv, MeshSpec
from repro.models import build, registry

MESHES = (MeshSpec.from_string("4x2"), MeshSpec.from_string("2x4x2"))


def _flat_specs(tree, path=()):
    """(path, spec-as-tuple) pairs; PartitionSpec is a leaf, not a tuple
    container."""
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flat_specs(tree[k], path + (str(k),))
    elif isinstance(tree, (list, tuple)) and not isinstance(tree, P):
        for i, v in enumerate(tree):
            yield from _flat_specs(v, path + (str(i),))
    else:
        yield "/".join(path), tuple(tree)


def _tiny(arch="yi-6b", method="fourierft"):
    cfg = C.reduced(C.get(arch)).replace(vocab=64)
    return build(cfg, PEFTConfig(method=method, n=16))


def _sweep():
    """Every arch x fourierft plus every audited method on the first arch —
    the same coverage surface the sharding audit walks."""
    yield from registry.analysis_models()
    from repro.analysis.sharding_audit import DEFAULT_METHODS
    first = C.ARCH_IDS[0]
    yield from registry.analysis_models(methods=DEFAULT_METHODS[1:],
                                        archs=(first,))


class TestRulesByteIdentity:
    @pytest.mark.parametrize("mesh", MESHES, ids=lambda m: "x".join(
        map(str, m.devices.shape)))
    def test_state_specs_every_arch_method(self, mesh):
        """RulesSource == the legacy module functions, and a plan table built
        FROM the rules specs reproduces them exactly after the
        encode -> JSON -> decode -> sanitize round trip."""
        rules = plan_mod.RulesSource()
        for arch, method, model in _sweep():
            tree = model.init_shapes()
            for fsdp in (False, True):
                want = shd.state_specs(tree, mesh, model.cfg, fsdp=fsdp)
                got = rules.state_specs(tree, mesh, model.cfg, fsdp=fsdp)
                assert list(_flat_specs(got)) == list(_flat_specs(want)), \
                    f"{arch}[{method}] fsdp={fsdp}"
                plan = plan_mod.ShardingPlan(meta={}, tables={})
                shapes = dict(planner._iter_leaves(tree))
                for path, spec in _flat_specs(want):
                    plan.put("state", path,
                             tuple(shapes[path].shape), spec)
                via_table = plan_mod.PlanTableSource(plan).state_specs(
                    tree, mesh, model.cfg, fsdp=fsdp)
                assert (list(_flat_specs(via_table))
                        == list(_flat_specs(want))), \
                    f"{arch}[{method}] fsdp={fsdp} plan round-trip"

    def test_cache_and_batch_specs_match(self):
        mesh = MESHES[0]
        model = _tiny()
        shape = ShapeConfig("decode", 32, 8, "decode")
        cache = model.cache_specs(shape)
        batch = model.input_specs(shape)
        rules = plan_mod.RulesSource()
        assert (list(_flat_specs(rules.cache_specs(cache, mesh, model.cfg,
                                                   shape)))
                == list(_flat_specs(shd.cache_specs(cache, mesh, model.cfg,
                                                    shape))))
        assert (list(_flat_specs(rules.batch_specs(batch, mesh, shape)))
                == list(_flat_specs(shd.batch_specs(batch, mesh, shape))))

    def test_leaf_rules_pin_known_placements(self):
        """The extracted leaf functions keep the legacy decisions."""
        mesh = MESHES[0]
        b = shd.batch_axes(mesh, 8)
        assert tuple(shd.cache_leaf_spec("layers/k", (2, 4, 32, 4, 8),
                                         mesh, b))[:2] == (None, b)
        assert tuple(shd.batch_leaf_spec("tokens", (8, 32), b))[0] == b
        assert shd.batch_rule_kind("tokens", (8, 32)) == "batch"
        assert shd.cache_rule_kind("layers/k", (2, 4, 32, 4, 8)) == "kv"
        assert shd.cache_rule_kind("layers/pk", (2, 4, 16, 8, 8, 8)) is None


class TestPlanRoundTrip:
    def test_serialize_load_identical(self, tmp_path):
        model = _tiny()
        mesh = MESHES[0]
        shape = ShapeConfig("train", 32, 8, "train")
        plan = planner.plan_model(model, mesh, shape=shape, workload="train")
        p = tmp_path / "plan.json"
        plan.save(str(p))
        loaded = plan_mod.ShardingPlan.load(str(p))
        assert loaded.to_json() == plan.to_json()
        tree = model.init_shapes()
        a = plan_mod.PlanTableSource(plan).state_specs(tree, mesh, model.cfg)
        b = plan_mod.PlanTableSource(loaded).state_specs(tree, mesh,
                                                         model.cfg)
        assert list(_flat_specs(a)) == list(_flat_specs(b))

    def test_sanitize_degrades_across_meshes(self):
        # an axis the mesh lacks, or that doesn't divide, drops to replicate
        assert tuple(plan_mod.sanitize_spec(P("model", "data"), (7, 8),
                                            MESHES[0])) == (None, "data")
        assert tuple(plan_mod.sanitize_spec(P("pod"), (8,),
                                            MESHES[0])) == (None,)


class TestPlannerSearch:
    @pytest.mark.parametrize("workload,shape", [
        ("train", ShapeConfig("train", 64, 8, "train")),
        ("decode", ShapeConfig("decode", 64, 8, "decode")),
    ])
    def test_search_never_worse_than_rules(self, workload, shape):
        model = _tiny()
        for mesh in MESHES:
            plan = planner.plan_model(model, mesh, shape=shape,
                                      workload=workload)
            ranked = plan.meta["ranked"]
            rules_obj = next(r["objective_s"] for r in ranked
                             if r["strategy"] == "rules")
            assert ranked[0]["objective_s"] <= rules_obj * (1 + 1e-9)

    def test_score_source_prices_placements(self):
        model = _tiny()
        mesh = MESHES[0]
        shape = ShapeConfig("train", 64, 8, "train")
        cost = planner.score_source(model, mesh, shape,
                                    plan_mod.RulesSource(), workload="train")
        assert cost.total_s > 0 and cost.resident_bytes > 0

    def test_cost_model_collective_formulas(self):
        env = ClusterEnv(MESHES[0])
        nbytes = 1 << 20
        ar = env.all_reduce_cost(nbytes, ("data",))
        ag = env.all_gather_cost(nbytes, ("data",))
        assert ar > ag > 0                       # 2(n-1)/n vs (n-1)/n
        assert env.all_reduce_cost(nbytes, ()) == 0.0


class TestHloCollectiveBuckets:
    A2A = """HloModule m
ENTRY %main (p: f32[64,64]) -> f32[64,64] {
  %p = f32[64,64]{1,0} parameter(0)
  ROOT %a2a = f32[64,64]{1,0} all-to-all(%p), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
}
"""
    PERMUTE_ASYNC = """HloModule m
ENTRY %main (p: f32[32,32]) -> f32[32,32] {
  %p = f32[32,32]{1,0} parameter(0)
  %cps = f32[32,32]{1,0} collective-permute-start(%p), source_target_pairs={{0,1},{1,0}}
  ROOT %cpd = f32[32,32]{1,0} collective-permute-done(%cps)
}
"""

    def test_all_to_all_own_bucket(self):
        s = hlo.analyze_module(self.A2A)
        assert s.bytes_by_kind == {"all-to-all": 64 * 64 * 4}
        assert s.count_by_kind["all-to-all"] == 1
        assert s.group_by_kind["all-to-all"] == 4

    def test_collective_permute_async_counted_once(self):
        s = hlo.analyze_module(self.PERMUTE_ASYNC)
        assert s.bytes_by_kind == {"collective-permute": 32 * 32 * 4}
        assert s.count_by_kind["collective-permute"] == 1
        assert s.group_by_kind["collective-permute"] == 2

    def test_replica_group_size_forms(self):
        assert hlo.replica_group_size("replica_groups={{0,1},{2,3}}") == 2
        assert hlo.replica_group_size("replica_groups=[2,4]<=[8]") == 4
        assert hlo.replica_group_size(
            "source_target_pairs={{0,1},{1,2},{2,0}}") == 2
        assert hlo.replica_group_size("channel_id=3") is None


PLAN_SMOKE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
import repro.configs as C
from repro.launch import dryrun_lib as dl
from repro.launch.mesh import make_mesh
from repro.configs.base import PEFTConfig, ShapeConfig, TrainConfig
from repro.models import build
from repro.train import step as ts

orig_get = C.get
dl.configs.get = lambda a: C.reduced(orig_get(a), layers=2, width=64, vocab=256)
shapes = {"train_4k": ShapeConfig("train_4k", 128, 8, "train"),
          "decode_32k": ShapeConfig("decode_32k", 256, 8, "decode")}
dl.configs.shape_for = lambda n: shapes[n]
mesh = make_mesh((4, 2), ("data", "model"))

# 1) searched plan beats the rules on ANALYZER-MEASURED collective bytes
for shape, strict in (("decode_32k", True), ("train_4k", True)):
    coll = {}
    for plan in ("rules", "search"):
        cell = dl.build_cell("yi-6b", shape, mesh, sharding_plan=plan)
        with mesh:
            compiled = dl.lower_cell(cell).compile()
        res = dl.analyze(cell, None, compiled, mesh, 0.0)
        coll[plan] = res["collective_bytes_per_device"]
        assert res["sharding_plan"]["source"] == (
            "rules" if plan == "rules" else "plan")
        assert "predicted" in res["sharding_plan"]
    assert coll["search"] <= coll["rules"], (shape, coll)
    if strict:
        assert coll["search"] < coll["rules"], (shape, coll)

# 2) fp32 train equivalence: same losses under rules and searched plans
cfg = C.reduced(orig_get("yi-6b"), layers=2, width=64, vocab=256).replace(
    param_dtype="float32", dtype="float32")
peft = PEFTConfig(method="fourierft", n=16, param_dtype="float32")
model = build(cfg, peft)
tcfg = TrainConfig(learning_rate=1e-2, total_steps=4, warmup_steps=1)
from repro.data import SyntheticLM
data = SyntheticLM(vocab=256, batch=8, seq=16, seed=0)
losses = {}
from repro.dist import plan as plan_mod
for kind in ("rules", "search"):
    src = plan_mod.resolve(kind, model=model, mesh=mesh,
                           shape=ShapeConfig("t", 16, 8, "train"),
                           workload="train")
    state, frozen = ts.init_state(model, tcfg, jax.random.PRNGKey(0))
    state, frozen, st_sh, fr_sh = ts.shard_train_state(
        model, state, frozen, mesh, plan=src)
    step_fn, b_sh = ts.make_sharded_train_step(
        model, tcfg, mesh, state, frozen, data.batch_at(0),
        shardings=(st_sh, fr_sh), plan=src)
    ls = []
    for i in range(3):
        state, m = step_fn(state, frozen,
                           jax.device_put(data.batch_at(i), b_sh))
        ls.append(float(m["loss"]))
    losses[kind] = ls
np.testing.assert_allclose(losses["rules"], losses["search"], rtol=1e-5)

# 3) serve equivalence: fp32 forward logits match under rules vs searched
# placement (token-level identity is too strict across placements: a
# random-init model's near-uniform logits flip argmax on reduction order)
from repro.dist import sharding as shd
params = model.init(jax.random.PRNGKey(0))
sshape = ShapeConfig("s", 16, 8, "prefill")
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16),
                                      0, 256)}
outs = {}
for kind in ("rules", "search"):
    src = plan_mod.resolve(kind, model=model, mesh=mesh, shape=sshape,
                           workload="prefill")
    p_sh = shd.named(params, src.state_specs(params, mesh, model.cfg), mesh)
    b_sh = shd.named(batch, src.batch_specs(batch, mesh, sshape), mesh)
    fwd = jax.jit(lambda p, b: model.forward(p, b)[0],
                  in_shardings=(p_sh, b_sh))
    with mesh:
        outs[kind] = np.asarray(fwd(jax.device_put(params, p_sh),
                                    jax.device_put(batch, b_sh)))
np.testing.assert_allclose(outs["rules"], outs["search"],
                           atol=1e-4, rtol=1e-4)
print("PLAN_SMOKE_OK")
"""


def test_searched_plan_compiled_smoke():
    """8-fake-device subprocess: searched plan reduces analyzer-measured
    collective bytes vs the rules and stays fp32-equivalent for train and
    serve (the PR-10 acceptance demonstration)."""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", PLAN_SMOKE],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)) or ".")
    assert r.returncode == 0, r.stderr[-3000:]
    assert "PLAN_SMOKE_OK" in r.stdout
