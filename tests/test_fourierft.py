"""Core FourierFT math: oracle equivalence, entry sampling, strategies,
paper Table 1 parameter accounting, Parseval norm, frequency bias."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import PEFTConfig
from repro.core import fourierft as F
from repro.core import peft as peft_mod
from repro.core.peft import AdapterSite
import repro.configs as configs
from repro.configs.paper_models import PAPER_MODELS


def _oracle(c, E, d1, d2, alpha):
    dense = jnp.zeros((d1, d2), jnp.complex64).at[E[0], E[1]].set(
        c.astype(jnp.complex64))
    return alpha * jnp.fft.ifft2(dense).real


class TestMaterialization:
    def test_matches_ifft2_oracle(self):
        d1, d2, n = 48, 80, 37
        E = F.sample_entries(d1, d2, n, seed=2024)
        c = jax.random.normal(jax.random.PRNGKey(0), (n,))
        out = F.materialize_delta(c, E, d1, d2, 300.0)
        np.testing.assert_allclose(out, _oracle(c, E, d1, d2, 300.0),
                                   atol=2e-4)

    def test_stacked_layers(self):
        d1, d2, n, L = 32, 64, 16, 5
        E = F.sample_entries(d1, d2, n, seed=1)
        cs = jax.random.normal(jax.random.PRNGKey(1), (L, n))
        outs = F.materialize_delta(cs, E, d1, d2, 10.0)
        assert outs.shape == (L, d1, d2)
        for l in range(L):
            np.testing.assert_allclose(outs[l], _oracle(cs[l], E, d1, d2, 10.0),
                                       atol=2e-4)

    def test_factored_equals_merged(self):
        d1, d2, n = 64, 48, 20
        E = F.sample_entries(d1, d2, n, seed=3)
        c = jax.random.normal(jax.random.PRNGKey(2), (n,))
        x = jax.random.normal(jax.random.PRNGKey(3), (7, d1))
        y1 = F.factored_apply(x, c, E, d1, d2, 300.0)
        y2 = x @ F.materialize_delta(c, E, d1, d2, 300.0)
        np.testing.assert_allclose(y1, y2, atol=2e-4)

    def test_parseval_norm(self):
        d1, d2, n = 40, 56, 25
        E = F.sample_entries(d1, d2, n, seed=4)
        c = jax.random.normal(jax.random.PRNGKey(4), (n,))
        analytic = F.delta_norm(c, E, d1, d2, 17.0)
        actual = jnp.linalg.norm(F.materialize_delta(c, E, d1, d2, 17.0))
        np.testing.assert_allclose(analytic, actual, rtol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(8, 64), st.integers(8, 64), st.integers(1, 32),
           st.integers(0, 2**16))
    def test_linearity_in_c_property(self, d1, d2, n, seed):
        """ΔW is linear in c (hypothesis property)."""
        n = min(n, d1 * d2)
        E = F.sample_entries(d1, d2, n, seed=seed)
        c1 = jax.random.normal(jax.random.PRNGKey(seed), (n,))
        c2 = jax.random.normal(jax.random.PRNGKey(seed + 1), (n,))
        lhs = F.materialize_delta(c1 + 2.0 * c2, E, d1, d2, 5.0)
        rhs = (F.materialize_delta(c1, E, d1, d2, 5.0)
               + 2.0 * F.materialize_delta(c2, E, d1, d2, 5.0))
        np.testing.assert_allclose(lhs, rhs, atol=1e-3)


class TestEntrySampling:
    def test_distinct_and_in_range(self):
        E = np.array(F.sample_entries(100, 200, 500, seed=2024))
        assert E.shape == (2, 500)
        assert E[0].min() >= 0 and E[0].max() < 100
        assert E[1].min() >= 0 and E[1].max() < 200
        assert len({(u, v) for u, v in E.T}) == 500

    def test_deterministic_and_seed_sensitivity(self):
        a = np.array(F.sample_entries(64, 64, 50, seed=2024))
        b = np.array(F.sample_entries(64, 64, 50, seed=2024))
        c = np.array(F.sample_entries(64, 64, 50, seed=2025))
        assert (a == b).all()
        assert not (a == c).all()

    def test_huge_grid_dedup_path(self):
        E = np.array(F.sample_entries(152064, 8192, 64, seed=0))
        assert len({(u, v) for u, v in E.T}) == 64

    def test_freq_bias_concentrates(self):
        """Eq. 5: entries cluster around the favored central frequency."""
        fc = 60.0
        E = np.array(F.sample_entries(256, 256, 400, seed=1, freq_bias=True,
                                      fc=fc, bandwidth=25.0))
        D = np.hypot(E[0] - 128.0, E[1] - 128.0)
        assert abs(D.mean() - fc) < 15.0
        E0 = np.array(F.sample_entries(256, 256, 400, seed=1))
        D0 = np.hypot(E0[0] - 128.0, E0[1] - 128.0)
        assert D0.std() > D.std()


class TestTable1Accounting:
    """Reproduces the paper's Table 1 trainable-parameter counts exactly."""

    @pytest.mark.parametrize("model,n,expected", [
        ("roberta-base", 200, 4_800),
        ("roberta-base", 1000, 24_000),
        ("roberta-large", 200, 9_600),
        ("roberta-large", 1000, 48_000),
        ("gpt2-medium", 500, 24_000),
        ("gpt2-medium", 1000, 48_000),
        ("gpt2-large", 500, 36_000),
        ("gpt2-large", 1000, 72_000),
        ("llama2-7b", 1000, 64_000),
        ("llama2-7b", 2000, 128_000),
        ("llama2-13b", 1000, 80_000),
        ("llama2-13b", 2000, 160_000),
        ("vit-base", 3000, 72_000),
        ("vit-base", 10000, 240_000),
        ("vit-large", 3000, 144_000),
        ("vit-large", 10000, 480_000),
    ])
    def test_fourierft_param_counts(self, model, n, expected):
        cfg = PAPER_MODELS[model]
        sites = peft_mod.qv_sites_for(cfg)
        peft = PEFTConfig(method="fourierft", n=n)
        assert peft_mod.count_trainable(sites, peft) == expected

    @pytest.mark.parametrize("model,r,expected", [
        ("roberta-base", 4, 147_456),
        ("roberta-base", 8, 294_912),
        ("roberta-large", 4, 393_216),
        ("roberta-large", 8, 786_432),
        ("gpt2-medium", 4, 393_216),   # paper reports 0.35M (rounded)
        ("llama2-7b", 16, 8_388_608),
        ("llama2-7b", 64, 33_554_432),
        ("llama2-13b", 64, 52_428_800),
        ("vit-base", 16, 589_824),
        ("vit-large", 16, 1_572_864),
    ])
    def test_lora_param_counts(self, model, r, expected):
        cfg = PAPER_MODELS[model]
        sites = peft_mod.qv_sites_for(cfg)
        peft = PEFTConfig(method="lora", lora_r=r)
        assert peft_mod.count_trainable(sites, peft) == expected

    def test_fourierft_vs_lora_ratio_llama2_7b(self):
        """Headline claim: 0.064M vs 33.5M (≈0.2%) on LLaMA2-7B."""
        cfg = PAPER_MODELS["llama2-7b"]
        sites = peft_mod.qv_sites_for(cfg)
        four = peft_mod.count_trainable(sites, PEFTConfig(method="fourierft", n=1000))
        lora = peft_mod.count_trainable(sites, PEFTConfig(method="lora", lora_r=64))
        assert four == 64_000 and lora == 33_554_432
        assert four / lora < 0.002

    def test_storage_bytes(self):
        cfg = PAPER_MODELS["llama2-7b"]
        sites = peft_mod.qv_sites_for(cfg)
        b = peft_mod.storage_bytes(sites, PEFTConfig(method="fourierft", n=1000))
        # 64K coefficients + one shared 2x1000 entry matrix, f32
        assert b == (64_000 + 2_000) * 4
        assert b / 1024 < 260  # paper: 250KB


class TestBasisAblation:
    def test_random_and_orthogonal_shapes(self):
        from repro.core import basis
        b1, b2 = basis.make_basis(jax.random.PRNGKey(0), "orthogonal", 64, 48, 16)
        np.testing.assert_allclose(b1.T @ b1, np.eye(16), atol=1e-4)
        c = jax.random.normal(jax.random.PRNGKey(1), (16,))
        dw = basis.materialize_delta_basis(c, b1, b2, "orthogonal", 10.0)
        assert dw.shape == (64, 48)
        b1r, b2r = basis.make_basis(jax.random.PRNGKey(0), "random", 64, 48, 16)
        dwr = basis.materialize_delta_basis(c, b1r, b2r, "random", 10.0)
        assert dwr.shape == (64, 48)
        assert not np.allclose(dw, dwr)
