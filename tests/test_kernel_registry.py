"""Kernel-backend conformance (DESIGN.md §Kernels): every registered
AdapterMethod × every available backend must agree with its einsum reference
— forward and gradient — through the same `AdapterMethod` dispatch the
train/serve/merge hot paths use. Plus the policy layer: capability fallback
(vocab dims), build-time resolution snapshots, and the `use_pallas`
deprecation shim."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.configs.base import PEFTConfig
from repro.core import adapter as adapter_api
from repro.core.adapter import AdapterSite
from repro.kernels import api
from repro.models import build

SITE = AdapterSite("layers/wq", 96, 160, 2)

PARAM_METHODS = adapter_api.registered_methods(site_params_only=True)

# backends worth cross-checking against einsum on this host: interpret
# everywhere, compiled pallas only where it can actually run
ALT_BACKENDS = ("interpret", "pallas") if jax.default_backend() == "tpu" \
    else ("interpret",)


def _peft(method: str, backend: str = "auto") -> PEFTConfig:
    return PEFTConfig(method=method, n=24, alpha=25.0, lora_r=2,
                      param_dtype="float32", kernel_backend=backend)


def _randomized_site(method: str, site=SITE, seed=0):
    m = adapter_api.resolve(method)
    peft = _peft(method)
    ad = m.init_site(jax.random.PRNGKey(seed), site, peft)
    ad = {k: (v + 0.05 * jax.random.normal(jax.random.PRNGKey(i + seed + 1),
                                           v.shape)
              if jnp.issubdtype(v.dtype, jnp.floating) else v)
          for i, (k, v) in enumerate(ad.items())}
    return m, ad


def _alt_backends(method: str, op: str, d1=SITE.d_in, d2=SITE.d_out):
    """Alternative backends that both exist and would actually be selected
    for this (method, op, dims) on this host."""
    out = []
    for b in ALT_BACKENDS:
        chosen = api.resolve_op(op, method, _peft(method, b), d1, d2,
                                missing_ok=True)
        if chosen is not None and chosen.backend == b:
            out.append(b)
    return out


class TestBackendParity:
    @pytest.mark.parametrize("method", PARAM_METHODS)
    def test_site_delta_backends_agree(self, method):
        m, ad = _randomized_site(method)
        if "deltaw" not in api.ops_for(m):
            return
        dw_ref = m.site_delta(ad, SITE, _peft(method, "einsum"))
        for b in _alt_backends(method, "deltaw"):
            dw = m.site_delta(ad, SITE, _peft(method, b))
            np.testing.assert_allclose(np.asarray(dw), np.asarray(dw_ref),
                                       atol=2e-5, rtol=1e-5,
                                       err_msg=f"{method}/{b}")

    @pytest.mark.parametrize("method", PARAM_METHODS)
    def test_factored_apply_backends_agree(self, method):
        m, ad = _randomized_site(method)
        tr, aux = m.split_adapter({k: v[0] for k, v in ad.items()
                                   if k in m.trainable_leaves(_peft(method))}
                                  | {k: v for k, v in ad.items()
                                     if k not in m.trainable_leaves(
                                         _peft(method))}, _peft(method))
        x = jax.random.normal(jax.random.PRNGKey(7), (5, SITE.d_in))
        y_ref = m.factored_apply(x, tr, aux, SITE.d_in, SITE.d_out,
                                 _peft(method, "einsum"))
        for b in _alt_backends(method, "factored_apply"):
            y = m.factored_apply(x, tr, aux, SITE.d_in, SITE.d_out,
                                 _peft(method, b))
            np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                       atol=2e-5, rtol=1e-5,
                                       err_msg=f"{method}/{b}")

    @pytest.mark.parametrize("method", PARAM_METHODS)
    def test_bank_apply_backends_agree(self, method):
        m, _ = _randomized_site(method)
        names = m.trainable_leaves(_peft(method))
        rows = [_randomized_site(method, seed=s)[1] for s in range(3)]
        aux = {k: v for k, v in rows[0].items() if k not in names}
        tr = {k: jnp.stack([r[k][0] for r in rows]) for k in names}
        x = jax.random.normal(jax.random.PRNGKey(9), (3, 4, SITE.d_in))
        y_ref = m.bank_apply(x, tr, aux, SITE.d_in, SITE.d_out,
                             _peft(method, "einsum"))
        for b in _alt_backends(method, "bank_apply"):
            y = m.bank_apply(x, tr, aux, SITE.d_in, SITE.d_out,
                             _peft(method, b))
            np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                       atol=2e-5, rtol=1e-5,
                                       err_msg=f"{method}/{b}")
            # zero trainables stay exactly zero on every backend (the
            # adapter bank's reserved-row contract)
            zero = {k: jnp.zeros_like(v) for k, v in tr.items()}
            yz = m.bank_apply(x, zero, aux, SITE.d_in, SITE.d_out,
                              _peft(method, b))
            assert not np.any(np.asarray(yz)), f"{method}/{b}"

    @pytest.mark.parametrize("method", PARAM_METHODS)
    def test_gradcheck_backends_agree(self, method):
        """d(loss)/d(trainables) through site_delta (stacked, the merged
        train path — exercises the custom-VJP dc kernels under vmap) and
        through factored_apply must match the einsum gradients."""
        m, ad = _randomized_site(method)
        names = m.trainable_leaves(_peft(method))

        if "deltaw" in api.ops_for(m):
            g = jax.random.normal(jax.random.PRNGKey(3),
                                  (SITE.stack, SITE.d_in, SITE.d_out))

            def loss_delta(tr, peft):
                return jnp.vdot(g, m.site_delta({**ad, **tr}, SITE, peft))

            tr0 = {k: ad[k] for k in names}
            g_ref = jax.grad(loss_delta)(tr0, _peft(method, "einsum"))
            for b in _alt_backends(method, "deltaw"):
                g_b = jax.grad(loss_delta)(tr0, _peft(method, b))
                for k in g_ref:
                    np.testing.assert_allclose(
                        np.asarray(g_b[k]), np.asarray(g_ref[k]),
                        atol=1e-4, rtol=1e-3, err_msg=f"{method}/{b}/{k}")

        x = jax.random.normal(jax.random.PRNGKey(4), (5, SITE.d_in))
        aux = {k: v for k, v in ad.items() if k not in names}
        tr0 = {k: ad[k][0] for k in names}

        def loss_fact(tr, peft):
            return jnp.sum(m.factored_apply(x, tr, aux, SITE.d_in,
                                            SITE.d_out, peft) ** 2)

        g_ref = jax.grad(loss_fact)(tr0, _peft(method, "einsum"))
        for b in _alt_backends(method, "factored_apply"):
            g_b = jax.grad(loss_fact)(tr0, _peft(method, b))
            for k in g_ref:
                np.testing.assert_allclose(
                    np.asarray(g_b[k]), np.asarray(g_ref[k]),
                    atol=1e-4, rtol=1e-3, err_msg=f"{method}/{b}/{k}")

    def test_every_dispatched_op_has_einsum_reference(self):
        """The terminal fallback must exist for every op a method serves."""
        for method in PARAM_METHODS:
            for op in api.ops_for(method):
                assert api.lookup(op, method, "einsum") is not None, \
                    (method, op)


class TestCapabilityFallback:
    def test_vocab_dim_routes_to_einsum(self):
        """> int32-phase-bound dims (embedding/vocab grids) fall off the
        Pallas path even when explicitly requested — per-op bounds."""
        for method, safe in (("fourierft", 46336), ("dct", 32500)):
            peft = _peft(method, "interpret")
            assert api.resolve_op("deltaw", method, peft, 152064,
                                  4096).backend == "einsum"
            assert api.resolve_op("deltaw", method, peft, safe,
                                  128).backend == "interpret"
            assert api.resolve_op("deltaw", method, peft, safe + 1,
                                  128).backend == "einsum"

    def test_compiled_pallas_needs_tpu(self):
        if jax.default_backend() == "tpu":
            pytest.skip("compiled path IS available here")
        peft = _peft("fourierft", "auto")
        assert api.resolve_op("deltaw", "fourierft", peft, 256,
                              256).backend == "einsum"
        assert api.resolve_op("deltaw", "fourierft", peft, 256, 256,
                              platform="tpu").backend == "pallas"

    def test_non_fourier_basis_uses_einsum(self):
        """Table-6 ablation bases have no integer-phase structure — the
        config predicate keeps them off the Pallas path."""
        peft = _peft("fourierft", "interpret").replace(basis="random")
        assert api.resolve_op("deltaw", "fourierft", peft, 256,
                              256).backend == "einsum"

    def test_unknown_backend_rejected(self):
        with pytest.raises(ValueError, match="kernel_backend"):
            PEFTConfig(kernel_backend="cuda")
        with pytest.raises(ValueError, match="kernel backend"):
            api.resolve_op("deltaw", "fourierft", None, 8, 8,
                           backend="cuda")


class TestKernelPolicy:
    def test_model_policy_snapshot_and_explain(self):
        cfg = C.reduced(C.get("yi-6b")).replace(vocab=64)
        model = build(cfg, _peft("fourierft", "interpret"))
        pol = model.kernel_policy.validate()
        assert pol.method == "fourierft" and pol.requested == "interpret"
        assert {r.op for r in pol.resolutions} == {"deltaw", "factored_apply",
                                                   "bank_apply"}
        assert pol.backend_for("layers/wq", "deltaw") == "interpret"
        text = model.explain_kernels()
        assert "layers/wq" in text and "deltaw -> interpret" in text

    def test_explicit_pallas_downgrade_warns(self):
        if jax.default_backend() == "tpu":
            pytest.skip("no downgrade on TPU")
        cfg = C.reduced(C.get("yi-6b")).replace(vocab=64)
        with pytest.warns(UserWarning, match="pallas.*unavailable"):
            model = build(cfg, _peft("fourierft", "pallas"))
        assert model.kernel_policy.backend_for("layers/wq",
                                               "deltaw") == "einsum"

    def test_stateless_methods_have_empty_policy(self):
        cfg = C.reduced(C.get("yi-6b")).replace(vocab=64)
        for name in ("none", "full"):
            model = build(cfg, PEFTConfig(method=name))
            assert model.kernel_policy.resolutions == ()
            assert "no registered kernel ops" in model.explain_kernels()


class TestHotPathDispatch:
    """End to end: merged (site_delta through the Pallas interpret harness)
    == factored (einsum bypass) through a real model forward, for every
    spectral method — the acceptance gate for train/serve wiring."""

    @pytest.mark.parametrize("method", ["fourierft", "dct", "circulant"])
    def test_interpret_forward_matches_einsum(self, method):
        cfg = C.reduced(C.get("yi-6b")).replace(vocab=64,
                                                param_dtype="float32",
                                                dtype="float32")
        peft = _peft(method, "einsum")
        model_e = build(cfg, peft)
        params = model_e.init(jax.random.PRNGKey(0))
        params["peft"] = jax.tree.map(
            lambda x: x + 0.03 if jnp.issubdtype(x.dtype, jnp.floating) else x,
            params["peft"])
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 10),
                                              0, 64)}
        ref_logits, _ = model_e.forward(params, batch)
        for strategy in ("merged", "factored"):
            model_i = build(cfg, peft.replace(kernel_backend="interpret",
                                              strategy=strategy))
            got, _ = model_i.forward(params, batch)
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref_logits),
                                       atol=5e-4, rtol=1e-3,
                                       err_msg=f"{method}/{strategy}")

    def test_train_step_grads_through_interpret_kernels(self):
        """One real train step (merged strategy) with the interpret backend:
        the dc VJP kernel feeds the optimizer, matching einsum grads."""
        from repro.configs.base import TrainConfig
        from repro.train import step as train_step
        cfg = C.reduced(C.get("yi-6b"), layers=2, width=64).replace(
            vocab=32, param_dtype="float32", dtype="float32")
        tcfg = TrainConfig(total_steps=2, warmup_steps=1)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(0), (2, 8),
                                              0, 32),
                 "labels": jax.random.randint(jax.random.PRNGKey(1), (2, 8),
                                              0, 32)}
        metrics = {}
        for backend in ("einsum", "interpret"):
            model = build(cfg, _peft("fourierft", backend))
            state, frozen = train_step.init_state(model, tcfg,
                                                  jax.random.PRNGKey(2))
            step = train_step.make_train_step(model, tcfg)
            _, m = step(state, frozen, batch)
            metrics[backend] = m
        np.testing.assert_allclose(float(metrics["interpret"]["loss"]),
                                   float(metrics["einsum"]["loss"]),
                                   rtol=1e-5)
        np.testing.assert_allclose(float(metrics["interpret"]["grad_norm"]),
                                   float(metrics["einsum"]["grad_norm"]),
                                   rtol=1e-3)


class TestLegacyShim:
    def test_use_pallas_maps_to_kernel_backend(self):
        for legacy, backend in (("auto", "auto"), ("never", "einsum"),
                                ("interpret", "interpret")):
            with pytest.warns(DeprecationWarning, match="use_pallas"):
                p = PEFTConfig(use_pallas=legacy)
            assert p.kernel_backend == backend
            assert p.use_pallas is None
            # replace() must not re-warn or lose the mapping
            assert p.replace(n=7).kernel_backend == backend

    def test_bad_use_pallas_rejected(self):
        with pytest.raises(ValueError, match="use_pallas"):
            PEFTConfig(use_pallas="always")

    def test_profile_key_ignores_kernel_backend(self):
        """Serving bank admission must not refuse tenants trained under a
        different kernel backend — same math, different implementation."""
        from repro.serve.engine import AdapterBank
        key = lambda p: AdapterBank._profile_key(AdapterBank, p)
        assert key(_peft("fourierft", "auto")) \
            == key(_peft("fourierft", "interpret"))
        assert key(_peft("fourierft")) != key(_peft("fourierft").replace(n=9))

    def test_old_manifest_migrates_silently(self, tmp_path):
        """Adapter exports written before the registry carry use_pallas;
        import maps it onto kernel_backend without a deprecation warning."""
        import warnings
        from repro.checkpoint import adapters as ckpt
        m, ad = _randomized_site("fourierft")
        ckpt.export_adapter(str(tmp_path), "t0", {"layers/wq": ad},
                            _peft("fourierft"))
        mpath = os.path.join(str(tmp_path), "t0", "manifest.json")
        with open(mpath) as f:
            manifest = json.load(f)
        manifest["peft"].pop("kernel_backend")
        manifest["peft"]["use_pallas"] = "never"
        with open(mpath, "w") as f:
            json.dump(manifest, f)
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            peft = ckpt.read_manifest(str(tmp_path), "t0")
        assert peft.kernel_backend == "einsum" and peft.use_pallas is None
