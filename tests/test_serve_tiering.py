"""Tiered-memory serving (DESIGN.md §Tiering): priority/fair queue
ordering, host tiers for KV pages and adapter rows, preempt-and-resume
exactness (swap and recompute, heterogeneous tenants, speculation),
preemption storms leaving no leaks, and the tiered-vs-deferral admission
throughput acceptance cell."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.checkpoint import adapters as adapter_ckpt
from repro.configs.base import PEFTConfig
from repro.core import adapter as adapter_api
from repro.core import peft as peft_mod
from repro.models import build
from repro.serve import (
    AdapterBank, ContinuousScheduler, Engine, HostAdapterTier, HostPagePool,
    Request, TieringConfig,
)
from repro.serve.scheduler.queue import RequestQueue
from repro.serve.spec import NGramDrafter
from repro.serve.tiering import VictimInfo, choose_mode, choose_victim

TENANTS = ("tenant-fft", "tenant-lora")
METHODS = ("fourierft", "lora")


def _cfg():
    return C.reduced(C.get("yi-6b")).replace(vocab=64, param_dtype="float32",
                                             dtype="float32")


def _base_model():
    model = build(_cfg(), PEFTConfig(method="none"))
    return model, model.init(jax.random.PRNGKey(0))


def _profiles():
    return {
        "fourierft": PEFTConfig(method="fourierft", n=16, alpha=25.0,
                                param_dtype="float32"),
        "lora": PEFTConfig(method="lora", lora_r=2, param_dtype="float32"),
    }


def _export_tenants(model, directory):
    profiles = _profiles()
    for i, (tid, m) in enumerate(zip(TENANTS, METHODS)):
        prof = profiles[m]
        tree = peft_mod.init_adapters(jax.random.PRNGKey(10 + i),
                                      model.sites, prof)
        tree = jax.tree.map(
            lambda x: x + 0.05 if jnp.issubdtype(x.dtype, jnp.floating)
            else x, tree)
        trainable = set(adapter_api.resolve(m).trainable_leaves(prof))
        tree = {s: {k: v for k, v in d.items() if k in trainable}
                for s, d in tree.items()}
        adapter_ckpt.export_adapter(str(directory), tid, tree, prof)
    return profiles


def _serial(engine, req):
    if req.adapter_id is not None and \
            req.adapter_id not in engine.bank.resident_ids:
        engine.bank.load_from_checkpoint(req.adapter_id)
    out = engine.generate([req.prompt], max_new=req.max_new,
                          adapter_ids=[req.adapter_id]
                          if engine.bank is not None else None)[0]
    return [int(t) for t in np.asarray(out).reshape(-1)]


def _req(prompt, max_new, priority="batch", adapter_id=None):
    return Request(prompt=jnp.asarray(prompt, jnp.int32), max_new=max_new,
                   priority=priority, adapter_id=adapter_id)


def _assert_clean(sched):
    """Post-drain invariants: no leaked pages, slots, pins or snapshots."""
    assert not sched.slots.any_active()
    if sched.pager is not None:
        sched.pager.assert_no_leaks()
    if sched.host_kv is not None:
        assert not sched.host_kv._snapshots
    if sched.bank is not None:
        # nothing is decoding, so no tenant row may stay pinned
        assert all(a is None for a in sched.slots.adapter_ids())


# ---- queue ordering ---------------------------------------------------------
class TestPriorityQueue:
    def test_priority_classes_order_every_policy(self):
        for policy in RequestQueue.POLICIES:
            q = RequestQueue(policy)
            q.push(_req([1], 1, "best_effort"), arrival=0.0)
            q.push(_req([2], 1, "interactive"), arrival=0.0)
            q.push(_req([3], 1, "batch"), arrival=0.0)
            got = [q.pop_next(0.0, lambda sr: True).request.priority
                   for _ in range(3)]
            assert got == ["interactive", "batch", "best_effort"], policy

    def test_single_class_keeps_pre_tiering_order(self):
        """Everything defaults to "batch": fcfs ordering must be exactly
        arrival order (priority ranking is a no-op tie)."""
        q = RequestQueue("fcfs")
        rids = [q.push(_req([i], 1), arrival=float(i % 2)) for i in range(6)]
        got = [q.pop_next(5.0, lambda sr: True).rid for _ in range(6)]
        assert got == sorted(rids, key=lambda r: (r % 2 == 1, r))

    def test_fair_share_prefers_quiet_tenant(self):
        q = RequestQueue("fair")
        q.push(_req([1], 1, adapter_id="chatty"), arrival=0.0)
        q.push(_req([2], 1, adapter_id="quiet"), arrival=0.0)
        q.note_usage("chatty", 100)
        q.note_usage("quiet", 3)
        assert q.peek_next(0.0).request.adapter_id == "quiet"
        # ...but never across class boundaries
        q.push(_req([3], 1, "interactive", adapter_id="chatty"), arrival=0.0)
        assert q.peek_next(0.0).request.priority == "interactive"

    def test_usage_tracked_only_under_fair_policy(self):
        """Non-fair policies never read the usage table, so feeding it
        would be pure memory growth per distinct tenant — note_usage must
        be a no-op there."""
        for policy in ("fcfs", "resident_first"):
            q = RequestQueue(policy)
            q.note_usage("tenant", 5)
            assert q.usage("tenant") == 0 and not q._usage, policy

    def test_fair_usage_decays_and_stays_bounded(self):
        """Hitting USAGE_HALF_AT halves every counter (fairness tracks
        RECENT consumption, not lifetime totals) and drops zeroed tenants
        (the table stays bounded by the recently-active set)."""
        q = RequestQueue("fair")
        q.note_usage("quiet", 1)
        q.note_usage("chatty", q.USAGE_HALF_AT)
        assert q.usage("chatty") == q.USAGE_HALF_AT // 2
        assert q.usage("quiet") == 0
        assert "quiet" not in q._usage

    def test_requeue_keeps_rid_and_position(self):
        q = RequestQueue("fcfs")
        r0 = q.push(_req([1], 1), arrival=0.0)
        q.push(_req([2], 1), arrival=5.0)
        sr = q.pop_next(9.0, lambda sr: True)
        assert sr.rid == r0
        q.requeue(sr)
        nxt = q.peek_next(9.0)
        assert nxt.rid == r0 and nxt is sr   # same identity, ahead again

    def test_unknown_priority_rejected_at_submit(self):
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=2, max_len=32)
        sched = ContinuousScheduler(eng)
        with pytest.raises(ValueError, match="priority"):
            sched.submit(_req([1, 2], 2, priority="urgent"))


# ---- host tiers (unit) ------------------------------------------------------
class TestHostPools:
    def _page(self, tag):
        k = np.full((2, 1, 4, 2, 3), float(tag), np.float32)
        return k, -k

    def test_prefix_lru_and_capacity(self):
        pool = HostPagePool(capacity_pages=2)
        for i in range(3):
            assert pool.put_prefix(bytes([i]), *self._page(i))
        assert not pool.has_prefix(b"\x00")     # LRU-evicted
        assert pool.has_prefix(b"\x01") and pool.has_prefix(b"\x02")
        k, _ = pool.get_prefix(b"\x01")
        assert float(k[0, 0, 0, 0, 0]) == 1.0
        assert pool.put_prefix(bytes([9]), *self._page(9))
        assert pool.has_prefix(b"\x01")         # get() refreshed its LRU slot
        assert not pool.has_prefix(b"\x02")

    def test_touch_prefix_refreshes_lru(self):
        """The admission planner probes fill candidates via touch_prefix:
        the touched key becomes MRU, so later same-plan demotions displace
        older entries first."""
        pool = HostPagePool(capacity_pages=2)
        assert pool.put_prefix(b"a", *self._page(1))
        assert pool.put_prefix(b"b", *self._page(2))
        assert pool.touch_prefix(b"a")
        assert not pool.touch_prefix(b"nope")
        assert pool.put_prefix(b"c", *self._page(3))
        assert pool.has_prefix(b"a")            # refreshed: survived
        assert not pool.has_prefix(b"b")        # the LRU went instead

    def test_snapshots_are_pinned_and_charged(self):
        pool = HostPagePool(capacity_pages=3)
        k = np.zeros((2, 2, 4, 2, 3), np.float32)  # 2 padded pages
        assert pool.put_snapshot(7, k, k.copy(), n_pages=1)
        assert pool.used_pages == 2             # charged at stored width
        assert pool.put_prefix(b"p", *self._page(1))
        # prefix eviction cannot make room by dropping the pinned snapshot
        assert not pool.put_snapshot(8, k, k.copy(), n_pages=2)
        with pytest.raises(KeyError):
            pool.put_snapshot(7, k, k.copy(), n_pages=1)
        _, _, n = pool.pop_snapshot(7)
        assert n == 1 and pool.used_pages == 1
        assert not pool.has_snapshot(7)

    def test_adapter_tier_spill_callback_and_lru(self):
        spills = []
        tier = HostAdapterTier(2, on_spill=lambda: spills.append(1))
        for i, aid in enumerate(("a", "b", "c")):
            tier.put(aid, "lora", {"s": {"w": np.full((2,), i, np.float32)}})
            assert len(spills) == i + 1
        assert "a" not in tier and len(tier) == 2
        method, tree = tier.get("b")
        assert method == "lora" and float(tree["s"]["w"][0]) == 1.0
        assert tier.drop("b") and "b" not in tier


# ---- victim/mode policy (unit) ---------------------------------------------
class TestPreemptPolicy:
    def test_victim_strictly_lower_class_only(self):
        occ = [VictimInfo(0, 1, 8, 4, 2), VictimInfo(1, 1, 8, 9, 2)]
        v = choose_victim(0, occ)              # interactive vs two batch
        assert v.slot == 0                     # least emitted loses least
        assert choose_victim(1, occ) is None   # batch cannot evict batch

    def test_mode_forcing_and_swap_requires_host(self):
        v = VictimInfo(0, 1, 8, 4, 2)
        cfg = TieringConfig(mode="swap", host_kv_pages=8)
        assert choose_mode(cfg, v, 8, host_can_swap=True) == "swap"
        assert choose_mode(cfg, v, 8, host_can_swap=False) == "recompute"
        cfg = TieringConfig(mode="recompute")
        assert choose_mode(cfg, v, 8, host_can_swap=True) == "recompute"

    def test_auto_mode_tracks_cost_estimate(self):
        cheap_swap = VictimInfo(0, 1, prompt_len=100, emitted=100,
                                used_pages=1)
        cheap_recompute = VictimInfo(0, 1, prompt_len=2, emitted=1,
                                     used_pages=8)
        cfg = TieringConfig(host_kv_pages=64)
        assert choose_mode(cfg, cheap_swap, 8, True) == "swap"
        assert choose_mode(cfg, cheap_recompute, 8, True) == "recompute"


# ---- preempt-and-resume exactness ------------------------------------------
# pool sizing: 3 slots, pps=6 -> 9 pages total, 6 allocatable; each batch
# long (5 prompt + 20 new -> 24 positions) owns 3 pages, so two longs own
# the entire pool and any interactive arrival must preempt to run
LONGS = dict(prompt=[1, 2, 3, 4, 5], max_new=20)
POOL = dict(page_size=8, n_pages=9)


def _overload_trace(n_interactive=3, adapter_ids=(None, None, None)):
    reqs = [_req(LONGS["prompt"], LONGS["max_new"], "batch", adapter_ids[0]),
            _req([7, 8, 9], 18, "batch", adapter_ids[1])]
    arrivals = [0.0, 0.0]
    for i in range(n_interactive):
        reqs.append(_req([11 + i, 12], 4, "interactive", adapter_ids[2]))
        arrivals.append(3.0 + 4.0 * i)
    return reqs, arrivals


class TestPreemptExactness:
    def _run(self, tiering, drafter=None, bank=None, trace=None):
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=3, max_len=48, bank=bank)
        sched = ContinuousScheduler(eng, drafter=drafter, tiering=tiering,
                                    **POOL)
        reqs, arrivals = trace or _overload_trace()
        sched.serve(reqs, arrivals)
        for r in reqs:
            assert r.out == _serial(eng, r)
        _assert_clean(sched)
        return sched

    def test_swap_resume_bit_identical(self):
        sched = self._run(TieringConfig(mode="swap", host_kv_pages=32))
        s = sched.metrics.summary()
        assert s["preempt_swap_total"] >= 1
        assert s["resumed_total"] >= 1
        assert s["kv_pages_spilled_total"] >= 1
        assert s["kv_pages_filled_total"] >= 1

    def test_recompute_resume_bit_identical(self):
        sched = self._run(TieringConfig(mode="recompute"))
        s = sched.metrics.summary()
        assert s["preempt_recompute_total"] >= 1
        assert s["resumed_total"] >= 1
        assert s["kv_pages_spilled_total"] == 0    # no host pool configured

    def test_swap_degrades_to_recompute_when_host_full(self):
        """A host pool too small for the victim's snapshot: the swap
        choice must degrade per-victim to recompute, never fail the
        preemption. The late arrival guarantees the victim has decoded
        past one page, so its snapshot (2 pages) exceeds the pool (1)."""
        reqs = [_req(LONGS["prompt"], LONGS["max_new"], "batch"),
                _req([7, 8, 9], 18, "batch"),
                _req([11, 12], 4, "interactive")]
        sched = self._run(TieringConfig(mode="swap", host_kv_pages=1),
                          trace=(reqs, [0.0, 0.0, 12.0]))
        s = sched.metrics.summary()
        assert s["preemptions_total"] >= 1
        assert s["preempt_recompute_total"] >= 1

    def test_heterogeneous_tenants_preempt_exact(self, tmp_path):
        model, _ = _base_model()
        profiles = _export_tenants(model, tmp_path)
        bank = AdapterBank(model, profiles, capacity=3,
                           checkpoint_dir=str(tmp_path))
        trace = _overload_trace(
            adapter_ids=("tenant-fft", None, "tenant-lora"))
        sched = self._run(TieringConfig(host_kv_pages=32), bank=bank,
                          trace=trace)
        assert sched.metrics.summary()["preemptions_total"] >= 1

    def test_speculative_preempt_exact(self):
        sched = self._run(TieringConfig(mode="swap", host_kv_pages=32),
                          drafter=NGramDrafter(k=3))
        assert sched.metrics.summary()["preemptions_total"] >= 1

    def test_preemption_storm_no_leaks(self):
        """8 interactive arrivals hammer two pool-owning batch requests
        through repeated preempt/resume cycles (tiny host pool: some swaps
        degrade mid-storm); everything still drains exact and leak-free."""
        trace = _overload_trace(n_interactive=8)
        sched = self._run(TieringConfig(host_kv_pages=4), trace=trace)
        s = sched.metrics.summary()
        assert s["preemptions_total"] >= 2
        assert s["requests_finished_total"] == 10


class TestTieredThroughput:
    def test_tiered_admits_strictly_more_within_horizon(self):
        """Acceptance: under the constrained pool, preempt-and-resume
        admits strictly more requests inside a fixed step horizon than
        deferral-only scheduling of the identical trace."""
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=3, max_len=48)
        horizon = 15.0
        admits = {}
        for name, tiering in (("deferral", None),
                              ("tiered", TieringConfig(host_kv_pages=32))):
            sched = ContinuousScheduler(eng, tiering=tiering, **POOL)
            reqs, arrivals = _overload_trace()
            for r, at in zip(reqs, arrivals):
                sched.submit(r, arrival=at)
            admits[name] = sum(
                1 for ev in sched.events()
                if ev[0] == "admit" and ev[-1] <= horizon)
            for r in reqs:
                assert r.out == _serial(eng, r)
            _assert_clean(sched)
        assert admits["tiered"] > admits["deferral"], admits


# ---- host tiers through the runtime ----------------------------------------
class TestHostTierRuntime:
    def test_adapter_rows_spill_and_refill_from_host(self, tmp_path):
        """capacity-1 bank, two tenants arriving serially: the LRU victim
        spills to the host tier, and the tenant's return admission refills
        from host (a hit, not a checkpoint re-read) — streams exact."""
        model, params = _base_model()
        profiles = _export_tenants(model, tmp_path)
        bank = AdapterBank(model, profiles, capacity=1,
                           checkpoint_dir=str(tmp_path))
        eng = Engine(model, params, batch_slots=2, max_len=48, bank=bank)
        sched = ContinuousScheduler(
            eng, page_size=8,
            tiering=TieringConfig(host_adapter_slots=4, preempt=False))
        reqs = [_req([1, 2, 3], 4, adapter_id="tenant-fft"),
                _req([4, 5, 6], 4, adapter_id="tenant-lora"),
                _req([1, 2, 3], 4, adapter_id="tenant-fft")]
        sched.serve(reqs, arrivals=[0.0, 30.0, 60.0])
        s = sched.metrics.summary()
        assert s["adapter_spills_total"] >= 1
        assert s["adapter_host_hits_total"] >= 1
        for r in reqs:
            assert r.out == _serial(eng, r)
        _assert_clean(sched)

    def test_prefix_pages_demote_to_host_and_promote_back(self):
        """Cold-prefix eviction demotes pages to the host tier instead of
        dropping them; a later prompt sharing that prefix promotes them
        back (fills) and still decodes bit-identically."""
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=2, max_len=32)
        sched = ContinuousScheduler(
            eng, page_size=4, n_pages=10,
            tiering=TieringConfig(host_kv_pages=16))
        shared = list(range(1, 10))                      # 2 full chunks
        reqs = [_req(shared, 4),
                _req([21, 22, 23, 24, 25], 24, "batch"), # forces eviction
                _req(shared, 4)]
        sched.serve(reqs, arrivals=[0.0, 20.0, 60.0])
        s = sched.metrics.summary()
        # eviction frees exactly what pressure needs, so only the leaf
        # chunk demotes; the return of the shared prompt promotes it back
        assert s["kv_pages_spilled_total"] >= 1
        assert s["prefix_host_hits_total"] >= 1
        assert s["kv_pages_filled_total"] >= 1
        for r in reqs:
            assert r.out == _serial(eng, r)
        assert reqs[0].out == reqs[2].out
        _assert_clean(sched)

    def test_fill_displaced_by_own_demotions_degrades_exact(self):
        """Regression: full host pool + device page pressure in ONE
        admission. plan_admit matches a host-resident chunk (fill), then
        its own eviction demotes another page into the full host pool,
        displacing the planned fill before the promote. The prime must
        degrade that chunk to on-device recompute — stream exact, round
        not crashed. Sizing: 3 allocatable pages, host pool of 1; req1
        caches chunks A+B, req2's admission demotes B to host (pool now
        full) and registers its own chunk C, req3 (same prompt as req1)
        matches A on device, plans a fill for B, and its eviction of C
        demotes C — popping B out of the capacity-1 pool."""
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=2, max_len=12)
        sched = ContinuousScheduler(
            eng, page_size=4, n_pages=5,
            tiering=TieringConfig(host_kv_pages=1, preempt=False))
        shared = list(range(1, 10))                  # chunks A, B
        reqs = [_req(shared, 4),
                _req([21, 22, 23, 24, 25], 4),
                _req(shared, 4)]
        sched.serve(reqs, arrivals=[0.0, 20.0, 60.0])
        s = sched.metrics.summary()
        assert s["kv_fills_degraded_total"] >= 1
        assert s["kv_pages_spilled_total"] >= 1
        for r in reqs:
            assert r.out == _serial(eng, r)
        assert reqs[0].out == reqs[2].out
        _assert_clean(sched)


# ---- gateway extension ------------------------------------------------------
class TestGatewayPriority:
    def test_parse_request_priority_field(self):
        from repro.serve.gateway.protocol import ApiError, parse_request

        ok = parse_request("completion",
                           {"model": "base", "prompt": [1, 2],
                            "priority": "interactive"},
                           vocab=64, max_len=64)
        assert ok.priority == "interactive"
        default = parse_request("completion",
                                {"model": "base", "prompt": [1, 2]},
                                vocab=64, max_len=64)
        assert default.priority == "batch"
        with pytest.raises(ApiError, match="priority"):
            parse_request("completion",
                          {"model": "base", "prompt": [1], "priority": "x"},
                          vocab=64, max_len=64)

    def test_interactive_bypass_requires_preemption(self):
        """`priority` is client-supplied: the interactive page-frac bypass
        must hold only when the scheduler can actually preempt — otherwise
        self-declared interactive traffic would simply disable overload
        protection while still queueing behind pressure."""
        from repro.serve.gateway.server import GatewayServer

        model, params = _base_model()
        eng = Engine(model, params, batch_slots=2, max_len=32)
        for tiering, bypass in ((None, False),
                                (TieringConfig(preempt=False), False),
                                (TieringConfig(host_kv_pages=4), True)):
            sched = ContinuousScheduler(eng, page_size=8, tiering=tiering)
            gw = GatewayServer(sched, min_free_page_frac=0.5)
            assert gw.bridge.preempting() is bypass
            gw.bridge.queued = lambda: 1           # simulate pressure:
            gw.bridge.free_page_frac = lambda: 0.0  # starved pool, work queued
            assert gw._overloaded("batch")
            assert gw._overloaded("best_effort")
            assert gw._overloaded("interactive") is not bypass
