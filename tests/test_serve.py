"""Serving tests: merged-adapter equivalence (the paper's zero-latency
property), batched generation, engine consistency with raw decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.configs.base import PEFTConfig
from repro.models import build
from repro.serve import Engine, merge_for_serving


def _model(arch="yi-6b", method="fourierft", **kw):
    cfg = C.reduced(C.get(arch)).replace(vocab=64, param_dtype="float32",
                                         dtype="float32")
    peft = PEFTConfig(method=method, n=24, alpha=25.0, lora_r=2,
                      param_dtype="float32", **kw)
    m = build(cfg, peft)
    return m, m.init(jax.random.PRNGKey(0))


class TestMerge:
    @pytest.mark.parametrize("method", ["fourierft", "lora"])
    def test_merged_equals_unmerged_forward(self, method):
        model, params = _model(method=method)
        # make adapters non-trivial (lora_b inits to zero; c is random)
        if method == "lora":
            params["peft"] = jax.tree.map(
                lambda x: x + 0.01, params["peft"])
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                              0, 64)}
        logits_adapter, _ = model.forward(params, batch)
        merged_model, merged_params = merge_for_serving(model, params)
        assert not merged_params["peft"]  # fully merged
        logits_merged, _ = merged_model.forward(merged_params, batch)
        np.testing.assert_allclose(np.asarray(logits_adapter),
                                   np.asarray(logits_merged),
                                   atol=5e-4, rtol=1e-3)

    def test_zamba2_shared_adapters_stay_factored(self):
        model, params = _model(arch="zamba2-7b")
        merged_model, merged_params = merge_for_serving(model, params)
        assert any(k.startswith("shared/") for k in merged_params["peft"])
        assert not any(k.startswith("layers/") for k in merged_params["peft"])
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 12),
                                              0, 64)}
        a, _ = model.forward(params, batch)
        b, _ = merged_model.forward(merged_params, batch)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4,
                                   rtol=1e-3)

    @pytest.mark.parametrize("method", ["lora", "fourierft"])
    def test_zamba2_leftover_keeps_true_method(self, method):
        """Regression: shared-block leftovers must be rebuilt under their TRUE
        method — the old code rebuilt any leftover as method="fourierft", so a
        lora leftover would be misinterpreted (or crash) at apply time."""
        model, params = _model(arch="zamba2-7b", method=method)
        if method == "lora":
            params["peft"] = jax.tree.map(lambda x: x + 0.02, params["peft"])
        merged_model, merged_params = merge_for_serving(model, params)
        assert merged_model.peft.method == method
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 10),
                                              0, 64)}
        a, _ = model.forward(params, batch)
        b, _ = merged_model.forward(merged_params, batch)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4,
                                   rtol=1e-3)
        # and the leftover tree still carries the method's own leaves
        shared = [v for k, v in merged_params["peft"].items()
                  if k.startswith("shared/")]
        assert shared
        expect = {"lora": "lora_a", "fourierft": "c"}[method]
        assert all(expect in d for d in shared)

    def test_bitfit_merge(self):
        cfg = C.reduced(C.get("qwen2.5-32b")).replace(vocab=64)
        model = build(cfg, PEFTConfig(method="bitfit"))
        params = model.init(jax.random.PRNGKey(0))
        params["peft"] = jax.tree.map(lambda x: x + 0.05, params["peft"])
        batch = {"tokens": jnp.zeros((1, 8), jnp.int32)}
        a, _ = model.forward(params, batch)
        mm, mp = merge_for_serving(model, params)
        b, _ = mm.forward(mp, batch)
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-2)


class TestEngine:
    def test_generation_consistency(self):
        """Engine output == manual decode loop on the merged model."""
        model, params = _model()
        eng = Engine(model, params, batch_slots=2, max_len=48)
        prompts = [jnp.array([1, 2, 3, 4], jnp.int32),
                   jnp.array([5, 6], jnp.int32)]
        outs = eng.generate(prompts, max_new=6)
        assert len(outs) == 2 and outs[0].shape == (6,)
        # manual replay for prompt 0 on merged params
        mm, mp = merge_for_serving(model, params)
        cache = mm.init_cache(2, 48, dtype=jnp.float32)
        toks = jnp.zeros((2, 4), jnp.int32).at[0, :4].set(prompts[0]) \
            .at[1, :2].set(prompts[1])
        last = None
        for t in range(4):
            last, cache = mm.decode_step(mp, cache, {"tokens": toks[:, t:t+1]})
        manual = [last[0]]
        cur = last[:, None]
        for _ in range(5):
            nt, cache = mm.decode_step(mp, cache, {"tokens": cur})
            manual.append(nt[0])
            cur = nt[:, None]
        np.testing.assert_array_equal(np.asarray(outs[0]),
                                      np.asarray(jnp.stack(manual)))

    def test_greedy_determinism(self):
        model, params = _model()
        eng = Engine(model, params, batch_slots=1, max_len=32)
        p = [jnp.array([3, 1, 4], jnp.int32)]
        a = eng.generate(p, max_new=5)[0]
        b = eng.generate(p, max_new=5)[0]
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestPrefill:
    """The jitted one-call prefill must produce the same generations as the
    legacy token-by-token teacher-forced loop (S decode dispatches)."""

    @pytest.mark.parametrize("arch", ["yi-6b", "olmoe-1b-7b", "zamba2-7b",
                                      "mamba2-2.7b"])
    def test_prefill_matches_stepwise(self, arch):
        model, params = _model(arch=arch)
        eng = Engine(model, params, batch_slots=2, max_len=32)
        prompts = [jnp.array([1, 2, 3, 4, 5], jnp.int32),
                   jnp.array([7, 8, 9], jnp.int32)]
        fast = eng.generate(prompts, max_new=6)
        slow = eng.generate(prompts, max_new=6, stepwise_prefill=True)
        for a, b in zip(fast, slow):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_mesh_sharded_engine_matches(self):
        """Engine with a host mesh (dist sharding placement) is equivalent."""
        from repro.launch.mesh import make_host_mesh
        model, params = _model()
        plain = Engine(model, params, batch_slots=2, max_len=32)
        sharded = Engine(model, params, batch_slots=2, max_len=32,
                         mesh=make_host_mesh())
        prompts = [jnp.array([1, 2, 3], jnp.int32),
                   jnp.array([9, 8], jnp.int32)]
        a = plain.generate(prompts, max_new=4)
        b = sharded.generate(prompts, max_new=4)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
