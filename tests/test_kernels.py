"""Pallas kernel validation: shape/dtype sweeps against the ref.py pure-jnp
(ifft2) oracle in interpret mode, forward and VJP, through the kernel
registry's backend selection (DESIGN.md §Kernels)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.fourierft import sample_entries
from repro.kernels import api, ops, ref


SHAPES = [
    (128, 128, 16),      # tile-aligned square
    (256, 512, 100),     # tile-aligned rectangular
    (300, 520, 64),      # ragged both dims
    (768, 768, 1000),    # paper's RoBERTa-base grid
    (512, 96, 37),       # ragged cols, odd n
    (64, 2048, 128),     # wide
]


@pytest.mark.parametrize("d1,d2,n", SHAPES)
def test_deltaw_kernel_vs_oracle(d1, d2, n):
    E = sample_entries(d1, d2, n, seed=7)
    c = jax.random.normal(jax.random.PRNGKey(1), (n,))
    r = ref.deltaw_ref(c, E, d1, d2, 300.0)
    k = ops.fourier_deltaw(c, E, d1, d2, 300.0, backend="interpret")
    np.testing.assert_allclose(k, r, atol=2e-4)


@pytest.mark.parametrize("d1,d2,n", SHAPES[:4])
def test_dc_kernel_vjp_vs_oracle(d1, d2, n):
    E = sample_entries(d1, d2, n, seed=7)
    c = jax.random.normal(jax.random.PRNGKey(1), (n,))
    g = jax.random.normal(jax.random.PRNGKey(2), (d1, d2))
    f = lambda c: jnp.vdot(g, ops.fourier_deltaw(c, E, d1, d2, 300.0,
                                                 backend="interpret"))
    dc = jax.grad(f)(c)
    np.testing.assert_allclose(dc, ref.dc_ref(g, E, 300.0), atol=2e-3,
                               rtol=1e-4)


@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_deltaw_out_dtypes(out_dtype):
    d1, d2, n = 256, 256, 64
    E = sample_entries(d1, d2, n, seed=5)
    c = jax.random.normal(jax.random.PRNGKey(0), (n,))
    k = ops.fourier_deltaw(c, E, d1, d2, 10.0, backend="interpret",
                           out_dtype=out_dtype)
    assert k.dtype == out_dtype
    r = ref.deltaw_ref(c, E, d1, d2, 10.0)
    np.testing.assert_allclose(np.asarray(k, np.float32), r,
                               atol=(2e-4 if out_dtype == jnp.float32 else 2e-2))


def test_deltaw_stacked_vmap():
    d1, d2, n, L = 300, 520, 100, 4
    E = sample_entries(d1, d2, n, seed=7)
    cs = jax.random.normal(jax.random.PRNGKey(3), (L, n))
    ks = ops.fourier_deltaw(cs, E, d1, d2, 300.0, backend="interpret")
    es = ops.fourier_deltaw(cs, E, d1, d2, 300.0, backend="einsum")
    assert ks.shape == (L, d1, d2)
    np.testing.assert_allclose(ks, es, atol=2e-4)


def test_einsum_fallback_for_huge_dims():
    """dims over the int32 phase bound must resolve to the einsum backend
    even when the Pallas path is requested explicitly."""
    from repro.configs.base import PEFTConfig
    peft = PEFTConfig(method="fourierft", kernel_backend="interpret")
    assert api.resolve_op("deltaw", "fourierft", peft,
                          152064, 4096).backend == "einsum"
    assert api.resolve_op("deltaw", "fourierft", peft,
                          4096, 4096).backend == "interpret"
    # the DCT half-integer phase overflows earlier than the fourier phase
    assert api.resolve_op("deltaw", "dct", peft.replace(method="dct"),
                          40000, 128).backend == "einsum"
    assert api.resolve_op("deltaw", "fourierft", peft,
                          40000, 128).backend == "interpret"


def test_kernel_grad_matches_einsum_grad():
    d1, d2, n = 256, 384, 48
    E = sample_entries(d1, d2, n, seed=9)
    c = jax.random.normal(jax.random.PRNGKey(4), (n,))
    x = jax.random.normal(jax.random.PRNGKey(5), (3, d1))
    tgt = jax.random.normal(jax.random.PRNGKey(6), (3, d2))

    def loss(c, mode):
        dw = ops.fourier_deltaw(c, E, d1, d2, 50.0, backend=mode)
        return jnp.mean((x @ dw - tgt) ** 2)

    gk = jax.grad(lambda c: loss(c, "interpret"))(c)
    ge = jax.grad(lambda c: loss(c, "einsum"))(c)
    np.testing.assert_allclose(gk, ge, atol=1e-4, rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 8), st.integers(1, 8), st.integers(1, 200),
       st.integers(0, 1000))
def test_kernel_property_sweep(mh, mw, n, seed):
    """Hypothesis sweep over block-count space: kernel == oracle."""
    d1, d2 = 128 * mh, 128 * mw
    n = min(n, d1 * d2)
    E = sample_entries(d1, d2, n, seed=seed)
    c = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    k = ops.fourier_deltaw(c, E, d1, d2, 100.0, backend="interpret")
    r = ref.deltaw_ref(c, E, d1, d2, 100.0)
    np.testing.assert_allclose(k, r, atol=2e-4)
