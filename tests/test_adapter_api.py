"""AdapterMethod protocol conformance, run over EVERY registered method:
registry behavior, init shapes, factored == x @ ΔW, row-batched bank_apply,
trainable-leaf masking, merge_site, and paper Table-1 accounting through the
protocol (the redesign must not move a single count)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.configs.base import PEFTConfig
from repro.core import adapter as adapter_api
from repro.core import peft as peft_mod
from repro.core.adapter import AdapterSite
from repro.configs.paper_models import PAPER_MODELS
from repro.models import build

SITE = AdapterSite("layers/wq", 48, 32, 3)

# every method owning per-site state, with a config that gives it nontrivial
# trainables after randomization
PARAM_METHODS = adapter_api.registered_methods(site_params_only=True)


def _peft(method: str) -> PEFTConfig:
    return PEFTConfig(method=method, n=12, alpha=20.0, lora_r=2,
                      param_dtype="float32")


def _randomized_site(method: str, site=SITE):
    m = adapter_api.resolve(method)
    peft = _peft(method)
    ad = m.init_site(jax.random.PRNGKey(0), site, peft)
    ad = {k: (v + 0.05 * jax.random.normal(jax.random.PRNGKey(i + 1),
                                           v.shape)
              if jnp.issubdtype(v.dtype, jnp.floating) else v)
          for i, (k, v) in enumerate(ad.items())}
    return m, peft, ad


class TestRegistry:
    def test_all_methods_registered(self):
        names = adapter_api.registered_methods()
        for expect in ("fourierft", "lora", "bitfit", "dct", "circulant",
                       "none", "full"):
            assert expect in names

    def test_unknown_method_raises(self):
        with pytest.raises(KeyError, match="unknown adapter method"):
            adapter_api.resolve("does-not-exist")
        with pytest.raises(KeyError):
            build(C.reduced(C.get("yi-6b")), PEFTConfig(method="nope"))

    def test_duplicate_registration_rejected(self):
        class Dup(adapter_api.AdapterMethod):
            name = "fourierft"
        with pytest.raises(ValueError, match="already registered"):
            adapter_api.register(Dup())

    def test_degenerate_methods_have_no_state(self):
        for name in ("none", "full"):
            m = adapter_api.resolve(name)
            assert not m.has_site_params
            assert m.trainable_leaves(_peft(name)) == ()
            assert peft_mod.init_adapters(jax.random.PRNGKey(0), [SITE],
                                          _peft(name)) == {}
        assert adapter_api.resolve("full").trains_base
        assert not adapter_api.resolve("fourierft").trains_base


class TestConformance:
    @pytest.mark.parametrize("method", PARAM_METHODS)
    def test_init_shapes_stack_leading(self, method):
        m, peft, ad = _randomized_site(method)
        trainable = m.trainable_leaves(peft)
        assert trainable, method
        for leaf in trainable:
            assert leaf in ad, (method, leaf)
            assert ad[leaf].shape[0] == SITE.stack, (method, leaf)

    @pytest.mark.parametrize("method", PARAM_METHODS)
    def test_factored_equals_x_at_delta(self, method):
        """factored_apply == x @ site_delta for linear-delta methods; BitFit's
        bias shift equals its (broadcast) delta_b."""
        m, peft, ad = _randomized_site(method)
        trainable = {k: ad[k][0] for k in m.trainable_leaves(peft)}
        aux = {k: v for k, v in ad.items()
               if k not in m.trainable_leaves(peft)}
        x = jax.random.normal(jax.random.PRNGKey(7), (5, SITE.d_in))
        y = m.factored_apply(x, trainable, aux, SITE.d_in, SITE.d_out, peft)
        assert y.shape == (5, SITE.d_out)
        if m.linear_delta:
            single = AdapterSite(SITE.name, SITE.d_in, SITE.d_out, 1)
            dw = m.site_delta({k: v[:1] for k, v in ad.items()
                               if k in m.trainable_leaves(peft)} | aux,
                              single, peft, None)[0]
            np.testing.assert_allclose(np.asarray(y), np.asarray(x @ dw),
                                       atol=2e-4, rtol=1e-4)
        else:
            np.testing.assert_allclose(
                np.asarray(y),
                np.broadcast_to(np.asarray(ad["delta_b"][0]),
                                (5, SITE.d_out)), atol=1e-6)

    @pytest.mark.parametrize("method", PARAM_METHODS)
    def test_bank_apply_matches_per_row_factored(self, method):
        """Row-batched bank_apply == per-row factored_apply (the serving
        adapter-bank contract), and the zero row contributes exactly zero."""
        m, peft, _ = _randomized_site(method)
        B, T = 3, 4
        rows = []
        for b in range(B):
            _, _, ad = _randomized_site(method)
            rows.append(ad)
        trainable_names = m.trainable_leaves(peft)
        aux = {k: v for k, v in rows[0].items() if k not in trainable_names}
        tr = {k: jnp.stack([r[k][0] for r in rows]) for k in trainable_names}
        x = jax.random.normal(jax.random.PRNGKey(9), (B, T, SITE.d_in))
        y = m.bank_apply(x, tr, aux, SITE.d_in, SITE.d_out, peft)
        assert y.shape == (B, T, SITE.d_out)
        for b in range(B):
            yb = m.factored_apply(x[b], {k: v[b] for k, v in tr.items()},
                                  aux, SITE.d_in, SITE.d_out, peft)
            np.testing.assert_allclose(np.asarray(y[b]), np.asarray(yb),
                                       atol=2e-5, rtol=1e-5)
        zero = {k: jnp.zeros_like(v) for k, v in tr.items()}
        yz = m.bank_apply(x, zero, aux, SITE.d_in, SITE.d_out, peft)
        assert not np.any(np.asarray(yz)), f"{method}: zero row must be zero"

    @pytest.mark.parametrize("method", PARAM_METHODS)
    def test_trainable_leaf_masking(self, method):
        """trainable_adapter_tree keeps exactly the protocol's trainable
        leaves — the train step's gradient filter."""
        m, peft, ad = _randomized_site(method)
        tree = {"layers/wq": ad}
        tr = peft_mod.trainable_adapter_tree(tree, peft)
        assert set(tr["layers/wq"]) == set(m.trainable_leaves(peft))
        frozen = set(ad) - set(m.trainable_leaves(peft))
        for leaf in frozen:
            assert leaf not in tr["layers/wq"]

    @pytest.mark.parametrize("method", PARAM_METHODS)
    def test_merge_site_folds_delta(self, method):
        m, peft, ad = _randomized_site(method)
        w = jax.random.normal(jax.random.PRNGKey(3),
                              (SITE.stack, SITE.d_in, SITE.d_out))
        eff = {"wq": w}
        m.merge_site(eff, "wq", ad, SITE, peft)
        if m.linear_delta:
            dw = m.site_delta(ad, SITE, peft, w.dtype)
            np.testing.assert_allclose(np.asarray(eff["wq"]),
                                       np.asarray(w + dw), atol=1e-5)
        else:
            np.testing.assert_allclose(np.asarray(eff["wq__b"]),
                                       np.asarray(ad["delta_b"]), atol=1e-6)

    @pytest.mark.parametrize("method", PARAM_METHODS)
    def test_forward_merged_equals_factored(self, method):
        """End to end through a real model: merged strategy == factored."""
        cfg = C.reduced(C.get("yi-6b")).replace(vocab=64,
                                                param_dtype="float32",
                                                dtype="float32")
        peft = _peft(method)
        model = build(cfg, peft)
        params = model.init(jax.random.PRNGKey(0))
        params["peft"] = jax.tree.map(
            lambda x: x + 0.03 if jnp.issubdtype(x.dtype, jnp.floating) else x,
            params["peft"])
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 10),
                                              0, 64)}
        lm, _ = model.forward(params, batch)
        lf, _ = build(cfg, peft.replace(strategy="factored")).forward(params,
                                                                      batch)
        np.testing.assert_allclose(np.asarray(lm), np.asarray(lf),
                                   atol=5e-4, rtol=1e-3)


class TestMergeableFlag:
    """A method with mergeable=False must stay factored under the merged
    strategy and survive merge_for_serving as a true-method leftover."""

    @pytest.fixture(scope="class")
    def nomerge(self):
        name = "_test_nomerge"
        try:
            return adapter_api.resolve(name)
        except KeyError:
            pass

        class NoMerge(adapter_api.resolve("fourierft").__class__):
            pass
        NoMerge.name = name
        NoMerge.mergeable = False
        return adapter_api.register(NoMerge())

    def test_merged_strategy_falls_back_to_factored(self, nomerge):
        cfg = C.reduced(C.get("yi-6b")).replace(vocab=64,
                                                param_dtype="float32",
                                                dtype="float32")
        peft = _peft(nomerge.name)                    # strategy="merged"
        model = build(cfg, peft)
        params = model.init(jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8),
                                              0, 64)}
        a, _ = model.forward(params, batch)
        ref, _ = build(cfg, peft.replace(method="fourierft")).forward(params,
                                                                      batch)
        np.testing.assert_allclose(np.asarray(a), np.asarray(ref),
                                   atol=5e-4, rtol=1e-3)

    def test_merge_for_serving_keeps_leftover(self, nomerge):
        from repro.serve import merge_for_serving
        cfg = C.reduced(C.get("yi-6b")).replace(vocab=64,
                                                param_dtype="float32",
                                                dtype="float32")
        model = build(cfg, _peft(nomerge.name))
        params = model.init(jax.random.PRNGKey(0))
        mm, mp = merge_for_serving(model, params)
        assert mm.peft.method == nomerge.name         # true method kept
        assert set(mp["peft"]) == set(params["peft"])  # nothing folded
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8),
                                              0, 64)}
        a, _ = model.forward(params, batch)
        b, _ = mm.forward(mp, batch)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestAccounting:
    """Paper Table 1 through the protocol — the redesign moves no count."""

    def test_fourierft_and_lora_counts_unchanged(self):
        cfg = PAPER_MODELS["llama2-7b"]
        sites = peft_mod.qv_sites_for(cfg)
        assert peft_mod.count_trainable(
            sites, PEFTConfig(method="fourierft", n=1000)) == 64_000
        assert peft_mod.count_trainable(
            sites, PEFTConfig(method="lora", lora_r=64)) == 33_554_432
        b = peft_mod.storage_bytes(sites, PEFTConfig(method="fourierft",
                                                     n=1000))
        assert b == (64_000 + 2_000) * 4

    def test_new_method_counts(self):
        cfg = PAPER_MODELS["llama2-7b"]
        sites = peft_mod.qv_sites_for(cfg)
        # dct mirrors fourierft: n per layer per site + 2n entries per shape
        assert peft_mod.count_trainable(
            sites, PEFTConfig(method="dct", n=1000)) == 64_000
        assert peft_mod.storage_bytes(
            sites, PEFTConfig(method="dct", n=1000)) == (64_000 + 2_000) * 4
        # circulant: max(d1,d2) per layer per site, no frozen numbers
        d = cfg.d_model
        expect = 2 * cfg.num_layers * d
        assert peft_mod.count_trainable(
            sites, PEFTConfig(method="circulant")) == expect
        assert peft_mod.storage_bytes(
            sites, PEFTConfig(method="circulant")) == expect * 4

    def test_bitfit_count(self):
        sites = [SITE]
        assert peft_mod.count_trainable(
            sites, PEFTConfig(method="bitfit")) == SITE.d_out * SITE.stack

    @pytest.mark.parametrize("method", PARAM_METHODS)
    def test_count_matches_actual_leaves(self, method):
        """count_trainable == the summed size of the actual trainable leaves
        init_site produces (counts can't drift from reality)."""
        m, peft, ad = _randomized_site(method)
        actual = sum(int(np.prod(ad[k].shape))
                     for k in m.trainable_leaves(peft))
        assert m.count_trainable(SITE, peft) == actual
