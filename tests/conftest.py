import os

# Tests run on the single host device (the dry-run sets its own 512-device
# flag in its own subprocesses; never globally — see the assignment brief).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import sys
import types

import jax
import pytest

jax.config.update("jax_enable_x64", False)

# ---------------------------------------------------------------------------
# hypothesis compat shim: when hypothesis is absent (minimal containers),
# install a stub so property-based test modules still collect; every
# @given-decorated test then skips instead of erroring at import.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    def _given(*_a, **_k):
        def deco(fn):
            def skipper(*args, **kwargs):
                pytest.skip("hypothesis not installed (property test)")
            skipper.__name__ = fn.__name__
            skipper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
            skipper.__doc__ = fn.__doc__
            skipper.__module__ = fn.__module__
            return skipper
        return deco

    def _settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    class _Strategy:
        """Inert stand-in: supports chaining (.map/.filter) and call."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: _Strategy()   # PEP 562: any strategy name

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.assume = lambda *a, **k: True
    _hyp.note = lambda *a, **k: None
    _hyp.HealthCheck = _Strategy()
    _hyp.__getattr__ = lambda name: _Strategy()
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
