import os

# Tests run on the single host device (the dry-run sets its own 512-device
# flag in its own subprocesses; never globally — see the assignment brief).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
